.PHONY: artifacts fixtures test bench

# AOT-lower every env spec to HLO text + manifest (needed only for the
# `pjrt` feature; the default native backend needs nothing).
artifacts:
	cd python && python -m compile.aot --out ../artifacts

# Regenerate the NativeBackend parity fixtures from the JAX reference.
fixtures:
	cd python && python -m compile.gen_fixtures --out ../rust/tests/fixtures

# Tier-1 verification.
test:
	cargo build --release && cargo test -q

bench:
	cargo bench
