.PHONY: artifacts fixtures test bench bench-py bench-all loom miri tsan lint develop-py test-py

# AOT-lower every env spec to HLO text + manifest (needed only for the
# `pjrt` feature; the default native backend needs nothing).
artifacts:
	cd python && python -m compile.aot --out ../artifacts

# Regenerate the NativeBackend parity fixtures from the JAX reference.
fixtures:
	cd python && python -m compile.gen_fixtures --out ../crates/puffer-train/tests/fixtures

# Tier-1 verification (builds and tests every workspace crate:
# puffer-core, puffer-train, puffer-py's pure-Rust bridge, xtask).
test:
	cargo build --release && cargo test -q

# Build the Python extension into the active venv, then run the
# binding tests (they skip themselves if the module isn't built).
develop-py:
	maturin develop --release --features python

test-py:
	cd python && python -m pytest tests/test_bindings.py -q

# Exhaustive model checking of the cross-thread protocols: the
# crate::sync facade swaps to loom's instrumented primitives under
# --cfg loom, and tests/loom_models.rs explores every interleaving of
# the slab handoff, shutdown, snapshot, rotation, and reset-seed
# protocols (see CONCURRENCY.md). Release profile:
# loom's state exploration is CPU-bound, and the debug-only slab
# sentinel must stay out of the modeled state space.
loom:
	RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
		cargo test --release -p puffer-train --test loom_models

# Miri over the unsafe-adjacent lib tests (slab windows + sentinel,
# queue, snapshot): undefined behavior (aliasing, leaks, invalid
# reads) fails the lane. Scoped — full-crate Miri is far too slow.
miri:
	cargo +nightly miri test -p puffer-core --lib -- \
		sync:: vector::shared policy::snapshot

# ThreadSanitizer over the integration suites that actually thread:
# the pipelined trainer and the vectorizer semantics. Needs nightly +
# rust-src (build-std instruments std too).
tsan:
	RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
		cargo +nightly test -p puffer-train -Zbuild-std \
		--target x86_64-unknown-linux-gnu \
		--test pipeline --test vector_semantics

# Repo-invariant lint floor (xtask/src/main.rs): ordering comments on
# atomics, PANIC-justified unwrap/expect, allocation-free wrapper hot
# paths, forbid(unsafe_code) coverage.
lint:
	cargo xtask lint

# Vector throughput bench (paper Table 2 + the W1 wrapper-overhead
# cell), the pipelined-vs-serial trainer bench (P2), the per-
# architecture policy fwd/bwd bench (P3), the RunSpec-construction
# microbench (R1), the inference-serving latency bench (S1), and the
# real Python-driven puffer-vs-Gymnasium comparison (needs the wheel:
# `make develop-py`); write machine-readable results to
# BENCH_vector.json / BENCH_train.json / BENCH_policy.json /
# BENCH_runspec.json / BENCH_serve.json / BENCH_pybind.json.
bench:
	PUFFER_BENCH_JSON=BENCH_vector.json cargo bench --bench vectorization
	PUFFER_BENCH_JSON=BENCH_train.json cargo bench --bench train_pipeline
	PUFFER_BENCH_JSON=BENCH_policy.json cargo bench --bench policy_forward
	PUFFER_BENCH_JSON=BENCH_runspec.json cargo bench --bench runspec
	PUFFER_BENCH_JSON=BENCH_serve.json cargo bench --bench serve_latency
	$(MAKE) bench-py

# The puffer-py side alone: Rust vectorizer through the zero-copy
# Python adapter vs gymnasium.vector.SyncVectorEnv, same workload.
bench-py:
	PUFFER_BENCH_JSON=BENCH_pybind.json python examples/python/bench_vec.py

# Every bench target.
bench-all:
	cargo bench
