.PHONY: artifacts fixtures test bench bench-all

# AOT-lower every env spec to HLO text + manifest (needed only for the
# `pjrt` feature; the default native backend needs nothing).
artifacts:
	cd python && python -m compile.aot --out ../artifacts

# Regenerate the NativeBackend parity fixtures from the JAX reference.
fixtures:
	cd python && python -m compile.gen_fixtures --out ../rust/tests/fixtures

# Tier-1 verification.
test:
	cargo build --release && cargo test -q

# Vector throughput bench (paper Table 2 + the W1 wrapper-overhead
# cell), the pipelined-vs-serial trainer bench (P2), the per-
# architecture policy fwd/bwd bench (P3), and the RunSpec-construction
# microbench (R1); write machine-readable results to
# BENCH_vector.json / BENCH_train.json / BENCH_policy.json /
# BENCH_runspec.json.
bench:
	PUFFER_BENCH_JSON=BENCH_vector.json cargo bench --bench vectorization
	PUFFER_BENCH_JSON=BENCH_train.json cargo bench --bench train_pipeline
	PUFFER_BENCH_JSON=BENCH_policy.json cargo bench --bench policy_forward
	PUFFER_BENCH_JSON=BENCH_runspec.json cargo bench --bench runspec

# Every bench target.
bench-all:
	cargo bench
