"""Generate golden-value fixtures for the Rust NativeBackend parity tests.

The fixtures are produced by the same JAX code the PJRT path executes —
``model.py`` (which builds on the ``kernels/ref.py`` oracles) — at a small
architecture (D=3, H=4, act_dims=[2, 3]) so the checked-in JSON stays tiny
and the Rust tests need no Python or XLA at test time.

Run from ``python/``:

    python -m compile.gen_fixtures --out ../rust/tests/fixtures

Regenerate whenever the model math or the flat parameter layout changes;
``rust/tests/native_parity.rs`` consumes the output.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from . import model
from .kernels import ref

D, H = 3, 4
ACT_DIMS = [2, 3]
GAMMA, LAM = 0.99, 0.95


def lst(x):
    return np.asarray(x, np.float64).ravel().tolist()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../rust/tests/fixtures")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    model.HIDDEN = H  # small architecture for compact fixtures
    rng = np.random.default_rng(20240612)

    fx = {"d": D, "h": H, "act_dims": ACT_DIMS, "gamma": GAMMA, "lam": LAM}

    # ---- forward (feedforward) ----
    params = model.init_params(jax.random.PRNGKey(7), D, ACT_DIMS, False)
    p0, _ = ravel_pytree(params)
    p0 = np.asarray(p0, np.float32)
    n = 5
    obs = rng.standard_normal((n, D)).astype(np.float32)
    logits, value = model.make_forward(D, ACT_DIMS, False)(
        jnp.asarray(p0), jnp.asarray(obs)
    )
    fx["forward"] = {
        "rows": n,
        "params": lst(p0),
        "obs": lst(obs),
        "logits": lst(logits),
        "value": lst(value),
    }

    # ---- forward (lstm cell) ----
    params_l = model.init_params(jax.random.PRNGKey(8), D, ACT_DIMS, True)
    pl0, _ = ravel_pytree(params_l)
    pl0 = np.asarray(pl0, np.float32)
    h_in = (rng.standard_normal((n, H)) * 0.5).astype(np.float32)
    c_in = (rng.standard_normal((n, H)) * 0.5).astype(np.float32)
    lo, va, h2, c2 = model.make_forward(D, ACT_DIMS, True)(
        jnp.asarray(pl0), jnp.asarray(obs), jnp.asarray(h_in), jnp.asarray(c_in)
    )
    fx["forward_lstm"] = {
        "rows": n,
        "params": lst(pl0),
        "obs": lst(obs),
        "h": lst(h_in),
        "c": lst(c_in),
        "logits": lst(lo),
        "value": lst(va),
        "h2": lst(h2),
        "c2": lst(c2),
    }

    # ---- gae (ref.py oracle) ----
    t, b = 8, 6
    rew = rng.standard_normal((t, b)).astype(np.float32)
    val = rng.standard_normal((t, b)).astype(np.float32)
    done = (rng.random((t, b)) < 0.2).astype(np.float32)
    lastv = rng.standard_normal(b).astype(np.float32)
    adv, ret = ref.gae_ref(
        jnp.asarray(rew), jnp.asarray(val), jnp.asarray(done), jnp.asarray(lastv),
        GAMMA, LAM,
    )
    fx["gae"] = {
        "t": t,
        "b": b,
        "rewards": lst(rew),
        "values": lst(val),
        "dones": lst(done),
        "last_values": lst(lastv),
        "adv": lst(adv),
        "ret": lst(ret),
    }

    # ---- train_step (full PPO update: grads + clip + Adam) ----
    n2 = 16
    obs2 = rng.standard_normal((n2, D)).astype(np.float32)
    actions = np.stack(
        [rng.integers(0, k, n2) for k in ACT_DIMS], axis=1
    ).astype(np.int32)
    old_logp = (rng.standard_normal(n2) * 0.5 - 1.0).astype(np.float32)
    adv2 = rng.standard_normal(n2).astype(np.float32)
    ret2 = rng.standard_normal(n2).astype(np.float32)
    m0 = (np.abs(rng.standard_normal(p0.shape[0])) * 1e-3).astype(np.float32)
    v0 = (np.abs(rng.standard_normal(p0.shape[0])) * 1e-4).astype(np.float32)
    step0, lr, ent_coef = 3.0, 2.5e-3, 0.01
    ts = model.make_train_step(D, ACT_DIMS, False)
    p2, m2, v2, s2, metrics = ts(
        jnp.asarray(p0), jnp.asarray(m0), jnp.asarray(v0),
        jnp.asarray(step0, jnp.float32), jnp.asarray(lr, jnp.float32),
        jnp.asarray(ent_coef, jnp.float32),
        jnp.asarray(obs2), jnp.asarray(actions), jnp.asarray(old_logp),
        jnp.asarray(adv2), jnp.asarray(ret2),
    )
    fx["train_step"] = {
        "rows": n2,
        "params": lst(p0),
        "m": lst(m0),
        "v": lst(v0),
        "step": step0,
        "lr": lr,
        "ent_coef": ent_coef,
        "obs": lst(obs2),
        "actions": np.asarray(actions).ravel().tolist(),
        "old_logp": lst(old_logp),
        "adv": lst(adv2),
        "ret": lst(ret2),
        "params2": lst(p2),
        "m2": lst(m2),
        "v2": lst(v2),
        "step2": float(s2),
        "metrics": lst(metrics),
    }

    path = os.path.join(args.out, "native_parity.json")
    with open(path, "w") as f:
        json.dump(fx, f)
    print(f"wrote {path} ({os.path.getsize(path)} bytes)")


if __name__ == "__main__":
    main()
