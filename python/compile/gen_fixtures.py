"""Generate golden-value fixtures for the Rust NativeBackend parity tests.

The fixtures are produced by the same JAX code the PJRT path executes —
``model.py`` (which builds on the ``kernels/ref.py`` oracles) — at a small
architecture (D=3, H=4, act_dims=[2, 3]) so the checked-in JSON stays tiny
and the Rust tests need no Python or XLA at test time.

Run from ``python/``:

    python -m compile.gen_fixtures --out ../crates/puffer-train/tests/fixtures

Regenerate whenever the model math or the flat parameter layout changes;
``crates/puffer-train/tests/native_parity.rs`` consumes the output.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from . import model
from .kernels import ref

D, H = 3, 4
ACT_DIMS = [2, 3]
GAMMA, LAM = 0.99, 0.95


def lst(x):
    return np.asarray(x, np.float64).ravel().tolist()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../crates/puffer-train/tests/fixtures")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    model.HIDDEN = H  # small architecture for compact fixtures
    rng = np.random.default_rng(20240612)

    fx = {"d": D, "h": H, "act_dims": ACT_DIMS, "gamma": GAMMA, "lam": LAM}

    # ---- forward (feedforward) ----
    params = model.init_params(jax.random.PRNGKey(7), D, ACT_DIMS, False)
    p0, _ = ravel_pytree(params)
    p0 = np.asarray(p0, np.float32)
    n = 5
    obs = rng.standard_normal((n, D)).astype(np.float32)
    logits, value = model.make_forward(D, ACT_DIMS, False)(
        jnp.asarray(p0), jnp.asarray(obs)
    )
    fx["forward"] = {
        "rows": n,
        "params": lst(p0),
        "obs": lst(obs),
        "logits": lst(logits),
        "value": lst(value),
    }

    # ---- forward (lstm cell) ----
    params_l = model.init_params(jax.random.PRNGKey(8), D, ACT_DIMS, True)
    pl0, _ = ravel_pytree(params_l)
    pl0 = np.asarray(pl0, np.float32)
    h_in = (rng.standard_normal((n, H)) * 0.5).astype(np.float32)
    c_in = (rng.standard_normal((n, H)) * 0.5).astype(np.float32)
    lo, va, h2, c2 = model.make_forward(D, ACT_DIMS, True)(
        jnp.asarray(pl0), jnp.asarray(obs), jnp.asarray(h_in), jnp.asarray(c_in)
    )
    fx["forward_lstm"] = {
        "rows": n,
        "params": lst(pl0),
        "obs": lst(obs),
        "h": lst(h_in),
        "c": lst(c_in),
        "logits": lst(lo),
        "value": lst(va),
        "h2": lst(h2),
        "c2": lst(c2),
    }

    # ---- gae (ref.py oracle) ----
    t, b = 8, 6
    rew = rng.standard_normal((t, b)).astype(np.float32)
    val = rng.standard_normal((t, b)).astype(np.float32)
    done = (rng.random((t, b)) < 0.2).astype(np.float32)
    lastv = rng.standard_normal(b).astype(np.float32)
    adv, ret = ref.gae_ref(
        jnp.asarray(rew), jnp.asarray(val), jnp.asarray(done), jnp.asarray(lastv),
        GAMMA, LAM,
    )
    fx["gae"] = {
        "t": t,
        "b": b,
        "rewards": lst(rew),
        "values": lst(val),
        "dones": lst(done),
        "last_values": lst(lastv),
        "adv": lst(adv),
        "ret": lst(ret),
    }

    # ---- train_step (full PPO update: grads + clip + Adam) ----
    n2 = 16
    obs2 = rng.standard_normal((n2, D)).astype(np.float32)
    actions = np.stack(
        [rng.integers(0, k, n2) for k in ACT_DIMS], axis=1
    ).astype(np.int32)
    old_logp = (rng.standard_normal(n2) * 0.5 - 1.0).astype(np.float32)
    adv2 = rng.standard_normal(n2).astype(np.float32)
    ret2 = rng.standard_normal(n2).astype(np.float32)
    m0 = (np.abs(rng.standard_normal(p0.shape[0])) * 1e-3).astype(np.float32)
    v0 = (np.abs(rng.standard_normal(p0.shape[0])) * 1e-4).astype(np.float32)
    step0, lr, ent_coef = 3.0, 2.5e-3, 0.01
    ts = model.make_train_step(D, ACT_DIMS, False)
    p2, m2, v2, s2, metrics = ts(
        jnp.asarray(p0), jnp.asarray(m0), jnp.asarray(v0),
        jnp.asarray(step0, jnp.float32), jnp.asarray(lr, jnp.float32),
        jnp.asarray(ent_coef, jnp.float32),
        jnp.asarray(obs2), jnp.asarray(actions), jnp.asarray(old_logp),
        jnp.asarray(adv2), jnp.asarray(ret2),
    )
    fx["train_step"] = {
        "rows": n2,
        "params": lst(p0),
        "m": lst(m0),
        "v": lst(v0),
        "step": step0,
        "lr": lr,
        "ent_coef": ent_coef,
        "obs": lst(obs2),
        "actions": np.asarray(actions).ravel().tolist(),
        "old_logp": lst(old_logp),
        "adv": lst(adv2),
        "ret": lst(ret2),
        "params2": lst(p2),
        "m2": lst(m2),
        "v2": lst(v2),
        "step2": float(s2),
        "metrics": lst(metrics),
    }

    # ---- train_step_lstm (BPTT through the scan: grads + clip + Adam) ----
    # Pins the native backend's hand-derived backward-through-time pass,
    # including episode-start state masking. Reuses the forward_lstm
    # parameters (pl0); fresh inputs are drawn *after* all prior groups so
    # the existing fixture values stay bit-identical.
    t_l, b_l = 5, 4
    obs_l = rng.standard_normal((t_l, b_l, D)).astype(np.float32)
    starts_l = (rng.random((t_l, b_l)) < 0.3).astype(np.float32)
    starts_l[0] = 1.0
    actions_l = np.stack(
        [rng.integers(0, k, (t_l, b_l)) for k in ACT_DIMS], axis=2
    ).astype(np.int32)
    old_logp_l = (rng.standard_normal((t_l, b_l)) * 0.5 - 1.0).astype(np.float32)
    adv_l = rng.standard_normal((t_l, b_l)).astype(np.float32)
    ret_l = rng.standard_normal((t_l, b_l)).astype(np.float32)
    ml0 = (np.abs(rng.standard_normal(pl0.shape[0])) * 1e-3).astype(np.float32)
    vl0 = (np.abs(rng.standard_normal(pl0.shape[0])) * 1e-4).astype(np.float32)
    ts_l = model.make_train_step(D, ACT_DIMS, True)
    pl2, ml2, vl2, sl2, metrics_l = ts_l(
        jnp.asarray(pl0), jnp.asarray(ml0), jnp.asarray(vl0),
        jnp.asarray(step0, jnp.float32), jnp.asarray(lr, jnp.float32),
        jnp.asarray(ent_coef, jnp.float32),
        jnp.asarray(obs_l), jnp.asarray(starts_l), jnp.asarray(actions_l),
        jnp.asarray(old_logp_l), jnp.asarray(adv_l), jnp.asarray(ret_l),
    )
    fx["train_step_lstm"] = {
        "t": t_l,
        "b": b_l,
        "params": lst(pl0),
        "m": lst(ml0),
        "v": lst(vl0),
        "step": step0,
        "lr": lr,
        "ent_coef": ent_coef,
        "obs": lst(obs_l),
        "starts": lst(starts_l),
        "actions": np.asarray(actions_l).ravel().tolist(),
        "old_logp": lst(old_logp_l),
        "adv": lst(adv_l),
        "ret": lst(ret_l),
        "params2": lst(pl2),
        "m2": lst(ml2),
        "v2": lst(vl2),
        "step2": float(sl2),
        "metrics": lst(metrics_l),
    }

    # ---- embedding lookup (fwd + one full train step for the bwd) ----
    # Architecture: {feat: f32[2], tok: MultiDiscrete([5, 5])} with
    # embed_dim 3 — the flat row is [f0, f1, t0, t1], the trunk input is
    # [f0, f1, emb(t0), emb(t1)] (2 + 2*3 wide). Param pytree keys are
    # chosen so ravel_pytree's alphabetical order matches the Rust
    # ArchRanges layout: actor, critic, embed_00, enc1, enc2.
    ED, VOCAB = 3, 5
    trunk_in = 2 + 2 * ED

    def init_embed(key):
        ks = jax.random.split(key, 8)

        def dense(k, fi, fo, scale):
            w = jax.random.normal(k, (fi, fo)) * (scale / jnp.sqrt(fi))
            return {"w": w.astype(jnp.float32), "b": jnp.zeros(fo, jnp.float32)}

        return {
            "actor": dense(ks[0], H, sum(ACT_DIMS), 0.01),
            "critic": dense(ks[1], H, 1, 1.0),
            "embed_00": {
                "w": (
                    jax.random.normal(ks[2], (VOCAB, ED)) * (1.0 / jnp.sqrt(VOCAB))
                ).astype(jnp.float32)
            },
            "enc1": dense(ks[3], trunk_in, H, 1.0),
            "enc2": dense(ks[4], H, H, 1.0),
        }

    ep_tree = init_embed(jax.random.PRNGKey(21))
    ep0, eunravel = ravel_pytree(ep_tree)
    ep0 = np.asarray(ep0, np.float32)

    from .kernels.fused_mlp import linear_act

    def embed_forward(pf, obs):
        p = eunravel(pf)
        toks = jnp.clip(jnp.round(obs[:, 2:]).astype(jnp.int32), 0, VOCAB - 1)
        emb = p["embed_00"]["w"][toks].reshape(obs.shape[0], 2 * ED)
        trunk = jnp.concatenate([obs[:, :2], emb], axis=1)
        h = linear_act(trunk, p["enc1"]["w"], p["enc1"]["b"], "tanh")
        x = linear_act(h, p["enc2"]["w"], p["enc2"]["b"], "tanh")
        logits = linear_act(x, p["actor"]["w"], p["actor"]["b"], "none")
        value = linear_act(x, p["critic"]["w"], p["critic"]["b"], "none")
        return logits, value[:, 0]

    ne = 12
    eobs = np.zeros((ne, 4), np.float32)
    eobs[:, :2] = rng.standard_normal((ne, 2)).astype(np.float32)
    eobs[:, 2:] = rng.integers(0, VOCAB, (ne, 2)).astype(np.float32)
    elo, eva = embed_forward(jnp.asarray(ep0), jnp.asarray(eobs))
    fx["embed_forward"] = {
        "rows": ne,
        "embed_dim": ED,
        "vocab": VOCAB,
        "params": lst(ep0),
        "obs": lst(eobs),
        "logits": lst(elo),
        "value": lst(eva),
    }

    eact = np.stack([rng.integers(0, k, ne) for k in ACT_DIMS], axis=1).astype(np.int32)
    elogp = (rng.standard_normal(ne) * 0.5 - 1.0).astype(np.float32)
    eadv = rng.standard_normal(ne).astype(np.float32)
    eret = rng.standard_normal(ne).astype(np.float32)
    em0 = (np.abs(rng.standard_normal(ep0.shape[0])) * 1e-3).astype(np.float32)
    ev0 = (np.abs(rng.standard_normal(ep0.shape[0])) * 1e-4).astype(np.float32)

    def embed_train_step(pf, m, v, step, lr_, ec, obs, actions, old_logp, adv, ret):
        def loss_fn(pf):
            logits, value = embed_forward(pf, obs)
            return model._ppo_loss(
                logits, value, actions, old_logp, adv, ret, ec, tuple(ACT_DIMS)
            )

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(pf)
        pf, m, v, step = model._adam(pf, m, v, step, lr_, grads)
        pg, vl, e, kl = aux
        return pf, m, v, step, jnp.stack([loss, pg, vl, e, kl])

    ep2, em2, ev2, es2, emetrics = embed_train_step(
        jnp.asarray(ep0), jnp.asarray(em0), jnp.asarray(ev0),
        jnp.asarray(step0, jnp.float32), jnp.asarray(lr, jnp.float32),
        jnp.asarray(ent_coef, jnp.float32),
        jnp.asarray(eobs), jnp.asarray(eact), jnp.asarray(elogp),
        jnp.asarray(eadv), jnp.asarray(eret),
    )
    fx["embed_train_step"] = {
        "rows": ne,
        "m": lst(em0),
        "v": lst(ev0),
        "step": step0,
        "lr": lr,
        "ent_coef": ent_coef,
        "actions": np.asarray(eact).ravel().tolist(),
        "old_logp": lst(elogp),
        "adv": lst(eadv),
        "ret": lst(eret),
        "params2": lst(ep2),
        "m2": lst(em2),
        "v2": lst(ev2),
        "step2": float(es2),
        "metrics": lst(emetrics),
    }

    path = os.path.join(args.out, "native_parity.json")
    with open(path, "w") as f:
        json.dump(fx, f)
    print(f"wrote {path} ({os.path.getsize(path)} bytes)")


if __name__ == "__main__":
    main()
