"""Layer-2: JAX policy networks + the PPO train step (Clean PuffeRL's
learner math), lowered once to HLO-text artifacts and executed from Rust.

Model format (paper §3.4): the forward pass is split into **encode** and
**decode** halves so an LSTM can be sandwiched between hidden-state
computation and action heads. The same encoder/decoder weights serve both
the feedforward and recurrent variants; recurrence is a per-experiment
config flag, not a second model.

All parameters (and Adam state) travel as ONE flat f32 vector — Rust owns
them as opaque buffers and the manifest records the total length. The
pytree structure lives only here, via ``ravel_pytree``.

Entry points (AOT-lowered per env spec by aot.py):
  forward          (params, obs[B,D])                    -> logits[B,A], value[B]
  forward_lstm     (params, obs[B,D], h[B,H], c[B,H])    -> logits, value, h', c'
  gae              (rew[T,B], val[T,B], done[T,B], last[B]) -> adv[T,B], ret[T,B]
  train_step       (params, m, v, step, lr, ent_coef,
                    obs[N,D], act[N,S], logp[N], adv[N], ret[N])
                                                         -> params', m', v', step', metrics[5]
  train_step_lstm  (params, m, v, step, lr, ent_coef,
                    obs[T,B,D], starts[T,B], act[T,B,S], logp[T,B], adv[T,B], ret[T,B])
                                                         -> params', m', v', step', metrics[5]

metrics = [loss, pg_loss, v_loss, entropy, approx_kl].
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.fused_mlp import linear_act
from .kernels.gae import gae as gae_kernel

HIDDEN = 128
CLIP = 0.2
VF_COEF = 0.5
MAX_GRAD_NORM = 0.5
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# --------------------------------------------------------------------------
# Parameters


def init_params(key, obs_dim: int, act_dims, lstm: bool):
    """Orthogonal-ish init matching CleanRL's layer_init scalings."""
    ks = jax.random.split(key, 8)

    def dense(k, fan_in, fan_out, scale):
        w = jax.random.normal(k, (fan_in, fan_out)) * (scale / jnp.sqrt(fan_in))
        return {"w": w.astype(jnp.float32), "b": jnp.zeros(fan_out, jnp.float32)}

    params = {
        "enc1": dense(ks[0], obs_dim, HIDDEN, 1.0),
        "enc2": dense(ks[1], HIDDEN, HIDDEN, 1.0),
        "actor": dense(ks[2], HIDDEN, sum(act_dims), 0.01),
        "critic": dense(ks[3], HIDDEN, 1, 1.0),
    }
    if lstm:
        # Fused LSTM cell weights: [x, h] -> 4H gates (i, f, g, o).
        params["lstm"] = dense(ks[4], HIDDEN + HIDDEN, 4 * HIDDEN, 1.0)
    return params


def param_spec(obs_dim: int, act_dims, lstm: bool):
    """(flat_len, unravel) for the given architecture."""
    params = init_params(jax.random.PRNGKey(0), obs_dim, act_dims, lstm)
    flat, unravel = ravel_pytree(params)
    return flat.shape[0], unravel


# --------------------------------------------------------------------------
# Encode / decode split (paper §3.4)


def encode(p, obs):
    """Observation -> hidden state. First op unflattens: the manifest's
    field table defines how obs maps back to the structured space; the
    dense encoder consumes the flat f32 row directly (the 'no loss of
    generality' path), so unflattening is the identity here and
    slice-based for models that want per-field processing."""
    h = linear_act(obs, p["enc1"]["w"], p["enc1"]["b"], "tanh")
    return linear_act(h, p["enc2"]["w"], p["enc2"]["b"], "tanh")


def decode(p, hidden):
    """Hidden state -> (logits, value): the action/value heads."""
    logits = linear_act(hidden, p["actor"]["w"], p["actor"]["b"], "none")
    value = linear_act(hidden, p["critic"]["w"], p["critic"]["b"], "none")
    return logits, value[:, 0]


def lstm_cell(p, x, h, c):
    """Fused-gate LSTM cell sandwiched between encode and decode."""
    gates = linear_act(jnp.concatenate([x, h], axis=1), p["lstm"]["w"], p["lstm"]["b"], "none")
    i, f, g, o = jnp.split(gates, 4, axis=1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c = f * c + i * g
    h = o * jnp.tanh(c)
    return h, c


def make_forward(obs_dim, act_dims, lstm: bool):
    _, unravel = param_spec(obs_dim, act_dims, lstm)

    if not lstm:

        def forward(params_flat, obs):
            p = unravel(params_flat)
            logits, value = decode(p, encode(p, obs))
            return logits, value

        return forward

    def forward_lstm(params_flat, obs, h, c):
        p = unravel(params_flat)
        x = encode(p, obs)
        h2, c2 = lstm_cell(p, x, h, c)
        logits, value = decode(p, h2)
        return logits, value, h2, c2

    return forward_lstm


# --------------------------------------------------------------------------
# PPO loss


def _logp_entropy(logits, actions, act_dims):
    """Sum of per-slot categorical log-probs and entropies for a
    MultiDiscrete action (the emulated action space is always one
    MultiDiscrete; a plain Discrete is the 1-slot case)."""
    logp = 0.0
    entropy = 0.0
    off = 0
    for slot, n in enumerate(act_dims):
        lg = logits[:, off : off + n]
        logz = jax.nn.logsumexp(lg, axis=1)
        lp_all = lg - logz[:, None]
        a = actions[:, slot]
        logp = logp + jnp.take_along_axis(lp_all, a[:, None], axis=1)[:, 0]
        entropy = entropy - jnp.sum(jnp.exp(lp_all) * lp_all, axis=1)
        off += n
    return logp, entropy


def _ppo_loss(logits, value, actions, old_logp, adv, ret, ent_coef, act_dims):
    logp, entropy = _logp_entropy(logits, actions, act_dims)
    logratio = logp - old_logp
    ratio = jnp.exp(logratio)
    # Advantage normalization (batch level, CleanRL default).
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    pg1 = -adv * ratio
    pg2 = -adv * jnp.clip(ratio, 1.0 - CLIP, 1.0 + CLIP)
    pg_loss = jnp.maximum(pg1, pg2).mean()
    v_loss = 0.5 * jnp.square(value - ret).mean()
    ent = entropy.mean()
    loss = pg_loss - ent_coef * ent + VF_COEF * v_loss
    approx_kl = ((ratio - 1.0) - logratio).mean()
    return loss, (pg_loss, v_loss, ent, approx_kl)


def _adam(params_flat, m, v, step, lr, grads):
    """Adam with global-norm gradient clipping, all flat."""
    gnorm = jnp.sqrt(jnp.sum(jnp.square(grads)) + 1e-12)
    scale = jnp.minimum(1.0, MAX_GRAD_NORM / gnorm)
    g = grads * scale
    step = step + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1**step)
    vhat = v / (1.0 - ADAM_B2**step)
    params_flat = params_flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return params_flat, m, v, step


def make_train_step(obs_dim, act_dims, lstm: bool):
    _, unravel = param_spec(obs_dim, act_dims, lstm)
    act_dims = tuple(act_dims)

    if not lstm:

        def train_step(params_flat, m, v, step, lr, ent_coef, obs, actions, old_logp, adv, ret):
            def loss_fn(pf):
                p = unravel(pf)
                logits, value = decode(p, encode(p, obs))
                return _ppo_loss(
                    logits, value, actions, old_logp, adv, ret, ent_coef, act_dims
                )

            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params_flat)
            params_flat, m, v, step = _adam(params_flat, m, v, step, lr, grads)
            pg_loss, v_loss, ent, kl = aux
            metrics = jnp.stack([loss, pg_loss, v_loss, ent, kl])
            return params_flat, m, v, step, metrics

        return train_step

    def train_step_lstm(
        params_flat, m, v, step, lr, ent_coef, obs, starts, actions, old_logp, adv, ret
    ):
        """obs: (T,B,D); starts[t,b] = 1 if obs[t,b] begins a new episode
        (LSTM state is zeroed there — the state-reshaping logic the paper
        calls the most common source of hard bugs, done once, here)."""
        T, B, _ = obs.shape

        def loss_fn(pf):
            p = unravel(pf)

            def scan_body(carry, xs):
                h, c = carry
                o_t, s_t = xs
                mask = (1.0 - s_t)[:, None]
                h, c = h * mask, c * mask
                x = encode(p, o_t)
                h, c = lstm_cell(p, x, h, c)
                logits, value = decode(p, h)
                return (h, c), (logits, value)

            zeros = jnp.zeros((B, HIDDEN), jnp.float32)
            (_, _), (logits, value) = jax.lax.scan(scan_body, (zeros, zeros), (obs, starts))
            flat = lambda x: x.reshape((T * B,) + x.shape[2:])
            return _ppo_loss(
                flat(logits),
                flat(value),
                flat(actions),
                old_logp.reshape(-1),
                adv.reshape(-1),
                ret.reshape(-1),
                ent_coef,
                act_dims,
            )

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params_flat)
        params_flat, m, v, step = _adam(params_flat, m, v, step, lr, grads)
        pg_loss, v_loss, ent, kl = aux
        metrics = jnp.stack([loss, pg_loss, v_loss, ent, kl])
        return params_flat, m, v, step, metrics

    return train_step_lstm


def make_gae(gamma: float = 0.99, lam: float = 0.95):
    return partial(gae_kernel, gamma=gamma, lam=lam)
