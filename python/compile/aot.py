"""AOT pipeline: lower every entry point for every env spec to HLO *text*
plus a JSON manifest, consumed by the Rust runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run via ``make artifacts`` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out ../artifacts

The env spec table below is the Python↔Rust contract: the Rust runtime
asserts at load time that each env's emulated observation layout matches
the obs_dim recorded in the manifest, so drift fails loudly.
"""

import argparse
import json
import os

import jax
import jax.flatten_util
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, obs_dim, act_dims, agents, lstm). obs_dim must equal the Rust
# StructLayout::flat_len() of the env's observation space.
ENV_SPECS = [
    ("ocean_squared", 121, [4], 1, False),
    ("ocean_password", 5, [2], 1, False),
    ("ocean_stochastic", 1, [2], 1, False),
    ("ocean_memory", 3, [2], 1, True),
    ("ocean_multiagent", 2, [2], 2, False),
    ("ocean_spaces", 5, [2, 2], 1, False),
    ("ocean_bandit", 1, [4], 1, False),
    ("classic_cartpole", 4, [2], 1, False),
    ("classic_minigrid", 26, [3], 1, False),
    ("classic_breakout", 53, [3], 1, False),
    ("profile_nmmo", 283, [5, 9], 16, False),
]

# Rollout geometry shared with the Rust trainer:
#   B_FWD  — agent rows per forward call (the pool batch, N rows);
#   B_ROLL — total agent rows across all envs (M rows, the GAE/train width);
#   T      — steps per rollout segment.
# B_ROLL = 2 * B_FWD gives the paper's double-buffered EnvPool setting
# (M = 2N); sync-mode training forwards all B_ROLL rows at once instead.
B_FWD = 16
B_ROLL = 32
T = 32

GAMMA = 0.99
LAM = 0.95


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_spec(name, obs_dim, act_dims, agents, lstm, out_dir):
    """Lower all entry points for one env spec; return its manifest dict."""
    n_params, _ = model.param_spec(obs_dim, act_dims, lstm)
    slots = len(act_dims)
    n = T * B_ROLL
    h = model.HIDDEN

    artifacts = {}

    def emit(key, fn, *args):
        path = f"{name}_{key}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(lower(fn, *args))
        artifacts[key] = path

    # Forward at both batch sizes: the pool batch (N rows) and the full
    # row set (M rows, sync mode / bootstrap passes).
    for b in sorted({B_FWD, B_ROLL}):
        if lstm:
            fwd_l = model.make_forward(obs_dim, act_dims, lstm=True)
            emit(f"forward_lstm_b{b}", fwd_l, f32(n_params), f32(b, obs_dim), f32(b, h), f32(b, h))
        else:
            fwd = model.make_forward(obs_dim, act_dims, lstm=False)
            emit(f"forward_b{b}", fwd, f32(n_params), f32(b, obs_dim))

    emit(
        "gae",
        lambda r, v, d, lv: model.make_gae(GAMMA, LAM)(r, v, d, lv),
        f32(T, B_ROLL),
        f32(T, B_ROLL),
        f32(T, B_ROLL),
        f32(B_ROLL),
    )

    if lstm:
        ts = model.make_train_step(obs_dim, act_dims, lstm=True)
        emit(
            "train_step",
            ts,
            f32(n_params), f32(n_params), f32(n_params), f32(), f32(), f32(),
            f32(T, B_ROLL, obs_dim), f32(T, B_ROLL), i32(T, B_ROLL, slots),
            f32(T, B_ROLL), f32(T, B_ROLL), f32(T, B_ROLL),
        )
    else:
        ts = model.make_train_step(obs_dim, act_dims, lstm=False)
        emit(
            "train_step",
            ts,
            f32(n_params), f32(n_params), f32(n_params), f32(), f32(), f32(),
            f32(n, obs_dim), i32(n, slots), f32(n), f32(n), f32(n),
        )

    # Initial parameters: ravel_pytree order is a Python-side detail, so
    # the initial flat vector is exported rather than re-derived in Rust.
    import hashlib

    import numpy as np
    seed = int(hashlib.md5(name.encode()).hexdigest()[:8], 16)  # stable across runs
    params0 = model.init_params(jax.random.PRNGKey(seed), obs_dim, act_dims, lstm)
    flat0, _ = jax.flatten_util.ravel_pytree(params0)
    params_file = f"{name}_params0.bin"
    np.asarray(flat0, dtype="<f4").tofile(os.path.join(out_dir, params_file))

    return {
        "obs_dim": obs_dim,
        "act_dims": act_dims,
        "agents": agents,
        "lstm": lstm,
        "n_params": n_params,
        "hidden": h,
        "batch_fwd": B_FWD,
        "batch_roll": B_ROLL,
        "horizon": T,
        "gamma": GAMMA,
        "lam": LAM,
        "params0": params_file,
        "artifacts": artifacts,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated spec names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"batch_fwd": B_FWD, "batch_roll": B_ROLL, "horizon": T, "specs": {}}
    for name, obs_dim, act_dims, agents, lstm in ENV_SPECS:
        if only and name not in only:
            continue
        print(f"lowering {name} (obs={obs_dim}, act={act_dims}, lstm={lstm}) ...", flush=True)
        manifest["specs"][name] = build_spec(name, obs_dim, act_dims, agents, lstm, args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest['specs'])} specs to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
