"""Layer-1 Pallas kernel: fused linear + bias + activation.

The policy MLP's hot op. On the paper's hardware this is a cuBLAS GEMM
followed by separate bias/activation kernels; the TPU-shaped rethink (see
DESIGN.md §Hardware-Adaptation) tiles the GEMM for VMEM with MXU-aligned
128x128 blocks and fuses the bias add + nonlinearity into the matmul
epilogue, so each output tile is produced in one VMEM-resident pass.

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the interpret path emits plain HLO with identical
numerics. Real-TPU efficiency is estimated from the BlockSpec footprint in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tile sides. Shapes smaller than a tile fall back to a single
# block covering the (padded) array — Pallas pads reads/writes internally.
TILE_M = 128
TILE_N = 128


def _kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    """One (TILE_M, TILE_N) output tile. K is kept whole per tile (policy
    nets have K ≤ a few hundred floats ≪ VMEM), so the MXU sees a single
    (bm, K) × (K, bn) contraction and the bias + nonlinearity run in the
    same VMEM-resident epilogue — no extra HBM round trip."""
    y = x_ref[...] @ w_ref[...] + b_ref[...]
    if act == "tanh":
        y = jnp.tanh(y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _pallas_linear(x, w, b, act: str):
    """Raw Pallas call: ``act(x @ w + b)``.

    x: (M, K) f32, w: (K, N) f32, b: (N,) f32. Grid tiles M and N; K stays
    resident per tile (policy-net K ≤ a few hundred floats ≪ VMEM).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,)
    bm = min(TILE_M, m)
    bn = min(TILE_N, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.lru_cache(maxsize=None)
def _make_linear_act(act: str):
    """Differentiable fused linear: forward is the Pallas kernel; the
    hand-written VJP reuses the same kernel for both backward GEMMs
    (``dx = dz @ wᵀ``, ``dw = xᵀ @ dz``), so the backward pass also runs
    tiled and fused — Pallas calls have no built-in reverse rule."""

    @jax.custom_vjp
    def f(x, w, b):
        return _pallas_linear(x, w, b, act)

    def fwd(x, w, b):
        y = _pallas_linear(x, w, b, act)
        return y, (x, w, y)

    def bwd(res, dy):
        x, w, y = res
        if act == "tanh":
            dz = dy * (1.0 - y * y)
        elif act == "relu":
            dz = dy * (y > 0.0).astype(dy.dtype)
        else:
            dz = dy
        zn = jnp.zeros((w.shape[0],), jnp.float32)
        zk = jnp.zeros((dz.shape[1],), jnp.float32)
        dx = _pallas_linear(dz, w.T, zn, "none")
        dw = _pallas_linear(x.T, dz, zk, "none")
        db = dz.sum(axis=0)
        return dx, dw, db

    f.defvjp(fwd, bwd)
    return f


def linear_act(x, w, b, act: str = "tanh"):
    """Fused, differentiable ``act(x @ w + b)``. See module docs."""
    return _make_linear_act(act)(x, w, b)
