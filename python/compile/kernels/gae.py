"""Layer-1 Pallas kernel: Generalized Advantage Estimation.

A reverse time scan over the rollout. The CUDA-era implementations run
this on the CPU in numpy (it is sequential in T); the TPU-shaped version
keeps the whole (T, B_tile) rollout tile resident in VMEM and performs the
scan in-kernel over the batch lanes, so the only HBM traffic is one read
of (rewards, values, dones) and one write of (adv) per tile.

``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic); numerics
are identical to the lowered TPU kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch lanes per tile; 128 matches the VPU lane count.
TILE_B = 128


def _kernel(rew_ref, val_ref, done_ref, lastv_ref, adv_ref, *, gamma: float, lam: float):
    T = rew_ref.shape[0]

    def body(i, carry):
        gae, next_value = carry
        t = T - 1 - i
        mask = 1.0 - done_ref[t, :]
        delta = rew_ref[t, :] + gamma * next_value * mask - val_ref[t, :]
        gae = delta + gamma * lam * mask * gae
        adv_ref[t, :] = gae
        return gae, val_ref[t, :]

    zeros = jnp.zeros(lastv_ref.shape, jnp.float32)
    jax.lax.fori_loop(0, T, body, (zeros, lastv_ref[...]))


def gae(rewards, values, dones, last_value, gamma: float = 0.99, lam: float = 0.95):
    """Pallas GAE over a (T, B) rollout; returns (advantages, returns)."""
    t, b = rewards.shape
    assert values.shape == (t, b) and dones.shape == (t, b)
    assert last_value.shape == (b,)
    bb = min(TILE_B, b)
    grid = (pl.cdiv(b, bb),)
    adv = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, lam=lam),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, bb), lambda j: (0, j)),
            pl.BlockSpec((t, bb), lambda j: (0, j)),
            pl.BlockSpec((t, bb), lambda j: (0, j)),
            pl.BlockSpec((bb,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((t, bb), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((t, b), jnp.float32),
        interpret=True,
    )(rewards, values, dones, last_value)
    return adv, adv + values
