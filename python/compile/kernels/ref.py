"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written in the most obvious jnp style. pytest (python/tests/test_kernels.py)
sweeps shapes and dtypes with hypothesis and asserts allclose between the
kernel and its oracle.
"""

import jax
import jax.numpy as jnp


def linear_act_ref(x, w, b, act: str):
    """y = act(x @ w + b).

    x: (M, K), w: (K, N), b: (N,). act in {"tanh", "relu", "none"}.
    """
    y = x @ w + b
    if act == "tanh":
        return jnp.tanh(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "none":
        return y
    raise ValueError(f"unknown activation {act!r}")


def gae_ref(rewards, values, dones, last_value, gamma: float, lam: float):
    """Generalized Advantage Estimation, time-major.

    rewards, values, dones: (T, B); last_value: (B,).
    dones[t] = 1 if the episode ended *at* step t (the env auto-reset after
    producing rewards[t]); bootstrap is masked accordingly.

    Returns (advantages, returns), both (T, B), with
    returns = advantages + values.
    """

    def body(carry, xs):
        gae, next_value = carry
        reward, value, done = xs
        mask = 1.0 - done
        delta = reward + gamma * next_value * mask - value
        gae = delta + gamma * lam * mask * gae
        return (gae, value), gae

    (_, _), adv_rev = jax.lax.scan(
        body,
        (jnp.zeros_like(last_value), last_value),
        (rewards[::-1], values[::-1], dones[::-1]),
    )
    adv = adv_rev[::-1]
    return adv, adv + values
