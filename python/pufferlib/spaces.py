"""Space/layout JSON → numpy dtypes and Gymnasium spaces.

The native module describes observation layouts and space trees as JSON
(see ``crates/puffer-py/src/bridge.rs``): Box bounds travel as strings
(``"inf"``/``"-inf"``/``"3"``) because JSON numbers cannot express
infinities, and Dict entries arrive as an **ordered** ``[name, space]``
list because field order is load-bearing for the packed byte layout.
"""

import json

import numpy as np

_DTYPES = {"f32": np.float32, "u8": np.uint8, "i32": np.int32}


def np_dtype(name):
    """Map a Rust dtype name (``f32``/``u8``/``i32``) to numpy."""
    try:
        return _DTYPES[name]
    except KeyError:
        raise ValueError(f"unknown dtype name {name!r} in layout JSON") from None


def structured_dtype(layout):
    """Build the numpy structured dtype matching a packed obs layout.

    ``layout`` is the parsed ``layout_json()`` dict. The result has one
    (possibly subarray) field per layout leaf, with explicit byte
    offsets and itemsize, so viewing the raw obs slab with it aliases
    the Rust memory exactly.
    """
    fields = layout["fields"]
    return np.dtype(
        {
            "names": [f["name"] for f in fields],
            "formats": [(np_dtype(f["dtype"]), tuple(int(d) for d in f["shape"])) for f in fields],
            "offsets": [int(f["byte_offset"]) for f in fields],
            "itemsize": int(layout["byte_len"]),
        }
    )


def _bound(text):
    # float() parses "inf"/"-inf"/"3.5" alike.
    return float(text)


def space_from_json(tree):
    """Recursively convert a native space JSON tree to a Gymnasium space."""
    import gymnasium

    kind = tree["type"]
    if kind == "discrete":
        return gymnasium.spaces.Discrete(int(tree["n"]))
    if kind == "multidiscrete":
        return gymnasium.spaces.MultiDiscrete([int(n) for n in tree["nvec"]])
    if kind == "box":
        dtype = np_dtype(tree["dtype"])
        shape = tuple(int(d) for d in tree["shape"])
        return gymnasium.spaces.Box(
            low=_bound(tree["low"]), high=_bound(tree["high"]), shape=shape, dtype=dtype
        )
    if kind == "tuple":
        return gymnasium.spaces.Tuple([space_from_json(t) for t in tree["items"]])
    if kind == "dict":
        # Entries are an ordered [name, space] list; Gymnasium Dict
        # preserves insertion order when given a list of pairs.
        return gymnasium.spaces.Dict(
            [(name, space_from_json(sub)) for name, sub in tree["entries"]]
        )
    raise ValueError(f"unknown space type {kind!r} in space JSON")


def parse_layout(native):
    """Parse a native handle's ``layout_json()`` into a plain dict."""
    return json.loads(native.layout_json())


def parse_obs_space(native):
    return space_from_json(json.loads(native.obs_space_json()))


def parse_act_space(native):
    return space_from_json(json.loads(native.act_space_json()))
