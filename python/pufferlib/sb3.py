"""Stable-Baselines3 ``VecEnv`` shim over the native puffer vectorizer.

SB3 predates the Gymnasium vector API and brings its own ``VecEnv``
base class (reset -> obs; step -> (obs, rews, dones, list-of-dict
infos)), so it gets its own thin adapter instead of reusing
:class:`pufferlib.vector.PufferVectorEnv`.

SB3 keeps references to the arrays it receives across steps, so —
unlike the Gymnasium adapter — this shim **copies** observations and
rewards out of the zero-copy views each step. The vectorization win
(batched Rust stepping, no per-env Python) is untouched; only the
final hand-off copies.

Known gap: the Rust core's same-step autoreset discards the terminal
observation, so ``info["terminal_observation"]`` is never set. SB3's
on-policy algorithms (PPO/A2C) only consult it to bootstrap truncated
episodes; returns at timeouts are slightly pessimistic.
"""

import numpy as np

try:
    from stable_baselines3.common.vec_env.base_vec_env import VecEnv as _SB3VecEnv
except ImportError as e:  # pragma: no cover - sb3 is optional
    raise ImportError(
        "pufferlib.sb3 needs stable-baselines3 "
        "(pip install 'pufferlib[sb3]')"
    ) from e

from .vector import PufferVectorEnv


def make_sb3_env(env_name, num_envs=1, **kwargs):
    """``pufferlib.emulate`` with an SB3 ``VecEnv`` interface."""
    import pufferlib

    return PufferSB3VecEnv(pufferlib.emulate(env_name, num_envs, **kwargs))


class PufferSB3VecEnv(_SB3VecEnv):
    """Wrap a :class:`PufferVectorEnv` for Stable-Baselines3."""

    def __init__(self, venv: PufferVectorEnv):
        self.venv = venv
        super().__init__(
            num_envs=venv.num_envs,
            observation_space=venv.single_observation_space,
            action_space=venv.single_action_space,
        )
        self._pending = None

    def reset(self):
        obs, _ = self.venv.reset(seed=0)
        return np.array(obs, copy=True)

    def step_async(self, actions):
        self._pending = actions

    def step_wait(self):
        obs, rewards, terms, truncs, vec_infos = self.venv.step(self._pending)
        self._pending = None
        dones = terms | truncs
        infos = []
        for i in range(self.num_envs):
            info = {
                key: float(vec_infos[key][i])
                for key in vec_infos
                if not key.startswith("_") and vec_infos[f"_{key}"][i]
            }
            if truncs[i] and not terms[i]:
                info["TimeLimit.truncated"] = True
            infos.append(info)
        return np.array(obs, copy=True), np.array(rewards, copy=True), np.array(dones, copy=True), infos

    def close(self):
        self.venv.close()

    # -- SB3 VecEnv plumbing the Rust backend has no use for ----------

    def get_attr(self, attr_name, indices=None):
        return [getattr(self.venv, attr_name)] * self._count(indices)

    def set_attr(self, attr_name, value, indices=None):
        raise NotImplementedError(
            "puffer envs live in Rust worker threads; per-env attribute "
            "mutation from Python is not supported"
        )

    def env_method(self, method_name, *args, indices=None, **kwargs):
        raise NotImplementedError(
            "puffer envs live in Rust worker threads; per-env method calls "
            "from Python are not supported"
        )

    def env_is_wrapped(self, wrapper_class, indices=None):
        return [False] * self._count(indices)

    def seed(self, seed=None):
        # Seeds apply at reset time in the Rust core.
        return [seed] * self.num_envs

    def _count(self, indices):
        if indices is None:
            return self.num_envs
        if isinstance(indices, int):
            return 1
        return len(list(indices))
