"""pufferlib — Python bindings for the Rust PufferLib vectorizer.

The package is a thin numpy/Gymnasium skin over the compiled extension
module ``pufferlib._puffer`` (built by maturin from ``crates/puffer-py``;
see the repo-root ``pyproject.toml``).  The extension hands out **raw
slab addresses**; :mod:`pufferlib.vector` wraps them into numpy arrays
that alias the Rust observation/reward/done buffers, so stepping never
copies observations into Python.

The one-liner::

    import pufferlib
    envs = pufferlib.emulate("ocean/squared", num_envs=256)
    obs, infos = envs.reset(seed=0)
    obs, rew, term, trunc, infos = envs.step(actions)

returns a Gymnasium ``VectorEnv``-compatible object that unmodified
CleanRL scripts train against; :mod:`pufferlib.sb3` adds the
Stable-Baselines3 ``VecEnv`` shim on top.
"""

__version__ = "0.1.0"

__all__ = ["emulate", "raw_vecenv", "__version__"]

# Flat spec keys understood by the Rust RunSpec grammar, minus the
# namespaced ones we build below. Kept in sync with
# crates/puffer-core/src/config/mod.rs WRAP_KNOBS.
_WRAP_KNOBS = (
    "clip_reward",
    "scale_reward",
    "normalize_obs",
    "stack",
    "time_limit",
    "action_repeat",
)


def _native():
    """Import the compiled extension, with an actionable error if absent."""
    try:
        from . import _puffer
    except ImportError as e:  # pragma: no cover - only without the wheel
        raise ImportError(
            "pufferlib._puffer is not built. Install the wheel, or build "
            "in place with: pip install maturin && maturin develop "
            "--features python (run from the repo root)."
        ) from e
    return _puffer


def _fmt(value):
    """Render a kwarg value in the spec's TOML-scalar spelling."""
    if isinstance(value, bool):
        return "true" if value else "false"
    return str(value)


def _flat_pairs(env_name, seed, vec, workers, batch, wrap):
    pairs = [("env.name", str(env_name)), ("seed", _fmt(seed)), ("vec.mode", str(vec))]
    if workers is not None:
        pairs.append(("vec.workers", _fmt(workers)))
    if batch is not None:
        pairs.append(("vec.batch", _fmt(batch)))
    for key, value in sorted(wrap.items()):
        if key not in _WRAP_KNOBS:
            raise TypeError(
                f"emulate() got an unexpected wrapper kwarg {key!r} "
                f"(known: {', '.join(_WRAP_KNOBS)})"
            )
        pairs.append((f"env.wrap.{key}", _fmt(value)))
    return pairs


def raw_vecenv(env_name, num_envs=1, *, seed=0, vec="serial", workers=None, batch=None, **wrap):
    """Build the raw native ``_puffer.VecEnv`` (addresses, no numpy).

    Most callers want :func:`emulate`; this is the escape hatch for code
    that manages its own views (benchmarks, the SB3 shim).
    """
    pairs = _flat_pairs(env_name, seed, vec, workers, batch, wrap)
    return _native().VecEnv.from_flat_pairs(pairs, num_envs)


def emulate(env_name, num_envs=1, *, seed=0, vec="serial", workers=None, batch=None, **wrap):
    """One-line vectorized-env constructor (paper §3.1, from Python).

    Builds ``num_envs`` copies of the named first-party env inside the
    Rust vectorizer and returns a Gymnasium ``VectorEnv``-compatible
    adapter with zero-copy observation views.

    Args:
        env_name: first-party env name, e.g. ``"ocean/squared"``.
        num_envs: number of env copies.
        seed: root seed recorded in the spec (``reset(seed=...)`` still
            controls the actual reset seed).
        vec: ``"serial"``, ``"mt"``, or ``"auto"``.
        workers / batch: multithreaded-backend knobs (``vec="mt"`` only).
        **wrap: wrapper knobs applied to every copy, e.g.
            ``stack=4, clip_reward=1.0, time_limit=500``.
    """
    from .vector import PufferVectorEnv

    return PufferVectorEnv(raw_vecenv(
        env_name, num_envs, seed=seed, vec=vec, workers=workers, batch=batch, **wrap
    ))
