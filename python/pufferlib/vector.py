"""Gymnasium ``VectorEnv`` adapter over the native puffer vectorizer.

The native extension returns **addresses** of the Rust-owned batch
slabs; this module wraps them with ``ctypes`` + ``np.ctypeslib`` into
numpy arrays that *alias* that memory, and caches the arrays keyed by
address. The serial and multithreaded-sync backends reuse one slab for
the lifetime of the env, so steady-state stepping allocates nothing and
copies nothing on the observation path — the whole point of the split
(paper §3.2).

Aliasing contract: the arrays returned by :meth:`PufferVectorEnv.reset`
and :meth:`PufferVectorEnv.step` are views into Rust memory, valid until
the next ``step``/``close`` and **overwritten in place** by the next
step. Trainers that keep observations across steps (every replay buffer
does) must copy — which CleanRL/SB3 already do when they stage rollouts
into their own storage.

Autoreset follows Gymnasium's **same-step** convention, matching the
Rust core (``PufferEnv`` in ``crates/puffer-core/src/emulation``): when
``terminated | truncated`` is set, the returned observation is already
the *next* episode's first observation, and the episode's aggregate
stats arrive in ``infos`` (``episode_return``, ``episode_length``).
"""

import ctypes

import numpy as np

try:
    import gymnasium
except ImportError as e:  # pragma: no cover - gymnasium is a test/CI dep
    raise ImportError(
        "pufferlib.vector needs gymnasium (pip install 'pufferlib[gymnasium]')"
    ) from e

from . import spaces as _spaces


def _u8_view(address, nbytes):
    """A uint8 numpy array aliasing ``nbytes`` of foreign memory."""
    buf = (ctypes.c_ubyte * nbytes).from_address(address)
    return np.ctypeslib.as_array(buf)


class _SlabViews:
    """Pointer-keyed cache of numpy views over the native batch slabs.

    The backends hand out stable addresses, so after the first step this
    is a dict hit per array — no ctypes traffic, no allocation.
    """

    def __init__(self):
        self._cache = {}

    def u8(self, address, nbytes):
        key = (address, nbytes)
        view = self._cache.get(key)
        if view is None:
            view = _u8_view(address, nbytes)
            self._cache[key] = view
        return view

    def f32(self, address, count):
        key = (address, count, "f32")
        view = self._cache.get(key)
        if view is None:
            view = self.u8(address, count * 4).view(np.float32)
            self._cache[key] = view
        return view

    def bools(self, address, count):
        key = (address, count, "b")
        view = self._cache.get(key)
        if view is None:
            view = self.u8(address, count).view(np.bool_)
            self._cache[key] = view
        return view


class PufferVectorEnv(gymnasium.vector.VectorEnv):
    """Gymnasium ``VectorEnv`` over a native ``_puffer.VecEnv`` handle.

    Built by :func:`pufferlib.emulate`. Observations come back as
    zero-copy views: a plain ndarray when the packed layout is a single
    leaf (the common Box case), or a numpy *structured array* whose
    field offsets mirror the Rust :class:`StructLayout` for multi-leaf
    Dict/Tuple observation spaces.
    """

    metadata = {"render_modes": []}
    render_mode = None

    def __init__(self, native):
        if native.agents_per_env != 1:
            raise ValueError(
                f"PufferVectorEnv is single-agent (agents_per_env="
                f"{native.agents_per_env}); drive multi-agent envs through "
                "the raw pufferlib.raw_vecenv() handle"
            )
        if native.batch_size != native.num_envs:
            raise ValueError(
                f"PufferVectorEnv needs synchronous batches (batch_size="
                f"{native.batch_size} != num_envs={native.num_envs}); "
                "async env pools don't fit the Gymnasium VectorEnv step "
                "contract — use the raw handle for pooled stepping"
            )
        self.native = native
        self.num_envs = native.num_envs
        self._views = _SlabViews()
        self._layout = _spaces.parse_layout(native)
        self._row_ids = list(range(self.num_envs))
        self._action_slots = len(native.action_dims())
        self._closed = False

        fields = self._layout["fields"]
        if len(fields) == 1 and int(fields[0]["byte_offset"]) == 0:
            # Single-leaf layout: expose a plain (num_envs, *shape) view.
            field = fields[0]
            self._obs_dtype = _spaces.np_dtype(field["dtype"])
            self._obs_shape = tuple(int(d) for d in field["shape"])
        else:
            # Multi-leaf layout: a structured dtype whose offsets mirror
            # the packed Rust layout, viewed in place over the slab.
            self._obs_dtype = _spaces.structured_dtype(self._layout)
            self._obs_shape = ()

        self.single_observation_space = _spaces.parse_obs_space(native)
        self.single_action_space = _spaces.parse_act_space(native)
        self.observation_space = gymnasium.vector.utils.batch_space(
            self.single_observation_space, self.num_envs
        )
        self.action_space = gymnasium.vector.utils.batch_space(
            self.single_action_space, self.num_envs
        )
        # Gymnasium >= 1.1 names the autoreset convention explicitly; the
        # Rust core implements same-step autoreset.
        autoreset = getattr(gymnasium.vector, "AutoresetMode", None)
        if autoreset is not None:
            self.metadata = dict(self.metadata, autoreset_mode=autoreset.SAME_STEP)

    # -- step surface -------------------------------------------------

    def reset(self, *, seed=None, options=None):
        del options
        if seed is None:
            seed = 0
        try:
            seed = int(seed)
        except (TypeError, ValueError):
            raise TypeError(
                "PufferVectorEnv.reset takes one int seed (the Rust core "
                "derives per-env seeds from it), not a per-env list"
            ) from None
        self.native.async_reset(seed)
        obs, _, _, _, infos = self._recv()
        return obs, infos

    def step(self, actions):
        flat = np.asarray(actions, dtype=np.int32)
        expect = self.num_envs * self._action_slots
        if flat.size != expect:
            raise ValueError(
                f"step() wants {expect} action slots "
                f"({self.num_envs} envs x {self._action_slots} per env), "
                f"got array of shape {flat.shape}"
            )
        self.native.send(flat.ravel().tolist())
        return self._recv()

    def close(self, **kwargs):
        del kwargs
        self._closed = True
        # Views alias slabs owned by the native object; drop them first.
        self._views = _SlabViews()
        self.native.close()

    # -- internals ----------------------------------------------------

    def _recv(self):
        rows, obs_ptr, obs_len, rew_ptr, term_ptr, trunc_ptr, env_ids, raw_infos = (
            self.native.recv()
        )
        if env_ids != self._row_ids:
            raise RuntimeError(
                f"backend returned rows {env_ids}; the Gymnasium adapter "
                "requires full batches in env order"
            )
        obs = self._obs_view(obs_ptr, obs_len, rows)
        rewards = self._views.f32(rew_ptr, rows)
        terms = self._views.bools(term_ptr, rows)
        truncs = self._views.bools(trunc_ptr, rows)
        return obs, rewards, terms, truncs, self._infos(raw_infos)

    def _obs_view(self, address, nbytes, rows):
        flat = self._views.u8(address, nbytes)
        key = (address, nbytes, "obs")
        obs = self._views._cache.get(key)
        if obs is None:
            obs = flat.view(self._obs_dtype)
            if self._obs_shape:
                obs = obs.reshape((rows,) + self._obs_shape)
            self._views._cache[key] = obs
        return obs

    def _infos(self, raw_infos):
        """Native per-row infos → the Gymnasium vector dict convention.

        ``[(row, [(key, value), ...]), ...]`` becomes
        ``{key: array(num_envs), "_key": present_mask}``.
        """
        infos = {}
        for row, pairs in raw_infos:
            for key, value in pairs:
                slot = infos.get(key)
                if slot is None:
                    slot = (
                        np.zeros(self.num_envs, dtype=np.float64),
                        np.zeros(self.num_envs, dtype=np.bool_),
                    )
                    infos[key] = slot
                slot[0][row] = value
                slot[1][row] = True
        return {
            name: arr
            for key, (values, mask) in infos.items()
            for name, arr in ((key, values), (f"_{key}", mask))
        }

    def __del__(self):
        if not getattr(self, "_closed", True):
            try:
                self.close()
            except Exception:
                pass

    def __repr__(self):
        return (
            f"PufferVectorEnv({self.native.spec_json()}, "
            f"num_envs={self.num_envs})"
        )
