"""Make `compile` and `pufferlib` importable when pytest runs from the
repo root. Appended (not prepended) so an installed pufferlib wheel —
which carries the compiled `_puffer` extension the source tree lacks —
always wins over the pure-Python fallback in python/pufferlib/."""

import os
import sys

_PYTHON_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _PYTHON_DIR not in sys.path:
    sys.path.append(_PYTHON_DIR)
