"""L2 correctness: model shapes, loss behaviour, and the AOT contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from compile import model


OBS_DIM, ACT_DIMS = 5, [2, 3]
B, T = 16, 8


def flat_params(lstm=False, seed=0):
    p = model.init_params(jax.random.PRNGKey(seed), OBS_DIM, ACT_DIMS, lstm)
    flat, _ = ravel_pytree(p)
    return flat


def test_param_spec_deterministic():
    n1, _ = model.param_spec(OBS_DIM, ACT_DIMS, False)
    n2, _ = model.param_spec(OBS_DIM, ACT_DIMS, False)
    assert n1 == n2 == flat_params().shape[0]
    n_lstm, _ = model.param_spec(OBS_DIM, ACT_DIMS, True)
    assert n_lstm > n1


def test_forward_shapes():
    fwd = model.make_forward(OBS_DIM, ACT_DIMS, lstm=False)
    obs = jnp.ones((B, OBS_DIM))
    logits, value = fwd(flat_params(), obs)
    assert logits.shape == (B, sum(ACT_DIMS))
    assert value.shape == (B,)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_lstm_shapes_and_state():
    fwd = model.make_forward(OBS_DIM, ACT_DIMS, lstm=True)
    obs = jnp.ones((B, OBS_DIM))
    h = c = jnp.zeros((B, model.HIDDEN))
    logits, value, h2, c2 = fwd(flat_params(lstm=True), obs, h, c)
    assert logits.shape == (B, sum(ACT_DIMS))
    assert h2.shape == (B, model.HIDDEN)
    # State must actually evolve.
    assert float(jnp.abs(h2).max()) > 0.0
    # And influence the output on the next step.
    logits2, *_ = fwd(flat_params(lstm=True), obs, h2, c2)
    assert not np.allclose(logits, logits2)


def _synthetic_batch(key, n):
    ks = jax.random.split(key, 5)
    obs = jax.random.normal(ks[0], (n, OBS_DIM))
    actions = jnp.stack(
        [jax.random.randint(ks[1], (n,), 0, d) for d in ACT_DIMS], axis=1
    ).astype(jnp.int32)
    old_logp = -jnp.log(float(np.prod([float(d) for d in ACT_DIMS]))) * jnp.ones(n)
    adv = jax.random.normal(ks[3], (n,))
    ret = jax.random.normal(ks[4], (n,))
    return obs, actions, old_logp, adv, ret


def test_train_step_reduces_loss():
    """Repeated full-batch steps on a fixed batch must reduce the loss —
    the core sanity check before Rust ever runs the artifact."""
    ts = jax.jit(model.make_train_step(OBS_DIM, ACT_DIMS, lstm=False))
    params = flat_params()
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    step = jnp.float32(0.0)
    obs, actions, old_logp, adv, ret = _synthetic_batch(jax.random.PRNGKey(1), T * B)
    losses = []
    for _ in range(30):
        params, m, v, step, metrics = ts(
            params, m, v, step, jnp.float32(3e-3), jnp.float32(0.0),
            obs, actions, old_logp, adv, ret,
        )
        losses.append(float(metrics[0]))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses[0]} -> {losses[-1]}"
    assert np.isfinite(losses).all()
    assert float(step) == 30.0


def test_train_step_lstm_runs_and_learns_values():
    ts = jax.jit(model.make_train_step(OBS_DIM, ACT_DIMS, lstm=True))
    params = flat_params(lstm=True)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    step = jnp.float32(0.0)
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 6)
    obs = jax.random.normal(ks[0], (T, B, OBS_DIM))
    starts = jnp.zeros((T, B)).at[0].set(1.0)
    actions = jnp.stack(
        [jax.random.randint(ks[1], (T, B), 0, d) for d in ACT_DIMS], axis=2
    ).astype(jnp.int32)
    old_logp = -1.8 * jnp.ones((T, B))
    adv = jax.random.normal(ks[3], (T, B))
    ret = jax.random.normal(ks[4], (T, B))
    v_losses = []
    for _ in range(20):
        params, m, v, step, metrics = ts(
            params, m, v, step, jnp.float32(3e-3), jnp.float32(0.0),
            obs, starts, actions, old_logp, adv, ret,
        )
        v_losses.append(float(metrics[2]))
    assert v_losses[-1] < v_losses[0]


def test_entropy_bonus_raises_entropy():
    """With a large entropy coefficient, policy entropy must go *up* —
    sign errors here are a classic PPO bug Ocean is designed to catch."""
    ts = jax.jit(model.make_train_step(OBS_DIM, ACT_DIMS, lstm=False))
    fwd = jax.jit(model.make_forward(OBS_DIM, ACT_DIMS, lstm=False))

    def mean_entropy(params, obs):
        logits, _ = fwd(params, obs)
        ent = 0.0
        off = 0
        for d in ACT_DIMS:
            lg = logits[:, off : off + d]
            lp = jax.nn.log_softmax(lg, axis=1)
            ent += -(jnp.exp(lp) * lp).sum(1).mean()
            off += d
        return float(ent)

    params = flat_params(seed=5)
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    step = jnp.float32(0.0)
    obs, actions, old_logp, adv, ret = _synthetic_batch(jax.random.PRNGKey(4), T * B)
    # Skew the policy first with a few biased updates.
    for _ in range(10):
        params, m, v, step, _ = ts(
            params, m, v, step, jnp.float32(1e-2), jnp.float32(0.0),
            obs, actions, old_logp, adv, ret,
        )
    e0 = mean_entropy(params, obs)
    for _ in range(20):
        params, m, v, step, _ = ts(
            params, m, v, step, jnp.float32(1e-2), jnp.float32(1.0),
            obs, actions, old_logp, jnp.zeros_like(adv), ret,
        )
    e1 = mean_entropy(params, obs)
    assert e1 > e0, f"entropy bonus failed: {e0} -> {e1}"


def test_metrics_layout():
    ts = jax.jit(model.make_train_step(OBS_DIM, ACT_DIMS, lstm=False))
    params = flat_params()
    z = jnp.zeros_like(params)
    obs, actions, old_logp, adv, ret = _synthetic_batch(jax.random.PRNGKey(7), T * B)
    out = ts(params, z, z, jnp.float32(0.0), jnp.float32(1e-3), jnp.float32(0.01),
             obs, actions, old_logp, adv, ret)
    assert len(out) == 5
    metrics = out[4]
    assert metrics.shape == (5,)  # loss, pg, vf, entropy, kl
    # entropy of a fresh policy ≈ uniform: log(2) + log(3).
    assert float(metrics[3]) == pytest.approx(np.log(2) + np.log(3), rel=0.05)
