"""Binding-level tests for pufferlib (the puffer-py extension + adapter).

Skipped wholesale when the native module isn't built — run
``maturin develop --features python`` from the repo root first (the CI
``pybind`` job installs the wheel). The aliasing/ordering assertions
here mirror the Rust contracts in
``crates/puffer-train/tests/vector_semantics.rs`` and the bridge unit
tests in ``crates/puffer-py/src/bridge.rs``.
"""

import json

import numpy as np
import pytest

_puffer = pytest.importorskip(
    "pufferlib._puffer",
    reason="native module not built (maturin develop --features python)",
)
gymnasium = pytest.importorskip("gymnasium")

import pufferlib
from pufferlib import spaces as pspaces
from pufferlib.vector import PufferVectorEnv


def test_zero_copy_views_alias_the_rust_slabs():
    """The tentpole property: no per-step observation copies.

    Hold the view returned by reset; after step() the *same array
    object* shows the new observations — the Rust backend rewrote the
    slab in place and the adapter's pointer-keyed cache returned the
    identical view, no re-fetch needed.
    """
    envs = pufferlib.emulate("classic/cartpole", num_envs=4)
    try:
        held, _ = envs.reset(seed=7)
        before = np.array(held, copy=True)
        obs, rew, term, trunc, _ = envs.step(np.zeros(4, dtype=np.int64))
        assert obs is held, "cached view must be reused, not rebuilt"
        assert np.shares_memory(obs, held)
        # Cartpole physics moves every step: the held view sees the new
        # state without any re-fetch.
        assert not np.array_equal(held, before)
        assert rew.dtype == np.float32
        assert term.dtype == np.bool_ and trunc.dtype == np.bool_
    finally:
        envs.close()


def test_dtype_and_shape_agree_with_the_native_layout():
    envs = pufferlib.emulate("ocean/bandit", num_envs=2)
    try:
        assert isinstance(envs.single_action_space, gymnasium.spaces.Discrete)
        assert envs.single_action_space.n == 4
        box = envs.single_observation_space
        assert isinstance(box, gymnasium.spaces.Box)
        assert box.shape == (1,) and box.dtype == np.float32
        layout = json.loads(envs.native.layout_json())
        assert layout["byte_len"] == 4 and layout["flat_len"] == 1
        obs, _ = envs.reset(seed=0)
        assert obs.shape == (2, 1) and obs.dtype == np.float32
    finally:
        envs.close()


def test_structured_view_matches_multi_leaf_layout():
    """Multi-leaf Dict obs → numpy structured dtype with the exact Rust
    byte offsets (field for field, offset for offset)."""
    envs = pufferlib.emulate("ocean/spaces", num_envs=2)
    try:
        layout = json.loads(envs.native.layout_json())
        assert len(layout["fields"]) > 1
        obs, _ = envs.reset(seed=0)
        assert obs.dtype.names == tuple(f["name"] for f in layout["fields"])
        for f in layout["fields"]:
            sub_dtype, offset = obs.dtype.fields[f["name"]][:2]
            assert offset == int(f["byte_offset"])
            assert sub_dtype.base == pspaces.np_dtype(f["dtype"])
        assert obs.dtype.itemsize == layout["byte_len"]
        assert isinstance(envs.single_observation_space, gymnasium.spaces.Dict)
    finally:
        envs.close()


def test_kwargs_and_toml_specs_are_equivalent():
    """emulate(**wrap) kwargs and a TOML spec produce the same RunSpec."""
    via_kwargs = pufferlib.raw_vecenv(
        "ocean/squared", 4, seed=3, stack=2, clip_reward=1.0
    )
    toml = via_kwargs.spec_toml()
    via_toml = _puffer.VecEnv.from_toml(toml, 4)
    assert via_toml.spec_toml() == toml
    assert via_toml.spec_json() == via_kwargs.spec_json()
    via_kwargs.close()
    via_toml.close()


def test_serial_batches_arrive_in_env_order():
    """Mirror of vector_semantics.rs serial_is_sync_and_in_order."""
    v = pufferlib.raw_vecenv("classic/cartpole", 4)
    slots = len(v.action_dims())
    v.async_reset(1)
    for _ in range(10):
        rows, _o, _l, _r, _t, _tr, env_ids, _infos = v.recv()
        assert env_ids == list(range(4)), "Serial batches are in env order"
        v.send([0] * (rows * slots))
    v.close()


def test_autoreset_is_same_step():
    """ocean/bandit episodes last one step: every step terminates, the
    returned obs is already the next episode's first observation, and
    episode stats arrive in the same step's infos — Gymnasium's
    same-step autoreset convention, matching the Rust PufferEnv."""
    envs = pufferlib.emulate("ocean/bandit", num_envs=8)
    try:
        mode = envs.metadata.get("autoreset_mode")
        if mode is not None:
            assert mode == gymnasium.vector.AutoresetMode.SAME_STEP
        envs.reset(seed=1)
        for _ in range(5):
            obs, rew, term, trunc, infos = envs.step(np.zeros(8, dtype=np.int64))
            assert term.all() and not trunc.any()
            assert obs.shape == (8, 1)  # next episode's obs, same step
            assert infos["_episode_return"].all()
            np.testing.assert_array_equal(
                infos["episode_return"], rew.astype(np.float64)
            )
            np.testing.assert_array_equal(infos["episode_length"], np.ones(8))
    finally:
        envs.close()


def test_gym_adapter_rejects_pooled_and_multiagent_configs():
    pooled = pufferlib.raw_vecenv("ocean/squared", 4, vec="mt", workers=2, batch=2)
    with pytest.raises(ValueError, match="batch_size"):
        PufferVectorEnv(pooled)
    pooled.close()
    multi = pufferlib.raw_vecenv("ocean/multiagent", 2)
    with pytest.raises(ValueError, match="agents_per_env"):
        PufferVectorEnv(multi)
    multi.close()


def test_step_after_close_raises():
    envs = pufferlib.emulate("ocean/squared", num_envs=2)
    envs.close()
    envs.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        envs.native.async_reset(0)


def _softmax(z):
    z = z - z.max()
    e = np.exp(z)
    return e / e.sum()


def test_cleanrl_style_ppo_clears_random_on_bandit():
    """~100-update PPO loop on ocean/bandit must beat the random policy.

    The bandit pays Bernoulli(0.9) on the best arm and Bernoulli(0.3)
    on the other three, so random play scores 0.45 in expectation; a
    greedy learned policy scores ~0.9. Fully deterministic: the numpy
    rng and the env seeds are fixed.
    """
    num_envs, n_arms = 32, 4
    envs = pufferlib.emulate("ocean/bandit", num_envs=num_envs)
    try:
        rng = np.random.default_rng(0)
        logits = np.zeros(n_arms)
        baseline = 0.45
        envs.reset(seed=0)
        for _ in range(100):
            probs = _softmax(logits)
            actions = rng.choice(n_arms, size=num_envs, p=probs)
            _, rew, _, _, _ = envs.step(actions)
            rew = np.asarray(rew, dtype=np.float64)
            adv = rew - baseline
            baseline = 0.9 * baseline + 0.1 * rew.mean()
            # Single-epoch clipped-surrogate step: at theta_old the PPO
            # gradient reduces to the policy gradient.
            grad = np.zeros(n_arms)
            for a, g in zip(actions, adv):
                grad += g * (np.eye(n_arms)[a] - probs)
            logits += 0.5 * grad / num_envs
        # Greedy evaluation: 20 batches of the argmax arm.
        best = int(np.argmax(logits))
        total = 0.0
        for _ in range(20):
            _, rew, _, _, _ = envs.step(np.full(num_envs, best, dtype=np.int64))
            total += float(np.asarray(rew, dtype=np.float64).mean())
        mean_reward = total / 20
        assert mean_reward > 0.6, (
            f"learned arm {best} scores {mean_reward:.3f}; random is 0.45"
        )
    finally:
        envs.close()


def test_sb3_shim_steps_and_reports_dones():
    pytest.importorskip("stable_baselines3")
    from pufferlib.sb3 import make_sb3_env

    venv = make_sb3_env("ocean/bandit", num_envs=4)
    try:
        obs = venv.reset()
        assert obs.shape == (4, 1)
        venv.step_async(np.zeros(4, dtype=np.int64))
        obs, rew, dones, infos = venv.step_wait()
        assert dones.all()  # one-step episodes
        assert all("episode_return" in info for info in infos)
    finally:
        venv.close()
