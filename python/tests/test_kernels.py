"""L1 correctness: Pallas kernels vs pure-jnp oracles, shape/param sweeps
via hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_mlp import linear_act
from compile.kernels.gae import gae
from compile.kernels.ref import gae_ref, linear_act_ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 140),
    k=st.integers(1, 80),
    n=st.integers(1, 140),
    act=st.sampled_from(["tanh", "relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_act_matches_ref(m, k, n, act, seed):
    x = rand(seed, m, k)
    w = rand(seed + 1, k, n)
    b = rand(seed + 2, n)
    out = linear_act(x, w, b, act)
    ref = linear_act_ref(x, w, b, act)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 48),
    b=st.integers(1, 140),
    gamma=st.floats(0.5, 0.999),
    lam=st.floats(0.0, 1.0),
    done_p=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_gae_matches_ref(t, b, gamma, lam, done_p, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    rewards = jax.random.normal(ks[0], (t, b))
    values = jax.random.normal(ks[1], (t, b))
    dones = (jax.random.uniform(ks[2], (t, b)) < done_p).astype(jnp.float32)
    last_value = jax.random.normal(ks[3], (b,))
    adv, ret = gae(rewards, values, dones, last_value, gamma, lam)
    adv_r, ret_r = gae_ref(rewards, values, dones, last_value, gamma, lam)
    np.testing.assert_allclose(adv, adv_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ret, ret_r, rtol=1e-4, atol=1e-4)


def test_linear_act_gradients_match_ref():
    """The custom VJP must agree with autodiff through the oracle."""
    x = rand(0, 9, 7)
    w = rand(1, 7, 5)
    b = rand(2, 5)
    for act in ["tanh", "relu", "none"]:
        loss_k = lambda x, w, b: jnp.sum(linear_act(x, w, b, act) ** 2)
        loss_r = lambda x, w, b: jnp.sum(linear_act_ref(x, w, b, act) ** 2)
        gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, w, b)
        for a, r in zip(gk, gr):
            np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


def test_gae_terminal_masking():
    """After a done, no reward leaks backward across the boundary."""
    t, b = 4, 1
    rewards = jnp.array([[0.0], [0.0], [0.0], [100.0]])
    values = jnp.zeros((t, b))
    dones = jnp.array([[0.0], [1.0], [0.0], [0.0]])  # episode ends at t=1
    last_value = jnp.zeros((b,))
    adv, _ = gae(rewards, values, dones, last_value, 0.99, 0.95)
    # Steps 0 and 1 belong to the first episode: the +100 at t=3 must not
    # flow into t<=1.
    assert float(adv[0, 0]) == pytest.approx(0.0, abs=1e-5)
    assert float(adv[1, 0]) == pytest.approx(0.0, abs=1e-5)
    assert float(adv[2, 0]) > 50.0


def test_gae_large_batch_tiling():
    """Batch wider than one tile (128) exercises the grid."""
    t, b = 8, 300
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    rewards = jax.random.normal(ks[0], (t, b))
    values = jax.random.normal(ks[1], (t, b))
    dones = jnp.zeros((t, b))
    last_value = jax.random.normal(ks[3], (b,))
    adv, _ = gae(rewards, values, dones, last_value, 0.99, 0.95)
    adv_r, _ = gae_ref(rewards, values, dones, last_value, 0.99, 0.95)
    np.testing.assert_allclose(adv, adv_r, rtol=1e-4, atol=1e-4)
