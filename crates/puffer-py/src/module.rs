//! The CPython skin over [`crate::bridge`]: one `#[pyclass]` (`VecEnv`)
//! plus module metadata, compiled only with `--features python` and
//! imported as `pufferlib._puffer` (maturin names the module; see the
//! repo-root `pyproject.toml`).
//!
//! Deliberately dumb: every method is a forwarder that converts
//! `anyhow::Error` into `RuntimeError` and releases the GIL around
//! blocking vectorizer calls (`recv` parks on worker slabs; holding the
//! GIL there would serialize Python-side threads against env stepping).
//! All numpy construction happens in `python/pufferlib/` from the raw
//! addresses [`bridge::RawBatch`](crate::bridge::RawBatch) reports.

use crate::bridge::NativeVecEnv;
use pyo3::exceptions::PyRuntimeError;
use pyo3::prelude::*;

fn to_py_err(e: anyhow::Error) -> PyErr {
    PyRuntimeError::new_err(format!("{e:#}"))
}

/// The raw vectorized-env handle. `recv` returns slab addresses, not
/// arrays — use `pufferlib.emulate(...)` / `pufferlib.vector` for the
/// numpy/Gymnasium surface.
#[pyclass(module = "pufferlib._puffer")]
pub struct VecEnv {
    inner: NativeVecEnv,
}

type RecvTuple = (
    usize,                                // rows
    usize,                                // obs address
    usize,                                // obs byte length
    usize,                                // rewards address
    usize,                                // terms address
    usize,                                // truncs address
    Vec<usize>,                           // env ids
    Vec<(usize, Vec<(String, f64)>)>,     // infos
);

#[pymethods]
impl VecEnv {
    /// Build from flat dotted `key = value` pairs (the kwargs path).
    #[staticmethod]
    fn from_flat_pairs(pairs: Vec<(String, String)>, num_envs: usize) -> PyResult<Self> {
        Ok(VecEnv {
            inner: NativeVecEnv::from_flat_pairs(&pairs, num_envs).map_err(to_py_err)?,
        })
    }

    /// Build from RunSpec TOML text.
    #[staticmethod]
    fn from_toml(text: &str, num_envs: usize) -> PyResult<Self> {
        Ok(VecEnv {
            inner: NativeVecEnv::from_toml_str(text, num_envs).map_err(to_py_err)?,
        })
    }

    /// Build from RunSpec JSON text (what checkpoints embed).
    #[staticmethod]
    fn from_json(text: &str, num_envs: usize) -> PyResult<Self> {
        Ok(VecEnv {
            inner: NativeVecEnv::from_json_str(text, num_envs).map_err(to_py_err)?,
        })
    }

    fn async_reset(&mut self, py: Python<'_>, seed: u64) -> PyResult<()> {
        let inner = &mut self.inner;
        py.allow_threads(|| inner.async_reset(seed)).map_err(to_py_err)
    }

    /// Blocking receive. Returns `(rows, obs_ptr, obs_len, rew_ptr,
    /// term_ptr, trunc_ptr, env_ids, infos)`; the pointers alias slabs
    /// owned by this object and are valid until the next `recv`/`close`.
    fn recv(&mut self, py: Python<'_>) -> PyResult<RecvTuple> {
        let inner = &mut self.inner;
        let b = py.allow_threads(|| inner.recv()).map_err(to_py_err)?;
        Ok((
            b.rows,
            b.obs_ptr,
            b.obs_len,
            b.rew_ptr,
            b.term_ptr,
            b.trunc_ptr,
            b.env_ids,
            b.infos,
        ))
    }

    /// Send one i32 action slot row per agent row of the last `recv`.
    fn send(&mut self, py: Python<'_>, actions: Vec<i32>) -> PyResult<()> {
        let inner = &mut self.inner;
        py.allow_threads(|| inner.send(&actions)).map_err(to_py_err)
    }

    /// Drop the vectorizer and join its workers. Idempotent.
    fn close(&mut self) {
        self.inner.close();
    }

    #[getter]
    fn num_envs(&self) -> usize {
        self.inner.num_envs()
    }
    #[getter]
    fn agents_per_env(&self) -> usize {
        self.inner.agents_per_env()
    }
    #[getter]
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }
    #[getter]
    fn batch_rows(&self) -> usize {
        self.inner.batch_rows()
    }
    #[getter]
    fn obs_byte_len(&self) -> usize {
        self.inner.obs_byte_len()
    }
    #[getter]
    fn obs_flat_len(&self) -> usize {
        self.inner.obs_flat_len()
    }

    fn action_dims(&self) -> Vec<usize> {
        self.inner.action_dims().to_vec()
    }
    fn layout_json(&self) -> String {
        self.inner.layout_json()
    }
    fn obs_space_json(&self) -> String {
        self.inner.obs_space_json()
    }
    fn act_space_json(&self) -> String {
        self.inner.act_space_json()
    }
    fn spec_toml(&self) -> PyResult<String> {
        self.inner.spec_toml().map_err(to_py_err)
    }
    fn spec_json(&self) -> String {
        self.inner.spec_json()
    }
}

#[pymodule]
fn _puffer(m: &Bound<'_, PyModule>) -> PyResult<()> {
    m.add_class::<VecEnv>()?;
    m.add("__version__", env!("CARGO_PKG_VERSION"))?;
    Ok(())
}
