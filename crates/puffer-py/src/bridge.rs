//! The pure-Rust half of the bindings: spec assembly, vectorizer
//! construction, and the raw step surface the Python package wraps.
//!
//! Everything here is plain data in and plain data out — `Vec`s,
//! `String`s, and slab addresses as `usize` — so the module compiles
//! and unit-tests without pyo3 (the `python` feature only adds the
//! CPython skin). The zero-copy contract lives at this boundary:
//! [`NativeVecEnv::recv`] returns the **addresses** of the batch's
//! obs/reward/term/trunc regions instead of copying them, and the
//! Python side reinterprets those addresses as numpy arrays. The
//! addresses stay valid until the next `recv` on the same object
//! (full-batch vectorizers reuse one persistent buffer, so in practice
//! they are stable for the life of the env — the adapter still re-keys
//! its view cache by address every step, so a vectorizer that rotates
//! buffers is merely slower, never unsound).

// Bindings glue is plain slice/integer plumbing; the crate's unsafe
// surface stays in puffer-core's vector/ (CONCURRENCY.md).
#![forbid(unsafe_code)]

use anyhow::{Context, Result};
use puffer_core::config::FlatConfig;
use puffer_core::runspec::RunSpec;
use puffer_core::spaces::{Space, StructLayout};
use puffer_core::util::json::{arr, num, obj, s, Json};
use puffer_core::vector::VecEnv;
use std::collections::BTreeMap;

/// One received batch, as raw slab geometry. All addresses point into
/// buffers owned by the [`NativeVecEnv`] this came from and are
/// readable for `rows` elements (× the row width for `obs`).
#[derive(Clone, Debug)]
pub struct RawBatch {
    /// Agent rows in the batch (`batch_size × agents_per_env`).
    pub rows: usize,
    /// Address of the packed obs rows (`rows × obs_byte_len` bytes).
    pub obs_ptr: usize,
    /// Total obs byte length (`rows × obs_byte_len`).
    pub obs_len: usize,
    /// Address of the f32 reward row (`rows` elements).
    pub rew_ptr: usize,
    /// Address of the bool termination row (`rows` one-byte elements).
    pub term_ptr: usize,
    /// Address of the bool truncation row (`rows` one-byte elements).
    pub trunc_ptr: usize,
    /// Env indices in row order (`batch_size` entries).
    pub env_ids: Vec<usize>,
    /// Non-empty infos drained this step: `(env_id, key/value pairs)`.
    pub infos: Vec<(usize, Vec<(String, f64)>)>,
}

/// A built vectorizer plus the spec it came from — the object behind
/// the Python `VecEnv` class.
pub struct NativeVecEnv {
    /// `None` after [`close`](Self::close); every step call errors.
    venv: Option<Box<dyn VecEnv>>,
    spec: RunSpec,
    num_envs: usize,
    // Geometry snapshot, kept so describe-side accessors outlive close().
    layout: StructLayout,
    action_dims: Vec<usize>,
    agents_per_env: usize,
    batch_size: usize,
    obs_space: Space,
    act_space: Space,
}

impl NativeVecEnv {
    /// Build from a parsed spec. Probes one env (index 0) for the space
    /// trees, then vectorizes via [`RunSpec::build_venv`].
    pub fn from_spec(spec: RunSpec, num_envs: usize) -> Result<Self> {
        anyhow::ensure!(num_envs > 0, "num_envs must be >= 1");
        let probe = spec.env.build(0);
        let obs_space = probe.observation_space().clone();
        let act_space = probe.action_space().clone();
        drop(probe);
        let venv = spec
            .build_venv(num_envs)
            .with_context(|| format!("vectorizing {num_envs}x {}", spec.env.key()))?;
        Ok(NativeVecEnv {
            layout: venv.obs_layout().clone(),
            action_dims: venv.action_dims().to_vec(),
            agents_per_env: venv.agents_per_env(),
            batch_size: venv.batch_size(),
            venv: Some(venv),
            spec,
            num_envs,
            obs_space,
            act_space,
        })
    }

    /// Build from flat dotted `key = value` pairs (the kwargs path:
    /// `emulate("ocean/squared", num_envs=256, stack=4)` becomes
    /// `[("env.name", "ocean/squared"), ("env.wrap.stack", "4")]`).
    /// Exactly the grammar of the TOML files, so kwargs and specs are
    /// provably equivalent ([`spec_toml`](Self::spec_toml) round-trips).
    pub fn from_flat_pairs(pairs: &[(String, String)], num_envs: usize) -> Result<Self> {
        let mut scalars = FlatConfig::new();
        for (k, v) in pairs {
            anyhow::ensure!(
                scalars.insert(k.clone(), v.clone()).is_none(),
                "duplicate config key '{k}'"
            );
        }
        let spec = RunSpec::from_parts(&scalars, &BTreeMap::new())?;
        Self::from_spec(spec, num_envs)
    }

    /// Build from RunSpec TOML text (the spec-file path).
    pub fn from_toml_str(text: &str, num_envs: usize) -> Result<Self> {
        Self::from_spec(RunSpec::from_toml_str(text)?, num_envs)
    }

    /// Build from RunSpec JSON (what checkpoints embed).
    pub fn from_json_str(text: &str, num_envs: usize) -> Result<Self> {
        Self::from_spec(RunSpec::from_json_str(text)?, num_envs)
    }

    fn venv_mut(&mut self) -> Result<&mut Box<dyn VecEnv>> {
        self.venv.as_mut().context("VecEnv is closed")
    }

    // -- stepping ------------------------------------------------------------

    /// Dispatch resets to every env; the next [`recv`](Self::recv)
    /// delivers reset observations with rewards/flags zeroed.
    pub fn async_reset(&mut self, seed: u64) -> Result<()> {
        self.venv_mut()?.async_reset(seed);
        Ok(())
    }

    /// Block until the next batch is ready and return its slab
    /// geometry (no observation/reward bytes are copied).
    pub fn recv(&mut self) -> Result<RawBatch> {
        let b = self.venv_mut()?.recv()?;
        Ok(RawBatch {
            rows: b.rewards.len(),
            obs_ptr: b.obs.as_ptr() as usize,
            obs_len: b.obs.len(),
            rew_ptr: b.rewards.as_ptr() as usize,
            term_ptr: b.terms.as_ptr() as usize,
            trunc_ptr: b.truncs.as_ptr() as usize,
            env_ids: b.env_ids.to_vec(),
            infos: b
                .infos
                .into_iter()
                .map(|(i, kv)| (i, kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect()))
                .collect(),
        })
    }

    /// Send actions (`batch_rows × action_slots` i32, row order matching
    /// the last `recv`) to those envs.
    pub fn send(&mut self, actions: &[i32]) -> Result<()> {
        self.venv_mut()?.send(actions)
    }

    /// Drop the vectorizer (joins worker threads). Idempotent; stepping
    /// afterwards errors.
    pub fn close(&mut self) {
        self.venv = None;
    }

    // -- geometry ------------------------------------------------------------

    pub fn num_envs(&self) -> usize {
        self.num_envs
    }
    pub fn agents_per_env(&self) -> usize {
        self.agents_per_env
    }
    /// Envs per batch (`N`; `< num_envs` on the EnvPool half-batch path).
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }
    /// Agent rows per batch.
    pub fn batch_rows(&self) -> usize {
        self.batch_size * self.agents_per_env
    }
    /// Packed bytes per observation row.
    pub fn obs_byte_len(&self) -> usize {
        self.layout.byte_len()
    }
    /// Scalars per observation row in the f32 view.
    pub fn obs_flat_len(&self) -> usize {
        self.layout.flat_len()
    }
    /// Per-slot cardinalities of the MultiDiscrete action interface.
    pub fn action_dims(&self) -> &[usize] {
        &self.action_dims
    }

    // -- descriptions --------------------------------------------------------

    /// The packed [`StructLayout`] as JSON — the numpy structured dtype
    /// recipe (field names, dtypes, shapes, byte offsets).
    pub fn layout_json(&self) -> String {
        layout_to_json(&self.layout).dump()
    }

    /// The (wrapped) observation space tree as JSON.
    pub fn obs_space_json(&self) -> String {
        space_to_json(&self.obs_space).dump()
    }

    /// The action space tree as JSON.
    pub fn act_space_json(&self) -> String {
        space_to_json(&self.act_space).dump()
    }

    /// The full spec in canonical TOML — byte-identical for any two
    /// construction paths (kwargs, TOML, JSON) describing the same run.
    pub fn spec_toml(&self) -> Result<String> {
        self.spec.to_toml()
    }

    /// The full spec in canonical JSON (the checkpoint-embedded form).
    pub fn spec_json(&self) -> String {
        self.spec.to_json().dump()
    }
}

/// [`StructLayout`] → JSON: `byte_len`, `flat_len`, and one entry per
/// packed field. Dtype names are the Rust-side `f32`/`u8`/`i32`; the
/// Python adapter maps them to numpy (`<f4`/`|u1`/`<i4` — rows are
/// little-endian by construction).
pub fn layout_to_json(layout: &StructLayout) -> Json {
    let fields = layout
        .fields()
        .iter()
        .map(|f| {
            obj(vec![
                ("name", s(&f.name)),
                ("dtype", s(f.dtype.name())),
                ("shape", arr(f.shape.iter().map(|&d| num(d as f64)).collect())),
                ("count", num(f.count as f64)),
                ("byte_offset", num(f.byte_offset as f64)),
                ("f32_offset", num(f.f32_offset as f64)),
                ("vocab", num(f.vocab as f64)),
                ("token_base", num(f.token_base as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("byte_len", num(layout.byte_len() as f64)),
        ("flat_len", num(layout.flat_len() as f64)),
        ("fields", arr(fields)),
    ])
}

/// [`Space`] → JSON tree. Box bounds travel as strings (`"-inf"`,
/// `"255"`) because JSON numbers cannot express infinities; Python's
/// `float()` parses both spellings.
pub fn space_to_json(space: &Space) -> Json {
    fn bound(v: f32) -> Json {
        s(&if v == f32::INFINITY {
            "inf".to_string()
        } else if v == f32::NEG_INFINITY {
            "-inf".to_string()
        } else {
            format!("{v}")
        })
    }
    match space {
        Space::Discrete(n) => obj(vec![("type", s("discrete")), ("n", num(*n as f64))]),
        Space::MultiDiscrete(nvec) => obj(vec![
            ("type", s("multidiscrete")),
            ("nvec", arr(nvec.iter().map(|&n| num(n as f64)).collect())),
        ]),
        Space::Box {
            dtype,
            shape,
            low,
            high,
        } => obj(vec![
            ("type", s("box")),
            ("dtype", s(dtype.name())),
            ("shape", arr(shape.iter().map(|&d| num(d as f64)).collect())),
            ("low", bound(*low)),
            ("high", bound(*high)),
        ]),
        Space::Tuple(subs) => obj(vec![
            ("type", s("tuple")),
            ("items", arr(subs.iter().map(space_to_json).collect())),
        ]),
        // Entries as an ordered [name, space] list — a JSON object would
        // re-sort keys, and canonical order is load-bearing for offsets.
        Space::Dict(entries) => obj(vec![
            ("type", s("dict")),
            (
                "entries",
                arr(entries
                    .iter()
                    .map(|(k, sub)| arr(vec![s(k), space_to_json(sub)]))
                    .collect()),
            ),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pairs(kv: &[(&str, &str)]) -> Vec<(String, String)> {
        kv.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn builds_and_steps_from_flat_pairs() {
        let mut venv = NativeVecEnv::from_flat_pairs(
            &pairs(&[("env.name", "ocean/squared"), ("vec.mode", "serial")]),
            4,
        )
        .unwrap();
        assert_eq!(venv.num_envs(), 4);
        assert_eq!(venv.batch_rows(), 4);
        venv.async_reset(7).unwrap();
        let b = venv.recv().unwrap();
        assert_eq!(b.rows, 4);
        assert_eq!(b.obs_len, 4 * venv.obs_byte_len());
        assert_eq!(b.env_ids, vec![0, 1, 2, 3]);
        assert_ne!(b.obs_ptr, 0);
        let slots = venv.action_dims().len();
        venv.send(&vec![0i32; 4 * slots]).unwrap();
        // Full-batch vectorizers reuse one persistent buffer: the slab
        // address must be stable across steps — this is what makes the
        // Python side's cached numpy views alias live data.
        let b2 = venv.recv().unwrap();
        assert_eq!(b2.obs_ptr, b.obs_ptr);
        assert_eq!(b2.rew_ptr, b.rew_ptr);
    }

    #[test]
    fn kwargs_and_toml_specs_are_equivalent() {
        let from_kwargs = NativeVecEnv::from_flat_pairs(
            &pairs(&[
                ("env.name", "ocean/squared"),
                ("env.wrap.stack", "2"),
                ("vec.mode", "serial"),
                ("seed", "9"),
            ]),
            2,
        )
        .unwrap();
        let toml = from_kwargs.spec_toml().unwrap();
        let from_toml = NativeVecEnv::from_toml_str(&toml, 2).unwrap();
        assert_eq!(from_toml.spec_toml().unwrap(), toml);
        assert_eq!(from_toml.obs_byte_len(), from_kwargs.obs_byte_len());
        // And through JSON (the checkpoint-embedded form).
        let from_json =
            NativeVecEnv::from_json_str(&from_kwargs.spec_json(), 2).unwrap();
        assert_eq!(from_json.spec_toml().unwrap(), toml);
    }

    #[test]
    fn close_is_idempotent_and_stepping_after_close_errors() {
        let mut venv = NativeVecEnv::from_flat_pairs(
            &pairs(&[("env.name", "ocean/bandit"), ("vec.mode", "serial")]),
            1,
        )
        .unwrap();
        venv.close();
        venv.close();
        assert!(venv.async_reset(0).is_err());
        assert!(venv.recv().is_err());
        // Geometry accessors survive close (describe-only use).
        assert_eq!(venv.num_envs(), 1);
        assert!(venv.obs_byte_len() > 0);
    }

    #[test]
    fn layout_json_names_every_field() {
        let venv = NativeVecEnv::from_flat_pairs(
            &pairs(&[("env.name", "ocean/spaces"), ("vec.mode", "serial")]),
            1,
        )
        .unwrap();
        let j = Json::parse(&venv.layout_json()).unwrap();
        let fields = j.get("fields").as_arr().unwrap();
        assert!(!fields.is_empty());
        let byte_len = j.get("byte_len").as_usize().unwrap();
        assert_eq!(byte_len, venv.obs_byte_len());
        // Fields are packed in order: offsets never decrease.
        let mut prev = 0usize;
        for f in fields {
            let off = f.get("byte_offset").as_usize().unwrap();
            assert!(off >= prev, "field offsets must be packed in order");
            prev = off;
        }
    }

    #[test]
    fn space_json_round_trips_bounds_and_order() {
        let sp = Space::dict(vec![
            ("b".into(), Space::boxf(&[3], f32::NEG_INFINITY, f32::INFINITY)),
            ("a".into(), Space::Discrete(5)),
        ]);
        let j = space_to_json(&sp);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("type").as_str(), Some("dict"));
        let entries = parsed.get("entries").as_arr().unwrap();
        // Canonical (sorted) order preserved as a list.
        assert_eq!(entries[0].at(0).as_str(), Some("a"));
        assert_eq!(entries[1].at(0).as_str(), Some("b"));
        let b = entries[1].at(1);
        assert_eq!(b.get("low").as_str(), Some("-inf"));
        assert_eq!(b.get("high").as_str(), Some("inf"));
    }

    #[test]
    fn multiagent_and_pooled_geometry_is_reported() {
        let venv = NativeVecEnv::from_flat_pairs(
            &pairs(&[("env.name", "ocean/multiagent"), ("vec.mode", "serial")]),
            3,
        )
        .unwrap();
        assert_eq!(venv.agents_per_env(), 2);
        assert_eq!(venv.batch_rows(), 6);
        // Pooled (half-batch) vectorization: batch < num_envs. The
        // Python Gymnasium adapter refuses this shape; the raw class
        // reports it honestly.
        let pooled = NativeVecEnv::from_flat_pairs(
            &pairs(&[
                ("env.name", "ocean/squared"),
                ("vec.mode", "mt"),
                ("vec.workers", "2"),
                ("vec.batch", "half"),
            ]),
            4,
        )
        .unwrap();
        assert_eq!(pooled.batch_size(), 2);
        assert_eq!(pooled.num_envs(), 4);
    }
}
