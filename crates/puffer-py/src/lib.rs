//! `puffer-py` — the Python binding crate: a PyO3 `cdylib` over
//! `puffer-core` that hands CPython **zero-copy numpy views** of the
//! Rust vectorizer slabs (paper §3.2: the shared-memory observation
//! buffers are the product; copying them per step would throw away the
//! vectorization win).
//!
//! ## Architecture
//!
//! - [`bridge`] (always compiled, pure Rust) — assembles a
//!   [`RunSpec`](puffer_core::runspec::RunSpec) from flat kwargs pairs /
//!   TOML / JSON, builds the vectorizer via `RunSpec::build_venv`, and
//!   exposes the recv/send step surface as **raw slab addresses +
//!   lengths** plus JSON descriptions of the packed
//!   [`StructLayout`](puffer_core::spaces::StructLayout) and the
//!   space trees. No `unsafe`: pointers leave as plain integers.
//! - `module` (behind the off-by-default `python` cargo feature) — the
//!   thin `#[pyclass]`/`#[pymodule]` skin (`pufferlib._puffer`) over the
//!   bridge. The numpy side lives in `python/pufferlib/`: it wraps the
//!   addresses with `np.ctypeslib` into arrays that alias the Rust
//!   slabs, caches them keyed by address, and presents the Gymnasium
//!   `VectorEnv` interface CleanRL/SB3 already speak.
//!
//! The feature split keeps the offline workspace build (`cargo build` /
//! `cargo test` from the repo root) free of the pyo3 dependency — the
//! stub build still compiles and unit-tests every line of [`bridge`] —
//! while `maturin build --features python` (see the repo-root
//! `pyproject.toml`) produces the abi3 wheel.
//!
//! This crate depends on `puffer-core` **only**. That is the point of
//! the workspace split: importing `pufferlib` in Python links the
//! spaces/emulation/vector stack and nothing from `puffer-train` (no
//! PPO loop, no kernels, no server).

pub mod bridge;

#[cfg(feature = "python")]
mod module;
