//! Observation/action space algebra and the flat byte layout ("structured
//! array") that powers the emulation layer.
//!
//! This is the Rust analog of the paper's key mechanism (§3.1, §5):
//! emulation *infers a structured-array datatype* from the environment's
//! space, then uses it two ways — as **flat bytes** for vectorization, and
//! with **dict-like accessors** for the model and the environment. A
//! [`Space`] describes the structure; [`StructLayout`] is the inferred
//! datatype: a packed field table mapping every leaf of the space tree to a
//! byte range of a flat row buffer, plus an f32 view used when handing
//! observations to the policy.

// Layout math is bounds-checked slice indexing throughout — no unsafe
// (CONCURRENCY.md — keep the unsafe surface in vector/).
#![forbid(unsafe_code)]

mod layout;
mod value;

pub use layout::{Field, StructLayout};
pub use value::Value;

use crate::util::rng::Rng;

/// Element type of a [`Space::Box`] leaf. Mirrors the numpy dtypes the
/// paper's environments actually use (images are u8, symbolic state i32,
/// continuous features f32).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    U8,
    I32,
}

impl Dtype {
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 => 1,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::U8 => "u8",
            Dtype::I32 => "i32",
        }
    }
}

/// A Gym/Gymnasium-style space tree.
///
/// `Dict` keys are sorted on construction (Gymnasium does the same), which
/// gives the canonical ordering the emulation layer relies on.
#[derive(Clone, Debug, PartialEq)]
pub enum Space {
    /// `n` mutually exclusive choices.
    Discrete(usize),
    /// A vector of independent discrete choices with per-slot cardinality.
    MultiDiscrete(Vec<usize>),
    /// An n-dimensional array of `dtype` elements in `[low, high]`.
    Box {
        dtype: Dtype,
        shape: Vec<usize>,
        low: f32,
        high: f32,
    },
    /// Heterogeneous fixed-length product of subspaces.
    Tuple(Vec<Space>),
    /// Named product of subspaces. Keys are kept sorted.
    Dict(Vec<(String, Space)>),
}

impl Space {
    /// Convenience constructor for an f32 Box.
    pub fn boxf(shape: &[usize], low: f32, high: f32) -> Space {
        Space::Box {
            dtype: Dtype::F32,
            shape: shape.to_vec(),
            low,
            high,
        }
    }

    /// Convenience constructor for a u8 image-style Box.
    pub fn boxu8(shape: &[usize]) -> Space {
        Space::Box {
            dtype: Dtype::U8,
            shape: shape.to_vec(),
            low: 0.0,
            high: 255.0,
        }
    }

    /// Convenience constructor for an i32 Box.
    pub fn boxi32(shape: &[usize], low: f32, high: f32) -> Space {
        Space::Box {
            dtype: Dtype::I32,
            shape: shape.to_vec(),
            low,
            high,
        }
    }

    /// Dict constructor; sorts keys into canonical order.
    pub fn dict(mut entries: Vec<(String, Space)>) -> Space {
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        Space::Dict(entries)
    }

    /// Total number of scalar elements across all leaves.
    pub fn num_elements(&self) -> usize {
        match self {
            Space::Discrete(_) => 1,
            Space::MultiDiscrete(nvec) => nvec.len(),
            Space::Box { shape, .. } => shape.iter().product::<usize>().max(1),
            Space::Tuple(subs) => subs.iter().map(Space::num_elements).sum(),
            Space::Dict(entries) => entries.iter().map(|(_, s)| s.num_elements()).sum(),
        }
    }

    /// Infer the packed structured-array layout for this space.
    pub fn layout(&self) -> StructLayout {
        StructLayout::infer(self)
    }

    /// Draw a uniformly random valid value (used by tests, mocked envs,
    /// and random rollouts).
    pub fn sample(&self, rng: &mut Rng) -> Value {
        match self {
            Space::Discrete(n) => Value::Discrete(rng.below(*n as u64) as i64),
            Space::MultiDiscrete(nvec) => Value::MultiDiscrete(
                nvec.iter().map(|&n| rng.below(n as u64) as i64).collect(),
            ),
            Space::Box {
                dtype,
                shape,
                low,
                high,
            } => {
                let n = shape.iter().product::<usize>().max(1);
                match dtype {
                    Dtype::F32 => {
                        Value::F32((0..n).map(|_| rng.uniform(*low, *high)).collect())
                    }
                    Dtype::U8 => Value::U8(
                        (0..n)
                            .map(|_| rng.range_i64(*low as i64, *high as i64) as u8)
                            .collect(),
                    ),
                    Dtype::I32 => Value::I32(
                        (0..n)
                            .map(|_| rng.range_i64(*low as i64, *high as i64) as i32)
                            .collect(),
                    ),
                }
            }
            Space::Tuple(subs) => Value::Tuple(subs.iter().map(|s| s.sample(rng)).collect()),
            Space::Dict(entries) => Value::Dict(
                entries
                    .iter()
                    .map(|(k, s)| (k.clone(), s.sample(rng)))
                    .collect(),
            ),
        }
    }

    /// Structural + range membership test.
    pub fn contains(&self, v: &Value) -> bool {
        match (self, v) {
            (Space::Discrete(n), Value::Discrete(x)) => *x >= 0 && (*x as usize) < *n,
            (Space::MultiDiscrete(nvec), Value::MultiDiscrete(xs)) => {
                nvec.len() == xs.len()
                    && nvec
                        .iter()
                        .zip(xs)
                        .all(|(&n, &x)| x >= 0 && (x as usize) < n)
            }
            (
                Space::Box {
                    dtype: Dtype::F32,
                    shape,
                    low,
                    high,
                },
                Value::F32(xs),
            ) => {
                xs.len() == shape.iter().product::<usize>().max(1)
                    && xs.iter().all(|x| *x >= *low && *x <= *high && x.is_finite())
            }
            (
                Space::Box {
                    dtype: Dtype::U8,
                    shape,
                    low,
                    high,
                },
                Value::U8(xs),
            ) => {
                xs.len() == shape.iter().product::<usize>().max(1)
                    && xs
                        .iter()
                        .all(|&x| x as f32 >= *low && x as f32 <= *high)
            }
            (
                Space::Box {
                    dtype: Dtype::I32,
                    shape,
                    low,
                    high,
                },
                Value::I32(xs),
            ) => {
                xs.len() == shape.iter().product::<usize>().max(1)
                    && xs
                        .iter()
                        .all(|&x| x as f32 >= *low && x as f32 <= *high)
            }
            (Space::Tuple(subs), Value::Tuple(vs)) => {
                subs.len() == vs.len() && subs.iter().zip(vs).all(|(s, v)| s.contains(v))
            }
            (Space::Dict(entries), Value::Dict(vs)) => {
                entries.len() == vs.len()
                    && entries
                        .iter()
                        .zip(vs)
                        .all(|((k, s), (vk, v))| k == vk && s.contains(v))
            }
            _ => false,
        }
    }

    /// The single-MultiDiscrete emulation of this space when used as an
    /// *action* space: the per-slot cardinalities of every discrete leaf.
    ///
    /// Returns `None` if the space contains a Box leaf (continuous actions;
    /// see [`crate::emulation`] for the continuous extension).
    pub fn action_dims(&self) -> Option<Vec<usize>> {
        let mut dims = Vec::new();
        if self.collect_action_dims(&mut dims) {
            Some(dims)
        } else {
            None
        }
    }

    fn collect_action_dims(&self, dims: &mut Vec<usize>) -> bool {
        match self {
            Space::Discrete(n) => {
                dims.push(*n);
                true
            }
            Space::MultiDiscrete(nvec) => {
                dims.extend_from_slice(nvec);
                true
            }
            Space::Box { .. } => false,
            Space::Tuple(subs) => subs.iter().all(|s| s.collect_action_dims(dims)),
            Space::Dict(entries) => entries.iter().all(|(_, s)| s.collect_action_dims(dims)),
        }
    }

    /// Pack a structured action value into the flat MultiDiscrete encoding.
    /// Inverse of [`Space::unflatten_action`].
    pub fn flatten_action(&self, v: &Value, out: &mut Vec<i32>) {
        match (self, v) {
            (Space::Discrete(_), Value::Discrete(x)) => out.push(*x as i32),
            (Space::MultiDiscrete(_), Value::MultiDiscrete(xs)) => {
                out.extend(xs.iter().map(|&x| x as i32))
            }
            (Space::Tuple(subs), Value::Tuple(vs)) => {
                for (s, v) in subs.iter().zip(vs) {
                    s.flatten_action(v, out);
                }
            }
            (Space::Dict(entries), Value::Dict(vs)) => {
                for ((_, s), (_, v)) in entries.iter().zip(vs) {
                    s.flatten_action(v, out);
                }
            }
            _ => panic!("flatten_action: value does not match space"),
        }
    }

    /// Rebuild the structured action from its flat MultiDiscrete encoding.
    /// This is the "call it in the first line of your env step" inverse.
    pub fn unflatten_action(&self, flat: &[i32]) -> Value {
        let mut pos = 0;
        let v = self.unflatten_action_inner(flat, &mut pos);
        assert_eq!(
            pos,
            flat.len(),
            "unflatten_action: consumed {pos} of {} slots",
            flat.len()
        );
        v
    }

    fn unflatten_action_inner(&self, flat: &[i32], pos: &mut usize) -> Value {
        match self {
            Space::Discrete(_) => {
                let x = flat[*pos];
                *pos += 1;
                Value::Discrete(x as i64)
            }
            Space::MultiDiscrete(nvec) => {
                let xs = flat[*pos..*pos + nvec.len()]
                    .iter()
                    .map(|&x| x as i64)
                    .collect();
                *pos += nvec.len();
                Value::MultiDiscrete(xs)
            }
            Space::Box { .. } => panic!("unflatten_action: Box leaf in action space"),
            Space::Tuple(subs) => Value::Tuple(
                subs.iter()
                    .map(|s| s.unflatten_action_inner(flat, pos))
                    .collect(),
            ),
            Space::Dict(entries) => Value::Dict(
                entries
                    .iter()
                    .map(|(k, s)| (k.clone(), s.unflatten_action_inner(flat, pos)))
                    .collect(),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, CheckConfig};

    fn nethack_like() -> Space {
        Space::dict(vec![
            ("glyphs".into(), Space::boxi32(&[21, 79], 0.0, 5976.0)),
            ("blstats".into(), Space::boxf(&[27], -1e6, 1e6)),
            ("message".into(), Space::boxu8(&[256])),
        ])
    }

    #[test]
    fn dict_keys_sorted() {
        let s = Space::dict(vec![
            ("zeta".into(), Space::Discrete(2)),
            ("alpha".into(), Space::Discrete(3)),
        ]);
        if let Space::Dict(entries) = &s {
            assert_eq!(entries[0].0, "alpha");
            assert_eq!(entries[1].0, "zeta");
        } else {
            unreachable!()
        }
    }

    #[test]
    fn num_elements() {
        assert_eq!(Space::Discrete(5).num_elements(), 1);
        assert_eq!(Space::MultiDiscrete(vec![2, 3, 4]).num_elements(), 3);
        assert_eq!(Space::boxf(&[4, 5], 0.0, 1.0).num_elements(), 20);
        assert_eq!(nethack_like().num_elements(), 21 * 79 + 27 + 256);
    }

    #[test]
    fn sample_is_contained() {
        let spaces = vec![
            Space::Discrete(7),
            Space::MultiDiscrete(vec![2, 5, 9]),
            Space::boxf(&[3, 2], -2.0, 2.0),
            Space::boxu8(&[8]),
            nethack_like(),
            Space::Tuple(vec![Space::Discrete(2), Space::boxf(&[3], 0.0, 1.0)]),
        ];
        let mut rng = Rng::new(11);
        for s in &spaces {
            for _ in 0..20 {
                let v = s.sample(&mut rng);
                assert!(s.contains(&v), "sample not contained in {s:?}");
            }
        }
    }

    #[test]
    fn contains_rejects_mismatches() {
        let s = Space::Discrete(3);
        assert!(!s.contains(&Value::Discrete(3)));
        assert!(!s.contains(&Value::Discrete(-1)));
        assert!(!s.contains(&Value::F32(vec![0.0])));
        let b = Space::boxf(&[2], 0.0, 1.0);
        assert!(!b.contains(&Value::F32(vec![0.5])), "wrong length");
        assert!(!b.contains(&Value::F32(vec![0.5, 2.0])), "out of range");
        assert!(!b.contains(&Value::F32(vec![0.5, f32::NAN])), "nan");
    }

    #[test]
    fn action_dims_flattening() {
        let s = Space::dict(vec![
            ("move".into(), Space::Discrete(5)),
            ("attack".into(), Space::Tuple(vec![
                Space::Discrete(3),
                Space::MultiDiscrete(vec![2, 2]),
            ])),
        ]);
        // canonical (sorted) key order: attack < move
        assert_eq!(s.action_dims(), Some(vec![3, 2, 2, 5]));
        assert_eq!(Space::boxf(&[1], 0.0, 1.0).action_dims(), None);
    }

    #[test]
    fn action_round_trip_property() {
        let s = Space::dict(vec![
            ("a".into(), Space::Discrete(4)),
            ("b".into(), Space::MultiDiscrete(vec![3, 6])),
            (
                "c".into(),
                Space::Tuple(vec![Space::Discrete(2), Space::Discrete(9)]),
            ),
        ]);
        check(
            CheckConfig::default(),
            |rng| s.sample(rng),
            |v| {
                let mut flat = Vec::new();
                s.flatten_action(v, &mut flat);
                let back = s.unflatten_action(&flat);
                if &back == v {
                    Ok(())
                } else {
                    Err(format!("round trip mismatch: {back:?} != {v:?}"))
                }
            },
        );
    }
}
