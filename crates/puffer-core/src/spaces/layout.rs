//! [`StructLayout`]: the inferred structured-array datatype.
//!
//! The paper (§5): *"Emulation works by inferring a numpy structured array
//! datatype from the environment's Gym/Gymnasium observation and action
//! spaces. ... we can use structured arrays as flat bytes, as is required
//! for efficient vectorization, or with dict-like accessors, as is required
//! by the model and the environment."*
//!
//! A [`StructLayout`] is a packed field table: each leaf of the space tree
//! gets a named [`Field`] with a byte range in the flat row and an element
//! range in the f32 view handed to the policy. Flattening writes a
//! structured [`Value`] into a `&mut [u8]` row with zero allocation;
//! unflattening reverses it exactly.

use super::{Dtype, Space, Value};

/// One leaf of the flattened space: a named, typed, contiguous slice of the
/// row buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct Field {
    /// Dotted path into the space tree, e.g. `"obs.inventory.0"`.
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    /// Scalar element count (product of `shape`, min 1).
    pub count: usize,
    /// Byte offset of this field within the packed row.
    pub byte_offset: usize,
    /// Element offset within the f32 view of the row.
    pub f32_offset: usize,
    /// Token vocabulary size when this leaf is categorical (`Discrete`,
    /// `MultiDiscrete`, or an i32 `Box`): the number of distinct values
    /// per slot, which a [`PolicySpec`](crate::policy::arch::PolicySpec)
    /// with `embed_dim > 0` turns into an embedding table. `0` for
    /// continuous/image leaves (f32/u8 boxes).
    pub vocab: usize,
    /// Smallest token value (embedding index = `value - token_base`);
    /// nonzero only for i32 boxes with a shifted range.
    pub token_base: i32,
}

/// Packed layout of a space tree: the structured-array "dtype".
#[derive(Clone, Debug, PartialEq)]
pub struct StructLayout {
    fields: Vec<Field>,
    byte_len: usize,
    flat_len: usize,
}

/// How a leaf kind maps to bytes. Discrete leaves are stored as one i32;
/// MultiDiscrete as i32 per slot. (Matches what a Gym structured dtype
/// would do with int32 catgorical data.) The trailing `(vocab, base)`
/// pair carries the token cardinality for categorical leaves (see
/// [`Field::vocab`]); `(0, 0)` marks continuous/image data.
fn leaf_dtype_count(space: &Space) -> Option<(Dtype, usize, Vec<usize>, usize, i32)> {
    match space {
        Space::Discrete(n) => Some((Dtype::I32, 1, vec![1], *n, 0)),
        Space::MultiDiscrete(nvec) => Some((
            Dtype::I32,
            nvec.len(),
            vec![nvec.len()],
            nvec.iter().copied().max().unwrap_or(0),
            0,
        )),
        Space::Box {
            dtype, shape, low, high, ..
        } => {
            let count = shape.iter().product::<usize>().max(1);
            let (vocab, base) = if *dtype == Dtype::I32 && low.is_finite() && high.is_finite() {
                let lo = *low as i64;
                let hi = *high as i64;
                if hi >= lo {
                    (((hi - lo) as usize).saturating_add(1), lo as i32)
                } else {
                    (0, 0)
                }
            } else {
                (0, 0)
            };
            Some((*dtype, count, shape.clone(), vocab, base))
        }
        _ => None,
    }
}

impl StructLayout {
    /// Infer the packed layout from a space tree (depth-first, Dict keys
    /// already canonically sorted by [`Space::dict`]).
    pub fn infer(space: &Space) -> StructLayout {
        let mut fields = Vec::new();
        let mut byte_off = 0usize;
        let mut f32_off = 0usize;
        Self::walk(space, "", &mut fields, &mut byte_off, &mut f32_off);
        StructLayout {
            fields,
            byte_len: byte_off,
            flat_len: f32_off,
        }
    }

    fn walk(
        space: &Space,
        prefix: &str,
        fields: &mut Vec<Field>,
        byte_off: &mut usize,
        f32_off: &mut usize,
    ) {
        if let Some((dtype, count, shape, vocab, token_base)) = leaf_dtype_count(space) {
            fields.push(Field {
                name: prefix.to_string(),
                dtype,
                shape,
                count,
                byte_offset: *byte_off,
                f32_offset: *f32_off,
                vocab,
                token_base,
            });
            *byte_off += count * dtype.size();
            *f32_off += count;
            return;
        }
        match space {
            Space::Tuple(subs) => {
                for (i, s) in subs.iter().enumerate() {
                    let name = if prefix.is_empty() {
                        i.to_string()
                    } else {
                        format!("{prefix}.{i}")
                    };
                    Self::walk(s, &name, fields, byte_off, f32_off);
                }
            }
            Space::Dict(entries) => {
                for (k, s) in entries {
                    let name = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    Self::walk(s, &name, fields, byte_off, f32_off);
                }
            }
            _ => unreachable!("leaf handled above"),
        }
    }

    pub fn fields(&self) -> &[Field] {
        &self.fields
    }
    /// Packed row size in bytes.
    pub fn byte_len(&self) -> usize {
        self.byte_len
    }
    /// Row length of the f32 view (total scalar count).
    pub fn flat_len(&self) -> usize {
        self.flat_len
    }
    /// Look up a field by dotted path.
    pub fn field(&self, name: &str) -> Option<&Field> {
        self.fields.iter().find(|f| f.name == name)
    }

    /// Flatten a structured value into a packed byte row. The row must be
    /// exactly [`byte_len`](Self::byte_len) bytes. Zero allocation.
    ///
    /// Panics if the value does not structurally match the layout's space —
    /// the emulation wrapper performs a full check on the *first* batch
    /// only (paper §3.1), then trusts the env.
    pub fn write_value(&self, value: &Value, row: &mut [u8]) {
        debug_assert_eq!(row.len(), self.byte_len);
        let mut idx = 0;
        self.write_walk(value, row, &mut idx);
        debug_assert_eq!(idx, self.fields.len(), "value has fewer leaves than layout");
    }

    fn write_walk(&self, value: &Value, row: &mut [u8], idx: &mut usize) {
        match value {
            Value::Discrete(x) => {
                let f = &self.fields[*idx];
                row[f.byte_offset..f.byte_offset + 4].copy_from_slice(&(*x as i32).to_le_bytes());
                *idx += 1;
            }
            Value::MultiDiscrete(xs) => {
                let f = &self.fields[*idx];
                debug_assert_eq!(xs.len(), f.count);
                for (i, &x) in xs.iter().enumerate() {
                    let o = f.byte_offset + 4 * i;
                    row[o..o + 4].copy_from_slice(&(x as i32).to_le_bytes());
                }
                *idx += 1;
            }
            Value::F32(xs) => {
                let f = &self.fields[*idx];
                debug_assert_eq!(xs.len(), f.count);
                // Bulk copy: f32 slice → le bytes.
                let dst = &mut row[f.byte_offset..f.byte_offset + 4 * xs.len()];
                for (chunk, &x) in dst.chunks_exact_mut(4).zip(xs) {
                    chunk.copy_from_slice(&x.to_le_bytes());
                }
                *idx += 1;
            }
            Value::U8(xs) => {
                let f = &self.fields[*idx];
                debug_assert_eq!(xs.len(), f.count);
                row[f.byte_offset..f.byte_offset + xs.len()].copy_from_slice(xs);
                *idx += 1;
            }
            Value::I32(xs) => {
                let f = &self.fields[*idx];
                debug_assert_eq!(xs.len(), f.count);
                let dst = &mut row[f.byte_offset..f.byte_offset + 4 * xs.len()];
                for (chunk, &x) in dst.chunks_exact_mut(4).zip(xs) {
                    chunk.copy_from_slice(&x.to_le_bytes());
                }
                *idx += 1;
            }
            Value::Tuple(vs) => {
                for v in vs {
                    self.write_walk(v, row, idx);
                }
            }
            Value::Dict(entries) => {
                for (_, v) in entries {
                    self.write_walk(v, row, idx);
                }
            }
        }
    }

    /// Rebuild the structured value from a packed byte row given the
    /// original space tree (the layout alone can't distinguish Tuple from
    /// Dict nesting). Exact inverse of [`write_value`](Self::write_value).
    pub fn read_value(&self, space: &Space, row: &[u8]) -> Value {
        debug_assert_eq!(row.len(), self.byte_len);
        let mut idx = 0;
        self.read_walk(space, row, &mut idx)
    }

    fn read_walk(&self, space: &Space, row: &[u8], idx: &mut usize) -> Value {
        match space {
            Space::Discrete(_) => {
                let f = &self.fields[*idx];
                *idx += 1;
                // PANIC: 4-byte slice by construction — try_into::<[u8; 4]> cannot fail.
                let x = i32::from_le_bytes(row[f.byte_offset..f.byte_offset + 4].try_into().unwrap());
                Value::Discrete(x as i64)
            }
            Space::MultiDiscrete(nvec) => {
                let f = &self.fields[*idx];
                *idx += 1;
                let xs = (0..nvec.len())
                    .map(|i| {
                        let o = f.byte_offset + 4 * i;
                        // PANIC: 4-byte slice by construction — try_into::<[u8; 4]> cannot fail.
                        i32::from_le_bytes(row[o..o + 4].try_into().unwrap()) as i64
                    })
                    .collect();
                Value::MultiDiscrete(xs)
            }
            Space::Box { dtype, .. } => {
                let f = &self.fields[*idx];
                *idx += 1;
                match dtype {
                    Dtype::F32 => Value::F32(
                        row[f.byte_offset..f.byte_offset + 4 * f.count]
                            .chunks_exact(4)
                            // PANIC: chunks_exact(4) yields exactly 4-byte chunks.
                            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    ),
                    Dtype::U8 => {
                        Value::U8(row[f.byte_offset..f.byte_offset + f.count].to_vec())
                    }
                    Dtype::I32 => Value::I32(
                        row[f.byte_offset..f.byte_offset + 4 * f.count]
                            .chunks_exact(4)
                            // PANIC: chunks_exact(4) yields exactly 4-byte chunks.
                            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    ),
                }
            }
            Space::Tuple(subs) => {
                Value::Tuple(subs.iter().map(|s| self.read_walk(s, row, idx)).collect())
            }
            Space::Dict(entries) => Value::Dict(
                entries
                    .iter()
                    .map(|(k, s)| (k.clone(), self.read_walk(s, row, idx)))
                    .collect(),
            ),
        }
    }

    /// Convert a packed byte row into its f32 view (`out` must be
    /// [`flat_len`](Self::flat_len) long): f32 fields pass through, u8 and
    /// i32 fields are cast. This is the policy-side representation; the
    /// field table (exported in the AOT manifest) lets the JAX model
    /// unflatten by slicing.
    pub fn row_to_f32(&self, row: &[u8], out: &mut [f32]) {
        debug_assert_eq!(row.len(), self.byte_len);
        debug_assert_eq!(out.len(), self.flat_len);
        for f in &self.fields {
            let dst = &mut out[f.f32_offset..f.f32_offset + f.count];
            match f.dtype {
                Dtype::F32 => {
                    let src = &row[f.byte_offset..f.byte_offset + 4 * f.count];
                    for (o, c) in dst.iter_mut().zip(src.chunks_exact(4)) {
                        // PANIC: chunks_exact(4) yields exactly 4-byte chunks.
                        *o = f32::from_le_bytes(c.try_into().unwrap());
                    }
                }
                Dtype::U8 => {
                    let src = &row[f.byte_offset..f.byte_offset + f.count];
                    for (o, &b) in dst.iter_mut().zip(src) {
                        *o = b as f32;
                    }
                }
                Dtype::I32 => {
                    let src = &row[f.byte_offset..f.byte_offset + 4 * f.count];
                    for (o, c) in dst.iter_mut().zip(src.chunks_exact(4)) {
                        // PANIC: chunks_exact(4) yields exactly 4-byte chunks.
                        *o = i32::from_le_bytes(c.try_into().unwrap()) as f32;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, CheckConfig};
    use crate::util::rng::Rng;

    fn complex_space() -> Space {
        Space::dict(vec![
            ("glyphs".into(), Space::boxi32(&[4, 3], 0.0, 100.0)),
            ("stats".into(), Space::boxf(&[5], -10.0, 10.0)),
            ("msg".into(), Space::boxu8(&[7])),
            (
                "inv".into(),
                Space::Tuple(vec![Space::Discrete(6), Space::MultiDiscrete(vec![2, 3])]),
            ),
        ])
    }

    #[test]
    fn layout_offsets_are_packed_and_ordered() {
        let s = complex_space();
        let l = s.layout();
        // canonical key order: glyphs, inv.0, inv.1, msg, stats
        let names: Vec<&str> = l.fields().iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["glyphs", "inv.0", "inv.1", "msg", "stats"]);
        // packed: each byte_offset = previous end
        let mut expect = 0;
        for f in l.fields() {
            assert_eq!(f.byte_offset, expect);
            expect += f.count * f.dtype.size();
        }
        assert_eq!(l.byte_len(), expect);
        assert_eq!(l.flat_len(), s.num_elements());
    }

    #[test]
    fn token_vocab_metadata_marks_categorical_leaves() {
        let l = complex_space().layout();
        // i32 box 0..=100 → vocab 101; Discrete(6) → 6;
        // MultiDiscrete([2,3]) → max slot cardinality 3; u8/f32 → 0.
        assert_eq!(l.field("glyphs").unwrap().vocab, 101);
        assert_eq!(l.field("glyphs").unwrap().token_base, 0);
        assert_eq!(l.field("inv.0").unwrap().vocab, 6);
        assert_eq!(l.field("inv.1").unwrap().vocab, 3);
        assert_eq!(l.field("msg").unwrap().vocab, 0);
        assert_eq!(l.field("stats").unwrap().vocab, 0);
        // Shifted i32 ranges record their base.
        let shifted = Space::boxi32(&[2], -5.0, 5.0).layout();
        let f = &shifted.fields()[0];
        assert_eq!((f.vocab, f.token_base), (11, -5));
    }

    #[test]
    fn write_read_round_trip_property() {
        let s = complex_space();
        let l = s.layout();
        check(
            CheckConfig::default(),
            |rng| s.sample(rng),
            |v| {
                let mut row = vec![0u8; l.byte_len()];
                l.write_value(v, &mut row);
                let back = l.read_value(&s, &row);
                if &back == v {
                    Ok(())
                } else {
                    Err(format!("{back:?} != {v:?}"))
                }
            },
        );
    }

    #[test]
    fn row_to_f32_casts() {
        let s = Space::dict(vec![
            ("a".into(), Space::boxu8(&[2])),
            ("b".into(), Space::boxf(&[2], -5.0, 5.0)),
            ("c".into(), Space::Discrete(10)),
        ]);
        let l = s.layout();
        let v = Value::Dict(vec![
            ("a".into(), Value::U8(vec![7, 255])),
            ("b".into(), Value::F32(vec![1.5, -2.25])),
            ("c".into(), Value::Discrete(4)),
        ]);
        let mut row = vec![0u8; l.byte_len()];
        l.write_value(&v, &mut row);
        let mut flat = vec![0f32; l.flat_len()];
        l.row_to_f32(&row, &mut flat);
        assert_eq!(flat, vec![7.0, 255.0, 1.5, -2.25, 4.0]);
    }

    #[test]
    fn random_space_round_trip_property() {
        // Generate random space *trees* and check flatten/unflatten on them.
        fn random_space(rng: &mut Rng, depth: usize) -> Space {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Space::Discrete(rng.range_i64(1, 8) as usize),
                1 => Space::MultiDiscrete(
                    (0..rng.range_i64(1, 4)).map(|_| rng.range_i64(1, 5) as usize).collect(),
                ),
                2 => Space::boxf(&[rng.range_i64(1, 6) as usize], -3.0, 3.0),
                3 => Space::boxu8(&[rng.range_i64(1, 6) as usize]),
                4 => Space::Tuple(
                    (0..rng.range_i64(1, 3))
                        .map(|_| random_space(rng, depth - 1))
                        .collect(),
                ),
                _ => Space::dict(
                    (0..rng.range_i64(1, 3))
                        .map(|i| (format!("k{i}"), random_space(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        check(
            CheckConfig { cases: 64, ..Default::default() },
            |rng| {
                let s = random_space(rng, 2);
                let v = s.sample(rng);
                (s, v)
            },
            |(s, v)| {
                let l = s.layout();
                let mut row = vec![0u8; l.byte_len()];
                l.write_value(v, &mut row);
                let back = l.read_value(s, &row);
                if &back == v {
                    Ok(())
                } else {
                    Err(format!("{back:?} != {v:?}"))
                }
            },
        );
    }
}
