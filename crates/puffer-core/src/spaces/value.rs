//! Structured runtime values exchanged with [`StructuredEnv`]s
//! (observations before flattening, actions after unflattening).

/// A structured value matching a [`Space`](super::Space) tree. This is the
/// Rust stand-in for "whatever Python object the environment returns".
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Discrete(i64),
    MultiDiscrete(Vec<i64>),
    F32(Vec<f32>),
    U8(Vec<u8>),
    I32(Vec<i32>),
    Tuple(Vec<Value>),
    Dict(Vec<(String, Value)>),
}

impl Value {
    /// Dict field lookup (linear scan; dicts are small).
    pub fn field(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Dict(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Tuple element access.
    pub fn elem(&self, idx: usize) -> Option<&Value> {
        match self {
            Value::Tuple(vs) => vs.get(idx),
            _ => None,
        }
    }

    pub fn as_f32s(&self) -> Option<&[f32]> {
        match self {
            Value::F32(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_u8s(&self) -> Option<&[u8]> {
        match self {
            Value::U8(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_i32s(&self) -> Option<&[i32]> {
        match self {
            Value::I32(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_discrete(&self) -> Option<i64> {
        match self {
            Value::Discrete(x) => Some(*x),
            _ => None,
        }
    }

    /// Total scalar count of the tree (matches
    /// [`Space::num_elements`](super::Space::num_elements) when the value
    /// matches its space).
    pub fn num_elements(&self) -> usize {
        match self {
            Value::Discrete(_) => 1,
            Value::MultiDiscrete(v) => v.len(),
            Value::F32(v) => v.len(),
            Value::U8(v) => v.len(),
            Value::I32(v) => v.len(),
            Value::Tuple(vs) => vs.iter().map(Value::num_elements).sum(),
            Value::Dict(entries) => entries.iter().map(|(_, v)| v.num_elements()).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = Value::Dict(vec![
            ("a".into(), Value::Discrete(3)),
            ("b".into(), Value::Tuple(vec![Value::F32(vec![1.0, 2.0])])),
        ]);
        assert_eq!(v.field("a").unwrap().as_discrete(), Some(3));
        assert_eq!(
            v.field("b").unwrap().elem(0).unwrap().as_f32s(),
            Some(&[1.0f32, 2.0][..])
        );
        assert!(v.field("zzz").is_none());
        assert_eq!(v.num_elements(), 3);
    }
}
