//! [`VecSpec`] — the declarative vectorization description, the third
//! spec currency alongside [`EnvSpec`](crate::wrappers::EnvSpec) (envs)
//! and [`PolicySpec`](crate::policy::PolicySpec) (models).
//!
//! A `VecSpec` says *how* to simulate, not *how many*: the env count is
//! supplied at build time (the trainer derives it from the backend
//! spec's `batch_roll`), so one spec file drives any env. It replaces
//! direct `Serial::from_spec` / `Multiprocessing::from_spec` calls as
//! the public construction path — [`VecSpec::build`] resolves the spec
//! against an env count into a validated [`VecConfig`] and returns the
//! boxed [`VecEnv`]; the `from_spec` constructors remain as the typed
//! low-level layer underneath.

use super::{Multiprocessing, Serial, VecConfig, VecEnv};
use crate::util::json::{self, Json};
use crate::wrappers::EnvSpec;
use anyhow::{bail, ensure, Result};
use std::fmt;

/// Envs returned per `recv`, relative to the env count resolved at
/// build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VecBatch {
    /// `N == M`: every env each step — the synchronous path.
    Full,
    /// `N == M / 2`: EnvPool double-buffering — `recv` returns the first
    /// half of the envs to finish while the other half simulates.
    Half,
    /// An explicit env count per batch. Must be a multiple of the
    /// resolved envs-per-worker.
    Envs(usize),
}

/// Declarative vectorization: which backend/code path simulates the
/// envs. Plain data — cloneable, comparable, serializable — so it can
/// sit in a [`RunSpec`](crate::runspec::RunSpec) file, a checkpoint, or
/// the autotune cache.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VecSpec {
    /// The single-threaded reference backend.
    Serial,
    /// The multi-worker shared-memory backend ("mt"). `workers` is a
    /// ceiling: like the pre-VecSpec trainer, resolution picks the
    /// largest count `<= workers` that divides the env total (and keeps
    /// the batch claimable at worker granularity), so one spec file
    /// works across envs with different `batch_roll`s.
    Mt {
        workers: usize,
        batch: VecBatch,
        /// Opt into the zero-copy band-rotation path (`Mode::ZeroCopy`)
        /// when the batch spans multiple workers.
        zero_copy: bool,
        /// Busy-wait iterations before yielding the core.
        spin_budget: u32,
    },
    /// Resolve via the autotune benchmark, cached under the run dir —
    /// see [`crate::vector::autotune::resolve_auto`]. Must be resolved
    /// to one of the concrete variants before [`VecSpec::build`].
    Auto,
}

impl Default for VecSpec {
    /// Matches `TrainConfig::default()` (2 workers, sync batch).
    fn default() -> Self {
        VecSpec::mt(2)
    }
}

impl VecSpec {
    /// `Mt` with the default batch (full), no zero-copy, default spin.
    pub fn mt(workers: usize) -> Self {
        VecSpec::Mt {
            workers,
            batch: VecBatch::Full,
            zero_copy: false,
            spin_budget: VecConfig::default().spin_budget,
        }
    }

    /// `Mt` with EnvPool half-batching (`M = 2N`).
    pub fn pooled(workers: usize) -> Self {
        VecSpec::Mt {
            workers,
            batch: VecBatch::Half,
            zero_copy: false,
            spin_budget: VecConfig::default().spin_budget,
        }
    }

    pub fn is_auto(&self) -> bool {
        matches!(self, VecSpec::Auto)
    }

    /// The legacy mapping from the pre-VecSpec `TrainConfig` knobs
    /// (`num_workers`, `pool`): `num_workers == 0` selected the serial
    /// backend, otherwise multiprocessing with a full (or half, when
    /// pooling) batch.
    pub fn from_workers_pool(num_workers: usize, pool: bool) -> Self {
        if num_workers == 0 {
            VecSpec::Serial
        } else if pool {
            VecSpec::pooled(num_workers)
        } else {
            VecSpec::mt(num_workers)
        }
    }

    /// Resolve against a concrete env count into a validated
    /// [`VecConfig`]. `seed` becomes `VecConfig::seed` (derive it from
    /// the run root via [`crate::util::seed::split`]).
    pub fn resolve(&self, num_envs: usize, seed: u64) -> Result<VecConfig> {
        ensure!(num_envs > 0, "vec: cannot vectorize 0 envs");
        let cfg = match self {
            VecSpec::Auto => bail!(
                "vec = \"auto\" must be resolved (via the autotune cache — \
                 RunSpec::build does this) before constructing a vectorizer"
            ),
            VecSpec::Serial => VecConfig {
                num_envs,
                num_workers: 1,
                batch_size: num_envs,
                seed,
                ..Default::default()
            },
            VecSpec::Mt {
                workers,
                batch,
                zero_copy,
                spin_budget,
            } => {
                ensure!(*workers >= 1, "vec.workers must be >= 1 (got {workers})");
                let want_half = matches!(batch, VecBatch::Half);
                let workers = pick_workers(num_envs, *workers, want_half);
                let batch_size = match batch {
                    VecBatch::Full => num_envs,
                    VecBatch::Half => {
                        ensure!(
                            num_envs >= 2 && num_envs % 2 == 0,
                            "vec.batch = \"half\" needs an even env count, got {num_envs}"
                        );
                        num_envs / 2
                    }
                    VecBatch::Envs(n) => *n,
                };
                VecConfig {
                    num_envs,
                    num_workers: workers,
                    batch_size,
                    zero_copy: *zero_copy,
                    spin_budget: *spin_budget,
                    seed,
                }
            }
        };
        // mode() carries the full divisibility story; surface its error
        // under the vec.* namespace so spec files fail actionably.
        cfg.mode()
            .map_err(|e| anyhow::anyhow!("vec spec '{self}' invalid for {num_envs} envs: {e}"))?;
        Ok(cfg)
    }

    /// This spec with `auto` resolved through the autotune cache under
    /// `run_dir` (concrete specs pass through unchanged) — the single
    /// resolution point shared by `Trainer::build` and
    /// `RunSpec::build_venv`, so both paths use the same cache and
    /// benchmark budget.
    pub fn resolved(
        &self,
        env: &EnvSpec,
        num_envs: usize,
        run_dir: Option<&str>,
    ) -> Result<VecSpec> {
        if self.is_auto() {
            super::autotune::resolve_auto(
                env,
                num_envs,
                run_dir,
                super::autotune::AUTO_SECS_PER_CANDIDATE,
            )
        } else {
            Ok(self.clone())
        }
    }

    /// Build the vectorized env — the public construction path that
    /// replaces direct `Serial::from_spec` / `Multiprocessing::from_spec`
    /// calls.
    pub fn build(&self, env: &EnvSpec, num_envs: usize, seed: u64) -> Result<Box<dyn VecEnv>> {
        let cfg = self.resolve(num_envs, seed)?;
        Ok(match self {
            VecSpec::Serial => Box::new(Serial::from_spec(env, cfg)?),
            VecSpec::Mt { .. } => Box::new(Multiprocessing::from_spec(env, cfg)?),
            VecSpec::Auto => unreachable!("resolve() rejects Auto"),
        })
    }

    // -- serialization ------------------------------------------------------

    /// Flat `vec.*` key/value pairs (the RunSpec TOML/override grammar).
    pub fn to_flat_pairs(&self) -> Vec<(&'static str, String)> {
        match self {
            VecSpec::Serial => vec![("mode", "serial".into())],
            VecSpec::Auto => vec![("mode", "auto".into())],
            VecSpec::Mt {
                workers,
                batch,
                zero_copy,
                spin_budget,
            } => vec![
                ("mode", "mt".into()),
                ("workers", workers.to_string()),
                (
                    "batch",
                    match batch {
                        VecBatch::Full => "full".into(),
                        VecBatch::Half => "half".into(),
                        VecBatch::Envs(n) => n.to_string(),
                    },
                ),
                ("zero_copy", zero_copy.to_string()),
                ("spin_budget", spin_budget.to_string()),
            ],
        }
    }

    /// Machine-readable form (what `puffer autotune` emits and the
    /// `vec = "auto"` cache stores).
    pub fn to_json(&self) -> Json {
        json::obj(
            self.to_flat_pairs()
                .into_iter()
                .map(|(k, v)| (k, json::s(&v)))
                .collect(),
        )
    }

    /// Parse the JSON emitted by [`to_json`](Self::to_json). Strict: a
    /// missing `mode` or a wrongly-typed field is an error, never a
    /// silent default — a hand-edited or corrupt autotune cache must
    /// fail loudly, not resolve to an unintended vectorizer.
    pub fn from_json(j: &Json) -> Result<Self> {
        let get = |k: &str| -> Result<Option<String>> {
            match j.get(k) {
                Json::Null => Ok(None),
                v => match v.as_str() {
                    Some(s) => Ok(Some(s.to_string())),
                    None => bail!(
                        "vec spec JSON: field '{k}' must be a string (got {})",
                        v.dump()
                    ),
                },
            }
        };
        let mode = get("mode")?
            .ok_or_else(|| anyhow::anyhow!("vec spec JSON: missing 'mode' field"))?;
        let (w, b, z, s) = (
            get("workers")?,
            get("batch")?,
            get("zero_copy")?,
            get("spin_budget")?,
        );
        Self::from_parts(&mode, w.as_deref(), b.as_deref(), z.as_deref(), s.as_deref())
    }

    /// Assemble from the flat `vec.*` key grammar. Any `None` field
    /// takes the default. Errors name the offending key.
    pub fn from_parts(
        mode: &str,
        workers: Option<&str>,
        batch: Option<&str>,
        zero_copy: Option<&str>,
        spin_budget: Option<&str>,
    ) -> Result<Self> {
        match mode {
            "serial" | "auto" => {
                for (k, v) in [
                    ("vec.workers", workers),
                    ("vec.batch", batch),
                    ("vec.zero_copy", zero_copy),
                    ("vec.spin_budget", spin_budget),
                ] {
                    ensure!(
                        v.is_none(),
                        "config key '{k}': only vec.mode = \"mt\" takes mt knobs \
                         (mode is \"{mode}\")"
                    );
                }
                Ok(if mode == "serial" {
                    VecSpec::Serial
                } else {
                    VecSpec::Auto
                })
            }
            "mt" => {
                let d = match VecSpec::default() {
                    VecSpec::Mt {
                        workers,
                        zero_copy,
                        spin_budget,
                        ..
                    } => (workers, zero_copy, spin_budget),
                    _ => unreachable!(),
                };
                let workers = match workers {
                    None => d.0,
                    Some(v) => match v.parse::<usize>() {
                        Ok(w) if w >= 1 => w,
                        _ => bail!("config key 'vec.workers': expected an integer >= 1, got '{v}'"),
                    },
                };
                let batch = match batch {
                    None | Some("full") => VecBatch::Full,
                    Some("half") => VecBatch::Half,
                    Some(v) => match v.parse::<usize>() {
                        Ok(n) if n >= 1 => VecBatch::Envs(n),
                        _ => bail!(
                            "config key 'vec.batch': expected \"full\", \"half\", or an \
                             integer >= 1, got '{v}'"
                        ),
                    },
                };
                let zero_copy = match zero_copy {
                    None => d.1,
                    Some(v) => v.parse::<bool>().map_err(|_| {
                        anyhow::anyhow!("config key 'vec.zero_copy': cannot parse '{v}' as bool")
                    })?,
                };
                let spin_budget = match spin_budget {
                    None => d.2,
                    Some(v) => v.parse::<u32>().map_err(|_| {
                        anyhow::anyhow!(
                            "config key 'vec.spin_budget': expected a non-negative integer, got '{v}'"
                        )
                    })?,
                };
                Ok(VecSpec::Mt {
                    workers,
                    batch,
                    zero_copy,
                    spin_budget,
                })
            }
            other => bail!(
                "config key 'vec.mode': expected \"serial\", \"mt\", or \"auto\", got '{other}'"
            ),
        }
    }
}

impl fmt::Display for VecSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VecSpec::Serial => write!(f, "serial"),
            VecSpec::Auto => write!(f, "auto"),
            VecSpec::Mt {
                workers,
                batch,
                zero_copy,
                spin_budget,
            } => {
                write!(f, "mt(workers={workers}, batch=")?;
                match batch {
                    VecBatch::Full => write!(f, "full")?,
                    VecBatch::Half => write!(f, "half")?,
                    VecBatch::Envs(n) => write!(f, "{n}")?,
                }
                write!(f, ", zero_copy={zero_copy}, spin_budget={spin_budget})")
            }
        }
    }
}

/// Pick a worker count `<= want` that divides `num_envs` (and keeps the
/// half batch a multiple of envs-per-worker when pooling). This is the
/// adjustment the trainer has always applied, now owned by the spec.
pub(crate) fn pick_workers(num_envs: usize, want: usize, pool: bool) -> usize {
    let mut best = 1;
    for w in 1..=want.min(num_envs) {
        if num_envs % w != 0 {
            continue;
        }
        let epw = num_envs / w;
        if pool && (num_envs / 2) % epw != 0 {
            continue;
        }
        best = w;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_workers_respects_divisibility() {
        assert_eq!(pick_workers(32, 4, false), 4);
        assert_eq!(pick_workers(32, 4, true), 4);
        assert_eq!(pick_workers(30, 4, false), 3);
        assert_eq!(pick_workers(7, 4, false), 1);
        // pool: batch 16, envs 32, w=4 → epw 8, 16 % 8 == 0 ✓
        assert_eq!(pick_workers(32, 3, true), 2);
    }

    #[test]
    fn resolve_matches_the_legacy_trainer_mapping() {
        // num_workers == 0 → serial backend, batch = all.
        let c = VecSpec::from_workers_pool(0, false).resolve(8, 7).unwrap();
        assert_eq!((c.num_workers, c.batch_size, c.seed), (1, 8, 7));
        // num_workers >= 1 → mt sync.
        let c = VecSpec::from_workers_pool(2, false).resolve(8, 7).unwrap();
        assert_eq!((c.num_workers, c.batch_size), (2, 8));
        // pool → half batch.
        let c = VecSpec::from_workers_pool(2, true).resolve(8, 7).unwrap();
        assert_eq!((c.num_workers, c.batch_size), (2, 4));
        // Worker ceiling adjusts downward exactly like pick_workers.
        let c = VecSpec::mt(4).resolve(30, 0).unwrap();
        assert_eq!(c.num_workers, 3);
    }

    #[test]
    fn resolve_validates_and_names_the_namespace() {
        let err = VecSpec::Auto.resolve(8, 0).unwrap_err().to_string();
        assert!(err.contains("auto"), "{err}");
        // Explicit batch that breaks worker granularity.
        let bad = VecSpec::Mt {
            workers: 4,
            batch: VecBatch::Envs(3),
            zero_copy: false,
            spin_budget: 64,
        };
        let err = bad.resolve(8, 0).unwrap_err().to_string();
        assert!(err.contains("vec spec"), "{err}");
        // Half with an odd env count.
        let err = VecSpec::pooled(2).resolve(7, 0).unwrap_err().to_string();
        assert!(err.contains("half"), "{err}");
    }

    #[test]
    fn flat_pairs_round_trip_through_from_parts() {
        let specs = [
            VecSpec::Serial,
            VecSpec::Auto,
            VecSpec::mt(4),
            VecSpec::pooled(8),
            VecSpec::Mt {
                workers: 4,
                batch: VecBatch::Envs(16),
                zero_copy: true,
                spin_budget: 128,
            },
        ];
        for spec in specs {
            let pairs = spec.to_flat_pairs();
            let get = |k: &str| {
                pairs
                    .iter()
                    .find(|(pk, _)| *pk == k)
                    .map(|(_, v)| v.as_str())
            };
            let back = VecSpec::from_parts(
                get("mode").unwrap(),
                get("workers"),
                get("batch"),
                get("zero_copy"),
                get("spin_budget"),
            )
            .unwrap();
            assert_eq!(back, spec);
            // JSON round trip too.
            assert_eq!(VecSpec::from_json(&spec.to_json()).unwrap(), spec);
        }
        // Corrupt cache JSON fails loudly instead of defaulting.
        let bad = Json::parse(r#"{"mode":"mt","workers":8}"#).unwrap();
        let err = VecSpec::from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("workers"), "{err}");
        let empty = Json::parse("{}").unwrap();
        let err = VecSpec::from_json(&empty).unwrap_err().to_string();
        assert!(err.contains("mode"), "{err}");
    }

    #[test]
    fn from_parts_errors_name_the_key() {
        for (args, needle) in [
            (("mt", Some("0"), None, None, None), "vec.workers"),
            (("mt", None, Some("0"), None, None), "vec.batch"),
            (("mt", None, None, Some("maybe"), None), "vec.zero_copy"),
            (("mt", None, None, None, Some("-1")), "vec.spin_budget"),
            (("warp", None, None, None, None), "vec.mode"),
            (("serial", Some("4"), None, None, None), "vec.workers"),
        ] {
            let (mode, w, b, z, s) = args;
            let err = VecSpec::from_parts(mode, w, b, z, s).unwrap_err().to_string();
            assert!(err.contains(needle), "{needle}: {err}");
        }
    }

    #[test]
    fn build_constructs_both_backends() {
        let env = EnvSpec::new("ocean/squared");
        let mut v = VecSpec::Serial.build(&env, 2, 0).unwrap();
        assert_eq!(v.num_envs(), 2);
        v.async_reset(0);
        let b = v.recv().unwrap();
        assert_eq!(b.env_ids.len(), 2);
        let mut v = VecSpec::mt(2).build(&env, 4, 0).unwrap();
        assert_eq!((v.num_envs(), v.batch_size()), (4, 4));
        v.async_reset(0);
        let _ = v.recv().unwrap();
    }
}
