//! Shared-memory slabs and the per-worker signaling flags.
//!
//! The multiprocessing backend exchanges *all* per-step data
//! (observations, rewards, terminals, truncateds, actions) through large
//! preallocated shared arrays, and signals readiness through per-worker
//! atomic flags that both sides busy-wait on — the paper's "shared memory
//! for data communication" + "shared flags for signaling" design, which
//! reduces steady-state inter-process communication to zero. Only infos
//! travel over a channel (the paper's pipes), and only when non-empty.
//!
//! ## Safety protocol
//!
//! Each worker owns a disjoint region of every slab. Region access
//! alternates strictly between leader and worker, mediated by that
//! worker's [`Flag`]:
//!
//! ```text
//!   leader writes actions ──Release──▶ ACTIONS_READY
//!   worker Acquire-loads, steps envs, writes obs/rew/term/trunc
//!          ──CAS(ACTIONS_READY → OBS_READY)──▶ OBS_READY
//!   leader Acquire-loads, reads results, (claims), writes next actions…
//! ```
//!
//! The Release/Acquire pair on the flag makes every slab write by one side
//! visible to the other before it touches the region, so the raw slices
//! handed out by [`Slab`] are never accessed concurrently. The worker's
//! step-completion edge is a *compare-exchange* ([`Flag::complete`]), not
//! a plain store: the leader may asynchronously store [`SHUTDOWN`] while
//! the worker is mid-step, and a blind `store(OBS_READY)` would overwrite
//! it — the worker would then park in [`Flag::wait`] on a signal that
//! will never be re-sent. The CAS loses that race detectably instead
//! (pinned by `tests/loom_models.rs::shutdown_is_never_lost`).
//!
//! ## Aliasing sentinel
//!
//! In debug builds (and under the `slab-sentinel` cargo feature) every
//! slab additionally tracks its outstanding windows and panics the
//! moment two overlap illegally — a dynamic double-check that the flag
//! protocol really did serialize region access. Mutable access is an
//! RAII [`SlabWindow`] guard; long-lived shared borrows that outlive a
//! call (the leader holding a [`StepBatch`](crate::vector::StepBatch)
//! view from `recv` until the next `send`) are registered with
//! [`Slab::hold`] / [`Slab::release`]. Release builds compile all of it
//! to nothing. See `CONCURRENCY.md`.

use std::cell::UnsafeCell;
use std::ops::{Deref, DerefMut};

use crate::sync::atomic::{AtomicU32, Ordering};
use crate::sync::Arc;

/// Worker flag states.
pub const IDLE: u32 = 0;
/// Leader → worker: actions for your envs are in the action slab; step.
pub const ACTIONS_READY: u32 = 1;
/// Worker → leader: observations/rewards/terms are in the slabs.
pub const OBS_READY: u32 = 2;
/// Leader → worker: reset all your envs (seed published with this store).
pub const RESET: u32 = 3;
/// Leader has taken this worker's OBS_READY output (pool bookkeeping).
pub const CLAIMED: u32 = 4;
/// Leader → worker: exit.
pub const SHUTDOWN: u32 = 5;
/// Worker → leader: an env panicked; the backend is dead.
pub const POISONED: u32 = 6;

/// Outstanding-window bookkeeping for one slab. Real in debug / with the
/// `slab-sentinel` feature; a zero-cost stub otherwise (and under loom,
/// where the slab protocol is modeled with loom's own access-checking
/// cells instead).
mod sentinel {
    #[cfg(all(not(loom), any(debug_assertions, feature = "slab-sentinel")))]
    pub(super) struct Tracker {
        // A plain std mutex on purpose: the sentinel is debug
        // instrumentation *about* the flag protocol, not part of it, so
        // it must not route through the crate::sync facade and perturb
        // the loom-modeled state space.
        ranges: std::sync::Mutex<Ranges>,
    }

    #[cfg(all(not(loom), any(debug_assertions, feature = "slab-sentinel")))]
    #[derive(Default)]
    struct Ranges {
        /// Live exclusive windows (from [`super::Slab::slice_mut`]).
        excl: Vec<(usize, usize)>,
        /// Registered long-lived shared holds (from [`super::Slab::hold`]).
        shared: Vec<(usize, usize)>,
    }

    #[cfg(all(not(loom), any(debug_assertions, feature = "slab-sentinel")))]
    fn overlaps(a: (usize, usize), b: (usize, usize)) -> bool {
        a.1 != 0 && b.1 != 0 && a.0 < b.0 + b.1 && b.0 < a.0 + a.1
    }

    #[cfg(all(not(loom), any(debug_assertions, feature = "slab-sentinel")))]
    impl Tracker {
        pub(super) fn new() -> Self {
            Tracker {
                ranges: std::sync::Mutex::new(Ranges::default()),
            }
        }

        fn lock(&self) -> std::sync::MutexGuard<'_, Ranges> {
            // The sentinel stays usable while a panic (possibly one it
            // raised itself) unwinds through another thread.
            self.ranges
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
        }

        pub(super) fn acquire_excl(&self, start: usize, len: usize) {
            let mut r = self.lock();
            if let Some(&(s, l)) = r.excl.iter().find(|&&w| overlaps(w, (start, len))) {
                panic!(
                    "slab sentinel: exclusive window {start}..{} overlaps live exclusive window {s}..{} — flag-protocol violation",
                    start + len,
                    s + l
                );
            }
            if let Some(&(s, l)) = r.shared.iter().find(|&&w| overlaps(w, (start, len))) {
                panic!(
                    "slab sentinel: exclusive window {start}..{} overlaps held shared window {s}..{} — flag-protocol violation",
                    start + len,
                    s + l
                );
            }
            r.excl.push((start, len));
        }

        pub(super) fn release_excl(&self, start: usize, len: usize) {
            let mut r = self.lock();
            match r.excl.iter().position(|&w| w == (start, len)) {
                Some(i) => {
                    r.excl.swap_remove(i);
                }
                // A guard is only constructed after a successful push,
                // so a miss means sentinel-internal corruption. Don't
                // compound a panic already unwinding through the guard's
                // drop with a second one (that would abort).
                None if std::thread::panicking() => {}
                None => panic!(
                    "slab sentinel: released exclusive window {start}..{} that was never acquired",
                    start + len
                ),
            }
        }

        pub(super) fn check_shared(&self, start: usize, len: usize) {
            let r = self.lock();
            if let Some(&(s, l)) = r.excl.iter().find(|&&w| overlaps(w, (start, len))) {
                panic!(
                    "slab sentinel: shared read {start}..{} overlaps live exclusive window {s}..{} — flag-protocol violation",
                    start + len,
                    s + l
                );
            }
        }

        pub(super) fn hold_shared(&self, start: usize, len: usize) {
            let mut r = self.lock();
            if let Some(&(s, l)) = r.excl.iter().find(|&&w| overlaps(w, (start, len))) {
                panic!(
                    "slab sentinel: shared hold {start}..{} overlaps live exclusive window {s}..{} — flag-protocol violation",
                    start + len,
                    s + l
                );
            }
            r.shared.push((start, len));
        }

        pub(super) fn release_shared(&self, start: usize, len: usize) {
            let mut r = self.lock();
            match r.shared.iter().position(|&w| w == (start, len)) {
                Some(i) => {
                    r.shared.swap_remove(i);
                }
                None if std::thread::panicking() => {}
                None => panic!(
                    "slab sentinel: released shared hold {start}..{} that was never registered",
                    start + len
                ),
            }
        }
    }

    #[cfg(not(all(not(loom), any(debug_assertions, feature = "slab-sentinel"))))]
    pub(super) struct Tracker;

    #[cfg(not(all(not(loom), any(debug_assertions, feature = "slab-sentinel"))))]
    impl Tracker {
        #[inline(always)]
        pub(super) fn new() -> Self {
            Tracker
        }
        #[inline(always)]
        pub(super) fn acquire_excl(&self, _start: usize, _len: usize) {}
        #[inline(always)]
        pub(super) fn release_excl(&self, _start: usize, _len: usize) {}
        #[inline(always)]
        pub(super) fn check_shared(&self, _start: usize, _len: usize) {}
        #[inline(always)]
        pub(super) fn hold_shared(&self, _start: usize, _len: usize) {}
        #[inline(always)]
        pub(super) fn release_shared(&self, _start: usize, _len: usize) {}
    }
}

/// A fixed-size shared array of `T` carved into per-worker regions.
///
/// Interior mutability + manual synchronization: see the module docs for
/// the flag protocol that makes region access exclusive, and the
/// aliasing sentinel that dynamically enforces it in debug builds.
pub struct Slab<T> {
    data: Box<[UnsafeCell<T>]>,
    tracker: sentinel::Tracker,
}

// SAFETY: sending a Slab<T> between threads moves the boxed cells, whose
// contents are plain `T: Send` values; the tracker's interior mutex is
// itself Send.
unsafe impl<T: Send> Send for Slab<T> {}
// SAFETY: concurrent `&Slab` access is sound because every data access
// goes through slice/slice_mut, whose contract (enforced by the Flag
// Release/Acquire protocol, double-checked by the sentinel) serializes
// access per region; `T: Send` suffices since only one thread touches a
// region at a time — no `&T` is ever shared across threads.
unsafe impl<T: Send> Sync for Slab<T> {}

impl<T: Copy + Default> Slab<T> {
    pub fn new(len: usize) -> Arc<Self> {
        let data: Box<[UnsafeCell<T>]> = (0..len).map(|_| UnsafeCell::new(T::default())).collect();
        Arc::new(Slab {
            data,
            tracker: sentinel::Tracker::new(),
        })
    }

    #[allow(dead_code)]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Borrow a region immutably.
    ///
    /// # Safety
    /// The caller must hold the flag state that grants it the region, and
    /// the range must stay within its region. If the returned slice is
    /// kept alive past this synchronization window (the leader's
    /// `StepBatch` views), the caller must bracket it with
    /// [`hold`](Self::hold) / [`release`](Self::release) so the sentinel
    /// can see the borrow.
    #[inline]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &[T] {
        debug_assert!(start + len <= self.data.len());
        self.tracker.check_shared(start, len);
        // SAFETY: the caller holds the flag state granting (at least
        // shared) access to [start, start+len), which is in bounds per
        // the debug_assert and the region arithmetic of the callers;
        // UnsafeCell<T> is layout-identical to T, so the cell pointer
        // reads as T.
        unsafe { std::slice::from_raw_parts(self.data.as_ptr().add(start) as *const T, len) }
    }

    /// Borrow a region mutably. The returned guard releases the
    /// sentinel's exclusive claim on drop — drop it **before** the flag
    /// store that hands the region to the other side.
    ///
    /// # Safety
    /// As [`slice`](Self::slice), plus exclusivity: no other live
    /// reference to the range (guaranteed by the flag protocol).
    #[inline]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> SlabWindow<'_, T> {
        debug_assert!(start + len <= self.data.len());
        // Panics on an illegal overlap *before* the aliasing &mut exists.
        self.tracker.acquire_excl(start, len);
        // SAFETY: the caller guarantees exclusive ownership of
        // [start, start+len) per the flag protocol (no concurrent reader
        // or writer until the next Release store), the range is in
        // bounds, and UnsafeCell<T> is layout-identical to T.
        let data =
            unsafe { std::slice::from_raw_parts_mut(self.data.as_ptr().add(start) as *mut T, len) };
        SlabWindow {
            data,
            tracker: &self.tracker,
            start,
        }
    }

    /// Register a long-lived shared borrow with the sentinel (no-op in
    /// release builds). Call after [`slice`](Self::slice) when the slice
    /// outlives the call — e.g. the leader keeping `StepBatch` views
    /// from `recv` until the next `send`.
    #[inline]
    pub fn hold(&self, start: usize, len: usize) {
        self.tracker.hold_shared(start, len);
    }

    /// Release a borrow registered with [`hold`](Self::hold). Must
    /// happen before the region is handed back to its worker.
    #[inline]
    pub fn release(&self, start: usize, len: usize) {
        self.tracker.release_shared(start, len);
    }
}

/// RAII mutable window into a [`Slab`] region. Dereferences to
/// `[T]`; dropping it ends the sentinel's exclusive claim.
pub struct SlabWindow<'a, T> {
    data: &'a mut [T],
    tracker: &'a sentinel::Tracker,
    start: usize,
}

impl<T> Deref for SlabWindow<'_, T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        self.data
    }
}

impl<T> DerefMut for SlabWindow<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        self.data
    }
}

impl<T> Drop for SlabWindow<'_, T> {
    #[inline]
    fn drop(&mut self) {
        self.tracker.release_excl(self.start, self.data.len());
    }
}

/// One worker's signaling flag.
pub struct Flag {
    state: AtomicU32,
}

impl Flag {
    pub fn new() -> Self {
        Flag {
            state: AtomicU32::new(IDLE),
        }
    }

    #[inline]
    pub fn load(&self) -> u32 {
        // ordering: Acquire pairs with the Release store/CAS of whichever
        // side published the state, making that side's slab writes
        // visible before the observer touches the region.
        self.state.load(Ordering::Acquire)
    }

    #[inline]
    pub fn store(&self, v: u32) {
        // ordering: Release publishes every slab write sequenced before
        // this store to the Acquire load on the other side. Leader-only
        // for ACTIONS_READY/RESET/SHUTDOWN; workers must use
        // `complete`/POISONED paths so they can never overwrite a
        // concurrent SHUTDOWN.
        self.state.store(v, Ordering::Release);
    }

    /// CAS used by the pool leader to claim an OBS_READY worker exactly
    /// once.
    #[inline]
    pub fn try_claim(&self) -> bool {
        // ordering: AcqRel on success — Acquire to see the worker's slab
        // writes behind its OBS_READY edge, Release so pool bookkeeping
        // sequenced before the claim is visible if anyone chains on
        // CLAIMED; Acquire on failure because the loaded state may still
        // be acted on (e.g. observing POISONED).
        self.state
            .compare_exchange(OBS_READY, CLAIMED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Worker-side step-completion edge: `from` → OBS_READY, but only if
    /// the leader didn't change the state (to SHUTDOWN) while the worker
    /// was stepping. Returns `false` when preempted — the caller must
    /// honor the new state (exit) instead of publishing results, because
    /// a blind `store(OBS_READY)` here would erase the shutdown signal
    /// and strand the worker in its next wait
    /// (`tests/loom_models.rs::shutdown_is_never_lost`).
    #[inline]
    pub fn complete(&self, from: u32) -> bool {
        // ordering: AcqRel on success — Release publishes the worker's
        // freshly written obs/rew/term/trunc regions to the leader's
        // Acquire load/claim; Acquire on both paths so on failure the
        // worker synchronizes with the leader's SHUTDOWN store before
        // tearing down its envs.
        self.state
            .compare_exchange(from, OBS_READY, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Busy-wait until the flag matches `pred`, spinning `spin_budget`
    /// iterations between yields. Returns the matched state.
    #[inline]
    pub fn wait(&self, spin_budget: u32, pred: impl Fn(u32) -> bool) -> u32 {
        loop {
            for _ in 0..spin_budget.max(1) {
                let s = self.load();
                if pred(s) {
                    return s;
                }
                crate::sync::spin_loop_hint();
            }
            // Oversubscribed or long step: give the core away. On the
            // paper's many-core desktop this branch is cold; on small
            // hosts it is what keeps busy-wait from starving the workers.
            crate::sync::yield_now();
        }
    }
}

impl Default for Flag {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn slab_regions_round_trip() {
        let slab = Slab::<f32>::new(8);
        unsafe {
            slab.slice_mut(2, 3).copy_from_slice(&[1.0, 2.0, 3.0]);
            assert_eq!(slab.slice(2, 3), &[1.0, 2.0, 3.0]);
            assert_eq!(slab.slice(0, 2), &[0.0, 0.0]);
        }
    }

    #[test]
    fn flag_claim_is_exclusive() {
        let f = Flag::new();
        f.store(OBS_READY);
        assert!(f.try_claim());
        assert!(!f.try_claim(), "double claim must fail");
        assert_eq!(f.load(), CLAIMED);
    }

    #[test]
    fn flag_complete_takes_the_expected_edge() {
        let f = Flag::new();
        f.store(ACTIONS_READY);
        assert!(f.complete(ACTIONS_READY));
        assert_eq!(f.load(), OBS_READY);
    }

    #[test]
    fn flag_complete_loses_to_a_concurrent_shutdown() {
        let f = Flag::new();
        f.store(ACTIONS_READY);
        // Leader preempts the worker mid-step.
        f.store(SHUTDOWN);
        assert!(!f.complete(ACTIONS_READY), "must not erase SHUTDOWN");
        assert_eq!(f.load(), SHUTDOWN, "shutdown signal survives");
    }

    #[test]
    fn flag_protocol_passes_data_across_threads() {
        let slab = Slab::<u32>::new(4);
        let flag = Arc::new(Flag::new());
        let (s2, f2) = (slab.clone(), flag.clone());
        let t = thread::spawn(move || {
            f2.wait(16, |s| s == ACTIONS_READY);
            let val = unsafe { s2.slice(0, 1) }[0];
            unsafe {
                s2.slice_mut(1, 1)[0] = val * 2;
            }
            assert!(f2.complete(ACTIONS_READY));
        });
        unsafe {
            slab.slice_mut(0, 1)[0] = 21;
        }
        flag.store(ACTIONS_READY);
        flag.wait(16, |s| s == OBS_READY);
        assert_eq!(unsafe { slab.slice(1, 1) }[0], 42);
        t.join().unwrap();
    }

    #[test]
    fn wait_matches_any_predicate() {
        let f = Flag::new();
        f.store(SHUTDOWN);
        let s = f.wait(4, |s| s == ACTIONS_READY || s == SHUTDOWN);
        assert_eq!(s, SHUTDOWN);
    }

    #[cfg(all(not(loom), any(debug_assertions, feature = "slab-sentinel")))]
    mod sentinel {
        use super::*;

        #[test]
        fn disjoint_mut_windows_coexist() {
            let slab = Slab::<u8>::new(8);
            let (mut a, mut b) = unsafe { (slab.slice_mut(0, 4), slab.slice_mut(4, 4)) };
            a[0] = 1;
            b[0] = 2;
            drop(a);
            drop(b);
            assert_eq!(unsafe { slab.slice(0, 8) }, &[1, 0, 0, 0, 2, 0, 0, 0]);
        }

        #[test]
        #[should_panic(expected = "overlaps live exclusive window")]
        fn overlapping_mut_windows_panic() {
            let slab = Slab::<u8>::new(8);
            let _a = unsafe { slab.slice_mut(0, 4) };
            let _b = unsafe { slab.slice_mut(2, 2) };
        }

        #[test]
        #[should_panic(expected = "overlaps live exclusive window")]
        fn shared_read_under_mut_window_panics() {
            let slab = Slab::<u8>::new(8);
            let _a = unsafe { slab.slice_mut(0, 4) };
            let _r = unsafe { slab.slice(3, 1) };
        }

        #[test]
        #[should_panic(expected = "overlaps held shared window")]
        fn mut_window_over_held_region_panics() {
            let slab = Slab::<u8>::new(8);
            let _r = unsafe { slab.slice(0, 2) };
            slab.hold(0, 2);
            let _w = unsafe { slab.slice_mut(1, 1) };
        }

        #[test]
        fn release_reopens_the_region() {
            let slab = Slab::<u8>::new(8);
            slab.hold(0, 2);
            slab.release(0, 2);
            unsafe { slab.slice_mut(0, 2) }[0] = 9;
            assert_eq!(unsafe { slab.slice(0, 1) }, &[9]);
        }

        #[test]
        fn dropping_a_window_reopens_the_region() {
            let slab = Slab::<u8>::new(8);
            {
                let mut w = unsafe { slab.slice_mut(0, 4) };
                w[1] = 7;
            }
            // Same range again: the drop above must have released it.
            assert_eq!(unsafe { slab.slice_mut(0, 4) }[1], 7);
        }
    }
}
