//! The Gymnasium `AsyncVectorEnv` design: one env per worker, command/
//! reply channels, wait for **all** envs every step, and "structured"
//! shared-memory writes — each observation field copied separately into
//! the batch (the multiple-small-copies path the paper calls out), plus
//! Python-side per-message buffer churn (fresh allocations per reply).

use super::{Cmd, Reply};
use crate::emulation::{FlatEnv, Info};
use crate::spaces::StructLayout;
use crate::vector::{probe_factory, EnvFactory, StepBatch, VecConfig, VecEnv};
use anyhow::Result;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Gymnasium-style synchronous vectorization over worker threads.
pub struct GymnasiumVec {
    layout: StructLayout,
    action_dims: Vec<usize>,
    agents: usize,
    num_envs: usize,
    cmd_tx: Vec<mpsc::Sender<Cmd>>,
    reply_rx: mpsc::Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,

    obs: Vec<u8>,
    rewards: Vec<f32>,
    terms: Vec<bool>,
    truncs: Vec<bool>,
    env_ids: Vec<usize>,
    infos: Vec<(usize, Info)>,
    outstanding: usize,
}

impl GymnasiumVec {
    pub fn new(
        factory: impl Fn(usize) -> Box<dyn FlatEnv> + Send + Sync + 'static,
        cfg: VecConfig,
    ) -> Result<Self> {
        anyhow::ensure!(
            cfg.batch_size == cfg.num_envs,
            "Gymnasium vectorization has no pool support: batch_size must equal num_envs"
        );
        let factory: EnvFactory = Box::new(factory);
        let (layout, action_dims, agents) = probe_factory(&factory);
        let factory = std::sync::Arc::new(factory);
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        let mut cmd_tx = Vec::new();
        let mut handles = Vec::new();
        for env_id in 0..cfg.num_envs {
            let (tx, rx) = mpsc::channel::<Cmd>();
            cmd_tx.push(tx);
            let reply_tx = reply_tx.clone();
            let factory = factory.clone();
            let w = layout.byte_len();
            handles.push(std::thread::spawn(move || {
                // One env per worker: the design both libraries use.
                let mut env = factory(env_id);
                let rows = env.num_agents();
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Close => return,
                        Cmd::Reset(seed) => {
                            let mut obs = vec![0u8; rows * w];
                            let info = env.reset(seed + env_id as u64, &mut obs);
                            let _ = reply_tx.send(Reply {
                                env_id,
                                obs,
                                rewards: vec![0.0; rows],
                                terms: vec![false; rows],
                                truncs: vec![false; rows],
                                info,
                            });
                        }
                        Cmd::Step(actions) => {
                            // Fresh allocations per message: the pickling
                            // analog.
                            let mut obs = vec![0u8; rows * w];
                            let mut rewards = vec![0.0; rows];
                            let mut terms = vec![false; rows];
                            let mut truncs = vec![false; rows];
                            let info =
                                env.step(&actions, &mut obs, &mut rewards, &mut terms, &mut truncs);
                            let _ = reply_tx.send(Reply {
                                env_id,
                                obs,
                                rewards,
                                terms,
                                truncs,
                                info,
                            });
                        }
                    }
                }
            }));
        }
        let rows = cfg.num_envs * agents;
        let w = layout.byte_len();
        Ok(GymnasiumVec {
            layout,
            action_dims,
            agents,
            num_envs: cfg.num_envs,
            cmd_tx,
            reply_rx,
            handles,
            obs: vec![0; rows * w],
            rewards: vec![0.0; rows],
            terms: vec![false; rows],
            truncs: vec![false; rows],
            env_ids: (0..cfg.num_envs).collect(),
            infos: Vec::new(),
            outstanding: 0,
        })
    }

    /// Copy a reply into the batch buffers, field by field — Gymnasium's
    /// structured shared-memory discipline (one small copy per leaf field
    /// per env rather than one row copy).
    fn place(&mut self, r: Reply) {
        let w = self.layout.byte_len();
        let rows = self.agents;
        let base_row = r.env_id * rows;
        for row in 0..rows {
            let src = &r.obs[row * w..(row + 1) * w];
            let dst_off = (base_row + row) * w;
            for f in self.layout.fields() {
                let nbytes = f.count * f.dtype.size();
                self.obs[dst_off + f.byte_offset..dst_off + f.byte_offset + nbytes]
                    .copy_from_slice(&src[f.byte_offset..f.byte_offset + nbytes]);
            }
        }
        self.rewards[base_row..base_row + rows].copy_from_slice(&r.rewards);
        self.terms[base_row..base_row + rows].copy_from_slice(&r.terms);
        self.truncs[base_row..base_row + rows].copy_from_slice(&r.truncs);
        if !r.info.is_empty() {
            self.infos.push((r.env_id, r.info));
        }
    }
}

impl VecEnv for GymnasiumVec {
    fn obs_layout(&self) -> &StructLayout {
        &self.layout
    }
    fn action_dims(&self) -> &[usize] {
        &self.action_dims
    }
    fn agents_per_env(&self) -> usize {
        self.agents
    }
    fn num_envs(&self) -> usize {
        self.num_envs
    }
    fn batch_size(&self) -> usize {
        self.num_envs
    }

    fn async_reset(&mut self, seed: u64) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Reset(seed));
        }
        self.outstanding = self.num_envs;
    }

    fn recv(&mut self) -> Result<StepBatch<'_>> {
        anyhow::ensure!(self.outstanding > 0, "recv without outstanding work");
        // Wait for ALL envs — the design's defining (and costly) property.
        for _ in 0..self.outstanding {
            let r = self
                .reply_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("baseline worker died"))?;
            self.place(r);
        }
        self.outstanding = 0;
        Ok(StepBatch {
            env_ids: &self.env_ids,
            obs: &self.obs,
            rewards: &self.rewards,
            terms: &self.terms,
            truncs: &self.truncs,
            infos: std::mem::take(&mut self.infos),
        })
    }

    fn send(&mut self, actions: &[i32]) -> Result<()> {
        let slots = self.action_dims.len();
        let rows = self.agents;
        anyhow::ensure!(
            actions.len() == self.num_envs * rows * slots,
            "bad action length"
        );
        for (env_id, tx) in self.cmd_tx.iter().enumerate() {
            let a = actions[env_id * rows * slots..(env_id + 1) * rows * slots].to_vec();
            let _ = tx.send(Cmd::Step(a));
        }
        self.outstanding = self.num_envs;
        Ok(())
    }
}

impl Drop for GymnasiumVec {
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Close);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs;

    #[test]
    fn round_trip() {
        let cfg = VecConfig {
            num_envs: 4,
            num_workers: 4,
            batch_size: 4,
            ..Default::default()
        };
        let mut v = GymnasiumVec::new(|i| envs::make("ocean/squared", i as u64), cfg).unwrap();
        v.async_reset(1);
        let slots = v.action_dims().len();
        let rows = v.batch_rows();
        for _ in 0..20 {
            let b = v.recv().unwrap();
            assert_eq!(b.env_ids, &[0, 1, 2, 3]);
            assert_eq!(b.obs.len(), rows * v.obs_layout().byte_len());
            v.send(&vec![0i32; rows * slots]).unwrap();
        }
    }

    #[test]
    fn rejects_pooling() {
        let cfg = VecConfig {
            num_envs: 4,
            num_workers: 4,
            batch_size: 2,
            ..Default::default()
        };
        assert!(GymnasiumVec::new(|i| envs::make("ocean/squared", i as u64), cfg).is_err());
    }
}
