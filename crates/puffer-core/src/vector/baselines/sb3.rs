//! The SB3 `SubprocVecEnv` design: one env per worker, a pipe per worker
//! polled **in worker order** (not completion order), no shared memory —
//! observations travel inside the reply message — and flattening performed
//! on the main process (the paper: *"For some reason, it does this on the
//! main process and with a rather inefficient implementation"*).

use super::{Cmd, Reply};
use crate::emulation::{FlatEnv, Info};
use crate::spaces::StructLayout;
use crate::vector::{probe_factory, EnvFactory, StepBatch, VecConfig, VecEnv};
use anyhow::Result;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// SB3-style synchronous vectorization over worker threads.
pub struct Sb3Vec {
    layout: StructLayout,
    action_dims: Vec<usize>,
    agents: usize,
    num_envs: usize,
    cmd_tx: Vec<mpsc::Sender<Cmd>>,
    /// One reply pipe per worker, read in order — a straggler at worker 0
    /// blocks everything behind it.
    reply_rx: Vec<mpsc::Receiver<Reply>>,
    handles: Vec<JoinHandle<()>>,

    obs: Vec<u8>,
    rewards: Vec<f32>,
    terms: Vec<bool>,
    truncs: Vec<bool>,
    env_ids: Vec<usize>,
    infos: Vec<(usize, Info)>,
    outstanding: bool,
}

impl Sb3Vec {
    pub fn new(
        factory: impl Fn(usize) -> Box<dyn FlatEnv> + Send + Sync + 'static,
        cfg: VecConfig,
    ) -> Result<Self> {
        anyhow::ensure!(
            cfg.batch_size == cfg.num_envs,
            "SB3 vectorization has no pool support: batch_size must equal num_envs"
        );
        let factory: EnvFactory = Box::new(factory);
        let (layout, action_dims, agents) = probe_factory(&factory);
        let factory = std::sync::Arc::new(factory);
        let mut cmd_tx = Vec::new();
        let mut reply_rx = Vec::new();
        let mut handles = Vec::new();
        let w = layout.byte_len();
        for env_id in 0..cfg.num_envs {
            let (tx, rx) = mpsc::channel::<Cmd>();
            let (rtx, rrx) = mpsc::channel::<Reply>();
            cmd_tx.push(tx);
            reply_rx.push(rrx);
            let factory = factory.clone();
            handles.push(std::thread::spawn(move || {
                let mut env = factory(env_id);
                let rows = env.num_agents();
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Close => return,
                        Cmd::Reset(seed) => {
                            let mut obs = vec![0u8; rows * w];
                            let info = env.reset(seed + env_id as u64, &mut obs);
                            let _ = rtx.send(Reply {
                                env_id,
                                obs,
                                rewards: vec![0.0; rows],
                                terms: vec![false; rows],
                                truncs: vec![false; rows],
                                info,
                            });
                        }
                        Cmd::Step(actions) => {
                            let mut obs = vec![0u8; rows * w];
                            let mut rewards = vec![0.0; rows];
                            let mut terms = vec![false; rows];
                            let mut truncs = vec![false; rows];
                            let info =
                                env.step(&actions, &mut obs, &mut rewards, &mut terms, &mut truncs);
                            let _ = rtx.send(Reply {
                                env_id,
                                obs,
                                rewards,
                                terms,
                                truncs,
                                info,
                            });
                        }
                    }
                }
            }));
        }
        let rows = cfg.num_envs * agents;
        Ok(Sb3Vec {
            layout,
            action_dims,
            agents,
            num_envs: cfg.num_envs,
            cmd_tx,
            reply_rx,
            handles,
            obs: vec![0; rows * w],
            rewards: vec![0.0; rows],
            terms: vec![false; rows],
            truncs: vec![false; rows],
            env_ids: (0..cfg.num_envs).collect(),
            infos: Vec::new(),
            outstanding: false,
        })
    }
}

impl VecEnv for Sb3Vec {
    fn obs_layout(&self) -> &StructLayout {
        &self.layout
    }
    fn action_dims(&self) -> &[usize] {
        &self.action_dims
    }
    fn agents_per_env(&self) -> usize {
        self.agents
    }
    fn num_envs(&self) -> usize {
        self.num_envs
    }
    fn batch_size(&self) -> usize {
        self.num_envs
    }

    fn async_reset(&mut self, seed: u64) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Reset(seed));
        }
        self.outstanding = true;
    }

    fn recv(&mut self) -> Result<StepBatch<'_>> {
        anyhow::ensure!(self.outstanding, "recv without outstanding work");
        let w = self.layout.byte_len();
        let rows = self.agents;
        // Poll pipes in worker order; main-thread "flatten" copies each
        // env's observation into the stacked batch.
        for env_id in 0..self.num_envs {
            let r = self.reply_rx[env_id]
                .recv()
                .map_err(|_| anyhow::anyhow!("baseline worker died"))?;
            let base = env_id * rows;
            self.obs[base * w..(base + rows) * w].copy_from_slice(&r.obs);
            self.rewards[base..base + rows].copy_from_slice(&r.rewards);
            self.terms[base..base + rows].copy_from_slice(&r.terms);
            self.truncs[base..base + rows].copy_from_slice(&r.truncs);
            if !r.info.is_empty() {
                self.infos.push((env_id, r.info));
            }
        }
        self.outstanding = false;
        Ok(StepBatch {
            env_ids: &self.env_ids,
            obs: &self.obs,
            rewards: &self.rewards,
            terms: &self.terms,
            truncs: &self.truncs,
            infos: std::mem::take(&mut self.infos),
        })
    }

    fn send(&mut self, actions: &[i32]) -> Result<()> {
        let slots = self.action_dims.len();
        let rows = self.agents;
        anyhow::ensure!(
            actions.len() == self.num_envs * rows * slots,
            "bad action length"
        );
        for (env_id, tx) in self.cmd_tx.iter().enumerate() {
            let a = actions[env_id * rows * slots..(env_id + 1) * rows * slots].to_vec();
            let _ = tx.send(Cmd::Step(a));
        }
        self.outstanding = true;
        Ok(())
    }
}

impl Drop for Sb3Vec {
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Close);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs;

    #[test]
    fn round_trip() {
        let cfg = VecConfig {
            num_envs: 4,
            num_workers: 4,
            batch_size: 4,
            ..Default::default()
        };
        let mut v = Sb3Vec::new(|i| envs::make("classic/cartpole", i as u64), cfg).unwrap();
        v.async_reset(1);
        let slots = v.action_dims().len();
        let rows = v.batch_rows();
        for _ in 0..20 {
            let b = v.recv().unwrap();
            assert_eq!(b.env_ids.len(), 4);
            v.send(&vec![0i32; rows * slots]).unwrap();
        }
    }
}
