//! Reimplementations of the Gymnasium and SB3 vectorization *designs*,
//! used as the comparison points for the paper's Table 2.
//!
//! These are faithful to the designs' documented structure — one env per
//! worker, wait-for-all synchronization, channel (queue/pipe) signaling,
//! per-message allocation, and the copy discipline each library uses — not
//! to their Python constant factors. The paper's Table 2 differences come
//! from exactly these design choices (scaling degradation above ~1000
//! synchronizations/sec/core, straggler waits, extra copies), all of which
//! reproduce in Rust; see EXPERIMENTS.md for measured shapes.
//!
//! These simulations keep Table 2 runnable without a Python toolchain,
//! but they are no longer the only comparison: with the `puffer-py`
//! bindings built, `examples/python/bench_vec.py` (`make bench-py`)
//! measures the *actual* `gymnasium.vector.SyncVectorEnv` against the
//! Rust vectorizer through the zero-copy adapter on the same workload,
//! writing `BENCH_pybind.json`. Prefer those numbers when citing
//! head-to-head throughput.

mod gymnasium;
mod sb3;

pub use gymnasium::GymnasiumVec;
pub use sb3::Sb3Vec;

use crate::emulation::Info;

/// Command sent to a baseline worker (one env per worker, as both
/// libraries do).
pub(crate) enum Cmd {
    Reset(u64),
    Step(Vec<i32>),
    Close,
}

/// Reply from a baseline worker. Every message allocates fresh buffers —
/// the analog of pickling through a pipe/queue.
pub(crate) struct Reply {
    pub env_id: usize,
    pub obs: Vec<u8>,
    pub rewards: Vec<f32>,
    pub terms: Vec<bool>,
    pub truncs: Vec<bool>,
    pub info: Info,
}
