//! The serial backend: all envs stepped inline on the caller's thread.
//! Zero parallelism, zero synchronization overhead — the baseline every
//! other backend is compared against, and the right choice for very fast
//! envs at small counts (and for debugging).

use super::{probe_factory, EnvFactory, StepBatch, VecConfig, VecEnv};
use crate::emulation::{FlatEnv, Info};
use crate::spaces::StructLayout;
use crate::wrappers::EnvSpec;
use anyhow::Result;

/// In-thread vectorization.
pub struct Serial {
    envs: Vec<Box<dyn FlatEnv>>,
    layout: StructLayout,
    action_dims: Vec<usize>,
    agents: usize,
    obs: Vec<u8>,
    rewards: Vec<f32>,
    terms: Vec<bool>,
    truncs: Vec<bool>,
    env_ids: Vec<usize>,
    infos: Vec<(usize, Info)>,
    seed: u64,
    /// Results pending delivery by `recv` (reset or step happened).
    ready: bool,
}

impl Serial {
    /// Build from a composable [`EnvSpec`] — the preferred constructor.
    pub fn from_spec(spec: &EnvSpec, cfg: VecConfig) -> Result<Self> {
        Self::from_factory_box(spec.to_factory(), cfg)
    }

    /// Low-level escape hatch: build from a raw factory closure. Prefer
    /// [`from_spec`](Self::from_spec); for custom envs see
    /// [`EnvSpec::custom`].
    pub fn from_factory(
        factory: impl Fn(usize) -> Box<dyn FlatEnv> + Send + Sync + 'static,
        cfg: VecConfig,
    ) -> Result<Self> {
        Self::from_factory_box(Box::new(factory), cfg)
    }

    fn from_factory_box(factory: EnvFactory, cfg: VecConfig) -> Result<Self> {
        anyhow::ensure!(
            cfg.batch_size == cfg.num_envs,
            "Serial requires batch_size == num_envs (got {} vs {})",
            cfg.batch_size,
            cfg.num_envs
        );
        let (layout, action_dims, agents) = probe_factory(&factory);
        let envs: Vec<_> = (0..cfg.num_envs).map(|i| factory(i)).collect();
        let rows = cfg.num_envs * agents;
        let w = layout.byte_len();
        Ok(Serial {
            envs,
            layout,
            action_dims,
            agents,
            obs: vec![0; rows * w],
            rewards: vec![0.0; rows],
            terms: vec![false; rows],
            truncs: vec![false; rows],
            env_ids: (0..cfg.num_envs).collect(),
            infos: Vec::new(),
            seed: cfg.seed,
            ready: false,
        })
    }
}

impl VecEnv for Serial {
    fn obs_layout(&self) -> &StructLayout {
        &self.layout
    }
    fn action_dims(&self) -> &[usize] {
        &self.action_dims
    }
    fn agents_per_env(&self) -> usize {
        self.agents
    }
    fn num_envs(&self) -> usize {
        self.envs.len()
    }
    fn batch_size(&self) -> usize {
        self.envs.len()
    }

    fn async_reset(&mut self, seed: u64) {
        self.seed = seed;
        let w = self.layout.byte_len();
        let rows_per_env = self.agents;
        self.infos.clear();
        for (i, env) in self.envs.iter_mut().enumerate() {
            let start = i * rows_per_env * w;
            let info = env.reset(seed + i as u64, &mut self.obs[start..start + rows_per_env * w]);
            if !info.is_empty() {
                self.infos.push((i, info));
            }
        }
        self.rewards.fill(0.0);
        self.terms.fill(false);
        self.truncs.fill(false);
        self.ready = true;
    }

    fn recv(&mut self) -> Result<StepBatch<'_>> {
        anyhow::ensure!(self.ready, "recv called before async_reset/send");
        self.ready = false;
        Ok(StepBatch {
            env_ids: &self.env_ids,
            obs: &self.obs,
            rewards: &self.rewards,
            terms: &self.terms,
            truncs: &self.truncs,
            infos: std::mem::take(&mut self.infos),
        })
    }

    fn send(&mut self, actions: &[i32]) -> Result<()> {
        let slots = self.action_dims.len();
        let rows_per_env = self.agents;
        anyhow::ensure!(
            actions.len() == self.envs.len() * rows_per_env * slots,
            "expected {} action slots, got {}",
            self.envs.len() * rows_per_env * slots,
            actions.len()
        );
        let w = self.layout.byte_len();
        for (i, env) in self.envs.iter_mut().enumerate() {
            let o = i * rows_per_env;
            let info = env.step(
                &actions[o * slots..(o + rows_per_env) * slots],
                &mut self.obs[o * w..(o + rows_per_env) * w],
                &mut self.rewards[o..o + rows_per_env],
                &mut self.terms[o..o + rows_per_env],
                &mut self.truncs[o..o + rows_per_env],
            );
            if !info.is_empty() {
                self.infos.push((i, info));
            }
        }
        self.ready = true;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_round_trip_on_cartpole() {
        let cfg = VecConfig {
            num_envs: 4,
            num_workers: 1,
            batch_size: 4,
            ..Default::default()
        };
        let mut v = Serial::from_spec(&EnvSpec::new("classic/cartpole"), cfg).unwrap();
        v.async_reset(7);
        let slots = v.action_dims().len();
        let rows = v.batch_rows();
        let w = v.obs_layout().byte_len();
        for _ in 0..50 {
            let b = v.recv().unwrap();
            assert_eq!(b.obs.len(), rows * w);
            assert_eq!(b.env_ids, &[0, 1, 2, 3]);
            let actions = vec![1i32; rows * slots];
            v.send(&actions).unwrap();
        }
    }

    #[test]
    fn serial_rejects_pool_config() {
        let cfg = VecConfig {
            num_envs: 4,
            num_workers: 1,
            batch_size: 2,
            ..Default::default()
        };
        assert!(Serial::from_spec(&EnvSpec::new("classic/cartpole"), cfg).is_err());
    }

    #[test]
    fn recv_before_reset_errors() {
        let cfg = VecConfig {
            num_envs: 1,
            num_workers: 1,
            batch_size: 1,
            ..Default::default()
        };
        let mut v = Serial::from_spec(&EnvSpec::new("ocean/bandit"), cfg).unwrap();
        assert!(v.recv().is_err());
    }

    #[test]
    fn multiagent_rows() {
        let cfg = VecConfig {
            num_envs: 2,
            num_workers: 1,
            batch_size: 2,
            ..Default::default()
        };
        let mut v = Serial::from_spec(&EnvSpec::new("ocean/multiagent"), cfg).unwrap();
        assert_eq!(v.agents_per_env(), 2);
        assert_eq!(v.batch_rows(), 4);
        v.async_reset(0);
        let b = v.recv().unwrap();
        assert_eq!(b.rewards.len(), 4);
        let slots = v.action_dims().len();
        v.send(&vec![0i32; 4 * slots]).unwrap();
        let b = v.recv().unwrap();
        // agent 0 rows picked action 0 → reward 1; agent 1 rows → 0.
        assert_eq!(b.rewards, &[1.0, 0.0, 1.0, 0.0]);
    }
}
