//! The multiprocessing backend: `W` worker threads, each simulating
//! `M / W` environments, exchanging all step data through preallocated
//! shared slabs and signaling through busy-wait flags.
//!
//! This is the Rust analog of the paper's Python multiprocessing design
//! (see DESIGN.md §Hardware-Adaptation: threads + shared buffers preserve
//! the copy counts and synchronization topology of the original's shared
//! memory + process model). All four optimized code paths live here,
//! selected by [`VecConfig::mode`]:
//!
//! | [`Mode`]              | wait policy             | obs copies |
//! |-----------------------|-------------------------|------------|
//! | `Sync`                | all workers             | 0 (slab *is* the batch) |
//! | `Async`               | first workers to finish | 1 gather   |
//! | `AsyncSingleWorker`   | first worker to finish  | 0 (worker region is the batch) |
//! | `ZeroCopy`            | next band in rotation   | 0 (band region is the batch) |
//!
//! The leader↔worker handoff, shutdown, and reset-seed protocols are
//! documented in `CONCURRENCY.md` and model-checked in
//! `crates/puffer-train/tests/loom_models.rs` (see [`crate::sync`]).

use super::shared::{Flag, Slab, ACTIONS_READY, OBS_READY, POISONED, RESET, SHUTDOWN};
use super::{probe_factory, EnvFactory, Mode, StepBatch, VecConfig, VecEnv};
use crate::emulation::{FlatEnv, Info};
use crate::spaces::StructLayout;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::Arc;
use crate::wrappers::EnvSpec;
use anyhow::Result;
// The info channel is the documented exception to the crate::sync facade
// rule (CONCURRENCY.md): fire-and-forget, unbounded, never part of the
// flag protocol's blocking structure, so it stays std mpsc and outside
// the loom-modeled surface.
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Shared-memory threaded vectorization with EnvPool semantics.
pub struct Multiprocessing {
    cfg: VecConfig,
    mode: Mode,
    layout: StructLayout,
    action_dims: Vec<usize>,
    agents: usize,

    flags: Vec<Arc<Flag>>,
    obs: Arc<Slab<u8>>,
    rewards: Arc<Slab<f32>>,
    terms: Arc<Slab<bool>>,
    truncs: Arc<Slab<bool>>,
    actions: Arc<Slab<i32>>,
    reset_seed: Arc<AtomicU64>,
    /// Advisory fast-exit hint for workers. The *authoritative* shutdown
    /// signal is the SHUTDOWN flag state: a worker's step-completion edge
    /// is a CAS ([`Flag::complete`]) that loses to a concurrent SHUTDOWN
    /// store, so the signal can never be overwritten and lost.
    shutdown: Arc<AtomicBool>,
    info_rx: mpsc::Receiver<(usize, Info)>,
    handles: Vec<JoinHandle<()>>,

    /// Worker ids claimed by the last `recv`, in claim order.
    pending: Vec<usize>,
    env_ids: Vec<usize>,
    awaiting_send: bool,
    /// True while the leader's `StepBatch` views alias claimed workers'
    /// slab regions directly (Sync/AsyncSingleWorker/ZeroCopy); drives
    /// the sentinel hold/release bookkeeping (see [`Slab::hold`]).
    holding: bool,
    /// Round-robin scan start (Async fairness).
    scan_cursor: usize,
    /// Next band to claim (ZeroCopy rotation).
    band_cursor: usize,

    // Gather buffers (Async path only).
    g_obs: Vec<u8>,
    g_rewards: Vec<f32>,
    g_terms: Vec<bool>,
    g_truncs: Vec<bool>,
}

impl Multiprocessing {
    /// Build from a composable [`EnvSpec`] — the preferred constructor.
    /// Every worker instantiates its own envs (and wrapper state) from
    /// the spec, so wrapper chains need no cross-thread synchronization.
    pub fn from_spec(spec: &EnvSpec, cfg: VecConfig) -> Result<Self> {
        Self::from_factory_box(spec.to_factory(), cfg)
    }

    /// Low-level escape hatch: build from a raw factory closure. Prefer
    /// [`from_spec`](Self::from_spec); for custom envs see
    /// [`EnvSpec::custom`].
    pub fn from_factory(
        factory: impl Fn(usize) -> Box<dyn FlatEnv> + Send + Sync + 'static,
        cfg: VecConfig,
    ) -> Result<Self> {
        Self::from_factory_box(Box::new(factory), cfg)
    }

    fn from_factory_box(factory: EnvFactory, cfg: VecConfig) -> Result<Self> {
        let mode = cfg.mode()?;
        let (layout, action_dims, agents) = probe_factory(&factory);
        let w = layout.byte_len();
        let slots = action_dims.len();
        let rows = cfg.num_envs * agents;

        let obs = Slab::<u8>::new(rows * w);
        let rewards = Slab::<f32>::new(rows);
        let terms = Slab::<bool>::new(rows);
        let truncs = Slab::<bool>::new(rows);
        let actions = Slab::<i32>::new(rows * slots);
        let reset_seed = Arc::new(AtomicU64::new(cfg.seed));
        let shutdown = Arc::new(AtomicBool::new(false));
        let flags: Vec<Arc<Flag>> = (0..cfg.num_workers).map(|_| Arc::new(Flag::new())).collect();
        let (info_tx, info_rx) = mpsc::channel::<(usize, Info)>();

        let factory = Arc::new(factory);
        let epw = cfg.envs_per_worker();
        let mut handles: Vec<JoinHandle<()>> = Vec::with_capacity(cfg.num_workers);
        for wid in 0..cfg.num_workers {
            let ctx = WorkerCtx {
                wid,
                epw,
                agents,
                byte_len: w,
                slots,
                spin_budget: cfg.spin_budget,
                flag: flags[wid].clone(),
                obs: obs.clone(),
                rewards: rewards.clone(),
                terms: terms.clone(),
                truncs: truncs.clone(),
                actions: actions.clone(),
                reset_seed: reset_seed.clone(),
                shutdown: shutdown.clone(),
                info_tx: info_tx.clone(),
                factory: factory.clone(),
            };
            let spawned = std::thread::Builder::new()
                .name(format!("puffer-worker-{wid}"))
                .spawn(move || worker_main(ctx));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Tear down the workers already spawned so the error
                    // doesn't leak threads parked in Flag::wait forever.
                    // ordering: Relaxed — advisory hint; the SHUTDOWN
                    // flag store below is the authoritative signal.
                    shutdown.store(true, Ordering::Relaxed);
                    for f in &flags[..wid] {
                        f.store(SHUTDOWN);
                    }
                    for h in handles.drain(..) {
                        let _ = h.join();
                    }
                    anyhow::bail!("failed to spawn worker {wid}: {e}");
                }
            }
        }

        let batch_rows = cfg.batch_size * agents;
        Ok(Multiprocessing {
            mode,
            layout,
            action_dims,
            agents,
            flags,
            obs,
            rewards,
            terms,
            truncs,
            actions,
            reset_seed,
            shutdown,
            info_rx,
            handles,
            pending: Vec::with_capacity(cfg.num_workers),
            env_ids: Vec::with_capacity(cfg.batch_size),
            awaiting_send: false,
            holding: false,
            scan_cursor: 0,
            band_cursor: 0,
            g_obs: vec![0; batch_rows * w],
            g_rewards: vec![0.0; batch_rows],
            g_terms: vec![false; batch_rows],
            g_truncs: vec![false; batch_rows],
            cfg,
        })
    }

    /// The resolved code path.
    pub fn mode(&self) -> Mode {
        self.mode
    }

    fn epw(&self) -> usize {
        self.cfg.envs_per_worker()
    }
    /// Rows owned by one worker.
    fn rows_per_worker(&self) -> usize {
        self.epw() * self.agents
    }
    fn workers_per_batch(&self) -> usize {
        self.cfg.batch_size / self.epw()
    }

    fn drain_infos(&mut self) -> Vec<(usize, Info)> {
        let mut out = Vec::new();
        while let Ok(item) = self.info_rx.try_recv() {
            out.push(item);
        }
        out
    }

    fn check_poison(&self) -> Result<()> {
        for (wid, f) in self.flags.iter().enumerate() {
            if f.load() == POISONED {
                anyhow::bail!(
                    "worker {wid} poisoned: an environment panicked; the vectorizer is dead"
                );
            }
        }
        Ok(())
    }

    /// Wait until `wid` reaches a leader-owned state (OBS_READY), claiming
    /// it. Errors on poison.
    fn wait_and_claim(&self, wid: usize) -> Result<()> {
        let s = self.flags[wid].wait(self.cfg.spin_budget, |s| {
            s == OBS_READY || s == POISONED
        });
        if s == POISONED {
            self.check_poison()?;
        }
        // Exclusive claim (we are the only leader, but CAS keeps the
        // invariant explicit and cheap).
        anyhow::ensure!(self.flags[wid].try_claim(), "claim raced on worker {wid}");
        Ok(())
    }

    /// Register the leader's long-lived `StepBatch` views over
    /// `[first, first + n)` worker regions with the aliasing sentinel
    /// (no-op in release builds). Matched by the releases in `send`.
    fn hold_workers(&mut self, first_wid: usize, n: usize) {
        let rpw = self.rows_per_worker();
        let w = self.layout.byte_len();
        for wid in first_wid..first_wid + n {
            self.obs.hold(wid * rpw * w, rpw * w);
            self.rewards.hold(wid * rpw, rpw);
            self.terms.hold(wid * rpw, rpw);
            self.truncs.hold(wid * rpw, rpw);
        }
        self.holding = true;
    }

    /// Borrowed slices over a contiguous run of workers
    /// `[first, first + n)`.
    fn region_slices(&self, first_wid: usize, n_workers: usize) -> (&[u8], &[f32], &[bool], &[bool]) {
        let rpw = self.rows_per_worker();
        let w = self.layout.byte_len();
        let row0 = first_wid * rpw;
        let rows = n_workers * rpw;
        // SAFETY: all workers in the run are CLAIMED (leader-owned).
        unsafe {
            (
                self.obs.slice(row0 * w, rows * w),
                self.rewards.slice(row0, rows),
                self.terms.slice(row0, rows),
                self.truncs.slice(row0, rows),
            )
        }
    }

    fn set_env_ids(&mut self, worker_order: &[usize]) {
        self.env_ids.clear();
        let epw = self.epw();
        for &wid in worker_order {
            self.env_ids.extend(wid * epw..(wid + 1) * epw);
        }
    }
}

impl VecEnv for Multiprocessing {
    fn obs_layout(&self) -> &StructLayout {
        &self.layout
    }
    fn action_dims(&self) -> &[usize] {
        &self.action_dims
    }
    fn agents_per_env(&self) -> usize {
        self.agents
    }
    fn num_envs(&self) -> usize {
        self.cfg.num_envs
    }
    fn batch_size(&self) -> usize {
        self.cfg.batch_size
    }

    fn async_reset(&mut self, seed: u64) {
        assert!(
            !self.awaiting_send,
            "async_reset with a batch outstanding; send() first"
        );
        // Phase 1: quiesce. Every worker must be parked in a leader-owned
        // state (IDLE at startup, OBS_READY/CLAIMED mid-run, POISONED if
        // dead) before the new seed is published. Publishing first would
        // let a worker still processing a *previous* RESET load *this*
        // seed — back-to-back resets would then mix seed epochs (pinned
        // by `reset_seed_epochs_never_mix` below and the
        // `reset_seed_matches_epoch` loom model).
        for f in &self.flags {
            f.wait(self.cfg.spin_budget, |s| {
                s != ACTIONS_READY && s != RESET
            });
        }
        // Phase 2: publish the seed, then wake each worker into RESET.
        // ordering: Relaxed — publication piggybacks on the RESET flag
        // edge: this store is sequenced before the flag's Release store,
        // and workers read the seed only after their Acquire load
        // returns RESET; phase 1 guarantees no worker reads concurrently.
        self.reset_seed.store(seed, Ordering::Relaxed);
        for f in &self.flags {
            f.store(RESET);
        }
        self.pending.clear();
        self.scan_cursor = 0;
        self.band_cursor = 0;
    }

    fn recv(&mut self) -> Result<StepBatch<'_>> {
        anyhow::ensure!(
            !self.awaiting_send,
            "recv called twice without an intervening send"
        );
        self.check_poison()?;
        self.pending.clear();

        match self.mode {
            Mode::Sync => {
                for wid in 0..self.cfg.num_workers {
                    self.wait_and_claim(wid)?;
                    self.pending.push(wid);
                }
                self.set_env_ids(&(0..self.cfg.num_workers).collect::<Vec<_>>());
                self.hold_workers(0, self.cfg.num_workers);
                self.awaiting_send = true;
                let infos = self.drain_infos();
                let (obs, rewards, terms, truncs) =
                    self.region_slices(0, self.cfg.num_workers);
                Ok(StepBatch {
                    env_ids: &self.env_ids,
                    obs,
                    rewards,
                    terms,
                    truncs,
                    infos,
                })
            }
            Mode::AsyncSingleWorker => {
                // First worker to finish wins; round-robin scan for
                // fairness.
                let wid = loop {
                    self.check_poison()?;
                    let mut found = None;
                    for k in 0..self.cfg.num_workers {
                        let wid = (self.scan_cursor + k) % self.cfg.num_workers;
                        if self.flags[wid].try_claim() {
                            found = Some(wid);
                            break;
                        }
                    }
                    match found {
                        Some(wid) => break wid,
                        // Nothing ready: let workers run (crucial when
                        // cores are oversubscribed).
                        None => crate::sync::yield_now(),
                    }
                };
                self.scan_cursor = (wid + 1) % self.cfg.num_workers;
                self.pending.push(wid);
                self.set_env_ids(&[wid]);
                self.hold_workers(wid, 1);
                self.awaiting_send = true;
                let infos = self.drain_infos();
                let (obs, rewards, terms, truncs) = self.region_slices(wid, 1);
                Ok(StepBatch {
                    env_ids: &self.env_ids,
                    obs,
                    rewards,
                    terms,
                    truncs,
                    infos,
                })
            }
            Mode::Async => {
                // Claim the first `workers_per_batch` finishers, gather
                // their regions into one contiguous batch (the single copy
                // this path pays). The gather reads are transient, so no
                // sentinel holds: the StepBatch aliases g_* buffers, not
                // the slabs.
                let need = self.workers_per_batch();
                while self.pending.len() < need {
                    self.check_poison()?;
                    let mut progressed = false;
                    for k in 0..self.cfg.num_workers {
                        let wid = (self.scan_cursor + k) % self.cfg.num_workers;
                        if self.pending.contains(&wid) {
                            continue;
                        }
                        if self.flags[wid].try_claim() {
                            self.pending.push(wid);
                            progressed = true;
                            if self.pending.len() == need {
                                break;
                            }
                        }
                    }
                    if !progressed {
                        // Let workers run while we wait for finishers.
                        crate::sync::yield_now();
                    }
                }
                self.scan_cursor =
                    (self.pending.last().copied().unwrap_or(0) + 1) % self.cfg.num_workers;
                let order = self.pending.clone();
                self.set_env_ids(&order);

                let rpw = self.rows_per_worker();
                let w = self.layout.byte_len();
                for (slot, &wid) in order.iter().enumerate() {
                    let row0 = wid * rpw;
                    // SAFETY: worker `wid` is CLAIMED (leader-owned).
                    // Field-disjoint borrows: slab sources vs gather
                    // destinations.
                    unsafe {
                        self.g_obs[slot * rpw * w..(slot + 1) * rpw * w]
                            .copy_from_slice(self.obs.slice(row0 * w, rpw * w));
                        self.g_rewards[slot * rpw..(slot + 1) * rpw]
                            .copy_from_slice(self.rewards.slice(row0, rpw));
                        self.g_terms[slot * rpw..(slot + 1) * rpw]
                            .copy_from_slice(self.terms.slice(row0, rpw));
                        self.g_truncs[slot * rpw..(slot + 1) * rpw]
                            .copy_from_slice(self.truncs.slice(row0, rpw));
                    }
                }
                self.awaiting_send = true;
                let infos = self.drain_infos();
                Ok(StepBatch {
                    env_ids: &self.env_ids,
                    obs: &self.g_obs,
                    rewards: &self.g_rewards,
                    terms: &self.g_terms,
                    truncs: &self.g_truncs,
                    infos,
                })
            }
            Mode::ZeroCopy => {
                // Bands of adjacent workers claimed in rotation: the batch
                // is a contiguous slab window — a circular buffer of
                // batches.
                let wpb = self.workers_per_batch();
                let n_bands = self.cfg.num_workers / wpb;
                let band = self.band_cursor % n_bands;
                let first = band * wpb;
                for wid in first..first + wpb {
                    self.wait_and_claim(wid)?;
                    self.pending.push(wid);
                }
                self.band_cursor = (band + 1) % n_bands;
                self.set_env_ids(&(first..first + wpb).collect::<Vec<_>>());
                self.hold_workers(first, wpb);
                self.awaiting_send = true;
                let infos = self.drain_infos();
                let (obs, rewards, terms, truncs) = self.region_slices(first, wpb);
                Ok(StepBatch {
                    env_ids: &self.env_ids,
                    obs,
                    rewards,
                    terms,
                    truncs,
                    infos,
                })
            }
        }
    }

    fn send(&mut self, actions: &[i32]) -> Result<()> {
        anyhow::ensure!(self.awaiting_send, "send called without a pending recv");
        let slots = self.action_dims.len();
        let rpw = self.rows_per_worker();
        let w = self.layout.byte_len();
        anyhow::ensure!(
            actions.len() == self.pending.len() * rpw * slots,
            "expected {} action slots, got {}",
            self.pending.len() * rpw * slots,
            actions.len()
        );
        for slot in 0..self.pending.len() {
            let wid = self.pending[slot];
            if self.holding {
                // The caller's StepBatch views died when this call
                // borrowed self mutably; tell the sentinel before the
                // ACTIONS_READY store lets the worker write the regions.
                self.obs.release(wid * rpw * w, rpw * w);
                self.rewards.release(wid * rpw, rpw);
                self.terms.release(wid * rpw, rpw);
                self.truncs.release(wid * rpw, rpw);
            }
            {
                // SAFETY: worker is CLAIMED (leader-owned) until the flag
                // store below hands the region back.
                let mut dst = unsafe { self.actions.slice_mut(wid * rpw * slots, rpw * slots) };
                dst.copy_from_slice(&actions[slot * rpw * slots..(slot + 1) * rpw * slots]);
            } // window guard drops before the handoff store
            self.flags[wid].store(ACTIONS_READY);
        }
        self.pending.clear();
        self.holding = false;
        self.awaiting_send = false;
        Ok(())
    }
}

impl Drop for Multiprocessing {
    fn drop(&mut self) {
        // ordering: Relaxed — advisory fast-exit hint only; the flag
        // stores below are the authoritative, Release-ordered signal. A
        // worker mid-step cannot lose it: its completion edge is a CAS
        // that fails against SHUTDOWN instead of overwriting it.
        self.shutdown.store(true, Ordering::Relaxed);
        for f in &self.flags {
            f.store(SHUTDOWN);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------

struct WorkerCtx {
    wid: usize,
    epw: usize,
    agents: usize,
    byte_len: usize,
    slots: usize,
    spin_budget: u32,
    flag: Arc<Flag>,
    obs: Arc<Slab<u8>>,
    rewards: Arc<Slab<f32>>,
    terms: Arc<Slab<bool>>,
    truncs: Arc<Slab<bool>>,
    actions: Arc<Slab<i32>>,
    reset_seed: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    info_tx: mpsc::Sender<(usize, Info)>,
    factory: Arc<EnvFactory>,
}

fn worker_main(ctx: WorkerCtx) {
    let flag = ctx.flag.clone();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        worker_loop(ctx)
    }));
    if result.is_err() {
        // Mark the backend dead; the leader surfaces this as an error on
        // the next recv (failure injection tests exercise this path).
        flag.store(POISONED);
    }
}

fn worker_loop(ctx: WorkerCtx) {
    // Envs are constructed *inside* the worker (processes do the same),
    // parallelizing expensive env startup.
    let mut envs: Vec<Box<dyn FlatEnv>> = (0..ctx.epw)
        .map(|j| (ctx.factory)(ctx.wid * ctx.epw + j))
        .collect();

    let rpw = ctx.epw * ctx.agents;
    let row0 = ctx.wid * rpw;
    loop {
        // ordering: Relaxed — advisory fast exit (skip one last wake-up
        // cycle); correctness does not depend on observing it, because
        // the SHUTDOWN flag state below cannot be overwritten by this
        // worker (completion is a CAS, not a store).
        if ctx.shutdown.load(Ordering::Relaxed) {
            return;
        }
        let state = ctx
            .flag
            .wait(ctx.spin_budget, |s| matches!(s, ACTIONS_READY | RESET | SHUTDOWN));
        match state {
            SHUTDOWN => return,
            RESET => {
                // ordering: Relaxed — async_reset quiesces all workers,
                // stores the seed, *then* Release-stores RESET; our
                // Acquire load of RESET (in `wait`) makes the seed
                // visible, and no store can race while any worker is
                // processing RESET.
                let seed = ctx.reset_seed.load(Ordering::Relaxed);
                for (j, env) in envs.iter_mut().enumerate() {
                    let env_id = ctx.wid * ctx.epw + j;
                    let r = j * ctx.agents;
                    // SAFETY: RESET state grants the worker its regions.
                    let mut obs = unsafe {
                        ctx.obs
                            .slice_mut((row0 + r) * ctx.byte_len, ctx.agents * ctx.byte_len)
                    };
                    let info = env.reset(seed + env_id as u64, &mut obs);
                    // SAFETY: RESET state grants the worker its regions.
                    unsafe {
                        ctx.rewards.slice_mut(row0 + r, ctx.agents).fill(0.0);
                        ctx.terms.slice_mut(row0 + r, ctx.agents).fill(false);
                        ctx.truncs.slice_mut(row0 + r, ctx.agents).fill(false);
                    }
                    if !info.is_empty() {
                        let _ = ctx.info_tx.send((env_id, info));
                    }
                }
                // Publish results only if the leader didn't pull the flag
                // out from under us (SHUTDOWN mid-reset): a plain store
                // would erase that signal and strand this worker in its
                // next wait.
                if !ctx.flag.complete(RESET) {
                    return;
                }
            }
            ACTIONS_READY => {
                for (j, env) in envs.iter_mut().enumerate() {
                    let env_id = ctx.wid * ctx.epw + j;
                    let r = j * ctx.agents;
                    // SAFETY: ACTIONS_READY grants the worker its regions.
                    // Each env's rows are stacked directly into the shared
                    // slab — "multiple environments per worker" without
                    // extra copies.
                    let (actions, mut obs, mut rewards, mut terms, mut truncs) = unsafe {
                        (
                            ctx.actions
                                .slice((row0 + r) * ctx.slots, ctx.agents * ctx.slots),
                            ctx.obs
                                .slice_mut((row0 + r) * ctx.byte_len, ctx.agents * ctx.byte_len),
                            ctx.rewards.slice_mut(row0 + r, ctx.agents),
                            ctx.terms.slice_mut(row0 + r, ctx.agents),
                            ctx.truncs.slice_mut(row0 + r, ctx.agents),
                        )
                    };
                    let info =
                        env.step(actions, &mut obs, &mut rewards, &mut terms, &mut truncs);
                    if !info.is_empty() {
                        // The only cross-thread channel traffic: one send
                        // per episode per env (paper: pipes for infos).
                        let _ = ctx.info_tx.send((env_id, info));
                    }
                }
                // As in the RESET arm: CAS, never a blind store, so a
                // concurrent SHUTDOWN survives and we exit instead.
                if !ctx.flag.complete(ACTIONS_READY) {
                    return;
                }
            }
            _ => unreachable!("worker woke in state {state}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spaces::{Space, Value};

    fn cfg(num_envs: usize, num_workers: usize, batch_size: usize, zero_copy: bool) -> VecConfig {
        VecConfig {
            num_envs,
            num_workers,
            batch_size,
            zero_copy,
            ..Default::default()
        }
    }

    fn drive(mut v: Multiprocessing, steps: usize) {
        v.async_reset(3);
        let slots = v.action_dims().len();
        let rows = v.batch_rows();
        let w = v.obs_layout().byte_len();
        for _ in 0..steps {
            let ids;
            {
                let b = v.recv().unwrap();
                assert_eq!(b.obs.len(), rows * w);
                assert_eq!(b.rewards.len(), rows);
                ids = b.env_ids.to_vec();
            }
            assert_eq!(ids.len(), v.batch_size());
            v.send(&vec![0i32; rows * slots]).unwrap();
        }
    }

    #[test]
    fn sync_path() {
        let v = Multiprocessing::from_spec(&EnvSpec::new("ocean/squared"), cfg(8, 2, 8, false)).unwrap();
        assert_eq!(v.mode(), Mode::Sync);
        drive(v, 30);
    }

    #[test]
    fn async_path() {
        let v = Multiprocessing::from_spec(&EnvSpec::new("ocean/squared"), cfg(8, 4, 4, false)).unwrap();
        assert_eq!(v.mode(), Mode::Async);
        drive(v, 30);
    }

    #[test]
    fn async_single_worker_path() {
        let v = Multiprocessing::from_spec(&EnvSpec::new("ocean/squared"), cfg(8, 4, 2, false)).unwrap();
        assert_eq!(v.mode(), Mode::AsyncSingleWorker);
        drive(v, 30);
    }

    #[test]
    fn zero_copy_path() {
        let v = Multiprocessing::from_spec(&EnvSpec::new("ocean/squared"), cfg(8, 4, 4, true)).unwrap();
        assert_eq!(v.mode(), Mode::ZeroCopy);
        drive(v, 30);
    }

    /// Deterministic env whose obs encodes (env_instance_id, step_count,
    /// last_action) — catches row routing bugs across all code paths.
    struct Tracer {
        id: u64,
        t: f32,
        last: f32,
    }
    impl crate::emulation::StructuredEnv for Tracer {
        fn observation_space(&self) -> Space {
            Space::boxf(&[3], -1e6, 1e6)
        }
        fn action_space(&self) -> Space {
            Space::Discrete(64)
        }
        fn reset(&mut self, _seed: u64) -> Value {
            self.t = 0.0;
            self.last = -1.0;
            Value::F32(vec![self.id as f32, 0.0, -1.0])
        }
        fn step(&mut self, a: &Value) -> (Value, f32, bool, bool, crate::emulation::Info) {
            self.t += 1.0;
            self.last = a.as_discrete().unwrap() as f32;
            (
                Value::F32(vec![self.id as f32, self.t, self.last]),
                self.last,
                false,
                false,
                vec![],
            )
        }
    }

    fn tracer_factory(i: usize) -> Box<dyn FlatEnv> {
        Box::new(crate::emulation::PufferEnv::new(Tracer {
            id: i as u64,
            t: 0.0,
            last: -1.0,
        }))
    }

    fn decode_rows(w: usize, obs: &[u8]) -> Vec<(f32, f32, f32)> {
        obs.chunks_exact(w)
            .map(|row| {
                let f = |i: usize| {
                    f32::from_le_bytes(row[4 * i..4 * i + 4].try_into().unwrap())
                };
                (f(0), f(1), f(2))
            })
            .collect()
    }

    /// Actions sent for env e must arrive at env e, and its obs row must
    /// come back in the position its env_id claims — on every path.
    fn routing_check(num_envs: usize, num_workers: usize, batch_size: usize, zero_copy: bool) {
        let mut v = Multiprocessing::from_factory(
            tracer_factory,
            cfg(num_envs, num_workers, batch_size, zero_copy),
        )
        .unwrap();
        let w = v.obs_layout().byte_len();
        v.async_reset(0);
        for _round in 0..20 {
            let (ids, rows) = {
                let b = v.recv().unwrap();
                (b.env_ids.to_vec(), decode_rows(w, b.obs))
            };
            for (slot, &env_id) in ids.iter().enumerate() {
                let (id, _t, _last) = rows[slot];
                assert_eq!(id as usize, env_id, "row {slot} carries wrong env");
            }
            // Send action = env_id + 7; verify it echoes next time we see
            // that env.
            let actions: Vec<i32> = ids.iter().map(|&e| (e as i32 + 7) % 64).collect();
            v.send(&actions).unwrap();
            let (ids2, rows2) = {
                let b = v.recv().unwrap();
                (b.env_ids.to_vec(), decode_rows(w, b.obs))
            };
            for (slot, &env_id) in ids2.iter().enumerate() {
                let (id, t, last) = rows2[slot];
                assert_eq!(id as usize, env_id);
                if t > 0.0 {
                    assert_eq!(
                        last as i32,
                        (env_id as i32 + 7) % 64,
                        "env {env_id} got someone else's action"
                    );
                }
            }
            let actions: Vec<i32> = ids2.iter().map(|&e| (e as i32 + 7) % 64).collect();
            v.send(&actions).unwrap();
        }
    }

    #[test]
    fn routing_sync() {
        routing_check(8, 4, 8, false);
    }
    #[test]
    fn routing_async() {
        routing_check(8, 4, 4, false);
    }
    #[test]
    fn routing_single_worker() {
        routing_check(8, 4, 2, false);
    }
    #[test]
    fn routing_zero_copy() {
        routing_check(8, 4, 4, true);
    }
    #[test]
    fn routing_multi_env_per_worker() {
        routing_check(12, 3, 4, false);
    }

    #[test]
    fn infos_cross_once_per_episode() {
        let mut v =
            Multiprocessing::from_spec(&EnvSpec::new("ocean/bandit"), cfg(4, 2, 4, false)).unwrap();
        v.async_reset(1);
        let slots = v.action_dims().len();
        let rows = v.batch_rows();
        let mut episode_infos = 0;
        for _ in 0..10 {
            let b = v.recv().unwrap();
            episode_infos += b.infos.len();
            let n = rows * slots;
            v.send(&vec![0i32; n]).unwrap();
        }
        // Bandit episodes are one step: every step ends an episode, so
        // infos flow — but only via the channel, only non-empty.
        assert!(episode_infos > 0, "no episode infos arrived");
    }

    /// Env that panics on step `k` — the worker must poison, and the
    /// leader must report an error instead of hanging.
    struct Bomb {
        t: u32,
        fuse: u32,
    }
    impl crate::emulation::StructuredEnv for Bomb {
        fn observation_space(&self) -> Space {
            Space::boxf(&[1], 0.0, 1.0)
        }
        fn action_space(&self) -> Space {
            Space::Discrete(2)
        }
        fn reset(&mut self, _seed: u64) -> Value {
            Value::F32(vec![0.0])
        }
        fn step(&mut self, _a: &Value) -> (Value, f32, bool, bool, crate::emulation::Info) {
            self.t += 1;
            if self.t >= self.fuse {
                panic!("boom");
            }
            (Value::F32(vec![0.0]), 0.0, false, false, vec![])
        }
    }

    #[test]
    fn worker_panic_poisons_backend() {
        let mut v = Multiprocessing::from_factory(
            |_i| {
                Box::new(crate::emulation::PufferEnv::new(Bomb { t: 0, fuse: 3 }))
                    as Box<dyn FlatEnv>
            },
            cfg(4, 2, 4, false),
        )
        .unwrap();
        v.async_reset(0);
        let slots = v.action_dims().len();
        let rows = v.batch_rows();
        let mut saw_error = false;
        for _ in 0..10 {
            match v.recv() {
                Ok(_) => {
                    if v.send(&vec![0i32; rows * slots]).is_err() {
                        saw_error = true;
                        break;
                    }
                }
                Err(e) => {
                    assert!(e.to_string().contains("poisoned"), "{e}");
                    saw_error = true;
                    break;
                }
            }
        }
        assert!(saw_error, "poison never surfaced");
    }

    #[test]
    fn pool_returns_fast_envs_first() {
        // Workers 0..3: worker 3 is 50x slower. With batch = 1 worker, the
        // fast workers should dominate the claimed batches.
        use crate::envs::profile::{ProfileConfig, ProfileSim};
        let factory = |i: usize| -> Box<dyn FlatEnv> {
            let step_us = if i == 3 { 5000.0 } else { 100.0 };
            Box::new(crate::emulation::PufferEnv::new(ProfileSim::new(
                ProfileConfig::synthetic(step_us, 0.0, 0.0, 4),
                i as u64,
            )))
        };
        let mut v = Multiprocessing::from_factory(factory, cfg(4, 4, 1, false)).unwrap();
        assert_eq!(v.mode(), Mode::AsyncSingleWorker);
        v.async_reset(0);
        let slots = v.action_dims().len();
        let mut counts = [0usize; 4];
        for _ in 0..40 {
            let wid = {
                let b = v.recv().unwrap();
                b.env_ids[0]
            };
            counts[wid] += 1;
            v.send(&vec![0i32; slots]).unwrap();
        }
        let fast: usize = counts[..3].iter().sum();
        assert!(
            fast > counts[3] * 3,
            "slow worker claimed too often: {counts:?}"
        );
    }

    #[test]
    fn batch_sizes_and_agent_rows() {
        let v =
            Multiprocessing::from_spec(&EnvSpec::new("ocean/multiagent"), cfg(4, 2, 2, false))
                .unwrap();
        assert_eq!(v.agents_per_env(), 2);
        assert_eq!(v.batch_rows(), 4);
        drop(v);
    }

    #[test]
    fn protocol_misuse_errors() {
        let mut v =
            Multiprocessing::from_spec(&EnvSpec::new("ocean/bandit"), cfg(2, 1, 2, false)).unwrap();
        assert!(v.send(&[0, 0]).is_err(), "send before recv");
        v.async_reset(0);
        let _ = v.recv().unwrap();
        assert!(v.recv().is_err(), "double recv");
    }

    /// Env whose observation is exactly the seed its last reset received
    /// — the probe for reset-seed epoch mixing.
    struct SeedEcho {
        seed: f32,
    }
    impl crate::emulation::StructuredEnv for SeedEcho {
        fn observation_space(&self) -> Space {
            Space::boxf(&[1], 0.0, 1e9)
        }
        fn action_space(&self) -> Space {
            Space::Discrete(2)
        }
        fn reset(&mut self, seed: u64) -> Value {
            self.seed = seed as f32;
            Value::F32(vec![self.seed])
        }
        fn step(&mut self, _a: &Value) -> (Value, f32, bool, bool, crate::emulation::Info) {
            (Value::F32(vec![self.seed]), 0.0, false, false, vec![])
        }
    }

    /// Regression for the reset-seed epoch race: async_reset published
    /// the new seed *before* quiescing workers, so with back-to-back
    /// resets a worker still processing reset A could load seed B. The
    /// fix quiesces all workers first (phase 1), then publishes the seed
    /// and the RESET flags (phase 2); with the old order this test is
    /// racy, with the new order it must always pass.
    #[test]
    fn reset_seed_epochs_never_mix() {
        let mut v = Multiprocessing::from_factory(
            |_i| Box::new(crate::emulation::PufferEnv::new(SeedEcho { seed: -1.0 })) as Box<dyn FlatEnv>,
            cfg(8, 4, 8, false),
        )
        .unwrap();
        let w = v.obs_layout().byte_len();
        for round in 0..20u64 {
            let (a, b) = (1000 * round + 100, 1000 * round + 200);
            v.async_reset(a);
            v.async_reset(b); // immediately supersedes A
            let obs: Vec<f32> = {
                let batch = v.recv().unwrap();
                batch
                    .obs
                    .chunks_exact(w)
                    .map(|row| f32::from_le_bytes(row[0..4].try_into().unwrap()))
                    .collect()
            };
            // Sync mode: rows come back in env-id order 0..8.
            for env_id in 0..8usize {
                assert_eq!(
                    obs[env_id],
                    (b + env_id as u64) as f32,
                    "env {env_id} reset with a stale seed epoch"
                );
            }
            v.send(&vec![0i32; 8]).unwrap();
        }
    }

    /// Dropping the vectorizer while workers are mid-step (flags still
    /// ACTIONS_READY) must join every worker: the SHUTDOWN store lands
    /// while a worker is stepping, the worker's completion CAS fails,
    /// and it exits instead of stranding itself in the next wait. With
    /// the old blind `store(OBS_READY)` this could hang forever.
    #[test]
    fn drop_mid_step_joins_straggler_workers() {
        use crate::envs::profile::{ProfileConfig, ProfileSim};
        let factory = |i: usize| -> Box<dyn FlatEnv> {
            // 2ms steps: drop() below lands while workers are stepping.
            Box::new(crate::emulation::PufferEnv::new(ProfileSim::new(
                ProfileConfig::synthetic(2000.0, 0.0, 0.0, 4),
                i as u64,
            )))
        };
        let mut v = Multiprocessing::from_factory(factory, cfg(4, 4, 4, false)).unwrap();
        v.async_reset(0);
        let slots = v.action_dims().len();
        let rows = v.batch_rows();
        let _ = v.recv().unwrap();
        v.send(&vec![0i32; rows * slots]).unwrap();
        // Workers are now inside env.step; Drop must still join them all.
        drop(v);
    }
}
