//! The autotune utility (paper §3.3): *"Obtaining the best configuration
//! for your environment and hardware requires testing all four code paths.
//! We provide an utility that benchmarks valid vectorization settings."*

use super::{Multiprocessing, Serial, VecBatch, VecConfig, VecEnv, VecSpec};
use crate::util::json::{self, Json};
use crate::util::timer::Timer;
use crate::wrappers::EnvSpec;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Result of benchmarking one candidate configuration.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub label: String,
    pub cfg: VecConfig,
    /// Aggregate environment steps per second (env-steps, not agent-steps).
    pub sps: f64,
}

impl TuneResult {
    /// This candidate as a declarative [`VecSpec`] — the machine-readable
    /// form `puffer autotune` emits and `vec = "auto"` consumes.
    pub fn vec_spec(&self) -> VecSpec {
        if self.label == "serial" {
            return VecSpec::Serial;
        }
        let batch = if self.cfg.batch_size == self.cfg.num_envs {
            VecBatch::Full
        } else if self.cfg.batch_size * 2 == self.cfg.num_envs {
            VecBatch::Half
        } else {
            VecBatch::Envs(self.cfg.batch_size)
        };
        VecSpec::Mt {
            workers: self.cfg.num_workers,
            batch,
            zero_copy: self.cfg.zero_copy,
            spin_budget: self.cfg.spin_budget,
        }
    }
}

/// Benchmark every valid backend/code-path combination for `duration`
/// seconds each and return results sorted best-first.
///
/// The candidate env (including any wrapper chain — tuning with the
/// exact pipeline you will train with matters, since e.g. stacking
/// changes the bytes moved per step) is described by an [`EnvSpec`].
/// `num_envs` is the env budget; worker counts and batch sizes are swept
/// over the divisors that produce each of the four code paths plus the
/// serial baseline.
pub fn autotune(
    spec: &EnvSpec,
    num_envs: usize,
    max_workers: usize,
    duration_secs: f64,
) -> Result<Vec<TuneResult>> {
    let mut results = Vec::new();

    // Serial reference.
    {
        let cfg = VecConfig {
            num_envs,
            num_workers: 1,
            batch_size: num_envs,
            ..Default::default()
        };
        let v = Serial::from_spec(spec, cfg.clone())?;
        let sps = measure(v, duration_secs)?;
        results.push(TuneResult {
            label: "serial".into(),
            cfg,
            sps,
        });
    }

    let worker_counts: Vec<usize> = [1, 2, 4, 8, 16]
        .into_iter()
        .filter(|&w| w <= max_workers && w <= num_envs && num_envs % w == 0)
        .collect();

    for &workers in &worker_counts {
        let epw = num_envs / workers;
        // Candidate (batch, zero_copy, label) per code path.
        let mut candidates: Vec<(usize, bool, String)> =
            vec![(num_envs, false, format!("sync w={workers}"))];
        if workers > 1 {
            candidates.push((epw, false, format!("pool-single w={workers}")));
            if num_envs / 2 >= epw && (num_envs / 2) % epw == 0 && num_envs / 2 != epw {
                candidates.push((num_envs / 2, false, format!("pool-half w={workers}")));
                candidates.push((num_envs / 2, true, format!("zero-copy-half w={workers}")));
            }
        }
        for (batch, zero_copy, label) in candidates {
            let cfg = VecConfig {
                num_envs,
                num_workers: workers,
                batch_size: batch,
                zero_copy,
                ..Default::default()
            };
            if cfg.mode().is_err() {
                continue;
            }
            let v = Multiprocessing::from_spec(spec, cfg.clone())?;
            let sps = measure(v, duration_secs)?;
            results.push(TuneResult { label, cfg, sps });
        }
    }

    // PANIC: sps is steps over a positive duration — finite, never NaN.
    results.sort_by(|a, b| b.sps.partial_cmp(&a.sps).unwrap());
    Ok(results)
}

/// Drive a backend with no-op actions for `secs`, returning env-steps/sec.
pub fn measure<V: VecEnv>(mut v: V, secs: f64) -> Result<f64> {
    let slots = v.action_dims().len();
    let rows = v.batch_rows();
    let batch_envs = v.batch_size();
    let actions = vec![0i32; rows * slots];
    v.async_reset(0);
    // Warmup.
    for _ in 0..3 {
        let _ = v.recv()?;
        v.send(&actions)?;
    }
    let t = Timer::start();
    let mut steps = 0u64;
    while t.secs() < secs {
        let _ = v.recv()?;
        v.send(&actions)?;
        steps += batch_envs as u64;
    }
    Ok(steps as f64 / t.secs())
}

/// The fastest *trainable* candidate: the policy forward takes exactly
/// full (`N == M`) or half (`N == M/2`) batches, so e.g. a winning
/// `pool-single` config (one worker's slab per recv) cannot feed the
/// trainer. The serial baseline always qualifies, so on a sorted result
/// list this cannot come up empty. Every cache writer must go through
/// this filter — `read_cache` does not re-validate trainability.
pub fn trainable_winner(results: &[TuneResult], num_envs: usize) -> &TuneResult {
    results
        .iter()
        .find(|r| r.cfg.batch_size == num_envs || r.cfg.batch_size * 2 == num_envs)
        .unwrap_or(&results[0])
}

// -- the `vec = "auto"` cache -----------------------------------------------

/// Benchmark budget per candidate when `vec = "auto"` has no cached
/// winner: long enough to separate the code paths, short enough that a
/// cold-cache construction stays interactive.
pub const AUTO_SECS_PER_CANDIDATE: f64 = 0.15;

/// Where the autotune winner is cached: `<run_dir>/autotune.json`, or
/// `./autotune.json` when the run has no directory.
pub fn cache_path(run_dir: Option<&str>) -> PathBuf {
    match run_dir {
        Some(dir) => Path::new(dir).join("autotune.json"),
        None => PathBuf::from("autotune.json"),
    }
}

/// The cache entry: winning [`VecSpec`] keyed by env-spec key and env
/// count (a cached winner for a different env or scale is stale).
pub fn write_cache(path: &Path, env_key: &str, num_envs: usize, spec: &VecSpec) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let entry = json::obj(vec![
        ("env", json::s(env_key)),
        ("num_envs", json::num(num_envs as f64)),
        ("vec", spec.to_json()),
    ]);
    std::fs::write(path, entry.dump())
        .with_context(|| format!("writing autotune cache {}", path.display()))
}

/// Read a cached winner, returning `None` when the file is absent or was
/// tuned for a different env/scale (malformed files are an error — a
/// corrupt cache should fail loudly, not silently re-benchmark).
pub fn read_cache(path: &Path, env_key: &str, num_envs: usize) -> Result<Option<VecSpec>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("autotune cache {} is corrupt: {e}", path.display()))?;
    if j.get("env").as_str() != Some(env_key) || j.get("num_envs").as_usize() != Some(num_envs) {
        return Ok(None);
    }
    VecSpec::from_json(j.get("vec")).map(Some)
}

/// Resolve `vec = "auto"`: consume the cached winner under `run_dir` if
/// it matches this env + scale, otherwise benchmark (a short sweep —
/// `secs` per candidate, workers capped at the host's parallelism) and
/// cache the [`trainable_winner`] for subsequent runs.
pub fn resolve_auto(
    env: &EnvSpec,
    num_envs: usize,
    run_dir: Option<&str>,
    secs: f64,
) -> Result<VecSpec> {
    let path = cache_path(run_dir);
    if let Some(cached) = read_cache(&path, &env.key(), num_envs)? {
        return Ok(cached);
    }
    let max_workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let results = autotune(env, num_envs, max_workers, secs)?;
    let spec = trainable_winner(&results, num_envs).vec_spec();
    write_cache(&path, &env.key(), num_envs, &spec)?;
    Ok(spec)
}

/// Pretty-print tune results as an aligned table.
pub fn format_results(results: &[TuneResult]) -> String {
    let mut out = String::from(
        "rank  config                    workers  batch  zero_copy        SPS\n",
    );
    for (i, r) in results.iter().enumerate() {
        out.push_str(&format!(
            "{:>4}  {:<24}  {:>7}  {:>5}  {:>9}  {:>9.0}\n",
            i + 1,
            r.label,
            r.cfg.num_workers,
            r.cfg.batch_size,
            r.cfg.zero_copy,
            r.sps
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_round_trips_and_rejects_stale_entries() {
        let dir = std::env::temp_dir().join("puffer_autotune_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("autotune.json");
        let spec = VecSpec::pooled(4);
        write_cache(&path, "ocean/squared", 8, &spec).unwrap();
        assert_eq!(read_cache(&path, "ocean/squared", 8).unwrap(), Some(spec));
        // Different env or scale → cache miss, not a wrong answer.
        assert_eq!(read_cache(&path, "ocean/bandit", 8).unwrap(), None);
        assert_eq!(read_cache(&path, "ocean/squared", 16).unwrap(), None);
        // Absent file → None; corrupt file → loud error.
        assert_eq!(read_cache(&dir.join("nope.json"), "x", 1).unwrap(), None);
        std::fs::write(&path, "not json").unwrap();
        assert!(read_cache(&path, "ocean/squared", 8).is_err());
    }

    #[test]
    fn resolve_auto_benchmarks_once_then_consumes_the_cache() {
        let dir = std::env::temp_dir().join("puffer_resolve_auto_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let run_dir = dir.to_str().unwrap();
        let env = EnvSpec::new("ocean/squared");
        let first = resolve_auto(&env, 4, Some(run_dir), 0.02).unwrap();
        assert!(!first.is_auto());
        assert!(cache_path(Some(run_dir)).exists());
        // Second resolution must come from the cache (same answer, no
        // re-benchmark): poison the bench by asking for an absurd spec —
        // the cached value wins regardless.
        let second = resolve_auto(&env, 4, Some(run_dir), 0.02).unwrap();
        assert_eq!(first, second);
    }

    #[test]
    fn winner_converts_to_a_vec_spec() {
        let serial = TuneResult {
            label: "serial".into(),
            cfg: VecConfig {
                num_envs: 8,
                num_workers: 1,
                batch_size: 8,
                ..Default::default()
            },
            sps: 1.0,
        };
        assert_eq!(serial.vec_spec(), VecSpec::Serial);
        let half = TuneResult {
            label: "zero-copy-half w=4".into(),
            cfg: VecConfig {
                num_envs: 8,
                num_workers: 4,
                batch_size: 4,
                zero_copy: true,
                ..Default::default()
            },
            sps: 1.0,
        };
        match half.vec_spec() {
            VecSpec::Mt {
                workers,
                batch,
                zero_copy,
                ..
            } => {
                assert_eq!((workers, batch, zero_copy), (4, VecBatch::Half, true));
            }
            other => panic!("expected mt, got {other:?}"),
        }
    }

    #[test]
    fn autotune_covers_code_paths_and_ranks() {
        let spec = EnvSpec::new("ocean/squared");
        let results = autotune(&spec, 4, 2, 0.05).unwrap();
        assert!(results.len() >= 3, "too few candidates: {results:?}");
        // Sorted best-first.
        for pair in results.windows(2) {
            assert!(pair[0].sps >= pair[1].sps);
        }
        // Serial is always among the candidates.
        assert!(results.iter().any(|r| r.label == "serial"));
        let table = format_results(&results);
        assert!(table.contains("serial"));
    }
}
