//! Vectorization (paper §3.3): simulate many environments in parallel,
//! aggregate observations, distribute actions — implemented from scratch
//! with the paper's full feature set:
//!
//! - **Serial** and **Multiprocessing** backends behind one [`VecEnv`] API
//!   (the paper's Ray backend maps to nothing useful in-process; the
//!   [`baselines`] module instead reimplements the Gymnasium and SB3
//!   designs for the Table 2 comparison).
//! - **EnvPool semantics**: `recv` can return the first `N ≪ M`
//!   environments to finish, so the learner never waits for stragglers and
//!   simulation double-buffers against inference (`M = 2N`).
//! - **Multiple environments per worker**, stacked into preallocated
//!   shared buffers with no extra copies.
//! - **Shared memory + busy-wait flags** for signaling; a channel is used
//!   only for (rare, non-empty) infos.
//! - **Four separately optimized code paths** ([`Mode`]): `Sync`,
//!   `Async`, `AsyncSingleWorker`, and `ZeroCopy`.
//! - An [`autotune`] utility that benchmarks all valid settings.
//! - A declarative [`VecSpec`] (`serial` | `mt { workers, batch,
//!   zero_copy, spin_budget }` | `auto`) — the public construction
//!   path: `VecSpec::build(&env_spec, num_envs, seed)` resolves into a
//!   validated [`VecConfig`] and boxes the right backend, and is what a
//!   [`RunSpec`](crate::runspec::RunSpec)'s `[vec]` section
//!   deserializes into. `auto` resolves through the autotune cache
//!   ([`autotune::resolve_auto`]).
//!
//! The API follows PufferLib's async triple: [`VecEnv::async_reset`], then
//! alternate [`VecEnv::recv`] / [`VecEnv::send`].
//!
//! Vectorization takes a **hard dependency on emulation** (paper §3.3):
//! every backend works exclusively on [`FlatEnv`]s, whose fixed-size byte
//! rows are what make shared slabs and zero-copy batching possible.

pub mod autotune;
pub mod baselines;
mod multiproc;
mod serial;
pub mod shared;
mod spec;

pub use multiproc::Multiprocessing;
pub use serial::Serial;
pub use spec::{VecBatch, VecSpec};

use crate::emulation::{FlatEnv, Info};
use crate::spaces::StructLayout;
use anyhow::Result;

/// Factory that builds env instance `i` of `num_envs`. Must be callable
/// from worker threads.
///
/// This is the low-level form; the public construction currency is
/// [`EnvSpec`](crate::wrappers::EnvSpec) (`Serial::from_spec`,
/// `Multiprocessing::from_spec`), which carries the wrapper chain and
/// converts to a factory internally.
pub type EnvFactory = Box<dyn Fn(usize) -> Box<dyn FlatEnv> + Send + Sync>;

/// Vectorization settings.
#[derive(Clone, Debug)]
pub struct VecConfig {
    /// Total simulated environments `M`.
    pub num_envs: usize,
    /// Worker threads `W`; each owns `M / W` envs (must divide evenly).
    pub num_workers: usize,
    /// Environments returned per `recv` (`N`). `N == M` selects the
    /// synchronous path; `N < M` enables pooling (the Python-EnvPool
    /// analog). Must be a multiple of `M / W`.
    pub batch_size: usize,
    /// Opt into the zero-copy band-rotation path when
    /// `batch_size > envs_per_worker` (see [`Mode::ZeroCopy`]).
    pub zero_copy: bool,
    /// Busy-wait iterations before yielding the core (paper: workers
    /// busy-wait on an unlocked shared flag; the yield fallback keeps
    /// oversubscribed hosts live).
    pub spin_budget: u32,
    /// Base seed; env `i` resets with `seed + i`.
    pub seed: u64,
}

impl Default for VecConfig {
    fn default() -> Self {
        VecConfig {
            num_envs: 1,
            num_workers: 1,
            batch_size: 1,
            zero_copy: false,
            spin_budget: 64,
            seed: 0,
        }
    }
}

/// The four separately optimized code paths (paper §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// `N == M`: envs split evenly across cores, observations land in one
    /// contiguous shared slab — the batch is the slab, no extra copy.
    Sync,
    /// `N < M`: take the first workers to finish; one gather copy into a
    /// contiguous batch buffer.
    Async,
    /// `N == envs_per_worker`: a batch is exactly one worker's slab
    /// region, handed out without a copy.
    AsyncSingleWorker,
    /// `N` a multiple of `envs_per_worker`, workers grouped into
    /// contiguous bands claimed in rotation — a circular buffer of
    /// batches, no copies, at the cost of in-order claiming.
    ZeroCopy,
}

impl VecConfig {
    /// Envs per worker (`M / W`).
    pub fn envs_per_worker(&self) -> usize {
        self.num_envs / self.num_workers
    }

    /// Resolve the code path and validate the configuration.
    pub fn mode(&self) -> Result<Mode> {
        anyhow::ensure!(self.num_envs > 0 && self.num_workers > 0, "empty vec config");
        anyhow::ensure!(
            self.num_envs % self.num_workers == 0,
            "num_envs {} must divide evenly across {} workers",
            self.num_envs,
            self.num_workers
        );
        let epw = self.envs_per_worker();
        anyhow::ensure!(
            self.batch_size > 0 && self.batch_size <= self.num_envs,
            "batch_size {} must be in [1, num_envs {}]",
            self.batch_size,
            self.num_envs
        );
        anyhow::ensure!(
            self.batch_size % epw == 0,
            "batch_size {} must be a multiple of envs_per_worker {epw} \
             (batches are claimed at worker granularity)",
            self.batch_size
        );
        Ok(if self.batch_size == self.num_envs {
            Mode::Sync
        } else if self.batch_size == epw {
            Mode::AsyncSingleWorker
        } else if self.zero_copy {
            Mode::ZeroCopy
        } else {
            Mode::Async
        })
    }
}

/// One batch of step results. `obs` is a contiguous
/// `batch_rows × byte_len` view — borrowed straight from shared memory on
/// the no-copy paths.
pub struct StepBatch<'a> {
    /// Indices of the envs in this batch, in row order.
    pub env_ids: &'a [usize],
    /// Packed observation rows.
    pub obs: &'a [u8],
    pub rewards: &'a [f32],
    pub terms: &'a [bool],
    pub truncs: &'a [bool],
    /// Non-empty infos drained this step: `(env_id, info)`.
    pub infos: Vec<(usize, Info)>,
}

/// A vectorized environment. Drive it with the async triple:
///
/// ```text
/// venv.async_reset(seed);
/// loop {
///     let batch = venv.recv()?;            // obs for N envs
///     let actions = policy(batch.obs);     // batch_rows × slots i32
///     venv.send(&actions)?;                // routed to those same envs
/// }
/// ```
///
/// `Send` is a supertrait: the pipelined trainer drives the venv from a
/// dedicated collector thread, so every backend must be movable (and
/// `&mut`-borrowable) across threads. All env state is already `Send`
/// ([`FlatEnv`] requires it), so implementations get this for free.
pub trait VecEnv: Send {
    fn obs_layout(&self) -> &StructLayout;
    fn action_dims(&self) -> &[usize];
    /// Agent rows per env (1 for single-agent envs).
    fn agents_per_env(&self) -> usize;
    fn num_envs(&self) -> usize;
    /// Envs per batch (`N`).
    fn batch_size(&self) -> usize;
    /// Rows per batch: `batch_size × agents_per_env`.
    fn batch_rows(&self) -> usize {
        self.batch_size() * self.agents_per_env()
    }
    /// Dispatch resets to all envs. The following `recv`s deliver reset
    /// observations (rewards zeroed).
    fn async_reset(&mut self, seed: u64);
    /// Block until the next batch of `batch_size` envs is ready.
    fn recv(&mut self) -> Result<StepBatch<'_>>;
    /// Send actions (`batch_rows × action_dims().len()` slots, row order
    /// matching the last `recv`) to those envs.
    fn send(&mut self, actions: &[i32]) -> Result<()>;

    /// Convenience: reset + first recv, copying the batch out (tests,
    /// examples).
    fn reset(&mut self, seed: u64) -> Result<(Vec<u8>, Vec<f32>, Vec<bool>, Vec<bool>, Vec<(usize, Info)>)> {
        self.async_reset(seed);
        let b = self.recv()?;
        Ok((
            b.obs.to_vec(),
            b.rewards.to_vec(),
            b.terms.to_vec(),
            b.truncs.to_vec(),
            b.infos,
        ))
    }
}

/// Validate that every env a factory produces agrees on layout/spaces;
/// returns the canonical (layout, action_dims, agents_per_env) probed from
/// env 0.
pub(crate) fn probe_factory(factory: &EnvFactory) -> (StructLayout, Vec<usize>, usize) {
    let probe = factory(0);
    (
        probe.obs_layout().clone(),
        probe.action_dims().to_vec(),
        probe.num_agents(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_resolution() {
        let mk = |num_envs, num_workers, batch_size, zero_copy| VecConfig {
            num_envs,
            num_workers,
            batch_size,
            zero_copy,
            ..Default::default()
        };
        assert_eq!(mk(8, 4, 8, false).mode().unwrap(), Mode::Sync);
        assert_eq!(mk(8, 4, 2, false).mode().unwrap(), Mode::AsyncSingleWorker);
        assert_eq!(mk(8, 4, 4, false).mode().unwrap(), Mode::Async);
        assert_eq!(mk(8, 4, 4, true).mode().unwrap(), Mode::ZeroCopy);
        // batch not multiple of envs/worker
        assert!(mk(8, 4, 3, false).mode().is_err());
        // envs don't divide across workers
        assert!(mk(9, 4, 3, false).mode().is_err());
        // zero batch
        assert!(mk(8, 4, 0, false).mode().is_err());
        // batch > envs
        assert!(mk(8, 4, 16, false).mode().is_err());
    }
}
