//! Plain-data experiment-ops configuration — the `[runs]` section of a
//! [`RunSpec`](crate::runspec::RunSpec). The registry, resumable
//! sweeps, heartbeats, and `puffer ps`/`top` live in `puffer-train`,
//! which re-exports this type under the same `runs::` path.

// Plain data; no unsafe belongs here (CONCURRENCY.md).
#![forbid(unsafe_code)]

/// The strict `[runs]` section of a [`RunSpec`](crate::runspec::RunSpec)
/// and the `--runs.*` CLI namespace. Plain data, TOML/JSON
/// round-trippable like every other spec part; `None` on a spec means
/// "defaults" — registry logging is always on for runs with a run dir.
#[derive(Clone, Debug, PartialEq)]
pub struct RunsConfig {
    /// Registry root: where `index.jsonl` lives. Relative paths resolve
    /// against the working directory, like `train.run_dir`.
    pub root: String,
    /// Heartbeat period in seconds. Staleness is judged at
    /// `max(3 × period, 10 s)` (`heartbeat::stale_after_s` in
    /// `puffer-train`).
    pub heartbeat_s: f64,
}

impl Default for RunsConfig {
    fn default() -> Self {
        RunsConfig {
            root: "runs".to_string(),
            heartbeat_s: 5.0,
        }
    }
}

impl RunsConfig {
    /// The flat `runs.*` pairs (serialization form, mirroring
    /// [`ServeConfig`](crate::serve::ServeConfig)).
    pub fn to_flat_pairs(&self) -> Vec<(&'static str, String)> {
        vec![
            ("root", self.root.clone()),
            ("heartbeat_s", fmt_f64(self.heartbeat_s)),
        ]
    }

    /// The effective config for a spec: its `[runs]` section, or
    /// defaults when the section is absent.
    pub fn for_spec(spec: &crate::runspec::RunSpec) -> RunsConfig {
        spec.runs.clone().unwrap_or_default()
    }
}

/// Format an f64 so it round-trips through the flat string form
/// (integral values print without a fraction, like the JSON dumper).
pub fn fmt_f64(x: f64) -> String {
    if x == x.trunc() && x.is_finite() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_pairs_round_trip_defaults() {
        let cfg = RunsConfig::default();
        let pairs = cfg.to_flat_pairs();
        assert_eq!(
            pairs,
            vec![
                ("root", "runs".to_string()),
                ("heartbeat_s", "5".to_string()),
            ]
        );
        assert_eq!(fmt_f64(2.5), "2.5");
        assert_eq!(fmt_f64(5.0), "5");
    }
}
