//! Plain-data backend configuration — the kernel-path selector the
//! `train.kernels` spec key parses into. The backends themselves
//! (`NativeBackend`, the PJRT path, the kernel tree) live in
//! `puffer-train`, which re-exports this type under
//! `backend::KernelPath` / `backend::kernels::KernelPath`.

// Plain data; no unsafe belongs here (CONCURRENCY.md).
#![forbid(unsafe_code)]

/// Which kernel implementation the backend dispatches to (see
/// `puffer-train`'s `backend::kernels` module docs). Selected per run
/// via `train.kernels` / `--train.kernels`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelPath {
    /// Bit-exact scalar reference kernels (the pre-kernel-module math).
    Scalar,
    /// Lane-tiled, multithreaded kernels (tolerance-validated).
    #[default]
    Simd,
}

impl std::str::FromStr for KernelPath {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "scalar" => Ok(KernelPath::Scalar),
            "simd" => Ok(KernelPath::Simd),
            // ALLOC-OK: config-parse error path, not kernel code.
            other => Err(format!("unknown kernel path '{other}' (scalar|simd)")),
        }
    }
}

impl std::fmt::Display for KernelPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Simd => "simd",
        })
    }
}
