//! The synchronization facade every cross-thread protocol in this crate
//! is written against — and the seam that makes those protocols
//! **model-checkable**.
//!
//! Under a normal build these are straight re-exports of `std` types
//! with zero overhead. Under `RUSTFLAGS="--cfg loom"` (the `make loom`
//! lane) they swap to [loom](https://docs.rs/loom)'s instrumented
//! doubles, and `crates/puffer-train/tests/loom_models.rs` exhaustively explores every
//! interleaving of the protocols built on top:
//!
//! - the worker↔driver slab-ownership handoff
//!   ([`Flag`](crate::vector::shared::Flag)),
//! - the learner→collector parameter publish/acquire
//!   (`ParamSnapshot` in `puffer-train`),
//! - the rotating rollout-buffer exchange ([`queue`]),
//! - shutdown/reset-seed delivery
//!   ([`Multiprocessing`](crate::vector::Multiprocessing)).
//!
//! The rules for which primitive to use where, and the memory-ordering
//! contract each protocol relies on, live in `CONCURRENCY.md`.
//! New cross-thread state must go through this module (not `std::sync`
//! directly) so it stays inside the model-checked surface; the
//! exceptions, documented there, are `std::sync::mpsc` for the
//! fire-and-forget info channel and the debug-only aliasing sentinel's
//! internal mutex.

// The facade re-exports and builds on safe primitives only; the unsafe
// surface stays in vector/ (CONCURRENCY.md).
#![forbid(unsafe_code)]

pub mod queue;

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Atomics with the same paths under std and loom.
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};

    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

/// Busy-wait pause inside a spin loop. Under loom this is a yield — a
/// scheduling point the model checker uses to bound spin exploration.
#[inline]
pub fn spin_loop_hint() {
    #[cfg(not(loom))]
    std::hint::spin_loop();
    #[cfg(loom)]
    loom::thread::yield_now();
}

/// Give the core away (oversubscribed hosts, long waits).
#[inline]
pub fn yield_now() {
    #[cfg(not(loom))]
    std::thread::yield_now();
    #[cfg(loom)]
    loom::thread::yield_now();
}

/// Lock a facade mutex, recovering from poisoning.
///
/// Every mutex behind this facade guards state whose invariants hold
/// between (not within) critical sections that cannot panic mid-update,
/// so a poisoned lock only means *some other* thread panicked — its
/// protected data is still consistent and the caller may proceed. This
/// keeps a collector-thread panic from cascading into an opaque learner
/// panic; the original error still surfaces through the pipeline's
/// channel/join paths.
#[inline]
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_unpoisoned_recovers_after_a_panicking_holder() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*lock_unpoisoned(&m), 7, "state intact despite poison");
    }
}
