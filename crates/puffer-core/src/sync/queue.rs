//! A small closable blocking queue (Mutex + Condvar) — the transport of
//! the trainer's rotating-buffer pipeline.
//!
//! `std::sync::mpsc` would do the same job, but it cannot be swapped for
//! loom's doubles, which would leave the collector↔learner handover —
//! precisely the protocol whose hangup/backpressure behavior decides
//! whether shutdown can deadlock — outside the model-checked surface.
//! This queue is built purely on the [`crate::sync`] facade, so the
//! *production* rotation code runs under loom verbatim
//! (`tests/loom_models.rs::rotation_*`).
//!
//! Semantics (mirrors the `mpsc` subset the trainer used):
//!
//! - multi-producer ([`Sender`] is `Clone`), single-consumer;
//! - optional capacity: [`Sender::send`] blocks while full;
//! - hangup is a value, not a panic: `send` returns the item back once
//!   the [`Receiver`] is dropped, `recv` returns `None` once the queue
//!   is empty and every `Sender` is dropped. Both sides use that as
//!   their exit signal, so either side can abandon the pipeline (learner
//!   error, collector quota reached) without stranding the other.

use super::{lock_unpoisoned, Arc, Condvar, Mutex};
use std::collections::VecDeque;

struct State<T> {
    items: VecDeque<T>,
    /// `usize::MAX` = unbounded.
    cap: usize,
    senders: usize,
    rx_alive: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    /// One condvar for both directions: senders wait for space or
    /// rx-drop, the receiver waits for items or sender-drop. Cheap at
    /// this scale (2 threads, segment-sized items) and keeps the loom
    /// state space small.
    cond: Condvar,
}

/// Create a queue. `cap = None` is unbounded; `Some(n)` blocks senders
/// once `n` items are in flight (the trainer's filled-segment queue uses
/// `depth + 1`, though the buffer pool itself is the real bound).
pub fn channel<T>(cap: Option<usize>) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            items: VecDeque::new(),
            cap: cap.unwrap_or(usize::MAX),
            senders: 1,
            rx_alive: true,
        }),
        cond: Condvar::new(),
    });
    (
        Sender {
            shared: shared.clone(),
        },
        Receiver { shared },
    )
}

/// Producer endpoint. Cloning registers another producer; the receiver
/// sees hangup only after *all* clones drop.
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Consumer endpoint (not cloneable: single consumer).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Sender<T> {
    /// Blocking send. `Err(item)` hands the item back if the receiver
    /// hung up (now, or while we were blocked on a full queue).
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = lock_unpoisoned(&self.shared.state);
        loop {
            if !st.rx_alive {
                return Err(item);
            }
            if st.items.len() < st.cap {
                st.items.push_back(item);
                self.shared.cond.notify_all();
                return Ok(());
            }
            st = self
                .shared
                .cond
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// Outcome of a [`Receiver::try_recv`] poll.
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecv<T> {
    /// An item was waiting.
    Item(T),
    /// The queue is momentarily empty but senders are still alive.
    Empty,
    /// The queue is drained and every sender has dropped — the
    /// non-blocking twin of [`Receiver::recv`] returning `None`.
    Disconnected,
}

impl<T> Receiver<T> {
    /// Non-blocking receive — the serve batcher's dual-budget collect
    /// loop polls this between forwards instead of parking on `recv`, so
    /// the `max_wait_us` budget stays in the caller's hands.
    pub fn try_recv(&self) -> TryRecv<T> {
        let mut st = lock_unpoisoned(&self.shared.state);
        if let Some(item) = st.items.pop_front() {
            // Wake a sender blocked on capacity.
            self.shared.cond.notify_all();
            return TryRecv::Item(item);
        }
        if st.senders == 0 {
            TryRecv::Disconnected
        } else {
            TryRecv::Empty
        }
    }

    /// Blocking receive. `None` once the queue is drained and every
    /// sender has dropped.
    pub fn recv(&self) -> Option<T> {
        let mut st = lock_unpoisoned(&self.shared.state);
        loop {
            if let Some(item) = st.items.pop_front() {
                // Wake a sender blocked on capacity.
                self.shared.cond.notify_all();
                return Some(item);
            }
            if st.senders == 0 {
                return None;
            }
            st = self
                .shared
                .cond
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        lock_unpoisoned(&self.shared.state).senders += 1;
        Sender {
            shared: self.shared.clone(),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = lock_unpoisoned(&self.shared.state);
        st.senders -= 1;
        if st.senders == 0 {
            self.shared.cond.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        lock_unpoisoned(&self.shared.state).rx_alive = false;
        // Unblock senders parked on a full queue so they see the hangup.
        self.shared.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_round_trip() {
        let (tx, rx) = channel::<u32>(None);
        for v in 0..4 {
            assert!(tx.send(v).is_ok());
        }
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        drop(tx);
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
        assert_eq!(rx.recv(), None, "drained + senders gone = hangup");
    }

    #[test]
    fn send_after_receiver_drop_returns_the_item() {
        let (tx, rx) = channel::<String>(None);
        drop(rx);
        assert_eq!(tx.send("boomerang".into()), Err("boomerang".into()));
    }

    #[test]
    fn bounded_send_blocks_until_space_or_hangup() {
        let (tx, rx) = channel::<u32>(Some(1));
        assert!(tx.send(1).is_ok());
        let blocked = thread::spawn(move || tx.send(2));
        // The blocked sender is released by the recv freeing a slot.
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(blocked.join().expect("sender thread"), Ok(()));
        assert_eq!(rx.recv(), Some(2));
    }

    #[test]
    fn receiver_drop_releases_a_blocked_sender() {
        let (tx, rx) = channel::<u32>(Some(1));
        assert!(tx.send(1).is_ok());
        let blocked = thread::spawn(move || tx.send(2));
        // Let the sender reach the full-queue wait, then hang up.
        thread::sleep(std::time::Duration::from_millis(20));
        drop(rx);
        assert_eq!(blocked.join().expect("sender thread"), Err(2));
    }

    #[test]
    fn clones_keep_the_queue_open() {
        let (tx, rx) = channel::<u32>(None);
        let tx2 = tx.clone();
        drop(tx);
        assert!(tx2.send(9).is_ok());
        drop(tx2);
        assert_eq!(rx.recv(), Some(9));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn try_recv_reports_all_three_states() {
        let (tx, rx) = channel::<u32>(None);
        assert_eq!(rx.try_recv(), TryRecv::Empty);
        tx.send(5).unwrap();
        assert_eq!(rx.try_recv(), TryRecv::Item(5));
        assert_eq!(rx.try_recv(), TryRecv::Empty);
        tx.send(6).unwrap();
        drop(tx);
        // Drained-then-disconnected, never items lost to the hangup.
        assert_eq!(rx.try_recv(), TryRecv::Item(6));
        assert_eq!(rx.try_recv(), TryRecv::Disconnected);
    }

    #[test]
    fn try_recv_frees_a_blocked_sender() {
        let (tx, rx) = channel::<u32>(Some(1));
        assert!(tx.send(1).is_ok());
        let blocked = thread::spawn(move || tx.send(2));
        loop {
            match rx.try_recv() {
                TryRecv::Item(v) => {
                    assert_eq!(v, 1);
                    break;
                }
                _ => thread::yield_now(),
            }
        }
        assert_eq!(blocked.join().expect("sender thread"), Ok(()));
        assert_eq!(rx.recv(), Some(2));
    }

    #[test]
    fn cross_thread_stream() {
        let (tx, rx) = channel::<u64>(Some(2));
        let producer = thread::spawn(move || {
            for v in 0..200u64 {
                if tx.send(v).is_err() {
                    panic!("receiver died early");
                }
            }
        });
        let mut expected = 0u64;
        while let Some(v) = rx.recv() {
            assert_eq!(v, expected, "FIFO order violated");
            expected += 1;
        }
        assert_eq!(expected, 200);
        producer.join().expect("producer");
    }
}
