//! Plain-data train configuration and reports — the `[train]` section
//! of a [`RunSpec`](crate::runspec::RunSpec) and the result currencies
//! every executor returns.
//!
//! The training *loop* itself (Clean PuffeRL: the experience pipeline,
//! checkpoints, the `Trainer`) lives in `puffer-train`, which
//! re-exports these types under the same `train::` path. Defining the
//! config here keeps the spec layer self-contained: `RunSpec` parsing,
//! serialization, and grid expansion — and therefore the Python
//! bindings — never link trainer code.

// Plain data; no unsafe belongs here (CONCURRENCY.md).
#![forbid(unsafe_code)]

use crate::policy::PolicySpec;
use crate::vector::VecSpec;
use crate::wrappers::WrapperSpec;

/// Training configuration (Clean PuffeRL's YAML keys, as a struct; see
/// [`crate::config`] for the file/CLI layer, and
/// [`RunSpec`](crate::runspec::RunSpec) for the declarative experiment
/// currency that assembles one of these from its `[train]` section).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// First-party env name, e.g. "ocean/squared".
    pub env: String,
    /// Wrapper chain applied over the env, innermost first (the
    /// `train.wrap.*` config keys / `--wrap.*` CLI overrides). The whole
    /// pipeline — probe, backend spec, vectorizer slabs — sizes itself
    /// from the wrapped geometry.
    pub wrappers: Vec<WrapperSpec>,
    /// Policy architecture (the `train.policy.*` config keys /
    /// `--policy.*` CLI overrides). `None` (default) resolves the env's
    /// default spec — feedforward, except recurrent reference envs,
    /// which get the LSTM sandwich ([`PolicySpec::default_for`]). A
    /// non-default spec becomes part of the backend/checkpoint key, so
    /// parameters never cross architectures silently.
    pub policy: Option<PolicySpec>,
    /// Total environment interactions to train for.
    pub total_steps: u64,
    pub lr: f32,
    pub ent_coef: f32,
    /// PPO epochs per rollout segment.
    pub epochs: usize,
    /// Minibatches per epoch: the segment's agent rows are shuffled and
    /// split into this many row-subset batches (1 = full batch, the
    /// pre-pipeline behavior). Must divide `batch_roll`.
    pub minibatches: usize,
    /// Normalize advantages per minibatch (mean/var) inside the
    /// surrogate loss. Standard PPO; on by default.
    pub norm_adv: bool,
    pub anneal_lr: bool,
    pub seed: u64,
    /// Worker threads for the vectorizer (0 = serial backend). Legacy
    /// knob: ignored when [`TrainConfig::vec`] is set.
    pub num_workers: usize,
    /// EnvPool mode: recv half the envs per batch (M = 2N
    /// double-buffering). Requires `num_workers >= 2`. Legacy knob:
    /// ignored when [`TrainConfig::vec`] is set.
    pub pool: bool,
    /// Declarative vectorization ([`VecSpec`]: `serial`, `mt { … }`, or
    /// `auto`). `None` (default) maps the legacy `num_workers`/`pool`
    /// knobs through [`VecSpec::from_workers_pool`]. `auto` resolves
    /// through the autotune cache under [`TrainConfig::run_dir`].
    pub vec: Option<VecSpec>,
    /// Experience-pipeline depth (`train.pipeline.depth` /
    /// `--pipeline.depth`): 0 = serial loop; d ≥ 1 = a collector thread
    /// runs up to d segments ahead of the learner over d + 1 rotating
    /// buffers.
    pub pipeline_depth: usize,
    /// Optional run directory for metrics.csv + checkpoints.
    pub run_dir: Option<String>,
    /// Console log every n segments (0 = silent).
    pub log_every: usize,
    /// Kernel flavor for the native backend (`train.kernels` /
    /// `--train.kernels`): `simd` (default) = lane-tiled multithreaded
    /// kernels, `scalar` = the bit-exact reference path every
    /// bit-identity pin runs against.
    pub kernels: crate::backend::KernelPath,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            env: "ocean/squared".into(),
            wrappers: Vec::new(),
            policy: None,
            total_steps: 30_000,
            lr: 2.5e-3,
            ent_coef: 0.01,
            epochs: 4,
            minibatches: 1,
            norm_adv: true,
            anneal_lr: true,
            seed: 1,
            num_workers: 2,
            pool: false,
            vec: None,
            pipeline_depth: 0,
            run_dir: None,
            log_every: 5,
            kernels: crate::backend::KernelPath::default(),
        }
    }
}

/// Final report from a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub global_step: u64,
    /// End-to-end env steps per wall-clock second.
    pub sps: f64,
    /// Steps per second of *collection* alone (env stepping + rollout
    /// inference, excluding stalls). Equals learner-side idle capacity
    /// when it exceeds `sps`.
    pub env_sps: f64,
    /// Steps per second of *learning* alone (GAE + PPO epochs,
    /// excluding stalls).
    pub learn_sps: f64,
    /// Seconds the collector spent stalled waiting for a free segment
    /// buffer (pipelined mode; 0 when serial). High values → the learner
    /// is the bottleneck: raise `pipeline.depth` or lower `epochs` /
    /// `minibatches` cost.
    pub collector_stall_s: f64,
    /// Seconds the learner spent stalled waiting for a filled segment
    /// (pipelined mode; 0 when serial). High values → collection is the
    /// bottleneck: add env workers or enable `pool`.
    pub learner_stall_s: f64,
    /// Worst-case parameter staleness observed: how many published
    /// updates the collector's snapshot lagged behind the learner when a
    /// segment was consumed. 0 when serial; bounded by `pipeline_depth`
    /// (the learner publishes before recycling each buffer).
    pub max_param_staleness: u64,
    pub mean_score: Option<f64>,
    pub mean_return: Option<f64>,
    pub episodes: usize,
    pub last_loss: f32,
    /// (global_step, mean_score) curve sampled once per segment.
    pub score_curve: Vec<(u64, f64)>,
}

/// Report from an evaluation run.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub episodes: usize,
    pub mean_score: Option<f64>,
    pub mean_return: Option<f64>,
}
