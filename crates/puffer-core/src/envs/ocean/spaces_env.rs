//! **Spaces** (paper §4): a simple environment with hierarchical
//! observation *and* action spaces. Obtaining the maximal score requires
//! taking every subspace into account — a flattening bug that drops,
//! reorders, or mis-slices any field caps the score at 0.5.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;

/// Hierarchical-space sanity check.
///
/// Observation: `{image: u8[4] of bits, flag: Discrete(2)}`.
/// Action: `{parity: Discrete(2), mirror: Discrete(2)}`.
/// Correct play: `parity = XOR(image)`, `mirror = flag`; each pays 0.5.
pub struct SpacesEnv {
    horizon: u32,
    t: u32,
    image: [u8; 4],
    flag: i64,
    reward_sum: f64,
    rng: Rng,
}

impl SpacesEnv {
    pub fn new(horizon: u32) -> Self {
        assert!(horizon > 0);
        SpacesEnv {
            horizon,
            t: 0,
            image: [0; 4],
            flag: 0,
            reward_sum: 0.0,
            rng: Rng::new(0),
        }
    }

    fn randomize(&mut self) {
        for b in &mut self.image {
            *b = self.rng.below(2) as u8;
        }
        self.flag = self.rng.below(2) as i64;
    }

    fn parity(&self) -> i64 {
        self.image.iter().fold(0u8, |acc, &b| acc ^ b) as i64
    }

    fn obs(&self) -> Value {
        // Keys in canonical (sorted) order: flag < image.
        Value::Dict(vec![
            ("flag".into(), Value::Discrete(self.flag)),
            ("image".into(), Value::U8(self.image.to_vec())),
        ])
    }
}

impl StructuredEnv for SpacesEnv {
    fn observation_space(&self) -> Space {
        Space::dict(vec![
            ("image".into(), Space::boxu8(&[4])),
            ("flag".into(), Space::Discrete(2)),
        ])
    }

    fn action_space(&self) -> Space {
        Space::dict(vec![
            ("parity".into(), Space::Discrete(2)),
            ("mirror".into(), Space::Discrete(2)),
        ])
    }

    fn reset(&mut self, seed: u64) -> Value {
        self.rng = Rng::new(seed ^ 0x5350_4143);
        self.t = 0;
        self.reward_sum = 0.0;
        self.randomize();
        self.obs()
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        let parity = action
            .field("parity")
            .and_then(Value::as_discrete)
            // PANIC: emulation decodes actions against this env's declared space tree.
            .expect("SpacesEnv: action.parity");
        let mirror = action
            .field("mirror")
            .and_then(Value::as_discrete)
            // PANIC: emulation decodes actions against this env's declared space tree.
            .expect("SpacesEnv: action.mirror");

        let mut reward = 0.0;
        if parity == self.parity() {
            reward += 0.5;
        }
        if mirror == self.flag {
            reward += 0.5;
        }
        self.reward_sum += reward as f64;
        self.t += 1;
        let done = self.t >= self.horizon;
        let mut info = Info::new();
        if done {
            info.push(("score", self.reward_sum / self.horizon as f64));
        }
        self.randomize();
        (self.obs(), reward, done, false, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::{check_space_contract, rollout_score};

    fn oracle(obs: &Value) -> Value {
        let image = obs.field("image").unwrap().as_u8s().unwrap();
        let flag = obs.field("flag").unwrap().as_discrete().unwrap();
        let parity = image.iter().fold(0u8, |a, &b| a ^ b) as i64;
        // Action dict in canonical order: mirror < parity.
        Value::Dict(vec![
            ("mirror".into(), Value::Discrete(flag)),
            ("parity".into(), Value::Discrete(parity)),
        ])
    }

    #[test]
    fn space_contract() {
        check_space_contract(&mut SpacesEnv::new(4), 3);
    }

    #[test]
    fn oracle_scores_one() {
        let mut env = SpacesEnv::new(8);
        let score = rollout_score(&mut env, 20, 5, |obs, _| oracle(obs));
        assert_eq!(score, 1.0);
    }

    #[test]
    fn ignoring_one_subspace_caps_at_threequarters() {
        // Right parity, random mirror → 0.5 + 0.25 expected.
        let mut env = SpacesEnv::new(8);
        let score = rollout_score(&mut env, 60, 5, |obs, rng| {
            let mut a = oracle(obs);
            if let Value::Dict(entries) = &mut a {
                entries[0].1 = Value::Discrete(rng.below(2) as i64); // mirror
            }
            a
        });
        assert!((score - 0.75).abs() < 0.06, "score {score}");
    }

    #[test]
    fn random_scores_half() {
        let mut env = SpacesEnv::new(8);
        let aspace = env.action_space();
        let score = rollout_score(&mut env, 60, 5, |_, rng| aspace.sample(rng));
        assert!((score - 0.5).abs() < 0.06, "score {score}");
    }
}
