//! **Password** (paper §4): guess a static binary string. The reward is
//! sparse — exactly 1 when the full guess matches, else 0 — so *"the
//! policy has to not determinize before it happens to get the reward, and
//! it also has to latch onto the reward within a few instances of getting
//! it"*. Catches premature entropy collapse and broken advantage signs.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;

/// Sparse-reward binary string guessing.
pub struct Password {
    len: usize,
    password: Vec<i64>,
    guess: Vec<i64>,
    t: usize,
    obs_buf: Vec<f32>,
}

impl Password {
    /// The password is derived from `seed` (the *instance* seed), so it is
    /// static across episodes — that is what makes it learnable.
    pub fn new(len: usize, seed: u64) -> Self {
        assert!((1..=16).contains(&len));
        let mut rng = Rng::new(seed ^ 0x5057_4421);
        let password = (0..len).map(|_| rng.below(2) as i64).collect();
        Password {
            len,
            password,
            guess: Vec::with_capacity(len),
            t: 0,
            obs_buf: vec![0.0; len],
        }
    }

    pub fn password(&self) -> &[i64] {
        &self.password
    }

    /// Observation: one-hot of the current position in the string.
    fn obs(&mut self) -> Value {
        self.obs_buf.fill(0.0);
        if self.t < self.len {
            self.obs_buf[self.t] = 1.0;
        }
        Value::F32(self.obs_buf.clone())
    }
}

impl StructuredEnv for Password {
    fn observation_space(&self) -> Space {
        Space::boxf(&[self.len], 0.0, 1.0)
    }

    fn action_space(&self) -> Space {
        Space::Discrete(2)
    }

    fn reset(&mut self, _seed: u64) -> Value {
        self.guess.clear();
        self.t = 0;
        self.obs()
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        // PANIC: emulation decodes actions against this env's declared Discrete space.
        let bit = action.as_discrete().expect("Password: Discrete action");
        assert!((0..2).contains(&bit), "Password: bit {bit} out of range");
        self.guess.push(bit);
        self.t += 1;
        let done = self.t >= self.len;
        let mut reward = 0.0;
        let mut info = Info::new();
        if done {
            let correct = self.guess == self.password;
            reward = if correct { 1.0 } else { 0.0 };
            info.push(("score", if correct { 1.0 } else { 0.0 }));
        }
        (self.obs(), reward, done, false, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::{check_space_contract, rollout_score};

    #[test]
    fn space_contract() {
        check_space_contract(&mut Password::new(5, 9), 3);
    }

    #[test]
    fn password_static_across_episodes() {
        let env1 = Password::new(5, 123);
        let env2 = Password::new(5, 123);
        assert_eq!(env1.password(), env2.password());
        let env3 = Password::new(5, 124);
        // 1/32 collision chance per seed pair; these seeds differ.
        assert_ne!(env1.password(), env3.password());
    }

    #[test]
    fn oracle_policy_scores_one() {
        let mut env = Password::new(5, 77);
        let password = env.password().to_vec();
        let score = rollout_score(&mut env, 5, 0, |obs, _| {
            let pos = obs
                .as_f32s()
                .unwrap()
                .iter()
                .position(|&x| x > 0.5)
                .unwrap();
            Value::Discrete(password[pos])
        });
        assert_eq!(score, 1.0);
    }

    #[test]
    fn wrong_guess_scores_zero() {
        let mut env = Password::new(5, 77);
        let password = env.password().to_vec();
        let score = rollout_score(&mut env, 5, 0, |obs, _| {
            let pos = obs
                .as_f32s()
                .unwrap()
                .iter()
                .position(|&x| x > 0.5)
                .unwrap();
            Value::Discrete(1 - password[pos]) // always wrong
        });
        assert_eq!(score, 0.0);
    }

    #[test]
    fn random_policy_hits_rarely() {
        let mut env = Password::new(5, 3);
        let score = rollout_score(&mut env, 200, 11, |_, rng| {
            Value::Discrete(rng.below(2) as i64)
        });
        // Expected 1/32 ≈ 0.031.
        assert!(score < 0.15, "random score {score}");
    }
}
