//! **Bandit** (paper §4): a classic multiarmed bandit. One-step episodes;
//! pulling arm `k` pays 1 with probability `p_k`. The arm layout is fixed
//! per env instance (seeded), so the policy must find and commit to the
//! best arm — broken exploration or value baselines show up immediately.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;

/// Stationary Bernoulli bandit.
pub struct Bandit {
    probs: Vec<f64>,
    best: f64,
    rng: Rng,
}

impl Bandit {
    /// `k` arms; the best arm pays with probability 0.9, the rest 0.3.
    /// Which arm is best is derived from the instance `seed`.
    pub fn new(k: usize, seed: u64) -> Self {
        assert!(k >= 2);
        let mut rng = Rng::new(seed ^ 0x4241_4E44);
        let best_arm = rng.below(k as u64) as usize;
        let probs: Vec<f64> = (0..k)
            .map(|i| if i == best_arm { 0.9 } else { 0.3 })
            .collect();
        Bandit {
            probs,
            best: 0.9,
            rng,
        }
    }

    pub fn arm_probs(&self) -> &[f64] {
        &self.probs
    }
}

impl StructuredEnv for Bandit {
    /// Constant observation (contextless bandit).
    fn observation_space(&self) -> Space {
        Space::boxf(&[1], 0.0, 1.0)
    }

    fn action_space(&self) -> Space {
        Space::Discrete(self.probs.len())
    }

    fn reset(&mut self, _seed: u64) -> Value {
        Value::F32(vec![0.0])
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        // PANIC: emulation decodes actions against this env's declared Discrete space.
        let arm = action.as_discrete().expect("Bandit: Discrete action") as usize;
        assert!(arm < self.probs.len(), "Bandit: arm {arm} out of range");
        let reward = if self.rng.chance(self.probs[arm]) {
            1.0
        } else {
            0.0
        };
        // Score is the *expected* payout ratio of the pulled arm — noise-free
        // so the solved threshold is meaningful on few episodes.
        let info = vec![("score", self.probs[arm] / self.best)];
        (Value::F32(vec![0.0]), reward, true, false, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::{check_space_contract, rollout_score};

    #[test]
    fn space_contract() {
        check_space_contract(&mut Bandit::new(4, 5), 3);
    }

    #[test]
    fn best_arm_scores_one() {
        let mut env = Bandit::new(4, 9);
        let best = env
            .arm_probs()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i64;
        let score = rollout_score(&mut env, 20, 0, |_, _| Value::Discrete(best));
        assert_eq!(score, 1.0);
    }

    #[test]
    fn worst_arm_scores_third() {
        let mut env = Bandit::new(4, 9);
        let worst = env
            .arm_probs()
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i64;
        let score = rollout_score(&mut env, 20, 0, |_, _| Value::Discrete(worst));
        assert!((score - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn layout_static_per_seed() {
        assert_eq!(Bandit::new(4, 3).arm_probs(), Bandit::new(4, 3).arm_probs());
    }

    #[test]
    fn payout_rate_matches_prob() {
        let mut env = Bandit::new(2, 1);
        let arm = 0i64;
        let p = env.arm_probs()[0];
        let mut paid = 0u32;
        for _ in 0..2000 {
            env.reset(0);
            let (_, r, done, _, _) = env.step(&Value::Discrete(arm));
            assert!(done, "bandit episodes are one step");
            if r > 0.0 {
                paid += 1;
            }
        }
        let rate = paid as f64 / 2000.0;
        assert!((rate - p).abs() < 0.05, "rate {rate} vs p {p}");
    }
}
