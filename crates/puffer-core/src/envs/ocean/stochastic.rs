//! **Stochastic** (paper §4): the optimal policy plays action 0 with
//! probability `p` and action 1 with probability `1 − p`. The observation
//! is constant, so a memoryless policy *must* be genuinely stochastic to
//! score well — this catches algorithms that cannot represent or maintain
//! a nonuniform stochastic policy (e.g. broken entropy bonuses or
//! deterministic argmax evaluation).

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};

/// Blind stochastic-ratio matching.
pub struct Stochastic {
    p: f64,
    horizon: u32,
    t: u32,
    count0: u32,
}

impl Stochastic {
    pub fn new(p: f64, horizon: u32) -> Self {
        assert!((0.0..=1.0).contains(&p) && horizon > 0);
        Stochastic {
            p,
            horizon,
            t: 0,
            count0: 0,
        }
    }

    /// Score: 1 − |freq₀ − p| / max(p, 1−p), clamped to [0, 1]. A
    /// Bernoulli(p) policy concentrates near 1; any deterministic policy
    /// is capped well below 0.9 for p = 0.75.
    fn score(&self) -> f64 {
        let freq0 = self.count0 as f64 / self.horizon as f64;
        (1.0 - (freq0 - self.p).abs() / self.p.max(1.0 - self.p)).max(0.0)
    }
}

impl StructuredEnv for Stochastic {
    /// Constant observation: the env is intentionally blind.
    fn observation_space(&self) -> Space {
        Space::boxf(&[1], 0.0, 1.0)
    }

    fn action_space(&self) -> Space {
        Space::Discrete(2)
    }

    fn reset(&mut self, _seed: u64) -> Value {
        self.t = 0;
        self.count0 = 0;
        Value::F32(vec![0.0])
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        // PANIC: emulation decodes actions against this env's declared Discrete space.
        let a = action.as_discrete().expect("Stochastic: Discrete action");
        if a == 0 {
            self.count0 += 1;
        }
        self.t += 1;
        let done = self.t >= self.horizon;
        let mut reward = 0.0;
        let mut info = Info::new();
        if done {
            let score = self.score();
            reward = score as f32;
            info.push(("score", score));
        }
        (Value::F32(vec![0.0]), reward, done, false, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::{check_space_contract, rollout_score};

    #[test]
    fn space_contract() {
        check_space_contract(&mut Stochastic::new(0.75, 16), 3);
    }

    #[test]
    fn bernoulli_p_policy_scores_high() {
        let mut env = Stochastic::new(0.75, 64);
        let score = rollout_score(&mut env, 50, 4, |_, rng| {
            Value::Discrete(if rng.chance(0.75) { 0 } else { 1 })
        });
        assert!(score > 0.9, "matched policy score {score}");
    }

    #[test]
    fn deterministic_policies_capped() {
        let mut env = Stochastic::new(0.75, 64);
        let all0 = rollout_score(&mut env, 10, 0, |_, _| Value::Discrete(0));
        let all1 = rollout_score(&mut env, 10, 0, |_, _| Value::Discrete(1));
        // all-0: freq0 = 1 → score = 1 - 0.25/0.75 = 2/3.
        assert!((all0 - 2.0 / 3.0).abs() < 1e-9, "all0 {all0}");
        assert_eq!(all1, 0.0, "all1 {all1}");
        assert!(all0 < 0.9 && all1 < 0.9, "deterministic must not solve");
    }

    #[test]
    fn uniform_random_below_matched() {
        let mut env = Stochastic::new(0.75, 64);
        let uniform = rollout_score(&mut env, 50, 9, |_, rng| {
            Value::Discrete(rng.below(2) as i64)
        });
        // freq0 ≈ 0.5 → score ≈ 1 - 0.25/0.75 ≈ 0.67.
        assert!(uniform < 0.8, "uniform {uniform}");
    }
}
