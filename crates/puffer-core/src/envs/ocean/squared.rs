//! **Squared** (paper §4): the agent starts at the center of a square
//! grid; a target is placed on the perimeter. Reward is `1` minus the
//! (normalized) L∞ distance to the closest unhit target, so it varies from
//! −1 to 1, and hit targets stop paying. A correct implementation learns
//! to walk outward in a handful of updates; common sign/indexing bugs make
//! it unlearnable.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;

/// Grid-walk toward perimeter targets.
pub struct Squared {
    n: usize,
    rng: Rng,
    agent: (i32, i32),
    target: (i32, i32),
    hit: bool,
    t: u32,
    horizon: u32,
    reward_sum: f64,
    obs_buf: Vec<f32>,
}

impl Squared {
    /// `n` must be odd so the grid has an exact center.
    pub fn new(n: usize, seed: u64) -> Self {
        assert!(n >= 5 && n % 2 == 1, "grid side must be odd and >= 5");
        Squared {
            n,
            rng: Rng::new(seed),
            agent: (0, 0),
            target: (0, 0),
            hit: false,
            t: 0,
            horizon: 2 * n as u32,
            reward_sum: 0.0,
            obs_buf: vec![0.0; n * n],
        }
    }

    fn center(&self) -> i32 {
        (self.n / 2) as i32
    }

    /// Max possible L∞ distance to a perimeter target (from the opposite
    /// edge).
    fn dmax(&self) -> f32 {
        (self.n - 1) as f32
    }

    fn linf(&self) -> f32 {
        let dx = (self.agent.0 - self.target.0).abs();
        let dy = (self.agent.1 - self.target.1).abs();
        dx.max(dy) as f32
    }

    /// Reward in [-1, 1]: 1 - 2·d/dmax; zero once the target is hit.
    fn reward(&self) -> f32 {
        if self.hit {
            0.0
        } else {
            1.0 - 2.0 * self.linf() / self.dmax()
        }
    }

    fn sample_perimeter(&mut self) -> (i32, i32) {
        let n = self.n as i32;
        let side = self.rng.below(4);
        let along = self.rng.below(self.n as u64) as i32;
        match side {
            0 => (along, 0),
            1 => (along, n - 1),
            2 => (0, along),
            _ => (n - 1, along),
        }
    }

    fn obs(&mut self) -> Value {
        self.obs_buf.fill(0.0);
        let n = self.n;
        self.obs_buf[self.agent.1 as usize * n + self.agent.0 as usize] = 1.0;
        if !self.hit {
            self.obs_buf[self.target.1 as usize * n + self.target.0 as usize] = -1.0;
        }
        Value::F32(self.obs_buf.clone())
    }
}

impl StructuredEnv for Squared {
    fn observation_space(&self) -> Space {
        Space::boxf(&[self.n, self.n], -1.0, 1.0)
    }

    /// 0: up, 1: down, 2: left, 3: right.
    fn action_space(&self) -> Space {
        Space::Discrete(4)
    }

    fn reset(&mut self, seed: u64) -> Value {
        self.rng = Rng::new(seed ^ 0x5153_5152);
        self.agent = (self.center(), self.center());
        self.target = self.sample_perimeter();
        self.hit = false;
        self.t = 0;
        self.reward_sum = 0.0;
        self.obs()
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        // PANIC: emulation decodes actions against this env's declared Discrete space.
        let a = action.as_discrete().expect("Squared: Discrete action");
        let n = self.n as i32;
        let (dx, dy) = match a {
            0 => (0, -1),
            1 => (0, 1),
            2 => (-1, 0),
            3 => (1, 0),
            _ => panic!("Squared: action {a} out of range"),
        };
        self.agent.0 = (self.agent.0 + dx).clamp(0, n - 1);
        self.agent.1 = (self.agent.1 + dy).clamp(0, n - 1);
        self.t += 1;

        // The hit step itself pays the full reward (d = 0); only *already*
        // hit targets stop paying.
        let just_hit = !self.hit && self.agent == self.target;
        let reward = if just_hit { 1.0 } else { self.reward() };
        if just_hit {
            self.hit = true;
        }
        self.reward_sum += reward as f64;

        let done = self.t >= self.horizon;
        let mut info = Info::new();
        if done {
            // Score: 1 if the target was hit, else scaled closeness — both
            // normalized to [0, 1].
            let score = if self.hit {
                1.0
            } else {
                (1.0 - self.linf() as f64 / self.dmax() as f64).max(0.0)
            };
            info.push(("score", score));
        }
        (self.obs(), reward, done, false, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::{check_space_contract, rollout_score};

    #[test]
    fn space_contract() {
        check_space_contract(&mut Squared::new(7, 1), 3);
    }

    #[test]
    fn reward_increases_toward_target() {
        let mut env = Squared::new(11, 0);
        env.reset(5);
        // Greedy walk toward the target must strictly increase reward
        // until the hit.
        let mut last = -1.0f32;
        for _ in 0..env.horizon {
            let (tx, ty) = env.target;
            let (ax, ay) = env.agent;
            // Move along the dominant axis so L∞ strictly decreases.
            let (dx, dy) = (tx - ax, ty - ay);
            let a = if dx.abs() >= dy.abs() {
                if dx > 0 {
                    3
                } else {
                    2
                }
            } else if dy > 0 {
                1
            } else {
                0
            };
            let hit_before = env.hit;
            let (_, r, done, _, _) = env.step(&Value::Discrete(a));
            if env.hit && !hit_before {
                assert_eq!(r, 1.0, "reward exactly 1 on the hit step");
                return;
            }
            if !env.hit {
                // With 4-dir moves L∞ is non-increasing under the dominant-
                // axis policy (it can stall one step when |dx| == |dy|).
                assert!(r >= last, "reward {r} decreased from {last}");
                last = r;
            }
            if done {
                break;
            }
        }
        panic!("greedy policy failed to reach the target");
    }

    #[test]
    fn greedy_policy_scores_one() {
        let mut env = Squared::new(11, 0);
        let score = rollout_score(&mut env, 20, 42, |obs, _rng| {
            // Decode agent + target from the flat grid.
            let g = obs.as_f32s().unwrap();
            let n = 11i32;
            let mut agent = (0, 0);
            let mut target = None;
            for (i, &v) in g.iter().enumerate() {
                let (x, y) = (i as i32 % n, i as i32 / n);
                if v > 0.5 {
                    agent = (x, y);
                } else if v < -0.5 {
                    target = Some((x, y));
                }
            }
            let a = match target {
                Some((tx, ty)) => {
                    if agent.0 < tx {
                        3
                    } else if agent.0 > tx {
                        2
                    } else if agent.1 < ty {
                        1
                    } else {
                        0
                    }
                }
                None => 0,
            };
            Value::Discrete(a)
        });
        assert!(score > 0.95, "greedy score {score}");
    }

    #[test]
    fn random_policy_scores_low() {
        let mut env = Squared::new(11, 0);
        let score = rollout_score(&mut env, 30, 7, |_obs, rng| {
            Value::Discrete(rng.below(4) as i64)
        });
        assert!(score < 0.7, "random score {score} suspiciously high");
    }

    #[test]
    fn reward_bounds() {
        let mut env = Squared::new(7, 2);
        let mut rng = Rng::new(1);
        env.reset(0);
        for _ in 0..200 {
            let (_, r, done, _, _) = env.step(&Value::Discrete(rng.below(4) as i64));
            assert!((-1.0..=1.0).contains(&r), "reward {r} out of [-1,1]");
            if done {
                env.reset(1);
            }
        }
    }
}
