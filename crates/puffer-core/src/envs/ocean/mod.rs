//! Puffer Ocean (paper §4): sanity-check environments that are *"trivial
//! with correct implementations and impossible with specific common
//! bugs"*. Each trains in under a minute on one core; the paper's PPO
//! solves each (score > 0.9) in roughly 30k interactions with one set of
//! barely tuned hyperparameters — `benches/ocean_train.rs` reproduces that
//! claim.
//!
//! Per the paper: **never report Ocean scores in a comparative baseline.**
//! This is a sanity check only.
//!
//! Every env pushes a `("score", s)` info with `s ∈ [0, 1]` when an
//! episode ends; > 0.9 counts as solved.

mod bandit;
mod memory;
mod multiagent;
mod password;
mod spaces_env;
mod squared;
mod stochastic;

pub use bandit::Bandit;
pub use memory::Memory;
pub use multiagent::Multiagent;
pub use password::Password;
pub use spaces_env::SpacesEnv;
pub use squared::Squared;
pub use stochastic::Stochastic;

#[cfg(test)]
pub(crate) mod testutil {
    use crate::emulation::{Info, StructuredEnv};
    use crate::spaces::Value;
    use crate::util::rng::Rng;

    /// Run `episodes` episodes with a policy closure; returns mean score.
    pub fn rollout_score<E: StructuredEnv>(
        env: &mut E,
        episodes: usize,
        seed: u64,
        mut policy: impl FnMut(&Value, &mut Rng) -> Value,
    ) -> f64 {
        let mut rng = Rng::new(seed);
        let mut total = 0.0;
        for ep in 0..episodes {
            let mut obs = env.reset(seed + ep as u64);
            loop {
                let action = policy(&obs, &mut rng);
                let (next, _r, term, trunc, info) = env.step(&action);
                obs = next;
                if term || trunc {
                    let score = info
                        .iter()
                        .find(|(k, _)| *k == "score")
                        .map(|(_, v)| *v)
                        .expect("ocean env must emit score at episode end");
                    total += score;
                    break;
                }
            }
        }
        total / episodes as f64
    }

    /// Check the env's spaces accept its own observations for a few steps.
    pub fn check_space_contract<E: StructuredEnv>(env: &mut E, seed: u64) {
        let ospace = env.observation_space();
        let aspace = env.action_space();
        let mut rng = Rng::new(seed);
        let mut obs = env.reset(seed);
        for _ in 0..20 {
            assert!(
                ospace.contains(&obs),
                "obs violates space: {obs:?} vs {ospace:?}"
            );
            let action = aspace.sample(&mut rng);
            let (next, _, term, trunc, _) = env.step(&action);
            obs = if term || trunc { env.reset(seed + 1) } else { next };
        }
    }
}
