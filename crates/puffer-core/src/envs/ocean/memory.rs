//! **Memory** (paper §4): repeat an observed binary sequence after a
//! delay. The sequence is regenerated on every reset and presented one
//! digit at a time, followed by a string of zeros during which the agent
//! must echo it back. Unsolvable without recurrence — this is the env that
//! validates the LSTM sandwich (paper §3.4) and its state resets.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;

/// Delayed sequence recall.
pub struct Memory {
    len: usize,
    delay: usize,
    seq: Vec<i64>,
    t: usize,
    correct: u32,
    rng: Rng,
}

impl Memory {
    pub fn new(len: usize, delay: usize) -> Self {
        assert!((1..=16).contains(&len));
        Memory {
            len,
            delay,
            seq: Vec::new(),
            t: 0,
            correct: 0,
            rng: Rng::new(0),
        }
    }

    fn horizon(&self) -> usize {
        2 * self.len + self.delay
    }

    /// Observation: `[shown_bit, presenting_flag, recalling_flag]`.
    /// `shown_bit` carries the sequence during presentation and is 0
    /// afterwards (the "string of 0" from the paper).
    fn obs(&self) -> Value {
        let presenting = self.t < self.len;
        let recalling = self.t >= self.len + self.delay && self.t < self.horizon();
        let bit = if presenting { self.seq[self.t] as f32 } else { 0.0 };
        Value::F32(vec![
            bit,
            if presenting { 1.0 } else { 0.0 },
            if recalling { 1.0 } else { 0.0 },
        ])
    }
}

impl StructuredEnv for Memory {
    fn observation_space(&self) -> Space {
        Space::boxf(&[3], 0.0, 1.0)
    }

    fn action_space(&self) -> Space {
        Space::Discrete(2)
    }

    fn reset(&mut self, seed: u64) -> Value {
        self.rng = Rng::new(seed ^ 0x4D45_4D4F);
        self.seq = (0..self.len).map(|_| self.rng.below(2) as i64).collect();
        self.t = 0;
        self.correct = 0;
        self.obs()
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        // PANIC: emulation decodes actions against this env's declared Discrete space.
        let a = action.as_discrete().expect("Memory: Discrete action");
        let recall_start = self.len + self.delay;
        let mut reward = 0.0;
        if self.t >= recall_start {
            let target = self.seq[self.t - recall_start];
            if a == target {
                self.correct += 1;
                reward = 1.0 / self.len as f32;
            }
        }
        self.t += 1;
        let done = self.t >= self.horizon();
        let mut info = Info::new();
        if done {
            info.push(("score", self.correct as f64 / self.len as f64));
        }
        (self.obs(), reward, done, false, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::{check_space_contract, rollout_score};

    #[test]
    fn space_contract() {
        check_space_contract(&mut Memory::new(3, 0), 3);
    }

    #[test]
    fn perfect_recall_scores_one() {
        let mut env = Memory::new(4, 2);
        // Stateful oracle: record bits during presentation, replay during
        // recall (this is exactly what the LSTM must learn to do).
        let mut recorded: Vec<i64> = Vec::new();
        let mut replay_idx = 0usize;
        let score = rollout_score(&mut env, 10, 5, |obs, _| {
            let o = obs.as_f32s().unwrap();
            let (bit, presenting, recalling) = (o[0], o[1], o[2]);
            if presenting > 0.5 {
                if recorded.len() >= 4 {
                    recorded.clear(); // fresh episode
                }
                recorded.push(bit as i64);
            }
            if recalling > 0.5 {
                let a = recorded[replay_idx % recorded.len().max(1)];
                replay_idx += 1;
                return Value::Discrete(a);
            }
            replay_idx = 0;
            Value::Discrete(0)
        });
        assert_eq!(score, 1.0, "oracle recall score {score}");
    }

    #[test]
    fn sequence_regenerated_per_reset() {
        let mut env = Memory::new(8, 0);
        env.reset(1);
        let s1 = env.seq.clone();
        env.reset(2);
        let s2 = env.seq.clone();
        assert_ne!(s1, s2, "1/256 collision chance; these seeds differ");
    }

    #[test]
    fn memoryless_policy_near_half() {
        let mut env = Memory::new(8, 0);
        let score = rollout_score(&mut env, 100, 13, |_, rng| {
            Value::Discrete(rng.below(2) as i64)
        });
        assert!((score - 0.5).abs() < 0.1, "random score {score}");
    }
}
