//! **Multiagent** (paper §4): agent 1 must pick action 0 and agent 2 must
//! pick action 1. The simplest possible test that per-agent observations
//! and actions are routed to the right agents — shuffled or misaligned
//! agent batching (a classic vectorization bug) makes it unsolvable.

use crate::emulation::{AgentId, Info, MultiStep, StructuredMultiEnv};
use crate::spaces::{Space, Value};

/// Two-agent identity-routing check.
pub struct Multiagent {
    horizon: u32,
    t: u32,
    correct: u32,
}

impl Multiagent {
    pub fn new(horizon: u32) -> Self {
        assert!(horizon > 0);
        Multiagent {
            horizon,
            t: 0,
            correct: 0,
        }
    }

    /// Observation: one-hot of the agent's own id.
    fn obs(id: AgentId) -> Value {
        Value::F32(if id == 0 {
            vec![1.0, 0.0]
        } else {
            vec![0.0, 1.0]
        })
    }
}

impl StructuredMultiEnv for Multiagent {
    fn observation_space(&self) -> Space {
        Space::boxf(&[2], 0.0, 1.0)
    }

    fn action_space(&self) -> Space {
        Space::Discrete(2)
    }

    fn max_agents(&self) -> usize {
        2
    }

    fn reset(&mut self, _seed: u64) -> Vec<(AgentId, Value)> {
        self.t = 0;
        self.correct = 0;
        vec![(0, Self::obs(0)), (1, Self::obs(1))]
    }

    fn step(&mut self, actions: &[(AgentId, Value)]) -> MultiStep {
        self.t += 1;
        let mut agents = Vec::with_capacity(2);
        for &(id, ref a) in actions {
            // PANIC: emulation decodes actions against this env's declared Discrete space.
            let a = a.as_discrete().expect("Multiagent: Discrete action");
            // Agent `id` must play action `id`.
            let reward = if a == id as i64 { 1.0 } else { 0.0 };
            if reward > 0.0 {
                self.correct += 1;
            }
            agents.push((id, Self::obs(id), reward, false));
        }
        let over = self.t >= self.horizon;
        let mut info = Info::new();
        if over {
            info.push((
                "score",
                self.correct as f64 / (2 * self.horizon) as f64,
            ));
        }
        MultiStep {
            agents,
            episode_over: over,
            info,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_policy(pick: impl Fn(AgentId) -> i64, horizon: u32) -> f64 {
        let mut env = Multiagent::new(horizon);
        env.reset(0);
        loop {
            let actions: Vec<(AgentId, Value)> = (0..2u32)
                .map(|id| (id, Value::Discrete(pick(id))))
                .collect();
            let step = env.step(&actions);
            if step.episode_over {
                return step
                    .info
                    .iter()
                    .find(|(k, _)| *k == "score")
                    .map(|(_, v)| *v)
                    .unwrap();
            }
        }
    }

    #[test]
    fn correct_routing_scores_one() {
        assert_eq!(run_policy(|id| id as i64, 8), 1.0);
    }

    #[test]
    fn swapped_routing_scores_zero() {
        // A vectorizer that crosses agent rows produces exactly this.
        assert_eq!(run_policy(|id| 1 - id as i64, 8), 0.0);
    }

    #[test]
    fn one_sided_policy_scores_half() {
        assert_eq!(run_policy(|_| 0, 8), 0.5);
    }
}
