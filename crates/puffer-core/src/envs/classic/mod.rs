//! Full reimplementations of classic benchmark environments: exact
//! CartPole dynamics, a Minigrid-style egocentric gridworld, and a
//! Breakout-style paddle game. These are real environments (not workload
//! sims) used for end-to-end learning and for the fast-env rows of the
//! paper's tables.

mod breakout;
mod cartpole;
mod minigrid;

pub use breakout::Breakout;
pub use cartpole::CartPole;
pub use minigrid::MiniGrid;
