//! A Breakout-style paddle game on a continuous field with a discrete
//! brick wall. Structured observation (continuous kinematics + u8 brick
//! bitmap) exercises the mixed-dtype flattening path; the game itself is a
//! real learnable environment.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;

const ROWS: usize = 4;
const COLS: usize = 12;
const PADDLE_W: f32 = 0.15;
const PADDLE_SPEED: f32 = 0.05;
const BALL_SPEED: f32 = 0.025;
const BRICK_TOP: f32 = 0.1; // wall occupies y in [BRICK_TOP, BRICK_BOTTOM)
const BRICK_BOTTOM: f32 = 0.35;
const MAX_STEPS: u32 = 2000;

/// Paddle-and-bricks arcade game on the unit square. y grows downward;
/// the paddle sits at y = 1.
pub struct Breakout {
    ball: (f32, f32),
    vel: (f32, f32),
    paddle_x: f32,
    bricks: Vec<u8>, // 1 = alive
    t: u32,
    cleared: u32,
    rng: Rng,
}

impl Breakout {
    pub fn new() -> Self {
        Breakout {
            ball: (0.5, 0.5),
            vel: (0.0, 0.0),
            paddle_x: 0.5,
            bricks: vec![1; ROWS * COLS],
            t: 0,
            cleared: 0,
            rng: Rng::new(0),
        }
    }

    fn brick_at(x: f32, y: f32) -> Option<usize> {
        if !(BRICK_TOP..BRICK_BOTTOM).contains(&y) || !(0.0..1.0).contains(&x) {
            return None;
        }
        let row = ((y - BRICK_TOP) / (BRICK_BOTTOM - BRICK_TOP) * ROWS as f32) as usize;
        let col = (x * COLS as f32) as usize;
        Some(row.min(ROWS - 1) * COLS + col.min(COLS - 1))
    }

    fn obs(&self) -> Value {
        // Canonical key order: bricks < state.
        Value::Dict(vec![
            ("bricks".into(), Value::U8(self.bricks.clone())),
            (
                "state".into(),
                Value::F32(vec![
                    self.ball.0,
                    self.ball.1,
                    self.vel.0 / BALL_SPEED,
                    self.vel.1 / BALL_SPEED,
                    self.paddle_x,
                ]),
            ),
        ])
    }
}

impl Default for Breakout {
    fn default() -> Self {
        Self::new()
    }
}

impl StructuredEnv for Breakout {
    fn observation_space(&self) -> Space {
        Space::dict(vec![
            ("bricks".into(), Space::boxu8(&[ROWS, COLS])),
            ("state".into(), Space::boxf(&[5], -2.0, 2.0)),
        ])
    }

    /// 0: stay, 1: left, 2: right.
    fn action_space(&self) -> Space {
        Space::Discrete(3)
    }

    fn reset(&mut self, seed: u64) -> Value {
        self.rng = Rng::new(seed ^ 0x4252_4B54);
        self.ball = (self.rng.uniform(0.2, 0.8), 0.6);
        let angle = self.rng.uniform(-0.8, 0.8);
        self.vel = (BALL_SPEED * angle.sin(), BALL_SPEED * angle.cos().abs());
        self.paddle_x = 0.5;
        self.bricks.fill(1);
        self.t = 0;
        self.cleared = 0;
        self.obs()
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        // PANIC: emulation decodes actions against this env's declared Discrete space.
        let a = action.as_discrete().expect("Breakout: Discrete action");
        match a {
            0 => {}
            1 => self.paddle_x = (self.paddle_x - PADDLE_SPEED).max(PADDLE_W / 2.0),
            2 => self.paddle_x = (self.paddle_x + PADDLE_SPEED).min(1.0 - PADDLE_W / 2.0),
            _ => panic!("Breakout: action {a} out of range"),
        }

        let mut reward = 0.0;
        // Advance the ball.
        self.ball.0 += self.vel.0;
        self.ball.1 += self.vel.1;

        // Side walls.
        if self.ball.0 <= 0.0 {
            self.ball.0 = -self.ball.0;
            self.vel.0 = self.vel.0.abs();
        } else if self.ball.0 >= 1.0 {
            self.ball.0 = 2.0 - self.ball.0;
            self.vel.0 = -self.vel.0.abs();
        }
        // Ceiling.
        if self.ball.1 <= 0.0 {
            self.ball.1 = -self.ball.1;
            self.vel.1 = self.vel.1.abs();
        }
        // Brick collisions.
        if let Some(idx) = Self::brick_at(self.ball.0, self.ball.1) {
            if self.bricks[idx] == 1 {
                self.bricks[idx] = 0;
                self.cleared += 1;
                self.vel.1 = -self.vel.1;
                reward += 1.0;
            }
        }
        // Paddle (y = 1) when moving down.
        let mut dropped = false;
        if self.ball.1 >= 1.0 {
            if (self.ball.0 - self.paddle_x).abs() <= PADDLE_W / 2.0 {
                self.ball.1 = 2.0 - self.ball.1;
                // English: hit offset steers the ball.
                let off = (self.ball.0 - self.paddle_x) / (PADDLE_W / 2.0);
                self.vel.0 = BALL_SPEED * 0.9 * off;
                self.vel.1 = -(BALL_SPEED * BALL_SPEED - self.vel.0 * self.vel.0)
                    .max(1e-6)
                    .sqrt();
            } else {
                dropped = true;
            }
        }

        self.t += 1;
        let all_cleared = self.cleared as usize == ROWS * COLS;
        let timeout = self.t >= MAX_STEPS;
        let done = dropped || all_cleared || timeout;
        let mut info = Info::new();
        if done {
            info.push(("score", self.cleared as f64 / (ROWS * COLS) as f64));
        }
        (self.obs(), reward, dropped || all_cleared, timeout && !dropped && !all_cleared, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::check_space_contract;

    #[test]
    fn space_contract() {
        check_space_contract(&mut Breakout::new(), 3);
    }

    #[test]
    fn tracking_paddle_clears_bricks() {
        // Follow the ball's x: a decent heuristic that should clear a good
        // chunk of the wall and never drop early.
        let mut env = Breakout::new();
        env.reset(2);
        let mut total_reward = 0.0;
        for _ in 0..MAX_STEPS {
            let a = if env.paddle_x < env.ball.0 - 0.02 {
                2
            } else if env.paddle_x > env.ball.0 + 0.02 {
                1
            } else {
                0
            };
            let (_, r, term, trunc, _) = env.step(&Value::Discrete(a));
            total_reward += r;
            if term || trunc {
                break;
            }
        }
        assert!(total_reward >= 5.0, "tracker cleared only {total_reward}");
    }

    #[test]
    fn idle_paddle_drops_ball() {
        let mut env = Breakout::new();
        env.reset(7);
        let mut steps = 0;
        loop {
            let (_, _, term, trunc, _) = env.step(&Value::Discrete(0));
            steps += 1;
            if term || trunc {
                break;
            }
            assert!(steps < MAX_STEPS, "idle game never ended");
        }
        assert!(steps < 500, "idle survived suspiciously long: {steps}");
    }

    #[test]
    fn brick_hits_pay_exactly_once() {
        let mut env = Breakout::new();
        env.reset(0);
        // Aim the ball straight up into a brick column.
        env.ball = (0.5, 0.4);
        env.vel = (0.0, -BALL_SPEED);
        let mut rewards = 0.0;
        for _ in 0..12 {
            let (_, r, ..) = env.step(&Value::Discrete(0));
            rewards += r;
        }
        // Ball passes through the wall band once going up: exactly one
        // brick pays, then the ball bounces back down.
        assert_eq!(rewards, 1.0, "expected exactly one brick");
        assert!(env.vel.1 > 0.0, "ball should bounce downward");
    }

    #[test]
    fn ball_stays_in_bounds() {
        let mut env = Breakout::new();
        let mut rng = Rng::new(3);
        env.reset(1);
        for _ in 0..3000 {
            let (_, _, term, trunc, _) =
                env.step(&Value::Discrete(rng.below(3) as i64));
            assert!((-0.05..=1.05).contains(&env.ball.0), "x {}", env.ball.0);
            assert!((-0.05..=1.2).contains(&env.ball.1), "y {}", env.ball.1);
            if term || trunc {
                env.reset(2);
            }
        }
    }
}
