//! A Minigrid-style egocentric gridworld: walled N×N room, random start
//! pose, random goal. The agent sees a 5×5 egocentric window plus its own
//! direction — a Dict observation mixing u8 image data with a Discrete
//! field, exactly the structure the emulation layer exists to handle.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;

const VIEW: usize = 5; // egocentric window side
const EMPTY: u8 = 0;
const WALL: u8 = 1;
const GOAL: u8 = 2;

/// Egocentric grid navigation.
pub struct MiniGrid {
    n: usize,
    grid: Vec<u8>, // row-major n*n
    pos: (i32, i32),
    dir: u8, // 0:E 1:S 2:W 3:N
    goal: (i32, i32),
    t: u32,
    horizon: u32,
    rng: Rng,
}

impl MiniGrid {
    pub fn new(n: usize) -> Self {
        assert!(n >= 5);
        MiniGrid {
            n,
            grid: vec![EMPTY; n * n],
            pos: (1, 1),
            dir: 0,
            goal: (1, 1),
            t: 0,
            horizon: (4 * n * n) as u32,
            rng: Rng::new(0),
        }
    }

    fn at(&self, x: i32, y: i32) -> u8 {
        if x < 0 || y < 0 || x >= self.n as i32 || y >= self.n as i32 {
            WALL
        } else {
            self.grid[y as usize * self.n + x as usize]
        }
    }

    fn forward_delta(&self) -> (i32, i32) {
        match self.dir {
            0 => (1, 0),
            1 => (0, 1),
            2 => (-1, 0),
            _ => (0, -1),
        }
    }

    /// Egocentric 5×5 view: agent at the bottom-center looking "up" the
    /// window, rotated to its heading (the Minigrid convention).
    fn view(&self) -> Vec<u8> {
        let mut out = vec![0u8; VIEW * VIEW];
        let half = (VIEW / 2) as i32;
        for vy in 0..VIEW as i32 {
            for vx in 0..VIEW as i32 {
                // Window coords: (dx right of agent, dy ahead of agent).
                let dxr = vx - half;
                let dyf = (VIEW as i32 - 1) - vy;
                // Rotate into world coords by heading.
                let (wx, wy) = match self.dir {
                    0 => (self.pos.0 + dyf, self.pos.1 + dxr), // facing E
                    1 => (self.pos.0 - dxr, self.pos.1 + dyf), // S
                    2 => (self.pos.0 - dyf, self.pos.1 - dxr), // W
                    _ => (self.pos.0 + dxr, self.pos.1 - dyf), // N
                };
                out[vy as usize * VIEW + vx as usize] = self.at(wx, wy);
            }
        }
        out
    }

    fn obs(&self) -> Value {
        // Canonical key order: dir < view.
        Value::Dict(vec![
            ("dir".into(), Value::Discrete(self.dir as i64)),
            ("view".into(), Value::U8(self.view())),
        ])
    }
}

impl StructuredEnv for MiniGrid {
    fn observation_space(&self) -> Space {
        Space::dict(vec![
            ("view".into(), Space::boxu8(&[VIEW, VIEW])),
            ("dir".into(), Space::Discrete(4)),
        ])
    }

    /// 0: turn left, 1: turn right, 2: forward.
    fn action_space(&self) -> Space {
        Space::Discrete(3)
    }

    fn reset(&mut self, seed: u64) -> Value {
        self.rng = Rng::new(seed ^ 0x4D47_5244);
        let n = self.n;
        self.grid.fill(EMPTY);
        for i in 0..n {
            self.grid[i] = WALL; // top
            self.grid[(n - 1) * n + i] = WALL; // bottom
            self.grid[i * n] = WALL; // left
            self.grid[i * n + n - 1] = WALL; // right
        }
        let interior = || -> (i32, i32) { (0, 0) };
        let _ = interior;
        loop {
            self.pos = (
                self.rng.range_i64(1, n as i64 - 2) as i32,
                self.rng.range_i64(1, n as i64 - 2) as i32,
            );
            self.goal = (
                self.rng.range_i64(1, n as i64 - 2) as i32,
                self.rng.range_i64(1, n as i64 - 2) as i32,
            );
            if self.pos != self.goal {
                break;
            }
        }
        self.grid[self.goal.1 as usize * n + self.goal.0 as usize] = GOAL;
        self.dir = self.rng.below(4) as u8;
        self.t = 0;
        self.obs()
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        // PANIC: emulation decodes actions against this env's declared Discrete space.
        let a = action.as_discrete().expect("MiniGrid: Discrete action");
        match a {
            0 => self.dir = (self.dir + 3) % 4,
            1 => self.dir = (self.dir + 1) % 4,
            2 => {
                let (dx, dy) = self.forward_delta();
                let (nx, ny) = (self.pos.0 + dx, self.pos.1 + dy);
                if self.at(nx, ny) != WALL {
                    self.pos = (nx, ny);
                }
            }
            _ => panic!("MiniGrid: action {a} out of range"),
        }
        self.t += 1;

        let reached = self.pos == self.goal;
        let timeout = self.t >= self.horizon;
        let mut reward = 0.0;
        let mut info = Info::new();
        if reached {
            // Minigrid convention: 1 - 0.9 * t/T.
            reward = 1.0 - 0.9 * self.t as f32 / self.horizon as f32;
            info.push(("score", reward as f64));
        } else if timeout {
            info.push(("score", 0.0));
        }
        (self.obs(), reward, reached, timeout && !reached, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::check_space_contract;

    #[test]
    fn space_contract() {
        check_space_contract(&mut MiniGrid::new(7), 3);
    }

    #[test]
    fn walls_block_forward() {
        let mut env = MiniGrid::new(7);
        env.reset(0);
        env.pos = (1, 1);
        env.dir = 2; // facing W into the wall
        let before = env.pos;
        env.step(&Value::Discrete(2));
        assert_eq!(env.pos, before, "walked through a wall");
    }

    #[test]
    fn turning_cycles() {
        let mut env = MiniGrid::new(7);
        env.reset(0);
        let d0 = env.dir;
        for _ in 0..4 {
            env.step(&Value::Discrete(1));
        }
        assert_eq!(env.dir, d0);
    }

    #[test]
    fn reaching_goal_pays_and_terminates() {
        let mut env = MiniGrid::new(7);
        env.reset(0);
        // Teleport next to the goal, face it, step forward.
        env.pos = (env.goal.0 - 1, env.goal.1);
        env.dir = 0; // E
        let (_, r, term, _, info) = env.step(&Value::Discrete(2));
        assert!(term);
        assert!(r > 0.0);
        assert!(info.iter().any(|(k, _)| *k == "score"));
    }

    #[test]
    fn goal_visible_in_view_when_ahead() {
        let mut env = MiniGrid::new(9);
        env.reset(1);
        env.pos = (env.goal.0 - 2, env.goal.1);
        env.dir = 0; // goal two cells ahead (E)
        let obs = env.obs();
        let view = obs.field("view").unwrap().as_u8s().unwrap().to_vec();
        assert!(view.contains(&GOAL), "goal not rendered in view {view:?}");
    }

    #[test]
    fn bfs_policy_solves() {
        // Cheating planner using global state: rotate/step along the
        // shortest path; validates the dynamics end to end.
        let mut env = MiniGrid::new(7);
        for seed in 0..5 {
            env.reset(seed);
            let mut steps = 0;
            while env.pos != env.goal && steps < 100 {
                let (dx, dy) = (env.goal.0 - env.pos.0, env.goal.1 - env.pos.1);
                let want = if dx > 0 {
                    0
                } else if dx < 0 {
                    2
                } else if dy > 0 {
                    1
                } else {
                    3
                };
                let a = if env.dir == want { 2 } else { 1 };
                let (_, _, term, trunc, _) = env.step(&Value::Discrete(a));
                steps += 1;
                if term || trunc {
                    break;
                }
            }
            assert_eq!(env.pos, env.goal, "seed {seed} unsolved");
        }
    }
}
