//! CartPole-v1 with the exact OpenAI Gym dynamics (Barto, Sutton &
//! Anderson 1983 as implemented in `gym/envs/classic_control/cartpole.py`):
//! Euler integration at 0.02s, force ±10N, terminate at |x| > 2.4 or
//! |θ| > 12°. This is the paper's canonical "fast env" — 270k raw SPS in
//! Table 1 — so it doubles as the overhead stress test.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;

const GRAVITY: f64 = 9.8;
const MASS_CART: f64 = 1.0;
const MASS_POLE: f64 = 0.1;
const TOTAL_MASS: f64 = MASS_CART + MASS_POLE;
const LENGTH: f64 = 0.5; // half pole length
const POLE_MASS_LENGTH: f64 = MASS_POLE * LENGTH;
const FORCE_MAG: f64 = 10.0;
const TAU: f64 = 0.02;
const X_THRESHOLD: f64 = 2.4;
const THETA_THRESHOLD: f64 = 12.0 * std::f64::consts::PI / 180.0;

/// The classic pole-balancing control task.
pub struct CartPole {
    state: [f64; 4], // x, x_dot, theta, theta_dot
    t: u32,
    max_steps: u32,
    rng: Rng,
}

impl CartPole {
    pub fn new(max_steps: u32) -> Self {
        CartPole {
            state: [0.0; 4],
            t: 0,
            max_steps,
            rng: Rng::new(0),
        }
    }

    pub fn state(&self) -> &[f64; 4] {
        &self.state
    }

    fn obs(&self) -> Value {
        Value::F32(self.state.map(|x| x as f32).to_vec())
    }
}

impl StructuredEnv for CartPole {
    fn observation_space(&self) -> Space {
        // Gym reports ±4.8 / ±inf / ±0.418 / ±inf; use generous finite
        // bounds (the contract check needs finite ranges).
        Space::boxf(&[4], -1e6, 1e6)
    }

    fn action_space(&self) -> Space {
        Space::Discrete(2)
    }

    fn reset(&mut self, seed: u64) -> Value {
        self.rng = Rng::new(seed ^ 0x4341_5254);
        for s in &mut self.state {
            *s = self.rng.uniform(-0.05, 0.05) as f64;
        }
        self.t = 0;
        self.obs()
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        // PANIC: emulation decodes actions against this env's declared Discrete space.
        let a = action.as_discrete().expect("CartPole: Discrete action");
        let force = if a == 1 { FORCE_MAG } else { -FORCE_MAG };
        let [x, x_dot, theta, theta_dot] = self.state;

        let cos = theta.cos();
        let sin = theta.sin();
        let temp = (force + POLE_MASS_LENGTH * theta_dot * theta_dot * sin) / TOTAL_MASS;
        let theta_acc = (GRAVITY * sin - cos * temp)
            / (LENGTH * (4.0 / 3.0 - MASS_POLE * cos * cos / TOTAL_MASS));
        let x_acc = temp - POLE_MASS_LENGTH * theta_acc * cos / TOTAL_MASS;

        self.state = [
            x + TAU * x_dot,
            x_dot + TAU * x_acc,
            theta + TAU * theta_dot,
            theta_dot + TAU * theta_acc,
        ];
        self.t += 1;

        let fell = self.state[0].abs() > X_THRESHOLD || self.state[2].abs() > THETA_THRESHOLD;
        let timeout = self.t >= self.max_steps;
        let mut info = Info::new();
        if fell || timeout {
            info.push(("score", self.t as f64 / self.max_steps as f64));
        }
        // Gym semantics: reward 1 on every step, truncation on timeout.
        (self.obs(), 1.0, fell, timeout && !fell, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::ocean::testutil::check_space_contract;

    #[test]
    fn space_contract() {
        check_space_contract(&mut CartPole::new(50), 3);
    }

    #[test]
    fn random_policy_falls_quickly() {
        let mut env = CartPole::new(500);
        let mut rng = Rng::new(5);
        let mut lengths = Vec::new();
        for ep in 0..20 {
            env.reset(ep);
            let mut t = 0;
            loop {
                let (_, _, term, trunc, _) =
                    env.step(&Value::Discrete(rng.below(2) as i64));
                t += 1;
                if term || trunc {
                    break;
                }
            }
            lengths.push(t);
        }
        let mean = lengths.iter().sum::<i32>() as f64 / lengths.len() as f64;
        // Known property of CartPole: random play survives ~20 steps.
        assert!(mean > 8.0 && mean < 60.0, "random ep length {mean}");
    }

    #[test]
    fn balance_controller_survives() {
        // Simple hand controller: push in the direction the pole leans,
        // weighted by angular velocity — keeps the pole up far longer than
        // random (usually the full horizon).
        let mut env = CartPole::new(200);
        env.reset(3);
        let mut t = 0;
        loop {
            let s = env.state();
            let a = if s[2] + 0.5 * s[3] > 0.0 { 1 } else { 0 };
            let (_, _, term, trunc, _) = env.step(&Value::Discrete(a));
            t += 1;
            if term || trunc {
                break;
            }
        }
        assert!(t >= 150, "controller survived only {t} steps");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = CartPole::new(100);
        let mut b = CartPole::new(100);
        a.reset(9);
        b.reset(9);
        for _ in 0..50 {
            let (oa, ra, ta, _, _) = a.step(&Value::Discrete(1));
            let (ob, rb, tb, _, _) = b.step(&Value::Discrete(1));
            assert_eq!(oa, ob);
            assert_eq!(ra, rb);
            assert_eq!(ta, tb);
            if ta {
                break;
            }
        }
    }

    #[test]
    fn physics_sanity_pole_accelerates_downward() {
        // From rest with a small positive angle and no force balance, the
        // pole falls further positive.
        let mut env = CartPole::new(100);
        env.reset(0);
        env.state = [0.0, 0.0, 0.05, 0.0];
        // Alternate pushes cancel on average; the angle must grow.
        for i in 0..20 {
            env.step(&Value::Discrete(i % 2));
        }
        assert!(env.state[2] > 0.05, "theta {}", env.state[2]);
    }
}
