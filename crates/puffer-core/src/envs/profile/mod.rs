//! Workload simulators calibrated to the paper's Table 1 environment
//! profiles.
//!
//! The real binaries (NetHack, Neural MMO, Pokémon Red, ...) are not
//! available in this environment; per DESIGN.md §Substitutions we replace
//! each with a simulator that preserves exactly the properties the paper's
//! experiments measure:
//!
//! - the **observation/action space structure** (dict vs flat, dtypes,
//!   sizes) — drives emulation cost and data-movement volume;
//! - the **step-time distribution** (mean + coefficient of variation,
//!   lognormal to model "deeply branching logic paths") — drives the
//!   straggler effects EnvPool exploits;
//! - the **reset cost** and **episode length** — drives the Crafter-style
//!   6× pool speedups;
//! - **variable population** for Neural MMO — drives the multiagent
//!   padding/sorting paths.
//!
//! Step cost is modeled with a calibrated busy-spin
//! ([`spin_for`](crate::util::timer::spin_for)) because the real envs are
//! CPU-bound; sleeping would free the core and misstate contention.

mod nmmo;
mod sim;

pub use nmmo::NmmoSim;
pub use sim::{ProfileConfig, ProfileSim};

/// Fixed agent-row capacity of the NMMO simulator.
pub fn nmmo_max_agents() -> usize {
    nmmo::MAX_AGENTS
}

use crate::emulation::{FlatEnv, PufferEnv, PufferMultiEnv};
use crate::spaces::Space;

/// Global time scale applied to every profile sim (1.0 = the paper's
/// desktop-measured absolute step times). Benches may shrink it to keep
/// wall-clock reasonable; relative results are unaffected because *all*
/// simulated costs scale together.
pub const DEFAULT_TIME_SCALE: f64 = 1.0;

/// Table 1 calibrations (desktop column). SPS → mean step time; "% Step
/// STD" → lognormal CV; "% Reset" → reset cost as a share of episode
/// time: `reset_us = f/(1-f) · ep_len · step_us`.
pub fn config(name: &str) -> ProfileConfig {
    match name {
        // 29k SPS, reset 1.1%, step std 106%.
        "nethack" => ProfileConfig {
            name: "nethack",
            obs_space: Space::dict(vec![
                ("glyphs".into(), Space::boxi32(&[21, 79], 0.0, 5976.0)),
                ("blstats".into(), Space::boxf(&[27], -1e6, 1e6)),
                ("message".into(), Space::boxu8(&[256])),
            ]),
            action_space: Space::Discrete(23),
            step_us: 34.5,
            step_cv: 1.06,
            reset_frac: 0.011,
            ep_len: 250,
            time_scale: DEFAULT_TIME_SCALE,
        },
        // 11k SPS, reset 2.1%, step std 28%.
        "minihack" => ProfileConfig {
            name: "minihack",
            obs_space: Space::dict(vec![
                ("glyphs".into(), Space::boxi32(&[9, 9], 0.0, 5976.0)),
                ("blstats".into(), Space::boxf(&[27], -1e6, 1e6)),
                ("message".into(), Space::boxu8(&[256])),
            ]),
            action_space: Space::Discrete(8),
            step_us: 91.0,
            step_cv: 0.28,
            reset_frac: 0.021,
            ep_len: 100,
            time_scale: DEFAULT_TIME_SCALE,
        },
        // 700 SPS, reset ~0, step std 43%. Game Boy screen, downsampled 2x.
        "pokemon" => ProfileConfig {
            name: "pokemon",
            obs_space: Space::boxu8(&[72, 80]),
            action_space: Space::Discrete(8),
            step_us: 1430.0,
            step_cv: 0.43,
            reset_frac: 0.0,
            ep_len: 500,
            time_scale: DEFAULT_TIME_SCALE,
        },
        // 25k SPS, reset 0.36%, step std 14%. 64x64 RGB frames.
        "procgen" => ProfileConfig {
            name: "procgen",
            obs_space: Space::boxu8(&[64, 64, 3]),
            action_space: Space::Discrete(15),
            step_us: 40.0,
            step_cv: 0.14,
            reset_frac: 0.0036,
            ep_len: 200,
            time_scale: DEFAULT_TIME_SCALE,
        },
        // 1.2k SPS, reset 54%, step std 4.3%. 84x84 grayscale after the
        // standard wrappers.
        "atari" => ProfileConfig {
            name: "atari",
            obs_space: Space::boxu8(&[84, 84]),
            action_space: Space::Discrete(4),
            step_us: 833.0,
            step_cv: 0.043,
            reset_frac: 0.54,
            ep_len: 150,
            time_scale: DEFAULT_TIME_SCALE,
        },
        // 320 SPS, reset 80%(!), step std 26%. The paper's EnvPool 6x case.
        "crafter" => ProfileConfig {
            name: "crafter",
            obs_space: Space::boxu8(&[64, 64, 3]),
            action_space: Space::Discrete(17),
            step_us: 3125.0,
            step_cv: 0.26,
            reset_frac: 0.80,
            ep_len: 100,
            time_scale: DEFAULT_TIME_SCALE,
        },
        // 16k SPS, reset 4.5%, step std 8.1%.
        "minigrid" => ProfileConfig {
            name: "minigrid",
            obs_space: Space::dict(vec![
                ("image".into(), Space::boxu8(&[7, 7, 3])),
                ("direction".into(), Space::Discrete(4)),
            ]),
            action_space: Space::Discrete(7),
            step_us: 62.5,
            step_cv: 0.081,
            reset_frac: 0.045,
            ep_len: 80,
            time_scale: DEFAULT_TIME_SCALE,
        },
        other => panic!("no profile calibration for '{other}'"),
    }
}

/// Construct a wrapped profile sim by name ("nmmo" gets the multiagent
/// simulator; everything else a [`ProfileSim`]).
pub fn make_profile(name: &str, seed: u64) -> Box<dyn FlatEnv> {
    make_profile_scaled(name, seed, DEFAULT_TIME_SCALE)
}

/// As [`make_profile`] but with all simulated times multiplied by
/// `time_scale`. Benches shrink the slowest profiles (Crafter's 1.25 s
/// resets, Pokémon's 1.4 ms steps) to keep wall-clock sane on this
/// single-core host; relative comparisons are unaffected because every
/// simulated cost scales together (see DESIGN.md §Substitutions).
pub fn make_profile_scaled(name: &str, seed: u64, time_scale: f64) -> Box<dyn FlatEnv> {
    if name == "nmmo" {
        // 2400 SPS, reset 68%, step std 59%: variable-population dict-obs
        // multiagent env. Step time is per *agent* step.
        Box::new(PufferMultiEnv::new(NmmoSim::new(seed, time_scale)))
    } else {
        let mut cfg = config(name);
        cfg.time_scale = time_scale;
        Box::new(PufferEnv::new(ProfileSim::new(cfg, seed)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_reset_cost_matches_fraction() {
        for name in ["nethack", "atari", "crafter", "minigrid"] {
            let c = config(name);
            let reset_us = c.reset_us();
            let episode_us = c.ep_len as f64 * c.step_us;
            let frac = reset_us / (reset_us + episode_us);
            assert!(
                (frac - c.reset_frac).abs() < 1e-9,
                "{name}: frac {frac} vs {}",
                c.reset_frac
            );
        }
    }

    #[test]
    fn sps_matches_table1() {
        // mean step time implies the Table 1 SPS (without emulation).
        let c = config("nethack");
        let sps = 1e6 / c.step_us;
        assert!((sps - 29_000.0).abs() / 29_000.0 < 0.01, "sps {sps}");
    }
}
