//! [`ProfileSim`] — the single-agent workload simulator.

use crate::emulation::{Info, StructuredEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;
use crate::util::timer::spin_for;
use std::time::Duration;

/// Timing/structure profile of a simulated environment. See
/// [`super::config`] for the Table 1 calibrations.
#[derive(Clone, Debug)]
pub struct ProfileConfig {
    pub name: &'static str,
    pub obs_space: Space,
    pub action_space: Space,
    /// Mean step compute time in µs (at `time_scale` = 1).
    pub step_us: f64,
    /// Coefficient of variation of the lognormal step-time distribution.
    pub step_cv: f64,
    /// Fraction of total env time spent resetting (Table 1 "% Reset").
    pub reset_frac: f64,
    /// Mean episode length in steps (episodes are geometric around this).
    pub ep_len: u64,
    /// Global multiplier on all simulated times.
    pub time_scale: f64,
}

impl ProfileConfig {
    /// Reset cost implied by `reset_frac`:
    /// `reset/(reset + ep_len·step) = frac`.
    pub fn reset_us(&self) -> f64 {
        if self.reset_frac <= 0.0 {
            return 0.0;
        }
        self.reset_frac / (1.0 - self.reset_frac) * self.ep_len as f64 * self.step_us
    }

    /// A fully synthetic profile for sweeps (benches/emulation.rs F1,
    /// benches/pool_ablation.rs C1): flat f32 obs of `obs_elems`, given
    /// step time/CV/reset fraction.
    pub fn synthetic(step_us: f64, step_cv: f64, reset_frac: f64, obs_elems: usize) -> Self {
        ProfileConfig {
            name: "synthetic",
            obs_space: Space::boxf(&[obs_elems], -1e6, 1e6),
            action_space: Space::Discrete(4),
            step_us,
            step_cv,
            reset_frac,
            ep_len: 64,
            time_scale: 1.0,
        }
    }
}

/// Single-agent workload simulator: burns a lognormal-distributed amount
/// of CPU per step, produces structurally realistic observations, and
/// terminates episodes geometrically around the calibrated length.
pub struct ProfileSim {
    cfg: ProfileConfig,
    rng: Rng,
    t: u64,
    episode_len: u64,
    /// Lognormal parameters derived from (mean, cv).
    mu: f64,
    sigma: f64,
    /// Cached observation value, cheaply mutated per step so emulation
    /// flattens realistic (changing) data without the sim paying a full
    /// regeneration each step.
    obs: Value,
    counter: u32,
}

impl ProfileSim {
    pub fn new(cfg: ProfileConfig, seed: u64) -> Self {
        // lognormal: mean m, cv c  ⇒  σ² = ln(1+c²), µ = ln m − σ²/2.
        let sigma2 = (1.0 + cfg.step_cv * cfg.step_cv).ln();
        let mu = (cfg.step_us.max(1e-9)).ln() - sigma2 / 2.0;
        let mut rng = Rng::new(seed ^ 0x5052_4F46);
        let obs = Self::fresh_obs(&cfg.obs_space, &mut rng);
        ProfileSim {
            cfg,
            rng,
            t: 0,
            episode_len: 1,
            mu,
            sigma: sigma2.sqrt(),
            obs,
            counter: 0,
        }
    }

    pub fn config(&self) -> &ProfileConfig {
        &self.cfg
    }

    fn fresh_obs(space: &Space, rng: &mut Rng) -> Value {
        space.sample(rng)
    }

    /// Touch a handful of leaf entries so consecutive observations differ
    /// (defeats any accidental memoization downstream) without paying a
    /// full random regeneration.
    fn mutate_obs(&mut self) {
        self.counter = self.counter.wrapping_add(1);
        let c = self.counter;
        fn poke(v: &mut Value, c: u32) {
            match v {
                Value::F32(xs) => {
                    let n = xs.len();
                    xs[c as usize % n] = (c % 251) as f32;
                }
                Value::U8(xs) => {
                    let n = xs.len();
                    xs[c as usize % n] = (c % 251) as u8;
                }
                Value::I32(xs) => {
                    let n = xs.len();
                    xs[c as usize % n] = (c % 251) as i32;
                }
                Value::Discrete(x) => *x = (c % 2) as i64,
                Value::MultiDiscrete(xs) => {
                    let n = xs.len();
                    xs[c as usize % n] = (c % 2) as i64;
                }
                Value::Tuple(vs) => {
                    for v in vs {
                        poke(v, c);
                    }
                }
                Value::Dict(entries) => {
                    for (_, v) in entries {
                        poke(v, c);
                    }
                }
            }
        }
        poke(&mut self.obs, c);
    }

    fn sample_step_time(&mut self) -> Duration {
        let z = self.rng.normal();
        let us = (self.mu + self.sigma * z).exp() * self.cfg.time_scale;
        Duration::from_nanos((us * 1000.0) as u64)
    }

    fn sample_episode_len(&mut self) -> u64 {
        // Geometric-ish: uniform in [0.5, 1.5] × ep_len keeps the mean and
        // gives resets the jitter real envs have.
        let lo = (self.cfg.ep_len / 2).max(1);
        let hi = self.cfg.ep_len + self.cfg.ep_len / 2;
        self.rng.range_i64(lo as i64, hi as i64) as u64
    }
}

impl StructuredEnv for ProfileSim {
    fn observation_space(&self) -> Space {
        self.cfg.obs_space.clone()
    }

    fn action_space(&self) -> Space {
        self.cfg.action_space.clone()
    }

    fn reset(&mut self, seed: u64) -> Value {
        self.rng = Rng::new(seed ^ 0x5052_4F46 ^ self.counter as u64);
        let reset_us = self.cfg.reset_us() * self.cfg.time_scale;
        spin_for(Duration::from_nanos((reset_us * 1000.0) as u64));
        self.t = 0;
        self.episode_len = self.sample_episode_len();
        self.mutate_obs();
        self.obs.clone()
    }

    fn step(&mut self, _action: &Value) -> (Value, f32, bool, bool, Info) {
        let d = self.sample_step_time();
        spin_for(d);
        self.t += 1;
        self.mutate_obs();
        let done = self.t >= self.episode_len;
        let reward = self.rng.f32();
        let mut info = Info::new();
        if done {
            info.push(("score", self.rng.f64()));
        }
        (self.obs.clone(), reward, done, false, info)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;
    use std::time::Instant;

    #[test]
    fn step_time_mean_and_cv_match_profile() {
        let cfg = ProfileConfig::synthetic(200.0, 0.5, 0.0, 16);
        let mut sim = ProfileSim::new(cfg, 1);
        sim.reset(0);
        let mut w = Welford::new();
        let a = Value::Discrete(0);
        for _ in 0..400 {
            let t0 = Instant::now();
            let (_, _, done, _, _) = sim.step(&a);
            w.push(t0.elapsed().as_secs_f64() * 1e6);
            if done {
                sim.reset(1);
            }
        }
        let mean = w.mean();
        // Spin granularity adds a little; allow 25%.
        assert!(
            (mean - 200.0).abs() / 200.0 < 0.25,
            "mean step {mean}µs vs 200µs"
        );
        assert!(w.cv() > 0.25, "cv {} too low for profile 0.5", w.cv());
    }

    #[test]
    fn reset_cost_visible_for_high_reset_frac() {
        let mut cfg = ProfileConfig::synthetic(50.0, 0.1, 0.5, 4);
        cfg.ep_len = 10;
        let reset_us = cfg.reset_us();
        assert!((reset_us - 500.0).abs() < 1.0, "reset_us {reset_us}");
        let mut sim = ProfileSim::new(cfg, 2);
        let t0 = Instant::now();
        sim.reset(0);
        let took = t0.elapsed().as_secs_f64() * 1e6;
        assert!(took >= 450.0, "reset took only {took}µs");
    }

    #[test]
    fn observations_change_between_steps() {
        let cfg = ProfileConfig::synthetic(1.0, 0.0, 0.0, 8);
        let mut sim = ProfileSim::new(cfg, 3);
        let o1 = sim.reset(0);
        let (o2, ..) = sim.step(&Value::Discrete(0));
        assert_ne!(o1, o2);
    }

    #[test]
    fn episode_lengths_jitter_around_mean() {
        let mut cfg = ProfileConfig::synthetic(0.1, 0.0, 0.0, 4);
        cfg.ep_len = 40;
        let mut sim = ProfileSim::new(cfg, 4);
        let mut lens = Vec::new();
        for ep in 0..30 {
            sim.reset(ep);
            let mut t = 0u64;
            loop {
                let (_, _, done, _, _) = sim.step(&Value::Discrete(0));
                t += 1;
                if done {
                    break;
                }
            }
            lens.push(t);
        }
        let mean = lens.iter().sum::<u64>() as f64 / lens.len() as f64;
        assert!((mean - 40.0).abs() < 12.0, "mean ep len {mean}");
        assert!(lens.iter().any(|&l| l != lens[0]), "no jitter");
    }
}
