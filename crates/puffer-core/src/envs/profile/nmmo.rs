//! [`NmmoSim`] — the Neural MMO workload simulator: variable population,
//! per-agent Dict observations, structured Dict actions, long resets.
//!
//! Table 1 calibration: 2400 SPS (per agent-step), 68% reset share, 59%
//! step-time CV. Population starts at `max_agents` and decays as agents
//! "die", regrowing on respawn ticks — exercising the emulation layer's
//! padding and canonical-sort paths exactly as the real env does.

use crate::emulation::{AgentId, Info, MultiStep, StructuredMultiEnv};
use crate::spaces::{Space, Value};
use crate::util::rng::Rng;
use crate::util::timer::spin_for;
use std::time::Duration;

pub const MAX_AGENTS: usize = 16;

/// Variable-population multiagent workload simulator.
pub struct NmmoSim {
    rng: Rng,
    alive: Vec<AgentId>,
    t: u64,
    episode_len: u64,
    time_scale: f64,
    /// lognormal params for per-agent step cost.
    mu: f64,
    sigma: f64,
    next_id: AgentId,
    counter: u32,
}

/// Per agent-step mean cost: 2400 agent-steps/s → ~417µs each.
const STEP_US: f64 = 417.0;
const STEP_CV: f64 = 0.59;
const RESET_FRAC: f64 = 0.68;
const EP_LEN: u64 = 50;

impl NmmoSim {
    pub fn new(seed: u64, time_scale: f64) -> Self {
        let sigma2 = (1.0 + STEP_CV * STEP_CV).ln();
        let mu = STEP_US.ln() - sigma2 / 2.0;
        NmmoSim {
            rng: Rng::new(seed ^ 0x4E4D_4D4F),
            alive: Vec::new(),
            t: 0,
            episode_len: EP_LEN,
            time_scale,
            mu,
            sigma: sigma2.sqrt(),
            next_id: 0,
            counter: 0,
        }
    }

    fn reset_us() -> f64 {
        // Reset cost per Table 1: frac/(1-frac) · ep_len · per-env step
        // time. Per-env step cost ≈ alive·STEP_US; use the starting
        // population for calibration.
        RESET_FRAC / (1.0 - RESET_FRAC) * EP_LEN as f64 * STEP_US * MAX_AGENTS as f64
            / MAX_AGENTS as f64
    }

    fn obs_for(&mut self, id: AgentId) -> Value {
        self.counter = self.counter.wrapping_add(1);
        let c = self.counter;
        // Realistic NMMO-style local state: tile map patch, entity table,
        // own stats. Generated cheaply from (id, tick, counter).
        let tiles: Vec<i32> = (0..15 * 15)
            .map(|i| ((i as u32 ^ c ^ id) % 16) as i32)
            .collect();
        let entities: Vec<f32> = (0..8 * 6)
            .map(|i| ((i as u32).wrapping_mul(2654435761) ^ c) as f32 % 100.0)
            .collect();
        let stats: Vec<f32> = (0..10)
            .map(|i| ((id + i as u32 + c) % 100) as f32)
            .collect();
        // Canonical key order: entities < stats < tiles.
        Value::Dict(vec![
            ("entities".into(), Value::F32(entities)),
            ("stats".into(), Value::F32(stats)),
            ("tiles".into(), Value::I32(tiles)),
        ])
    }
}

impl StructuredMultiEnv for NmmoSim {
    fn observation_space(&self) -> Space {
        Space::dict(vec![
            ("tiles".into(), Space::boxi32(&[15, 15], 0.0, 16.0)),
            ("entities".into(), Space::boxf(&[8, 6], -1e6, 1e6)),
            ("stats".into(), Space::boxf(&[10], -1e6, 1e6)),
        ])
    }

    /// NMMO-style structured action: move direction + attack target slot.
    fn action_space(&self) -> Space {
        Space::dict(vec![
            ("move".into(), Space::Discrete(5)),
            ("attack".into(), Space::Discrete(9)),
        ])
    }

    fn max_agents(&self) -> usize {
        MAX_AGENTS
    }

    fn reset(&mut self, seed: u64) -> Vec<(AgentId, Value)> {
        self.rng = Rng::new(seed ^ 0x4E4D_4D4F ^ self.counter as u64);
        spin_for(Duration::from_nanos(
            (Self::reset_us() * self.time_scale * 1000.0) as u64,
        ));
        self.t = 0;
        self.episode_len = self.rng.range_i64(EP_LEN as i64 / 2, EP_LEN as i64 * 3 / 2) as u64;
        self.next_id = MAX_AGENTS as AgentId;
        self.alive = (0..MAX_AGENTS as AgentId).collect();
        self.alive
            .clone()
            .into_iter()
            .map(|id| (id, self.obs_for(id)))
            .collect()
    }

    fn step(&mut self, actions: &[(AgentId, Value)]) -> MultiStep {
        // Per-agent simulated compute.
        for _ in actions {
            let z = self.rng.normal();
            let us = (self.mu + self.sigma * z).exp() * self.time_scale;
            spin_for(Duration::from_nanos((us * 1000.0) as u64));
        }
        self.t += 1;

        // Population dynamics: each agent dies with small probability;
        // every 10 ticks one respawns (fresh id — exercising id churn).
        let mut survivors: Vec<AgentId> = Vec::with_capacity(self.alive.len());
        for &id in &self.alive {
            if self.rng.chance(0.03) && self.alive.len() > 2 {
                continue; // died
            }
            survivors.push(id);
        }
        if self.t % 10 == 0 && survivors.len() < MAX_AGENTS {
            survivors.push(self.next_id);
            self.next_id += 1;
        }
        self.alive = survivors;

        let over = self.t >= self.episode_len;
        let agents = self
            .alive
            .clone()
            .into_iter()
            .map(|id| {
                let obs = self.obs_for(id);
                let reward = self.rng.f32();
                (id, obs, reward, false)
            })
            .collect();
        let mut info = Info::new();
        if over {
            info.push(("score", self.rng.f64()));
        }
        MultiStep {
            agents,
            episode_over: over,
            info,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NmmoSim {
        // time_scale 0 ⇒ no spinning: structure-only tests stay fast.
        NmmoSim::new(7, 0.0)
    }

    #[test]
    fn population_varies_and_ids_churn() {
        let mut env = tiny();
        let first = env.reset(0);
        assert_eq!(first.len(), MAX_AGENTS);
        let mut saw_smaller = false;
        let mut max_id_seen = 0;
        for _ in 0..3 {
            let actions: Vec<(AgentId, Value)> = env
                .alive
                .clone()
                .into_iter()
                .map(|id| {
                    (
                        id,
                        Value::Dict(vec![
                            ("attack".into(), Value::Discrete(0)),
                            ("move".into(), Value::Discrete(0)),
                        ]),
                    )
                })
                .collect();
            let step = env.step(&actions);
            if step.agents.len() < MAX_AGENTS {
                saw_smaller = true;
            }
            for (id, ..) in &step.agents {
                max_id_seen = max_id_seen.max(*id);
            }
            if step.episode_over {
                env.reset(1);
            }
        }
        // Run long enough to observe churn.
        for t in 0..60 {
            let actions: Vec<(AgentId, Value)> = env
                .alive
                .clone()
                .into_iter()
                .map(|id| {
                    (
                        id,
                        Value::Dict(vec![
                            ("attack".into(), Value::Discrete(0)),
                            ("move".into(), Value::Discrete(0)),
                        ]),
                    )
                })
                .collect();
            let step = env.step(&actions);
            if step.agents.len() < MAX_AGENTS {
                saw_smaller = true;
            }
            for (id, ..) in &step.agents {
                max_id_seen = max_id_seen.max(*id);
            }
            if step.episode_over {
                env.reset(t);
            }
        }
        assert!(saw_smaller, "population never shrank");
        assert!(
            max_id_seen >= MAX_AGENTS as u32,
            "no respawn ids observed (max {max_id_seen})"
        );
    }

    #[test]
    fn observations_match_space() {
        let mut env = tiny();
        let space = env.observation_space();
        for (_, obs) in env.reset(3) {
            assert!(space.contains(&obs));
        }
    }
}
