//! First-party environments.
//!
//! - [`ocean`] — the paper's §4 sanity suite: each env trains in well under
//!   a minute and is *"trivial with correct implementations and impossible
//!   with specific common bugs"*. Every Ocean env reports a normalized
//!   `score` in `[0, 1]` at episode end; "solved" means score > 0.9.
//! - [`classic`] — full reimplementations of CartPole, a Minigrid-style
//!   gridworld, and a Breakout-style game, used for end-to-end learning.
//! - [`profile`] — workload simulators calibrated to the paper's Table 1
//!   profiles (NetHack, Neural MMO, Pokémon Red, Procgen, Crafter, Atari,
//!   MiniHack, Minigrid): same observation/action structure, step-time
//!   distribution, and reset cost as the real binaries, so the
//!   vectorization experiments exercise the same code paths. See DESIGN.md
//!   §Substitutions.
//!
//! There is deliberately **no registry** (paper §3.2): [`make`] is a plain
//! match over first-party names; downstream users construct their own envs
//! and wrap them with [`PufferEnv`](crate::emulation::PufferEnv) directly
//! (see `examples/custom_env.rs`).

// Environments are pure computation over their own state: nothing here
// may need unsafe (CONCURRENCY.md — keep the unsafe surface in vector/).
#![forbid(unsafe_code)]

pub mod classic;
pub mod ocean;
pub mod profile;

use crate::emulation::{FlatEnv, PufferEnv, PufferMultiEnv};

/// All first-party env names accepted by [`make`], in display order.
pub const ALL_ENVS: &[&str] = &[
    "ocean/squared",
    "ocean/password",
    "ocean/stochastic",
    "ocean/memory",
    "ocean/multiagent",
    "ocean/spaces",
    "ocean/bandit",
    "classic/cartpole",
    "classic/minigrid",
    "classic/breakout",
    "profile/nethack",
    "profile/minihack",
    "profile/nmmo",
    "profile/pokemon",
    "profile/procgen",
    "profile/atari",
    "profile/crafter",
    "profile/minigrid",
];

/// Ocean env names only (the sanity-suite sweep).
pub const OCEAN_ENVS: &[&str] = &[
    "ocean/squared",
    "ocean/password",
    "ocean/stochastic",
    "ocean/memory",
    "ocean/multiagent",
    "ocean/spaces",
    "ocean/bandit",
];

/// Construct a first-party environment, already wrapped for vectorization.
///
/// `seed` individualizes stochastic env internals (bandit arm layout,
/// profile-sim timing streams); episode randomness comes from the
/// `reset(seed)` calls issued by the vectorizer.
pub fn make(name: &str, seed: u64) -> Box<dyn FlatEnv> {
    make_scaled(name, seed, profile::DEFAULT_TIME_SCALE)
}

/// As [`make`], with a time-scale knob for the `profile/*` simulators
/// (ignored by every other env): all simulated step/reset costs are
/// multiplied by `profile_time_scale`. Tests shrink it so the slowest
/// profiles (Crafter's 1.25 s resets, Pokémon's 1.4 ms steps) can be
/// exercised in milliseconds; relative behavior is unaffected because
/// every simulated cost scales together (DESIGN.md §Substitutions).
pub fn make_scaled(name: &str, seed: u64, profile_time_scale: f64) -> Box<dyn FlatEnv> {
    if let Some(profile_name) = name.strip_prefix("profile/") {
        if !ALL_ENVS.contains(&name) {
            panic!(
                "unknown first-party env '{name}'. First-party names: {ALL_ENVS:?}. \
                 Custom envs need no registry: wrap them with PufferEnv::new directly."
            );
        }
        return profile::make_profile_scaled(profile_name, seed, profile_time_scale);
    }
    match name {
        "ocean/squared" => Box::new(PufferEnv::new(ocean::Squared::new(11, seed))),
        // Password/Bandit hide a *static* secret (paper §4) — it must be
        // the same secret in every vectorized copy or the task is
        // unlearnable, so the instance seed is fixed here.
        "ocean/password" => Box::new(PufferEnv::new(ocean::Password::new(5, 0x50AD))),
        "ocean/stochastic" => Box::new(PufferEnv::new(ocean::Stochastic::new(0.75, 64))),
        "ocean/memory" => Box::new(PufferEnv::new(ocean::Memory::new(3, 0))),
        "ocean/multiagent" => Box::new(PufferMultiEnv::new(ocean::Multiagent::new(8))),
        "ocean/spaces" => Box::new(PufferEnv::new(ocean::SpacesEnv::new(8))),
        "ocean/bandit" => Box::new(PufferEnv::new(ocean::Bandit::new(4, 0xA4A1))),
        "classic/cartpole" => Box::new(PufferEnv::new(classic::CartPole::new(200))),
        "classic/minigrid" => Box::new(PufferEnv::new(classic::MiniGrid::new(7))),
        "classic/breakout" => Box::new(PufferEnv::new(classic::Breakout::new())),
        other => panic!(
            "unknown first-party env '{other}'. First-party names: {ALL_ENVS:?}. \
             Custom envs need no registry: wrap them with PufferEnv::new directly."
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_first_party_envs_construct_and_step() {
        for name in ALL_ENVS {
            // Shrink the profile sims' simulated time by 1000x so even
            // Crafter (1.25s resets) and Pokémon (1.4ms steps) are
            // covered without dominating the test wall-clock.
            let mut env = make_scaled(name, 1, 1e-3);
            let rows = env.num_agents();
            let w = env.obs_layout().byte_len();
            let slots = env.action_dims().len();
            let mut obs = vec![0u8; rows * w];
            let mut rewards = vec![0.0; rows];
            let mut terms = vec![false; rows];
            let mut truncs = vec![false; rows];
            env.reset(0, &mut obs);
            let actions = vec![0i32; rows * slots];
            for _ in 0..4 {
                env.step(&actions, &mut obs, &mut rewards, &mut terms, &mut truncs);
            }
        }
    }

    #[test]
    #[should_panic(expected = "unknown first-party env")]
    fn unknown_name_panics_helpfully() {
        make("atari/breakout-v5", 0);
    }

    #[test]
    #[should_panic(expected = "unknown first-party env")]
    fn unknown_profile_name_panics_helpfully() {
        make("profile/doom", 0);
    }
}
