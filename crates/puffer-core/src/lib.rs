//! `puffer-core` — the environment half of the PufferLib reproduction:
//! structured spaces, the emulation layer that packs them into flat
//! byte rows, the first-party env suite, allocation-free wrapper
//! chains, the serial/multithreaded vectorizers, and the declarative
//! [`RunSpec`](runspec::RunSpec) experiment currency.
//!
//! This crate is deliberately free of trainer, server, and kernel code:
//! it is what the `puffer-py` Python bindings link (a cdylib wants the
//! vectorizer, not the PPO loop), and what `puffer-train` builds the
//! trainer/serve stack on top of. The boundary rule:
//!
//! - **Here**: anything needed to *describe* or *simulate* an
//!   experiment — `Space`/`StructLayout`, `FlatEnv` emulation,
//!   envs, wrappers, `VecEnv` vectorization, the `sync` facade, config
//!   parsing, and `RunSpec` — including the standalone vectorizer path
//!   [`build_venv`](runspec::RunSpec::build_venv).
//! - **In `puffer-train`**: anything that *executes* one — backends and
//!   kernels, the `Policy` runtime, the trainer/pipeline, checkpoints,
//!   the run registry executors, `puffer serve`, and the CLI.
//!
//! So the spec layer stays self-contained, the plain-data config types
//! whose *execution* lives upstream are defined here in thin mirror
//! modules — [`train::TrainConfig`], [`serve::ServeConfig`],
//! [`runs::RunsConfig`], [`backend::KernelPath`] — and re-exported by
//! `puffer-train` under the same module paths.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`spaces`] | `Space`, `Value`, packed [`StructLayout`](spaces::StructLayout) rows |
//! | [`emulation`] | `FlatEnv` byte-row contract, `PufferEnv`/`StructuredEnv` adapters |
//! | [`envs`] | first-party suite (`ocean/*`, `classic/*`, `profile/*`) |
//! | [`wrappers`] | `EnvSpec` = base env + allocation-free microwrapper chain |
//! | [`vector`] | `VecEnv`: `Serial`, `Multiprocessing`, autotune, shared slabs |
//! | [`sync`] | loom-swappable primitives (see `CONCURRENCY.md`) |
//! | [`config`] | strict flat `key = value` parsing (TOML/YAML subsets) |
//! | [`runspec`] | `RunSpec`: env × policy × vec × train × seed, TOML/JSON |
//! | [`policy`] | `PolicySpec` architecture descriptions (resolution only) |
//! | [`train`] / [`serve`] / [`runs`] / [`backend`] | plain-data config mirrors |
//! | [`util`] | rng, seed derivation, json, stats, timers |

pub mod backend;
pub mod config;
pub mod emulation;
pub mod envs;
pub mod policy;
pub mod runs;
pub mod runspec;
pub mod serve;
pub mod spaces;
pub mod sync;
pub mod train;
pub mod util;
pub mod vector;
pub mod wrappers;

pub mod prelude {
    //! One-line imports for the common core surface.
    pub use crate::emulation::{EpisodeStats, FlatEnv, PufferEnv, StructuredEnv};
    pub use crate::policy::{ActionHead, PolicySpec, Recurrence};
    pub use crate::runspec::RunSpec;
    pub use crate::spaces::{Space, StructLayout, Value};
    pub use crate::util::rng::Rng;
    pub use crate::vector::{
        Multiprocessing, Serial, StepBatch, VecBatch, VecConfig, VecEnv, VecSpec,
    };
    pub use crate::wrappers::{EnvSpec, Wrapper, WrapperSpec};
}
