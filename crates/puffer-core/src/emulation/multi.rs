//! [`PufferMultiEnv`] — the one-line wrapper for multiagent environments
//! with variable population size.
//!
//! Mirrors the paper's multiagent guarantees (§3.1): observations and
//! actions are kept in **canonical sorted order** by agent id, and when
//! the population is below `max_agents` the wrapper **pads** rows so data
//! buffers stay fixed-size. Padded rows carry zero observations, zero
//! reward, and `terminated = true` (so a learner masks them naturally).

use super::{AgentId, EpisodeStats, FlatEnv, Info, StructuredMultiEnv};
use crate::spaces::{Space, StructLayout, Value};

/// Flattening/padding wrapper around a [`StructuredMultiEnv`].
pub struct PufferMultiEnv<E: StructuredMultiEnv> {
    env: E,
    obs_space: Space,
    act_space: Space,
    layout: StructLayout,
    action_dims: Vec<usize>,
    max_agents: usize,
    /// Sorted ids of currently alive agents; index in this vec == row.
    alive: Vec<AgentId>,
    stats: EpisodeStats,
    checked: bool,
    episode_seed: u64,
    scratch_actions: Vec<(AgentId, Value)>,
}

impl<E: StructuredMultiEnv> PufferMultiEnv<E> {
    pub fn new(env: E) -> Self {
        let obs_space = env.observation_space();
        let act_space = env.action_space();
        let layout = obs_space.layout();
        let action_dims = act_space
            .action_dims()
            // PANIC: construction-time validation — continuous leaves are rejected here, loudly.
            .expect("PufferMultiEnv: continuous action leaves unsupported");
        let max_agents = env.max_agents();
        assert!(max_agents > 0, "max_agents must be positive");
        PufferMultiEnv {
            env,
            obs_space,
            act_space,
            layout,
            action_dims,
            max_agents,
            alive: Vec::with_capacity(max_agents),
            stats: EpisodeStats::default(),
            checked: false,
            episode_seed: 0,
            scratch_actions: Vec::with_capacity(max_agents),
        }
    }

    pub fn inner(&self) -> &E {
        &self.env
    }

    /// Currently alive agents, in canonical (sorted) row order.
    pub fn alive(&self) -> &[AgentId] {
        &self.alive
    }

    fn check_first(&mut self, obs: &Value) {
        if self.checked {
            return;
        }
        assert!(
            self.obs_space.contains(obs),
            "PufferMultiEnv: first observation does not match the declared \
             per-agent observation space.\n  space: {:?}\n  obs: {:?}",
            self.obs_space,
            obs
        );
        self.checked = true;
    }

    /// Write per-agent observations into fixed rows: alive agents in
    /// sorted order first, zero padding for the remainder.
    fn write_rows(&mut self, mut per_agent: Vec<(AgentId, Value)>, obs_out: &mut [u8]) {
        let w = self.layout.byte_len();
        debug_assert_eq!(obs_out.len(), self.max_agents * w);
        assert!(
            per_agent.len() <= self.max_agents,
            "env returned {} agents > max_agents {}",
            per_agent.len(),
            self.max_agents
        );
        // Canonical sorted order (paper §3.1).
        per_agent.sort_by_key(|(id, _)| *id);
        self.alive.clear();
        for (row, (id, obs)) in per_agent.iter().enumerate() {
            self.check_first(obs);
            self.alive.push(*id);
            self.layout
                .write_value(obs, &mut obs_out[row * w..(row + 1) * w]);
        }
        // Pad the tail with zeros so buffers stay fixed-size.
        obs_out[per_agent.len() * w..].fill(0);
    }
}

impl<E: StructuredMultiEnv> FlatEnv for PufferMultiEnv<E> {
    fn obs_layout(&self) -> &StructLayout {
        &self.layout
    }
    fn action_dims(&self) -> &[usize] {
        &self.action_dims
    }
    fn observation_space(&self) -> &Space {
        &self.obs_space
    }
    fn action_space(&self) -> &Space {
        &self.act_space
    }
    fn num_agents(&self) -> usize {
        self.max_agents
    }

    fn reset(&mut self, seed: u64, obs_out: &mut [u8]) -> Info {
        self.episode_seed = seed;
        self.stats = EpisodeStats::default();
        let per_agent = self.env.reset(seed);
        assert!(!per_agent.is_empty(), "reset returned no agents");
        self.write_rows(per_agent, obs_out);
        Info::new()
    }

    fn step(
        &mut self,
        actions: &[i32],
        obs_out: &mut [u8],
        rewards: &mut [f32],
        terms: &mut [bool],
        truncs: &mut [bool],
    ) -> Info {
        let slots = self.action_dims.len();
        debug_assert_eq!(actions.len(), self.max_agents * slots);

        // Route flat action rows back to alive agents (rows beyond the
        // alive population are padding and are dropped).
        self.scratch_actions.clear();
        for (row, &id) in self.alive.iter().enumerate() {
            let a = self
                .act_space
                .unflatten_action(&actions[row * slots..(row + 1) * slots]);
            self.scratch_actions.push((id, a));
        }
        let step = self.env.step(&std::mem::take(&mut self.scratch_actions));
        let mut info = step.info;

        // Per-agent outputs in canonical order, padded.
        let mut agents = step.agents;
        agents.sort_by_key(|(id, ..)| *id);
        let n = agents.len();
        assert!(n <= self.max_agents);
        let mut mean_reward = 0.0f32;
        let mut per_agent = Vec::with_capacity(n);
        for (row, (id, obs, reward, term)) in agents.into_iter().enumerate() {
            rewards[row] = reward;
            terms[row] = term || step.episode_over;
            truncs[row] = false;
            mean_reward += reward;
            per_agent.push((id, obs));
        }
        for row in n..self.max_agents {
            rewards[row] = 0.0;
            terms[row] = true; // padded rows read as terminated
            truncs[row] = false;
        }
        self.stats.push(mean_reward / n.max(1) as f32);

        if step.episode_over {
            self.stats.emit(&mut info);
            info.push(("num_agents", n as f64));
            self.episode_seed = crate::util::rng::next_episode_seed(self.episode_seed);
            let first = self.env.reset(self.episode_seed);
            self.write_rows(first, obs_out);
        } else {
            self.write_rows(per_agent, obs_out);
        }
        info
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulation::MultiStep;

    /// Mock multiagent env: starts with 3 agents (ids 5, 1, 9 — returned
    /// unsorted on purpose); agent dies when it picks action 0; episode
    /// ends after `horizon` steps or when all die. Obs = [id, t].
    struct MockArena {
        t: u32,
        horizon: u32,
        alive: Vec<AgentId>,
    }

    impl MockArena {
        fn new(horizon: u32) -> Self {
            MockArena {
                t: 0,
                horizon,
                alive: vec![],
            }
        }
        fn obs(&self, id: AgentId) -> Value {
            Value::F32(vec![id as f32, self.t as f32])
        }
    }

    impl StructuredMultiEnv for MockArena {
        fn observation_space(&self) -> Space {
            Space::boxf(&[2], 0.0, 1e6)
        }
        fn action_space(&self) -> Space {
            Space::Discrete(3)
        }
        fn max_agents(&self) -> usize {
            4
        }
        fn reset(&mut self, _seed: u64) -> Vec<(AgentId, Value)> {
            self.t = 0;
            self.alive = vec![5, 1, 9]; // deliberately unsorted
            self.alive.iter().map(|&id| (id, self.obs(id))).collect()
        }
        fn step(&mut self, actions: &[(AgentId, Value)]) -> MultiStep {
            self.t += 1;
            let mut survivors = Vec::new();
            for &(id, ref a) in actions {
                if a.as_discrete().unwrap() != 0 {
                    survivors.push(id);
                }
            }
            self.alive = survivors.clone();
            let over = self.t >= self.horizon || self.alive.is_empty();
            MultiStep {
                agents: survivors
                    .iter()
                    .map(|&id| (id, self.obs(id), 1.0, false))
                    .collect(),
                episode_over: over,
                info: Info::new(),
            }
        }
    }

    fn read_row(env: &PufferMultiEnv<MockArena>, obs: &[u8], row: usize) -> Vec<f32> {
        let w = env.obs_layout().byte_len();
        let v = env
            .obs_layout()
            .read_value(env.observation_space(), &obs[row * w..(row + 1) * w]);
        v.as_f32s().unwrap().to_vec()
    }

    #[test]
    fn canonical_sort_and_padding_on_reset() {
        let mut env = PufferMultiEnv::new(MockArena::new(10));
        let w = env.obs_layout().byte_len();
        let mut obs = vec![0xAAu8; 4 * w];
        env.reset(0, &mut obs);
        assert_eq!(env.alive(), &[1, 5, 9], "sorted canonical order");
        assert_eq!(read_row(&env, &obs, 0), vec![1.0, 0.0]);
        assert_eq!(read_row(&env, &obs, 1), vec![5.0, 0.0]);
        assert_eq!(read_row(&env, &obs, 2), vec![9.0, 0.0]);
        assert_eq!(read_row(&env, &obs, 3), vec![0.0, 0.0], "padded row zeroed");
    }

    #[test]
    fn dead_agents_padded_and_rewards_routed() {
        let mut env = PufferMultiEnv::new(MockArena::new(10));
        let w = env.obs_layout().byte_len();
        let mut obs = vec![0u8; 4 * w];
        env.reset(0, &mut obs);

        // Rows are [1, 5, 9, pad]. Kill agent 5 (row 1, action 0).
        let actions = [1, 0, 2, 0];
        let mut r = [0.0; 4];
        let (mut te, mut tr) = ([false; 4], [false; 4]);
        let info = env.step(&actions, &mut obs, &mut r, &mut te, &mut tr);
        assert!(info.is_empty());
        assert_eq!(env.alive(), &[1, 9]);
        assert_eq!(r, [1.0, 1.0, 0.0, 0.0]);
        assert_eq!(te, [false, false, true, true], "dead + padded rows terminated");
        assert_eq!(read_row(&env, &obs, 0), vec![1.0, 1.0]);
        assert_eq!(read_row(&env, &obs, 1), vec![9.0, 1.0]);
        assert_eq!(read_row(&env, &obs, 2), vec![0.0, 0.0]);
    }

    #[test]
    fn episode_over_resets_and_reports() {
        let mut env = PufferMultiEnv::new(MockArena::new(2));
        let w = env.obs_layout().byte_len();
        let mut obs = vec![0u8; 4 * w];
        env.reset(3, &mut obs);
        let mut r = [0.0; 4];
        let (mut te, mut tr) = ([false; 4], [false; 4]);
        env.step(&[1, 1, 1, 0], &mut obs, &mut r, &mut te, &mut tr);
        let info = env.step(&[1, 1, 1, 0], &mut obs, &mut r, &mut te, &mut tr);
        assert!(te[..3].iter().all(|&t| t), "episode over terminates everyone");
        assert!(info.iter().any(|(k, _)| *k == "episode_return"));
        assert!(info.iter().any(|(k, v)| *k == "num_agents" && *v == 3.0));
        // Fresh episode: 3 agents alive again at t=0.
        assert_eq!(env.alive(), &[1, 5, 9]);
        assert_eq!(read_row(&env, &obs, 0), vec![1.0, 0.0]);
    }
}
