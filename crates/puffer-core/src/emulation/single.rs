//! [`PufferEnv`] — the one-line wrapper for single-agent environments.
//!
//! `PufferEnv::new(env)` is the entire integration story for an env author
//! (paper §3.1): it infers the structured-array layout from the env's
//! spaces, flattens observations into packed rows, emulates the action
//! space as a single MultiDiscrete, validates shapes on the *first*
//! observation only (no steady-state overhead), auto-resets, and
//! aggregates episode statistics into once-per-episode infos.

use super::{EpisodeStats, FlatEnv, Info, StructuredEnv};
use crate::spaces::{Space, StructLayout, Value};

/// Flattening wrapper around a [`StructuredEnv`].
pub struct PufferEnv<E: StructuredEnv> {
    env: E,
    obs_space: Space,
    act_space: Space,
    layout: StructLayout,
    action_dims: Vec<usize>,
    stats: EpisodeStats,
    /// Shape checks run on the first observation and first action only.
    checked: bool,
    episode_seed: u64,
}

impl<E: StructuredEnv> PufferEnv<E> {
    /// Wrap an environment. Panics immediately if the action space
    /// contains a continuous (Box) leaf — mirroring the paper's current
    /// limitation (§8); native continuous heads are ROADMAP item 4.
    pub fn new(env: E) -> Self {
        let obs_space = env.observation_space();
        let act_space = env.action_space();
        let layout = obs_space.layout();
        let action_dims = act_space.action_dims().unwrap_or_else(|| {
            panic!(
                "PufferEnv: action space has continuous leaves — quantize them \
                 in the env (emulated MultiDiscrete); native continuous heads \
                 are ROADMAP item 4"
            )
        });
        PufferEnv {
            env,
            obs_space,
            act_space,
            layout,
            action_dims,
            stats: EpisodeStats::default(),
            checked: false,
            episode_seed: 0,
        }
    }

    /// Access the wrapped environment.
    pub fn inner(&self) -> &E {
        &self.env
    }
    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.env
    }

    fn check_first(&mut self, obs: &Value) {
        if self.checked {
            return;
        }
        assert!(
            self.obs_space.contains(obs),
            "PufferEnv: first observation does not match the declared \
             observation space.\n  space: {:?}\n  obs: {:?}\n(This check runs \
             only on the first batch, so it costs nothing at steady state.)",
            self.obs_space,
            obs
        );
        self.checked = true;
    }

    fn write_obs(&mut self, obs: &Value, obs_out: &mut [u8]) {
        self.check_first(obs);
        self.layout.write_value(obs, obs_out);
    }
}

impl<E: StructuredEnv> FlatEnv for PufferEnv<E> {
    fn obs_layout(&self) -> &StructLayout {
        &self.layout
    }
    fn action_dims(&self) -> &[usize] {
        &self.action_dims
    }
    fn observation_space(&self) -> &Space {
        &self.obs_space
    }
    fn action_space(&self) -> &Space {
        &self.act_space
    }

    fn reset(&mut self, seed: u64, obs_out: &mut [u8]) -> Info {
        self.episode_seed = seed;
        self.stats = EpisodeStats::default();
        let obs = self.env.reset(seed);
        self.write_obs(&obs, obs_out);
        Info::new()
    }

    fn step(
        &mut self,
        actions: &[i32],
        obs_out: &mut [u8],
        rewards: &mut [f32],
        terms: &mut [bool],
        truncs: &mut [bool],
    ) -> Info {
        debug_assert_eq!(actions.len(), self.action_dims.len());
        let action = self.act_space.unflatten_action(actions);
        let (obs, reward, term, trunc, mut info) = self.env.step(&action);
        self.stats.push(reward);
        rewards[0] = reward;
        terms[0] = term;
        truncs[0] = trunc;
        if term || trunc {
            // Auto-reset: surface episode stats, then write the next
            // episode's first observation.
            self.stats.emit(&mut info);
            self.episode_seed = crate::util::rng::next_episode_seed(self.episode_seed);
            let first = self.env.reset(self.episode_seed);
            self.write_obs(&first, obs_out);
        } else {
            self.write_obs(&obs, obs_out);
        }
        info
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spaces::Dtype;

    /// Minimal structured env for wrapper tests: dict obs {pos: f32[2],
    /// tile: u8[4]}, dict action {dir: Discrete(4), jump: Discrete(2)}.
    /// Terminates after `horizon` steps with reward 1 per step.
    struct MockEnv {
        t: u32,
        horizon: u32,
        last_action: Option<(i64, i64)>,
    }

    impl MockEnv {
        fn new(horizon: u32) -> Self {
            MockEnv {
                t: 0,
                horizon,
                last_action: None,
            }
        }
        fn obs(&self) -> Value {
            Value::Dict(vec![
                ("pos".into(), Value::F32(vec![self.t as f32, -1.0])),
                ("tile".into(), Value::U8(vec![1, 2, 3, self.t as u8])),
            ])
        }
    }

    impl StructuredEnv for MockEnv {
        fn observation_space(&self) -> Space {
            Space::dict(vec![
                ("pos".into(), Space::boxf(&[2], -1e6, 1e6)),
                ("tile".into(), Space::boxu8(&[4])),
            ])
        }
        fn action_space(&self) -> Space {
            Space::dict(vec![
                ("dir".into(), Space::Discrete(4)),
                ("jump".into(), Space::Discrete(2)),
            ])
        }
        fn reset(&mut self, _seed: u64) -> Value {
            self.t = 0;
            self.obs()
        }
        fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
            let dir = action.field("dir").unwrap().as_discrete().unwrap();
            let jump = action.field("jump").unwrap().as_discrete().unwrap();
            self.last_action = Some((dir, jump));
            self.t += 1;
            let done = self.t >= self.horizon;
            (self.obs(), 1.0, done, false, Info::new())
        }
    }

    #[test]
    fn wrapper_shapes() {
        let env = PufferEnv::new(MockEnv::new(3));
        // dict order: pos < tile → [f32 x2][u8 x4] = 12 bytes, 6 f32 elems
        assert_eq!(env.obs_layout().byte_len(), 12);
        assert_eq!(env.obs_layout().flat_len(), 6);
        assert_eq!(env.action_dims(), &[4, 2]);
        assert_eq!(env.num_agents(), 1);
        assert_eq!(env.obs_layout().fields()[0].dtype, Dtype::F32);
    }

    #[test]
    fn step_flattens_and_unflattens() {
        let mut env = PufferEnv::new(MockEnv::new(10));
        let w = env.obs_layout().byte_len();
        let mut obs = vec![0u8; w];
        env.reset(0, &mut obs);

        let (mut r, mut te, mut tr) = ([0.0], [false], [false]);
        let info = env.step(&[2, 1], &mut obs, &mut r, &mut te, &mut tr);
        assert!(info.is_empty(), "no info mid-episode");
        assert_eq!(env.inner().last_action, Some((2, 1)));
        assert_eq!(r[0], 1.0);
        assert!(!te[0] && !tr[0]);

        // Flat row decodes back to the structured obs.
        let v = env.obs_layout().read_value(&env.inner().observation_space(), &obs);
        assert_eq!(v.field("pos").unwrap().as_f32s(), Some(&[1.0f32, -1.0][..]));
        assert_eq!(v.field("tile").unwrap().as_u8s(), Some(&[1u8, 2, 3, 1][..]));
    }

    #[test]
    fn auto_reset_emits_episode_stats() {
        let mut env = PufferEnv::new(MockEnv::new(2));
        let w = env.obs_layout().byte_len();
        let mut obs = vec![0u8; w];
        env.reset(7, &mut obs);
        let (mut r, mut te, mut tr) = ([0.0], [false], [false]);

        let info = env.step(&[0, 0], &mut obs, &mut r, &mut te, &mut tr);
        assert!(info.is_empty());
        let info = env.step(&[0, 0], &mut obs, &mut r, &mut te, &mut tr);
        assert!(te[0]);
        assert_eq!(info, vec![("episode_return", 2.0), ("episode_length", 2.0)]);

        // Auto-reset wrote the *new* episode's first obs (t=0).
        let v = env.obs_layout().read_value(&env.inner().observation_space(), &obs);
        assert_eq!(v.field("pos").unwrap().as_f32s().unwrap()[0], 0.0);

        // Next episode accumulates fresh stats.
        let info = env.step(&[0, 0], &mut obs, &mut r, &mut te, &mut tr);
        assert!(info.is_empty());
    }

    /// Env that lies about its observation space — the first-batch check
    /// must catch it.
    struct LyingEnv;
    impl StructuredEnv for LyingEnv {
        fn observation_space(&self) -> Space {
            Space::boxf(&[4], 0.0, 1.0)
        }
        fn action_space(&self) -> Space {
            Space::Discrete(2)
        }
        fn reset(&mut self, _seed: u64) -> Value {
            Value::F32(vec![0.5; 3]) // wrong length!
        }
        fn step(&mut self, _a: &Value) -> (Value, f32, bool, bool, Info) {
            unreachable!()
        }
    }

    #[test]
    #[should_panic(expected = "first observation does not match")]
    fn first_batch_shape_check_catches_bad_envs() {
        let mut env = PufferEnv::new(LyingEnv);
        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs);
    }

    /// Continuous action spaces are rejected up front (paper §8).
    struct ContinuousActEnv;
    impl StructuredEnv for ContinuousActEnv {
        fn observation_space(&self) -> Space {
            Space::boxf(&[1], 0.0, 1.0)
        }
        fn action_space(&self) -> Space {
            Space::boxf(&[2], -1.0, 1.0)
        }
        fn reset(&mut self, _seed: u64) -> Value {
            Value::F32(vec![0.0])
        }
        fn step(&mut self, _a: &Value) -> (Value, f32, bool, bool, Info) {
            unreachable!()
        }
    }

    #[test]
    #[should_panic(expected = "continuous leaves")]
    fn continuous_actions_rejected() {
        let _ = PufferEnv::new(ContinuousActEnv);
    }
}
