//! The emulation layer (paper §3.1): one-line wrappers that make any
//! structured environment *look like Atari* — flat fixed-size observation
//! rows and a single MultiDiscrete action — with an exact inverse and no
//! loss of generality.
//!
//! - [`StructuredEnv`] / [`StructuredMultiEnv`] are what environment
//!   authors implement: arbitrary [`Space`] trees, structured [`Value`]s.
//! - [`PufferEnv`] / [`PufferMultiEnv`] wrap them into [`FlatEnv`]: packed
//!   byte rows per agent, flat `i32` action slots, auto-reset, episode-stat
//!   info aggregation, first-batch shape checks, canonical agent ordering,
//!   and padding for variable population sizes.
//! - Vectorization ([`crate::vector`]) operates **only** on [`FlatEnv`] —
//!   the "hard assumption on PufferLib emulation" that makes shared-memory
//!   and zero-copy batching possible (paper §3.3).
//! - Behavioral postprocessing (reward clipping/scaling, obs
//!   normalization/stacking, time limits, action repeat) does **not**
//!   live here: it composes over [`FlatEnv`] as microwrappers declared
//!   through [`EnvSpec`](crate::wrappers::EnvSpec) (see
//!   [`crate::wrappers`]), keeping this layer a pure flattening bridge.

// Emulation packs/unpacks bytes through safe slice APIs only
// (CONCURRENCY.md — keep the unsafe surface in vector/).
#![forbid(unsafe_code)]

mod flat;
mod multi;
mod single;

pub use flat::FlatEnv;
pub use multi::PufferMultiEnv;
pub use single::PufferEnv;

use crate::spaces::Value;

/// Per-step auxiliary information. Numeric key/value pairs; an empty info
/// is "pruned" by vectorization (never crosses the worker boundary), so
/// well-behaved envs emit infos only on episode end — exactly the paper's
/// pipes-once-per-episode discipline.
pub type Info = Vec<(&'static str, f64)>;

/// Identifier for an agent within a multiagent environment. Emulation
/// sorts observations/actions by this id (canonical order, paper §3.1).
pub type AgentId = u32;

/// A single-agent environment with arbitrary structured spaces. This is
/// the Gym/Gymnasium-shaped trait environment authors implement.
pub trait StructuredEnv: Send {
    fn observation_space(&self) -> crate::spaces::Space;
    fn action_space(&self) -> crate::spaces::Space;
    /// Start a new episode; returns the initial observation.
    fn reset(&mut self, seed: u64) -> Value;
    /// Advance one step. Returns (obs, reward, terminated, truncated, info).
    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info);
}

/// A multiagent environment (PettingZoo-shaped): a variable set of agents,
/// each with the same per-agent spaces. Agents may join/leave between
/// steps up to [`max_agents`](Self::max_agents).
pub trait StructuredMultiEnv: Send {
    /// Per-agent observation space.
    fn observation_space(&self) -> crate::spaces::Space;
    /// Per-agent action space.
    fn action_space(&self) -> crate::spaces::Space;
    /// Upper bound on simultaneously alive agents (fixed buffer size).
    fn max_agents(&self) -> usize;
    /// Start a new episode; returns (agent, obs) for each alive agent, in
    /// any order — emulation sorts them.
    fn reset(&mut self, seed: u64) -> Vec<(AgentId, Value)>;
    /// Advance one step given (agent, action) pairs for alive agents.
    /// Returns per-agent (id, obs, reward, terminated) plus a shared info;
    /// `episode_over` ends the episode for everyone.
    fn step(&mut self, actions: &[(AgentId, Value)]) -> MultiStep;
}

/// Result of one multiagent step.
pub struct MultiStep {
    pub agents: Vec<(AgentId, Value, f32, bool)>,
    pub episode_over: bool,
    pub info: Info,
}

/// Streaming accumulator for per-episode statistics. The wrappers use this
/// to aggregate rewards/lengths so that infos cross the worker boundary
/// once per episode (paper §3.3 "Empty infos are pruned, and we provide
/// wrappers to aggregate them over episodes").
#[derive(Clone, Debug, Default)]
pub struct EpisodeStats {
    pub ret: f64,
    pub len: u64,
}

impl EpisodeStats {
    #[inline]
    pub fn push(&mut self, reward: f32) {
        self.ret += reward as f64;
        self.len += 1;
    }

    /// Drain into an info payload and reset for the next episode.
    pub fn emit(&mut self, info: &mut Info) {
        info.push(("episode_return", self.ret));
        info.push(("episode_length", self.len as f64));
        *self = EpisodeStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn episode_stats_accumulate_and_reset() {
        let mut s = EpisodeStats::default();
        s.push(1.0);
        s.push(0.5);
        let mut info = Info::new();
        s.emit(&mut info);
        assert_eq!(info[0], ("episode_return", 1.5));
        assert_eq!(info[1], ("episode_length", 2.0));
        assert_eq!(s.ret, 0.0);
        assert_eq!(s.len, 0);
    }
}
