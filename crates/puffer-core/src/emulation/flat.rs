//! [`FlatEnv`] — the Atari-shaped interface every vectorization backend
//! consumes. One env owns `num_agents()` fixed-size rows; single-agent
//! envs have exactly one row.

use super::Info;
use crate::spaces::{Space, StructLayout};

/// An environment whose observations are packed byte rows and whose
/// actions are flat `i32` slot vectors. Produced by wrapping a
/// [`StructuredEnv`](super::StructuredEnv) in
/// [`PufferEnv`](super::PufferEnv) (or the multiagent analog); implemented
/// directly only by envs that are natively flat — and by
/// [`Wrapped`](crate::wrappers::Wrapped), which layers in-place
/// microwrappers over any `FlatEnv` while preserving this contract.
///
/// ### Buffer contract
///
/// For an env with `A = num_agents()` and row width `W =
/// obs_layout().byte_len()`:
/// - `obs_out` is `A * W` bytes, agent-major.
/// - `rewards`, `terms`, `truncs` are `A` long.
/// - `actions` is `A * action_dims().len()` slots, agent-major.
///
/// ### Auto-reset
///
/// `step` must auto-reset: when the episode ends, the env immediately
/// starts a new one and writes the *new* episode's first observation. The
/// final observation of the old episode is not surfaced (standard vector
/// semantics; PPO-style algorithms bootstrap off the done flag instead).
pub trait FlatEnv: Send {
    /// Layout of one observation row.
    fn obs_layout(&self) -> &StructLayout;
    /// Per-slot cardinalities of the emulated MultiDiscrete action.
    fn action_dims(&self) -> &[usize];
    /// The structured observation space (for user-side unflattening).
    fn observation_space(&self) -> &Space;
    /// The structured action space.
    fn action_space(&self) -> &Space;
    /// Fixed number of agent rows (1 for single-agent envs).
    fn num_agents(&self) -> usize {
        1
    }
    /// Begin a new episode; write each agent's first observation.
    fn reset(&mut self, seed: u64, obs_out: &mut [u8]) -> Info;
    /// Advance one step for all agents.
    fn step(
        &mut self,
        actions: &[i32],
        obs_out: &mut [u8],
        rewards: &mut [f32],
        terms: &mut [bool],
        truncs: &mut [bool],
    ) -> Info;
}

/// Blanket impl so `Box<dyn FlatEnv>` is itself a `FlatEnv` (workers store
/// trait objects).
impl FlatEnv for Box<dyn FlatEnv> {
    fn obs_layout(&self) -> &StructLayout {
        (**self).obs_layout()
    }
    fn action_dims(&self) -> &[usize] {
        (**self).action_dims()
    }
    fn observation_space(&self) -> &Space {
        (**self).observation_space()
    }
    fn action_space(&self) -> &Space {
        (**self).action_space()
    }
    fn num_agents(&self) -> usize {
        (**self).num_agents()
    }
    fn reset(&mut self, seed: u64, obs_out: &mut [u8]) -> Info {
        (**self).reset(seed, obs_out)
    }
    fn step(
        &mut self,
        actions: &[i32],
        obs_out: &mut [u8],
        rewards: &mut [f32],
        terms: &mut [bool],
        truncs: &mut [bool],
    ) -> Info {
        (**self).step(actions, obs_out, rewards, terms, truncs)
    }
}
