//! Plain-data serving configuration — the `[serve]` section of a
//! [`RunSpec`](crate::runspec::RunSpec). The inference server itself
//! (`puffer serve`: batcher shards, sessions, protocol) lives in
//! `puffer-train`, which re-exports this type under the same `serve::`
//! path.

// Plain data; no unsafe belongs here (CONCURRENCY.md).
#![forbid(unsafe_code)]

/// The strict `[serve]` section of a [`RunSpec`](crate::runspec::RunSpec)
/// and the `--serve.*` CLI namespace. Plain data, TOML/JSON
/// round-trippable like every other spec part.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// TCP port to bind on 127.0.0.1. `0` binds an ephemeral port (the
    /// selftest and tests always use this).
    pub port: u16,
    /// Row budget: a batch is dispatched as soon as this many requests
    /// are queued.
    pub max_batch: usize,
    /// Time budget: a non-empty batch is dispatched once this many
    /// microseconds have passed since its first request, even if
    /// `max_batch` was not reached. `0` dispatches whatever is queued.
    pub max_wait_us: u64,
    /// Idle sessions older than this many seconds are evicted (their
    /// recurrent state is dropped; a later request under the same id
    /// starts fresh).
    pub session_ttl_s: u64,
    /// Number of batcher shards. Sessions are pinned to a shard
    /// (`session_id % threads`), so per-session request order is
    /// preserved; batching happens independently per shard.
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            port: 7777,
            max_batch: 64,
            max_wait_us: 500,
            session_ttl_s: 300,
            threads: 1,
        }
    }
}

impl ServeConfig {
    /// Canonical `(knob, value)` pairs for the `[serve]` section — the
    /// inverse of [`crate::config::serve_config`].
    pub fn to_flat_pairs(&self) -> Vec<(&'static str, String)> {
        vec![
            ("port", self.port.to_string()),
            ("max_batch", self.max_batch.to_string()),
            ("max_wait_us", self.max_wait_us.to_string()),
            ("session_ttl_s", self.session_ttl_s.to_string()),
            ("threads", self.threads.to_string()),
        ]
    }
}
