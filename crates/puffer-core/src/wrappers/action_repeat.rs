//! Action repeat (frame skip): step the inner env `k` times per outer
//! step with the same actions, summing rewards.

use super::Wrapper;

/// Repeat each action `k` times. The driving layer performs the loop:
/// rewards are summed, done flags OR-ed, and the loop exits early when
/// the episode ends (the inner env has auto-reset by then; repeating
/// further would leak actions into the next episode). Only the final
/// observation is surfaced — the standard frame-skip contract.
pub struct ActionRepeat {
    k: usize,
}

impl ActionRepeat {
    /// `k` must be at least 1 (1 is an identity layer).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "ActionRepeat count must be >= 1");
        ActionRepeat { k }
    }
}

impl Wrapper for ActionRepeat {
    fn name(&self) -> &'static str {
        "action_repeat"
    }

    fn repeat(&self) -> usize {
        self.k
    }
}
