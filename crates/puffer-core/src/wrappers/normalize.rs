//! Observation normalization with running mean/variance, in place on the
//! packed byte rows.

use super::{Flow, Wrapper};
use crate::emulation::Info;
use crate::spaces::{Dtype, StructLayout};

/// Normalize every `f32` leaf element to zero mean / unit variance using
/// Welford running statistics, then clip to `±clip`. Integer leaves (u8
/// tiles, i32 glyphs, discrete slots) pass through untouched — they are
/// categorical data, and rewriting them in place would corrupt their
/// byte representation.
///
/// Statistics are **per env instance**: every vectorized copy maintains
/// its own running moments (wrapper state lives with the env on its
/// worker, which is what keeps the shared-slab paths copy- and
/// synchronization-free). With the identical reward/observation streams
/// of a vectorized sweep the per-copy moments converge to the same
/// values; exact cross-env aggregation would require a side channel and
/// is out of scope here.
pub struct NormalizeObs {
    eps: f32,
    clip: f32,
    /// `(byte_offset, element_count)` of each f32 leaf within a row.
    fields: Vec<(usize, usize)>,
    row_bytes: usize,
    /// Welford state over all rows seen, one slot per f32 element.
    count: f64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl NormalizeObs {
    pub fn new() -> Self {
        NormalizeObs {
            eps: 1e-8,
            clip: 10.0,
            fields: Vec::new(),
            row_bytes: 0,
            count: 0.0,
            mean: Vec::new(),
            m2: Vec::new(),
        }
    }

    fn normalize(&mut self, obs: &mut [u8]) {
        debug_assert_eq!(obs.len() % self.row_bytes.max(1), 0);
        for row in obs.chunks_exact_mut(self.row_bytes) {
            self.count += 1.0;
            let mut slot = 0usize;
            for &(off, n) in &self.fields {
                for i in 0..n {
                    let o = off + 4 * i;
                    // PANIC: 4-byte slice by construction — try_into::<[u8; 4]> cannot fail.
                    let x = f32::from_le_bytes(row[o..o + 4].try_into().unwrap()) as f64;
                    let d = x - self.mean[slot];
                    self.mean[slot] += d / self.count;
                    self.m2[slot] += d * (x - self.mean[slot]);
                    let var = if self.count > 1.0 { self.m2[slot] / (self.count - 1.0) } else { 1.0 };
                    let norm = ((x - self.mean[slot]) / (var.sqrt() + self.eps as f64)) as f32;
                    let norm = norm.clamp(-self.clip, self.clip);
                    row[o..o + 4].copy_from_slice(&norm.to_le_bytes());
                    slot += 1;
                }
            }
        }
    }
}

impl Default for NormalizeObs {
    fn default() -> Self {
        Self::new()
    }
}

impl Wrapper for NormalizeObs {
    fn name(&self) -> &'static str {
        "normalize_obs"
    }

    fn bind(&mut self, inner: &StructLayout, _num_agents: usize) {
        self.row_bytes = inner.byte_len();
        self.fields = inner
            .fields()
            .iter()
            .filter(|f| f.dtype == Dtype::F32)
            .map(|f| (f.byte_offset, f.count))
            .collect();
        let elems: usize = self.fields.iter().map(|&(_, n)| n).sum();
        self.count = 0.0;
        self.mean = vec![0.0; elems];
        self.m2 = vec![0.0; elems];
    }

    fn on_reset(&mut self, obs: &mut [u8]) {
        self.normalize(obs);
    }

    fn on_step(
        &mut self,
        obs: &mut [u8],
        _rewards: &mut [f32],
        _terms: &mut [bool],
        _truncs: &mut [bool],
        _info: &mut Info,
    ) -> Flow {
        self.normalize(obs);
        Flow::Continue
    }
}
