//! Reward microwrappers: clipping and scaling. Both mutate the reward
//! buffer in place; observations and flags pass through untouched.

use super::{Flow, Wrapper};
use crate::emulation::Info;

/// Clamp every reward into `[-bound, bound]` (the DQN-era stabilizer).
pub struct ClipReward {
    bound: f32,
}

impl ClipReward {
    /// `bound` must be positive and finite.
    pub fn new(bound: f32) -> Self {
        assert!(bound > 0.0 && bound.is_finite(), "ClipReward bound must be positive, got {bound}");
        ClipReward { bound }
    }
}

impl Wrapper for ClipReward {
    fn name(&self) -> &'static str {
        "clip_reward"
    }

    fn on_step(
        &mut self,
        _obs: &mut [u8],
        rewards: &mut [f32],
        _terms: &mut [bool],
        _truncs: &mut [bool],
        _info: &mut Info,
    ) -> Flow {
        for r in rewards.iter_mut() {
            *r = r.clamp(-self.bound, self.bound);
        }
        Flow::Continue
    }
}

/// Multiply every reward by a constant factor.
pub struct ScaleReward {
    scale: f32,
}

impl ScaleReward {
    /// `scale` must be finite.
    pub fn new(scale: f32) -> Self {
        assert!(scale.is_finite(), "ScaleReward factor must be finite, got {scale}");
        ScaleReward { scale }
    }
}

impl Wrapper for ScaleReward {
    fn name(&self) -> &'static str {
        "scale_reward"
    }

    fn on_step(
        &mut self,
        _obs: &mut [u8],
        rewards: &mut [f32],
        _terms: &mut [bool],
        _truncs: &mut [bool],
        _info: &mut Info,
    ) -> Flow {
        for r in rewards.iter_mut() {
            *r *= self.scale;
        }
        Flow::Continue
    }
}
