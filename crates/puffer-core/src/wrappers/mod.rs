//! Composable microwrappers over [`FlatEnv`] — the paper's "one-line
//! wrappers that eliminate common compatibility problems" (§3.1), in the
//! SuperSuit spirit of small, single-purpose transforms, but operating
//! **in place on the packed byte rows** so the vector backends keep their
//! zero-copy guarantees.
//!
//! Three pieces:
//!
//! - [`Wrapper`] — the transform trait. Width-preserving wrappers mutate
//!   the step buffers in place ([`Wrapper::on_step`]); layout-changing
//!   wrappers (obs stacking) project inner rows into wider output rows
//!   ([`Wrapper::project_step`]) and advertise the new layout via
//!   [`Wrapper::transform_space`], so `probe_factory`, the shared-memory
//!   slabs, and `NativeBackend::for_env` all size themselves from the
//!   *wrapped* geometry.
//! - [`Wrapped`] — the generic layer that drives one wrapper over one
//!   inner [`FlatEnv`]. Chains are built by nesting layers; each layer
//!   preallocates any state it needs, so steady-state stepping does **no
//!   per-step allocation**.
//! - [`EnvSpec`] — the declarative builder
//!   (`EnvSpec::new("ocean/squared").clip_reward(1.0).stack(4)`) that
//!   replaces raw `EnvFactory` closures as the currency passed to
//!   `Serial`/`Multiprocessing`, the `Trainer`, `autotune`, and the
//!   `puffer` CLI. Specs are cloneable descriptions; every vectorized env
//!   copy instantiates its own fresh wrapper state from them.
//!
//! Order matters and is explicit: the chain applies **innermost first**
//! (`.scale_reward(2.0).clip_reward(1.0)` scales at the env boundary,
//! then clips the scaled reward). The same list therefore produces the
//! same semantics on every backend — `Serial`, all four `Multiprocessing`
//! code paths, and the baselines (pinned by `tests/wrapper_semantics.rs`).

// Wrappers transform rows they are handed — no shared state, no unsafe
// (CONCURRENCY.md — keep the unsafe surface in vector/).
#![forbid(unsafe_code)]

mod action_repeat;
mod normalize;
mod reward;
mod spec;
mod stack;
mod time_limit;

pub use action_repeat::ActionRepeat;
pub use normalize::NormalizeObs;
pub use reward::{ClipReward, ScaleReward};
pub use spec::{EnvSpec, WrapperSpec};
pub use stack::ObsStack;
pub use time_limit::TimeLimit;

use crate::emulation::{FlatEnv, Info};
use crate::spaces::{Space, StructLayout};

/// Control-flow verdict a wrapper returns from its step hook.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flow {
    /// Pass the step results through.
    Continue,
    /// Force the episode to end now: the driving [`Wrapped`] layer resets
    /// its inner env (writing the new episode's first observation, per
    /// the [`FlatEnv`] auto-reset contract) and raises every `truncs`
    /// flag. Used by [`TimeLimit`].
    Truncate,
}

/// A single transform over [`FlatEnv`] step results.
///
/// All buffer arguments are whole-env buffers: `num_agents` rows,
/// agent-major, exactly as [`FlatEnv::step`] sees them. Implement the
/// `on_*` hooks when the observation layout is unchanged (the buffers are
/// mutated in place on the shared slab); implement [`transform_space`] +
/// the `project_*` hooks when the wrapper widens rows (the driver stages
/// inner rows in a preallocated scratch buffer and the wrapper writes the
/// output rows).
///
/// [`transform_space`]: Wrapper::transform_space
pub trait Wrapper: Send {
    /// Stable name for keys/diagnostics ("clip_reward", "stack", ...).
    fn name(&self) -> &'static str;

    /// The transformed observation space, or `None` if unchanged. The
    /// output layout is inferred from this space, exactly as emulation
    /// infers the inner layout.
    fn transform_space(&self, _inner: &Space) -> Option<Space> {
        None
    }

    /// The transformed action space, or `None` if unchanged. The
    /// advertised `action_dims` are re-derived from this space, so the
    /// two can never disagree. The space must stay discrete (emulation's
    /// MultiDiscrete contract).
    fn transform_action_space(&self, _inner: &Space) -> Option<Space> {
        None
    }

    /// One-time bind to the inner geometry; preallocate all state here.
    fn bind(&mut self, _inner: &StructLayout, _num_agents: usize) {}

    /// Inner steps per outer step (action repeat). The driver loops the
    /// inner env, accumulates rewards, and stops early on episode end.
    fn repeat(&self) -> usize {
        1
    }

    /// In-place hook after a reset (width-preserving wrappers).
    fn on_reset(&mut self, _obs: &mut [u8]) {}

    /// In-place hook after a step (width-preserving wrappers).
    fn on_step(
        &mut self,
        _obs: &mut [u8],
        _rewards: &mut [f32],
        _terms: &mut [bool],
        _truncs: &mut [bool],
        _info: &mut Info,
    ) -> Flow {
        Flow::Continue
    }

    /// Layout-changing hook after a reset: inner rows in `src` → output
    /// rows in `dst`.
    fn project_reset(&mut self, _src: &[u8], _dst: &mut [u8]) {
        unimplemented!("wrapper '{}' changes the layout but has no project_reset", self.name())
    }

    /// Layout-changing hook after a step.
    fn project_step(
        &mut self,
        _src: &[u8],
        _dst: &mut [u8],
        _rewards: &mut [f32],
        _terms: &mut [bool],
        _truncs: &mut [bool],
        _info: &mut Info,
    ) -> Flow {
        unimplemented!("wrapper '{}' changes the layout but has no project_step", self.name())
    }
}

/// Apply one wrapper to an env, producing a new [`FlatEnv`]. Chains are
/// built by repeated application, innermost first (what [`EnvSpec::build`]
/// does).
pub fn wrap(inner: Box<dyn FlatEnv>, wrapper: Box<dyn Wrapper>) -> Box<dyn FlatEnv> {
    Box::new(Wrapped::new(inner, wrapper))
}

/// One wrapper layer driving one inner env. Implements [`FlatEnv`], so
/// layers nest and the vectorizers see an ordinary flat env with the
/// *wrapped* layout and action dims.
pub struct Wrapped {
    inner: Box<dyn FlatEnv>,
    wrapper: Box<dyn Wrapper>,
    obs_space: Space,
    act_space: Space,
    layout: StructLayout,
    action_dims: Vec<usize>,
    /// Staging rows in the *inner* layout; `Some` only when the wrapper
    /// changes the layout (preallocated once, never grown).
    scratch: Option<Vec<u8>>,
    /// Accumulators for `repeat > 1` (preallocated; unused otherwise).
    acc_rewards: Vec<f32>,
    acc_terms: Vec<bool>,
    acc_truncs: Vec<bool>,
    episode_seed: u64,
}

impl Wrapped {
    fn new(inner: Box<dyn FlatEnv>, mut wrapper: Box<dyn Wrapper>) -> Self {
        let inner_layout = inner.obs_layout().clone();
        let agents = inner.num_agents();
        wrapper.bind(&inner_layout, agents);
        let (obs_space, layout, scratch) = match wrapper.transform_space(inner.observation_space()) {
            Some(space) => {
                let layout = space.layout();
                (space, layout, Some(vec![0u8; agents * inner_layout.byte_len()]))
            }
            None => (inner.observation_space().clone(), inner_layout, None),
        };
        let (act_space, action_dims) = match wrapper.transform_action_space(inner.action_space()) {
            Some(space) => {
                let dims = space.action_dims().unwrap_or_else(|| {
                    panic!(
                        "wrapper '{}' produced an action space with continuous leaves",
                        wrapper.name()
                    )
                });
                (space, dims)
            }
            None => (inner.action_space().clone(), inner.action_dims().to_vec()),
        };
        Wrapped {
            inner,
            wrapper,
            obs_space,
            act_space,
            layout,
            action_dims,
            scratch,
            acc_rewards: vec![0.0; agents],
            acc_terms: vec![false; agents],
            acc_truncs: vec![false; agents],
            episode_seed: 0,
        }
    }

    fn next_episode_seed(&mut self) -> u64 {
        self.episode_seed = crate::util::rng::next_episode_seed(self.episode_seed);
        self.episode_seed
    }
}

impl FlatEnv for Wrapped {
    fn obs_layout(&self) -> &StructLayout {
        &self.layout
    }
    fn action_dims(&self) -> &[usize] {
        &self.action_dims
    }
    fn observation_space(&self) -> &Space {
        &self.obs_space
    }
    fn action_space(&self) -> &Space {
        &self.act_space
    }
    fn num_agents(&self) -> usize {
        self.inner.num_agents()
    }

    fn reset(&mut self, seed: u64, obs_out: &mut [u8]) -> Info {
        self.episode_seed = seed;
        match &mut self.scratch {
            Some(scratch) => {
                let info = self.inner.reset(seed, scratch);
                self.wrapper.project_reset(scratch, obs_out);
                info
            }
            None => {
                let info = self.inner.reset(seed, obs_out);
                self.wrapper.on_reset(obs_out);
                info
            }
        }
    }

    fn step(
        &mut self,
        actions: &[i32],
        obs_out: &mut [u8],
        rewards: &mut [f32],
        terms: &mut [bool],
        truncs: &mut [bool],
    ) -> Info {
        let rows = rewards.len();
        let k = self.wrapper.repeat().max(1);
        let mut info = Info::new();

        if k == 1 {
            let dst: &mut [u8] = match &mut self.scratch {
                Some(s) => s,
                None => &mut *obs_out,
            };
            info = self.inner.step(actions, dst, rewards, terms, truncs);
        } else {
            // Action repeat: same actions, summed rewards, OR-ed done
            // flags, early exit once the episode ends (the inner env has
            // already auto-reset by then; repeating further would leak
            // actions into the next episode).
            self.acc_rewards[..rows].fill(0.0);
            self.acc_terms[..rows].fill(false);
            self.acc_truncs[..rows].fill(false);
            for _ in 0..k {
                let dst: &mut [u8] = match &mut self.scratch {
                    Some(s) => s,
                    None => &mut *obs_out,
                };
                let step_info = self.inner.step(actions, dst, rewards, terms, truncs);
                info.extend(step_info);
                for r in 0..rows {
                    self.acc_rewards[r] += rewards[r];
                    self.acc_terms[r] |= terms[r];
                    self.acc_truncs[r] |= truncs[r];
                }
                if terms.iter().zip(truncs.iter()).all(|(t, u)| *t || *u) {
                    break;
                }
            }
            rewards.copy_from_slice(&self.acc_rewards[..rows]);
            terms.copy_from_slice(&self.acc_terms[..rows]);
            truncs.copy_from_slice(&self.acc_truncs[..rows]);
        }

        let flow = match &mut self.scratch {
            Some(scratch) => {
                self.wrapper.project_step(scratch, obs_out, rewards, terms, truncs, &mut info)
            }
            None => self.wrapper.on_step(obs_out, rewards, terms, truncs, &mut info),
        };

        if flow == Flow::Truncate {
            // Forced episode end (time limit): honor the auto-reset
            // contract ourselves — reset the inner chain and surface the
            // *new* episode's first observation with `truncs` raised.
            let seed = self.next_episode_seed();
            match &mut self.scratch {
                Some(scratch) => {
                    info.extend(self.inner.reset(seed, scratch));
                    self.wrapper.project_reset(scratch, obs_out);
                }
                None => {
                    info.extend(self.inner.reset(seed, obs_out));
                    self.wrapper.on_reset(obs_out);
                }
            }
            truncs.fill(true);
        }
        info
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulation::{PufferEnv, StructuredEnv};
    use crate::spaces::Value;

    /// Deterministic env: obs [t, last_action], reward = 1 + t, episode
    /// ends after `horizon` steps.
    struct Counter {
        t: u32,
        horizon: u32,
    }

    impl Counter {
        fn boxed(horizon: u32) -> Box<dyn FlatEnv> {
            Box::new(PufferEnv::new(Counter { t: 0, horizon }))
        }
    }

    impl StructuredEnv for Counter {
        fn observation_space(&self) -> Space {
            Space::boxf(&[2], -1e6, 1e6)
        }
        fn action_space(&self) -> Space {
            Space::Discrete(4)
        }
        fn reset(&mut self, _seed: u64) -> Value {
            self.t = 0;
            Value::F32(vec![0.0, -1.0])
        }
        fn step(&mut self, a: &Value) -> (Value, f32, bool, bool, Info) {
            self.t += 1;
            let obs = Value::F32(vec![self.t as f32, a.as_discrete().unwrap() as f32]);
            (obs, 1.0 + self.t as f32, self.t >= self.horizon, false, Info::new())
        }
    }

    fn decode_f32s(row: &[u8]) -> Vec<f32> {
        row.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    fn drive(env: &mut Box<dyn FlatEnv>, action: i32) -> (Vec<u8>, f32, bool, bool, Info) {
        let w = env.obs_layout().byte_len();
        let mut obs = vec![0u8; w];
        let (mut r, mut te, mut tr) = ([0.0], [false], [false]);
        let info = env.step(&[action], &mut obs, &mut r, &mut te, &mut tr);
        (obs, r[0], te[0], tr[0], info)
    }

    #[test]
    fn clip_and_scale_apply_innermost_first() {
        // scale(2) then clip(3): rewards are 2·(1+t) clamped to 3.
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(100))
            .scale_reward(2.0)
            .clip_reward(3.0);
        let mut env = spec.build(0);
        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs);
        let (_, r1, ..) = drive(&mut env, 0);
        assert_eq!(r1, 3.0); // 2·2 = 4 → clipped to 3

        // clip(3) then scale(2): clamp first, then scale.
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(100))
            .clip_reward(3.0)
            .scale_reward(2.0);
        let mut env = spec.build(0);
        env.reset(0, &mut obs);
        let (_, r1, ..) = drive(&mut env, 0);
        assert_eq!(r1, 4.0); // min(2, 3)·2
    }

    #[test]
    fn stack_widens_layout_and_orders_frames_oldest_first() {
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(100)).stack(3);
        let mut env = spec.build(0);
        assert_eq!(env.obs_layout().byte_len(), 3 * 8);
        assert_eq!(env.obs_layout().flat_len(), 6);

        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs);
        // Reset fills every frame with the first observation.
        assert_eq!(decode_f32s(&obs), vec![0.0, -1.0, 0.0, -1.0, 0.0, -1.0]);

        let (obs, ..) = drive(&mut env, 2);
        assert_eq!(decode_f32s(&obs), vec![0.0, -1.0, 0.0, -1.0, 1.0, 2.0]);
        let (obs, ..) = drive(&mut env, 3);
        assert_eq!(decode_f32s(&obs), vec![0.0, -1.0, 1.0, 2.0, 2.0, 3.0]);
    }

    #[test]
    fn stack_refills_history_on_auto_reset() {
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(2)).stack(3);
        let mut env = spec.build(0);
        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs);
        drive(&mut env, 0);
        let (obs, _, term, _, _) = drive(&mut env, 0);
        assert!(term);
        // The inner env auto-reset: the stack must not leak old frames.
        assert_eq!(decode_f32s(&obs), vec![0.0, -1.0, 0.0, -1.0, 0.0, -1.0]);
    }

    #[test]
    fn time_limit_truncates_and_surfaces_fresh_obs() {
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(100)).time_limit(3);
        let mut env = spec.build(0);
        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(7, &mut obs);
        for step in 1..=2 {
            let (obs, _, term, trunc, _) = drive(&mut env, 0);
            assert!(!term && !trunc, "step {step}");
            assert_eq!(decode_f32s(&obs)[0], step as f32);
        }
        let (obs, _, term, trunc, info) = drive(&mut env, 0);
        assert!(!term && trunc, "limit must truncate, not terminate");
        // Auto-reset contract: the surfaced obs is the new episode's first.
        assert_eq!(decode_f32s(&obs), vec![0.0, -1.0]);
        assert!(info.iter().any(|(k, v)| *k == "truncated_at" && *v == 3.0));
        // The counter restarted with the new episode.
        let (obs, _, _, trunc, _) = drive(&mut env, 0);
        assert!(!trunc);
        assert_eq!(decode_f32s(&obs)[0], 1.0);
    }

    #[test]
    fn time_limit_defers_to_natural_episode_ends() {
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(2)).time_limit(3);
        let mut env = spec.build(0);
        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs);
        for _ in 0..4 {
            let (_, _, term, trunc, _) = drive(&mut env, 0);
            // Horizon 2 always beats limit 3: only natural terminations.
            assert!(!trunc);
            let _ = term;
        }
    }

    #[test]
    fn action_repeat_sums_rewards_and_stops_at_episode_end() {
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(100)).action_repeat(3);
        let mut env = spec.build(0);
        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs);
        let (obs, r, ..) = drive(&mut env, 1);
        // Three inner steps: rewards 2 + 3 + 4; obs is the last frame.
        assert_eq!(r, 9.0);
        assert_eq!(decode_f32s(&obs), vec![3.0, 1.0]);

        // Horizon 4: the second repeated step ends the episode after one
        // inner step (t = 4) and must not bleed into the next episode.
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(4)).action_repeat(3);
        let mut env = spec.build(0);
        let mut obs4 = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs4);
        let (_, r, term, ..) = drive(&mut env, 0);
        assert_eq!(r, 2.0 + 3.0 + 4.0);
        assert!(!term);
        let (obs, r, term, ..) = drive(&mut env, 0);
        assert_eq!(r, 5.0, "episode ended after one inner step");
        assert!(term);
        // Auto-reset already surfaced the new episode's first obs.
        assert_eq!(decode_f32s(&obs), vec![0.0, -1.0]);
    }

    #[test]
    fn normalize_rewrites_f32_leaves_in_place() {
        let spec = EnvSpec::custom("counter", |_| Counter::boxed(1000)).normalize_obs();
        let mut env = spec.build(0);
        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs);
        // Element [1] echoes the action: alternate 0/3 so the raw stream
        // has a stable mean the running stats can converge to.
        let mut seen = Vec::new();
        for i in 0..60 {
            let (obs, ..) = drive(&mut env, if i % 2 == 0 { 0 } else { 3 });
            seen.push(decode_f32s(&obs)[1]);
        }
        assert!(seen.iter().all(|x| x.abs() <= 10.0), "clip bound violated: {seen:?}");
        // Once the stats settle, 0 maps below the mean and 3 above it…
        let tail = &seen[40..];
        for pair in tail.chunks_exact(2) {
            assert!(pair[0] < 0.0 && pair[1] > 0.0, "not centered: {pair:?}");
        }
        // …and the normalized tail is roughly zero-mean, unit-scale.
        let mean: f32 = tail.iter().sum::<f32>() / tail.len() as f32;
        assert!(mean.abs() < 0.5, "running-normalized tail mean {mean} far from 0");
        assert!(tail.iter().all(|x| x.abs() < 3.0), "tail not unit-scale: {tail:?}");
    }

    #[test]
    fn chains_compose_and_key_is_ordered() {
        let spec = EnvSpec::new("classic/cartpole")
            .action_repeat(2)
            .clip_reward(0.5)
            .stack(4);
        assert_eq!(
            spec.key(),
            "classic/cartpole+action_repeat=2+clip_reward=0.5+stack=4"
        );
        let mut env = spec.build(0);
        let base = crate::envs::make("classic/cartpole", 0);
        assert_eq!(env.obs_layout().byte_len(), 4 * base.obs_layout().byte_len());
        assert_eq!(env.action_dims(), base.action_dims());
        let mut obs = vec![0u8; env.obs_layout().byte_len()];
        env.reset(0, &mut obs);
        let (_, r, ..) = drive(&mut env, 0);
        // Clip sits outside the repeat layer, so it clamps the *summed*
        // reward: two cartpole steps at 1.0 each → 2.0 → clipped to 0.5.
        assert_eq!(r, 0.5);
    }
}
