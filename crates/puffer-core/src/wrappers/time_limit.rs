//! Episode truncation after a fixed number of wrapper-level steps.

use super::{Flow, Wrapper};
use crate::emulation::Info;

/// Truncate the episode after `max_steps` outer steps. When the limit
/// hits, the driving layer resets the inner chain (auto-reset contract:
/// the surfaced observation is the *new* episode's first one) and raises
/// every `truncs` flag; a `("truncated_at", t)` info marks the event.
///
/// The counter counts *this layer's* steps: placed outside an
/// [`ActionRepeat`](super::ActionRepeat), one repeated step counts once;
/// placed inside, every inner step counts. Episode ends reported by the
/// inner env (all rows done) reset the counter.
pub struct TimeLimit {
    max_steps: u64,
    t: u64,
}

impl TimeLimit {
    /// `max_steps` must be at least 1.
    pub fn new(max_steps: u64) -> Self {
        assert!(max_steps >= 1, "TimeLimit must allow at least one step");
        TimeLimit { max_steps, t: 0 }
    }
}

impl Wrapper for TimeLimit {
    fn name(&self) -> &'static str {
        "time_limit"
    }

    fn on_reset(&mut self, _obs: &mut [u8]) {
        self.t = 0;
    }

    fn on_step(
        &mut self,
        _obs: &mut [u8],
        _rewards: &mut [f32],
        terms: &mut [bool],
        truncs: &mut [bool],
        info: &mut Info,
    ) -> Flow {
        self.t += 1;
        // "All rows done" is the episode boundary (multiagent padded rows
        // read as terminated, so `any` would fire spuriously).
        let episode_over = terms.iter().zip(truncs.iter()).all(|(t, u)| *t || *u);
        if episode_over {
            self.t = 0;
            return Flow::Continue;
        }
        if self.t >= self.max_steps {
            info.push(("truncated_at", self.t as f64));
            self.t = 0;
            return Flow::Truncate;
        }
        Flow::Continue
    }
}
