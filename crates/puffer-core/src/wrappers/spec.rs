//! [`EnvSpec`] — the declarative environment + wrapper-chain description
//! that replaces raw factory closures as the construction currency across
//! the stack.

use super::{wrap, ActionRepeat, ClipReward, NormalizeObs, ObsStack, ScaleReward, TimeLimit, Wrapper};
use crate::emulation::FlatEnv;
use crate::vector::EnvFactory;
use std::fmt;
use std::sync::Arc;

/// A declarative wrapper description. Specs are plain data (cloneable,
/// comparable), so an [`EnvSpec`] can cross worker-thread boundaries and
/// instantiate fresh, independent wrapper state for every vectorized env
/// copy.
#[derive(Clone, Debug, PartialEq)]
pub enum WrapperSpec {
    /// Clamp rewards into `[-bound, bound]`.
    ClipReward(f32),
    /// Multiply rewards by a constant.
    ScaleReward(f32),
    /// Running mean/var normalization of f32 observation leaves.
    NormalizeObs,
    /// Stack the last `k` observations (widens rows ×`k`).
    Stack(usize),
    /// Truncate episodes after `n` steps.
    TimeLimit(u64),
    /// Repeat each action `k` times, summing rewards.
    ActionRepeat(usize),
}

impl WrapperSpec {
    /// Build a fresh wrapper instance from this description.
    pub fn instantiate(&self) -> Box<dyn Wrapper> {
        match *self {
            WrapperSpec::ClipReward(b) => Box::new(ClipReward::new(b)),
            WrapperSpec::ScaleReward(s) => Box::new(ScaleReward::new(s)),
            WrapperSpec::NormalizeObs => Box::new(NormalizeObs::new()),
            WrapperSpec::Stack(k) => Box::new(ObsStack::new(k)),
            WrapperSpec::TimeLimit(n) => Box::new(TimeLimit::new(n)),
            WrapperSpec::ActionRepeat(k) => Box::new(ActionRepeat::new(k)),
        }
    }

    /// Stable `name=value` fragment for spec keys (checkpoint
    /// compatibility: a differently-wrapped env is a different spec).
    pub fn key_fragment(&self) -> String {
        match self {
            WrapperSpec::ClipReward(b) => format!("clip_reward={b}"),
            WrapperSpec::ScaleReward(s) => format!("scale_reward={s}"),
            WrapperSpec::NormalizeObs => "normalize_obs".to_string(),
            WrapperSpec::Stack(k) => format!("stack={k}"),
            WrapperSpec::TimeLimit(n) => format!("time_limit={n}"),
            WrapperSpec::ActionRepeat(k) => format!("action_repeat={k}"),
        }
    }
}

/// Base environment constructor: a first-party name (resolved through
/// [`crate::envs::make`]) or a user-supplied factory.
type BaseFactory = Arc<dyn Fn(usize) -> Box<dyn FlatEnv> + Send + Sync>;

/// A composable environment specification: base env + ordered wrapper
/// chain. This is what `Serial::from_spec`, `Multiprocessing::from_spec`,
/// the `Trainer`, `autotune`, and the `puffer` CLI consume.
///
/// The chain applies **innermost first**: in
/// `EnvSpec::new("classic/cartpole").scale_reward(2.0).clip_reward(1.0)`
/// the scale sits at the env boundary and the clip sees scaled rewards.
/// Order is part of the semantics (and of [`key`](Self::key)).
///
/// ```no_run
/// use pufferlib::wrappers::EnvSpec;
/// let spec = EnvSpec::new("ocean/squared").clip_reward(1.0).stack(4);
/// let _env = spec.build(0); // rows are 4× wider than the bare env's
/// assert_eq!(spec.key(), "ocean/squared+clip_reward=1+stack=4");
/// ```
#[derive(Clone)]
pub struct EnvSpec {
    name: String,
    wrappers: Vec<WrapperSpec>,
    base: Option<BaseFactory>,
}

impl EnvSpec {
    /// Spec for a first-party env name (see [`crate::envs::ALL_ENVS`]).
    pub fn new(name: impl Into<String>) -> Self {
        EnvSpec {
            name: name.into(),
            wrappers: Vec::new(),
            base: None,
        }
    }

    /// Spec over a custom base env: `factory(i)` builds instance `i`.
    /// `name` is only used for display and spec keys.
    pub fn custom(
        name: impl Into<String>,
        factory: impl Fn(usize) -> Box<dyn FlatEnv> + Send + Sync + 'static,
    ) -> Self {
        EnvSpec {
            name: name.into(),
            wrappers: Vec::new(),
            base: Some(Arc::new(factory)),
        }
    }

    /// Append one wrapper (outermost so far).
    pub fn wrap(mut self, w: WrapperSpec) -> Self {
        self.wrappers.push(w);
        self
    }

    /// Append a whole chain (innermost-first order preserved).
    pub fn with_wrappers(mut self, ws: impl IntoIterator<Item = WrapperSpec>) -> Self {
        self.wrappers.extend(ws);
        self
    }

    /// Clamp rewards into `[-bound, bound]`.
    pub fn clip_reward(self, bound: f32) -> Self {
        self.wrap(WrapperSpec::ClipReward(bound))
    }

    /// Multiply rewards by `scale`.
    pub fn scale_reward(self, scale: f32) -> Self {
        self.wrap(WrapperSpec::ScaleReward(scale))
    }

    /// Normalize f32 observation leaves with running mean/var.
    pub fn normalize_obs(self) -> Self {
        self.wrap(WrapperSpec::NormalizeObs)
    }

    /// Stack the last `k` observations (widens rows ×`k`).
    pub fn stack(self, k: usize) -> Self {
        self.wrap(WrapperSpec::Stack(k))
    }

    /// Truncate episodes after `n` steps.
    pub fn time_limit(self, n: u64) -> Self {
        self.wrap(WrapperSpec::TimeLimit(n))
    }

    /// Repeat each action `k` times, summing rewards.
    pub fn action_repeat(self, k: usize) -> Self {
        self.wrap(WrapperSpec::ActionRepeat(k))
    }

    /// Base env name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The wrapper chain, innermost first.
    pub fn wrappers(&self) -> &[WrapperSpec] {
        &self.wrappers
    }

    /// Spec key: the base name plus one `+name=value` fragment per
    /// wrapper, e.g. `"ocean/squared+clip_reward=1+stack=4"`. Used for
    /// backend/checkpoint keys so a differently-wrapped env never
    /// silently restores another chain's parameters.
    pub fn key(&self) -> String {
        let mut key = self.name.clone();
        for w in &self.wrappers {
            key.push('+');
            key.push_str(&w.key_fragment());
        }
        key
    }

    /// Build env instance `index`: base env (seeded with `index`, exactly
    /// like the factory convention) wrapped by the chain, innermost
    /// first.
    pub fn build(&self, index: usize) -> Box<dyn FlatEnv> {
        let mut env = match &self.base {
            Some(f) => f(index),
            None => crate::envs::make(&self.name, index as u64),
        };
        for w in &self.wrappers {
            env = wrap(env, w.instantiate());
        }
        env
    }

    /// Convert into the raw factory form the vector internals consume.
    pub fn into_factory(self) -> EnvFactory {
        Box::new(move |i| self.build(i))
    }

    /// As [`into_factory`](Self::into_factory), without consuming.
    pub fn to_factory(&self) -> EnvFactory {
        self.clone().into_factory()
    }
}

/// Specs compare by name + wrapper chain. Custom-base specs compare by
/// factory identity (same `Arc`): two independently-constructed custom
/// specs are never equal even under the same display name, because the
/// name does not determine the env they build.
impl PartialEq for EnvSpec {
    fn eq(&self, other: &Self) -> bool {
        let base_eq = match (&self.base, &other.base) {
            (None, None) => true,
            // Compare the data address only (thin pointers): vtable
            // addresses are not stable across codegen units.
            (Some(a), Some(b)) => {
                std::ptr::eq(Arc::as_ptr(a) as *const (), Arc::as_ptr(b) as *const ())
            }
            _ => false,
        };
        base_eq && self.name == other.name && self.wrappers == other.wrappers
    }
}

impl EnvSpec {
    /// True when this spec builds a first-party env by name (no custom
    /// factory) — the only form a [`RunSpec`](crate::runspec::RunSpec)
    /// file can express.
    pub fn is_named(&self) -> bool {
        self.base.is_none()
    }
}

impl fmt::Debug for EnvSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("EnvSpec")
            .field("name", &self.name)
            .field("wrappers", &self.wrappers)
            .field("custom_base", &self.base.is_some())
            .finish()
    }
}
