//! Observation stacking: each output row is the concatenation of the last
//! `k` inner rows (frame stacking generalized to arbitrary packed
//! layouts).

use super::{Flow, Wrapper};
use crate::emulation::Info;
use crate::spaces::{Space, StructLayout};

/// Stack the last `k` observations per agent row. The advertised space is
/// `Tuple` of `k` copies of the inner space, so the output layout is
/// exactly `k` concatenated inner layouts — frame 0 is the **oldest**,
/// frame `k-1` the **newest**. On reset (and on auto-reset, detected via
/// the done flags) the history is filled with the new episode's first
/// observation, so frames never leak across episode boundaries.
///
/// This is the one shipped wrapper that widens rows: the vectorizer's
/// shared slabs size themselves from the wrapped layout, the inner env
/// writes into a preallocated staging row, and the stack projects into
/// the slab — two bounded copies per step, no allocation.
pub struct ObsStack {
    k: usize,
    inner_bytes: usize,
    agents: usize,
    /// Per-agent history, agent-major, `k` frames each, oldest first —
    /// byte-identical to the output rows, so projection is one copy.
    frames: Vec<u8>,
}

impl ObsStack {
    /// `k` must be at least 2 (1 would be an identity wrap that still
    /// renames every layout field).
    pub fn new(k: usize) -> Self {
        assert!(k >= 2, "ObsStack depth must be >= 2, got {k}");
        ObsStack {
            k,
            inner_bytes: 0,
            agents: 0,
            frames: Vec::new(),
        }
    }
}

impl Wrapper for ObsStack {
    fn name(&self) -> &'static str {
        "stack"
    }

    fn transform_space(&self, inner: &Space) -> Option<Space> {
        Some(Space::Tuple(vec![inner.clone(); self.k]))
    }

    fn bind(&mut self, inner: &StructLayout, num_agents: usize) {
        self.inner_bytes = inner.byte_len();
        self.agents = num_agents;
        self.frames = vec![0u8; num_agents * self.k * self.inner_bytes];
    }

    fn project_reset(&mut self, src: &[u8], dst: &mut [u8]) {
        let w = self.inner_bytes;
        for a in 0..self.agents {
            let row = &src[a * w..(a + 1) * w];
            let hist = &mut self.frames[a * self.k * w..(a + 1) * self.k * w];
            for f in 0..self.k {
                hist[f * w..(f + 1) * w].copy_from_slice(row);
            }
        }
        dst.copy_from_slice(&self.frames);
    }

    fn project_step(
        &mut self,
        src: &[u8],
        dst: &mut [u8],
        _rewards: &mut [f32],
        terms: &mut [bool],
        truncs: &mut [bool],
        _info: &mut Info,
    ) -> Flow {
        let (w, k) = (self.inner_bytes, self.k);
        // The episode boundary is *all* rows done (an individual agent
        // dying mid-episode keeps reporting term on its padded row, with
        // no reset having happened — the same convention TimeLimit and
        // the multiagent emulation use). Only then has the inner env
        // auto-reset, making `src` the new episode's first observation.
        let episode_over = terms.iter().zip(truncs.iter()).all(|(t, u)| *t || *u);
        for a in 0..self.agents {
            let row = &src[a * w..(a + 1) * w];
            let hist = &mut self.frames[a * k * w..(a + 1) * k * w];
            if episode_over {
                for f in 0..k {
                    hist[f * w..(f + 1) * w].copy_from_slice(row);
                }
            } else {
                hist.copy_within(w.., 0);
                hist[(k - 1) * w..].copy_from_slice(row);
            }
        }
        dst.copy_from_slice(&self.frames);
        Flow::Continue
    }
}
