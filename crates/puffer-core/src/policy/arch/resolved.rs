//! [`ResolvedPolicy`] — a [`PolicySpec`] bound to a concrete observation
//! layout and action geometry: the per-leaf encoder plan, the trunk
//! width, and the single-source-of-truth flat parameter layout that the
//! native backend's forward and backward passes both read.

use super::{ActionHead, PolicySpec, Recurrence, MAX_EMBED_VOCAB};
use crate::spaces::StructLayout;
use anyhow::{ensure, Result};
use std::ops::Range;

/// One contiguous segment of the trunk input, in observation-field
/// order. The trunk consumes the concatenation of all segments.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TrunkSegment {
    /// Raw f32 pass-through of `count` scalars at `offset` in the flat
    /// observation row (f32/u8 leaves, and token leaves when embedding
    /// is off or the vocabulary is too large).
    Raw {
        name: String,
        offset: usize,
        count: usize,
    },
    /// A learned embedding table: each of the `count` token slots at
    /// `offset` indexes a `vocab × embed_dim` table (index =
    /// `round(value) - base`, clamped into the vocabulary), contributing
    /// `count × embed_dim` trunk features.
    Embed {
        name: String,
        offset: usize,
        count: usize,
        vocab: usize,
        base: i32,
    },
}

impl TrunkSegment {
    /// Trunk features this segment contributes.
    pub fn width(&self, embed_dim: usize) -> usize {
        match self {
            TrunkSegment::Raw { count, .. } => *count,
            TrunkSegment::Embed { count, .. } => count * embed_dim,
        }
    }
}

/// Byte-offset layout of every parameter leaf inside the flat vector, in
/// `ravel_pytree` (alphabetical) order: `actor.b, actor.w, critic.b,
/// critic.w, embed_00.w … (field order), enc1.b, enc1.w, enc2.b, enc2.w
/// [, lstm.b, lstm.w]`. For the default spec this is byte-identical to
/// the pre-PolicySpec layout.
pub struct ArchRanges {
    pub actor_b: Range<usize>,
    pub actor_w: Range<usize>,
    pub critic_b: Range<usize>,
    pub critic_w: Range<usize>,
    /// One table per `TrunkSegment::Embed`, in segment order.
    pub embeds: Vec<Range<usize>>,
    pub enc1_b: Range<usize>,
    pub enc1_w: Range<usize>,
    pub enc2_b: Range<usize>,
    pub enc2_w: Range<usize>,
    pub lstm_b: Range<usize>,
    pub lstm_w: Range<usize>,
    pub total: usize,
}

impl ArchRanges {
    /// Every parameter leaf as `(name, range)` in flat-vector order —
    /// the `ravel_pytree` order [`ResolvedPolicy::ranges`] assigns
    /// offsets in. The ranges tile `0..total` exactly (contiguous,
    /// non-overlapping, covering), which is what makes this layout the
    /// single source of truth for `n_params` and `ParamView::split`;
    /// `tests/run_spec.rs` pins the tiling for every gallery spec.
    pub fn leaves(&self) -> Vec<(String, Range<usize>)> {
        let mut out = vec![
            ("actor.b".to_string(), self.actor_b.clone()),
            ("actor.w".to_string(), self.actor_w.clone()),
            ("critic.b".to_string(), self.critic_b.clone()),
            ("critic.w".to_string(), self.critic_w.clone()),
        ];
        for (i, r) in self.embeds.iter().enumerate() {
            out.push((format!("embed_{i:02}.w"), r.clone()));
        }
        out.push(("enc1.b".to_string(), self.enc1_b.clone()));
        out.push(("enc1.w".to_string(), self.enc1_w.clone()));
        out.push(("enc2.b".to_string(), self.enc2_b.clone()));
        out.push(("enc2.w".to_string(), self.enc2_w.clone()));
        if !self.lstm_w.is_empty() {
            out.push(("lstm.b".to_string(), self.lstm_b.clone()));
            out.push(("lstm.w".to_string(), self.lstm_w.clone()));
        }
        out
    }
}

/// A policy architecture resolved against an observation layout: what
/// the native backend builds its passes from, and what
/// `puffer policy describe` prints.
#[derive(Clone, Debug, PartialEq)]
pub struct ResolvedPolicy {
    pub spec: PolicySpec,
    /// Flat f32 observation width (the emulated row).
    pub obs_dim: usize,
    pub act_dims: Vec<usize>,
    /// Trunk input plan, in observation-field order.
    pub segments: Vec<TrunkSegment>,
    /// Total trunk input width (== `obs_dim` when nothing is embedded).
    pub trunk_in: usize,
}

impl ResolvedPolicy {
    /// Resolve a spec against the env's emulated observation layout:
    /// f32/u8 leaves pass through raw; Discrete/MultiDiscrete/i32 token
    /// leaves become embedding tables when `spec.embed_dim > 0` (and the
    /// vocabulary fits [`MAX_EMBED_VOCAB`]).
    pub fn resolve(spec: &PolicySpec, layout: &StructLayout, act_dims: &[usize]) -> Result<Self> {
        ensure!(!act_dims.is_empty(), "policy needs at least one action slot");
        if let ActionHead::Quantized { bins } = spec.head {
            ensure!(
                act_dims.iter().all(|&k| k == bins),
                "quantized head declares {bins} bins per dim, but the env's \
                 emulated action dims are {act_dims:?} — the grid must match \
                 the env's quantized-action emulation exactly"
            );
        }
        let mut segments = Vec::new();
        for f in layout.fields() {
            let embeddable =
                spec.embed_dim > 0 && f.vocab > 0 && f.vocab <= MAX_EMBED_VOCAB;
            segments.push(if embeddable {
                TrunkSegment::Embed {
                    name: f.name.clone(),
                    offset: f.f32_offset,
                    count: f.count,
                    vocab: f.vocab,
                    base: f.token_base,
                }
            } else {
                TrunkSegment::Raw {
                    name: f.name.clone(),
                    offset: f.f32_offset,
                    count: f.count,
                }
            });
        }
        let trunk_in: usize = segments.iter().map(|s| s.width(spec.embed_dim)).sum();
        ensure!(trunk_in > 0, "empty observation layout");
        Ok(ResolvedPolicy {
            spec: spec.clone(),
            obs_dim: layout.flat_len(),
            act_dims: act_dims.to_vec(),
            segments,
            trunk_in,
        })
    }

    /// Resolve over an opaque flat observation of `obs_dim` f32s (one
    /// raw segment, nothing embedded) — the manifest path, where no
    /// layout is available. Embedding requests are ignored by
    /// construction here; use [`resolve`](Self::resolve) with a real
    /// layout for per-leaf encoders.
    pub fn from_flat(spec: &PolicySpec, obs_dim: usize, act_dims: &[usize]) -> Self {
        ResolvedPolicy {
            spec: spec.clone(),
            obs_dim,
            act_dims: act_dims.to_vec(),
            segments: vec![TrunkSegment::Raw {
                name: "obs".into(),
                offset: 0,
                count: obs_dim,
            }],
            trunk_in: obs_dim,
        }
    }

    pub fn hidden(&self) -> usize {
        self.spec.hidden
    }

    /// Recurrent state width (0 when feedforward).
    pub fn state_dim(&self) -> usize {
        self.spec.state_dim()
    }

    pub fn is_recurrent(&self) -> bool {
        self.spec.is_recurrent()
    }

    /// Actor/critic fan-in.
    pub fn decode_in(&self) -> usize {
        self.spec.decode_in()
    }

    pub fn act_sum(&self) -> usize {
        self.act_dims.iter().sum()
    }

    /// The embedding segments, in trunk order.
    pub fn embeds(&self) -> impl Iterator<Item = &TrunkSegment> {
        self.segments
            .iter()
            .filter(|s| matches!(s, TrunkSegment::Embed { .. }))
    }

    pub fn has_embeds(&self) -> bool {
        self.embeds().next().is_some()
    }

    /// The flat parameter layout (forward reads it, backward accumulates
    /// into it, init fills it — one source of truth).
    pub fn ranges(&self) -> ArchRanges {
        let (h, a, d_in) = (self.hidden(), self.act_sum(), self.decode_in());
        let sd = self.state_dim();
        let mut off = 0usize;
        let mut take = |n: usize| {
            let r = off..off + n;
            off += n;
            r
        };
        let actor_b = take(a);
        let actor_w = take(d_in * a);
        let critic_b = take(1);
        let critic_w = take(d_in);
        let mut embeds = Vec::new();
        for seg in &self.segments {
            if let TrunkSegment::Embed { vocab, .. } = seg {
                embeds.push(take(vocab * self.spec.embed_dim));
            }
        }
        let enc1_b = take(h);
        let enc1_w = take(self.trunk_in * h);
        let enc2_b = take(h);
        let enc2_w = take(h * h);
        let (lstm_b, lstm_w) = if sd > 0 {
            (take(4 * sd), take((h + sd) * 4 * sd))
        } else {
            (0..0, 0..0)
        };
        ArchRanges {
            actor_b,
            actor_w,
            critic_b,
            critic_w,
            embeds,
            enc1_b,
            enc1_w,
            enc2_b,
            enc2_w,
            lstm_b,
            lstm_w,
            total: off,
        }
    }

    /// Total flat parameter count.
    pub fn n_params(&self) -> usize {
        self.ranges().total
    }

    /// The spec as it actually resolved: an `embed_dim` that produced no
    /// tables (no token leaves, or all vocabularies too large) does not
    /// change the parameters and is canonicalized away.
    pub fn effective_spec(&self) -> PolicySpec {
        let mut effective = self.spec.clone();
        if !self.has_embeds() {
            effective.embed_dim = 0;
        }
        effective
    }

    /// The architecture fragment embedded in backend/checkpoint keys
    /// (after `#`), **relative to `baseline`** — the env's default spec.
    /// `None` when this *is* the baseline architecture, so default-spec
    /// checkpoints keep their pre-PolicySpec keys (and, for recurrent
    /// reference envs, stay interchangeable with the PJRT manifest
    /// spec). Any deviation records the full effective descriptor
    /// ([`PolicySpec::key`], `"mlp"` included) so no two architectures
    /// share a key.
    pub fn key_fragment(&self, baseline: &PolicySpec) -> Option<String> {
        let effective = self.effective_spec();
        if effective == *baseline {
            None
        } else {
            Some(effective.key())
        }
    }

    /// Human-readable architecture report: per-leaf encoders and
    /// per-stage parameter counts (the `puffer policy describe` output).
    pub fn describe(&self) -> String {
        use std::fmt::Write as _;
        let r = self.ranges();
        let mut out = String::new();
        let _ = writeln!(out, "observation leaves ({} f32 scalars):", self.obs_dim);
        let mut ei = 0usize;
        for seg in &self.segments {
            match seg {
                TrunkSegment::Raw { name, offset, count } => {
                    let _ = writeln!(
                        out,
                        "  {name:<24} f32[{count}] @ {offset:<5} -> raw ({count} trunk features)"
                    );
                }
                TrunkSegment::Embed {
                    name,
                    offset,
                    count,
                    vocab,
                    base,
                } => {
                    let dim = self.spec.embed_dim;
                    let _ = writeln!(
                        out,
                        "  {name:<24} tok[{count}] @ {offset:<5} -> embed[{vocab}x{dim}] \
                         (base {base}, {} trunk features, {} params)",
                        count * dim,
                        r.embeds[ei].len(),
                    );
                    ei += 1;
                }
            }
        }
        let _ = writeln!(out, "stages:");
        let stage = |out: &mut String, name: &str, shape: String, n: usize| {
            let _ = writeln!(out, "  {name:<8} {shape:<20} {n:>8} params");
        };
        let (h, sd, d_in, a) = (self.hidden(), self.state_dim(), self.decode_in(), self.act_sum());
        stage(
            &mut out,
            "enc1",
            format!("{}x{h} + {h}", self.trunk_in),
            r.enc1_b.len() + r.enc1_w.len(),
        );
        stage(&mut out, "enc2", format!("{h}x{h} + {h}"), r.enc2_b.len() + r.enc2_w.len());
        if sd > 0 {
            stage(
                &mut out,
                "lstm",
                format!("[{h}+{sd}]x{} + {}", 4 * sd, 4 * sd),
                r.lstm_b.len() + r.lstm_w.len(),
            );
        }
        let head = match self.spec.head {
            ActionHead::Categorical => format!("{:?} slots", self.act_dims),
            ActionHead::Quantized { bins } => {
                format!("quantized grid, {} dims x {bins} bins", self.act_dims.len())
            }
        };
        stage(
            &mut out,
            "actor",
            format!("{d_in}x{a} + {a} ({head})"),
            r.actor_b.len() + r.actor_w.len(),
        );
        stage(
            &mut out,
            "critic",
            format!("{d_in}x1 + 1"),
            r.critic_b.len() + r.critic_w.len(),
        );
        let recur = match self.spec.recurrence {
            Recurrence::None => "feedforward".to_string(),
            Recurrence::Lstm { hidden } => format!("lstm (state {hidden}, BPTT-trained natively)"),
        };
        let _ = writeln!(
            out,
            "total: {} params | trunk in {} | {recur} | arch key: {}",
            r.total,
            self.trunk_in,
            self.effective_spec().key(),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spaces::Space;

    #[test]
    fn default_flat_resolution_matches_legacy_n_params() {
        // The legacy formula: actor + critic + enc1 + enc2 [+ lstm].
        let legacy = |d: usize, a: usize, h: usize, lstm: bool| {
            let mut n = (a + h * a) + (1 + h) + (h + d * h) + (h + h * h);
            if lstm {
                n += 4 * h + (2 * h) * (4 * h);
            }
            n
        };
        let spec = PolicySpec::default().with_hidden(4);
        let arch = ResolvedPolicy::from_flat(&spec, 3, &[2, 3]);
        assert_eq!(arch.n_params(), legacy(3, 5, 4, false));
        let arch = ResolvedPolicy::from_flat(&spec.with_lstm(4), 3, &[2, 3]);
        assert_eq!(arch.n_params(), legacy(3, 5, 4, true));
    }

    #[test]
    fn token_leaves_resolve_to_embedding_tables() {
        // {feat: f32[2], tok: MultiDiscrete[5,5]} — canonical key order
        // puts feat first, so the flat row is [f0, f1, t0, t1].
        let space = Space::dict(vec![
            ("feat".into(), Space::boxf(&[2], -1.0, 1.0)),
            ("tok".into(), Space::MultiDiscrete(vec![5, 5])),
        ]);
        let spec = PolicySpec::default().with_hidden(4).with_embed_dim(3);
        let arch = ResolvedPolicy::resolve(&spec, &space.layout(), &[2, 3]).unwrap();
        assert_eq!(arch.obs_dim, 4);
        assert_eq!(arch.trunk_in, 2 + 2 * 3);
        assert_eq!(arch.segments.len(), 2);
        assert!(matches!(arch.segments[0], TrunkSegment::Raw { count: 2, .. }));
        match &arch.segments[1] {
            TrunkSegment::Embed { count, vocab, base, .. } => {
                assert_eq!((*count, *vocab, *base), (2, 5, 0));
            }
            other => panic!("expected embed segment, got {other:?}"),
        }
        // Params: actor(5+4*5) + critic(1+4) + embed(5*3) + enc1(4+8*4) + enc2(4+16)
        assert_eq!(arch.n_params(), 25 + 5 + 15 + 36 + 20);
        assert_eq!(
            arch.key_fragment(&PolicySpec::default()).unwrap(),
            "embed=3+h=4"
        );
    }

    #[test]
    fn embed_dim_without_token_leaves_is_effectively_default() {
        let space = Space::boxf(&[3], 0.0, 1.0);
        let spec = PolicySpec::default().with_embed_dim(8);
        let arch = ResolvedPolicy::resolve(&spec, &space.layout(), &[2]).unwrap();
        assert!(!arch.has_embeds());
        assert_eq!(arch.trunk_in, 3);
        assert_eq!(
            arch.key_fragment(&PolicySpec::default()),
            None,
            "no tables -> default params -> default key"
        );
        // Relative to a *recurrent* baseline, a feedforward arch records
        // its full descriptor, so the two never share a key.
        let base = PolicySpec::default().with_lstm(128);
        assert_eq!(arch.key_fragment(&base).unwrap(), "mlp");
    }

    #[test]
    fn huge_vocabularies_stay_raw() {
        let space = Space::boxi32(&[2], 0.0, 100_000.0);
        let spec = PolicySpec::default().with_embed_dim(4);
        let arch = ResolvedPolicy::resolve(&spec, &space.layout(), &[2]).unwrap();
        assert!(!arch.has_embeds());
    }

    #[test]
    fn quantized_head_must_match_the_grid() {
        let space = Space::boxf(&[3], 0.0, 1.0);
        let spec = PolicySpec::default().with_quantized_head(15);
        assert!(ResolvedPolicy::resolve(&spec, &space.layout(), &[15, 15]).is_ok());
        let err = ResolvedPolicy::resolve(&spec, &space.layout(), &[15, 9])
            .unwrap_err()
            .to_string();
        assert!(err.contains("15 bins"), "{err}");
    }

    #[test]
    fn leaves_tile_the_flat_vector_exactly() {
        // Feedforward + embedded + recurrent all at once: every leaf
        // kind is present, and the named leaves must tile 0..total with
        // no gap or overlap.
        let space = Space::dict(vec![
            ("feat".into(), Space::boxf(&[2], -1.0, 1.0)),
            ("tok".into(), Space::Discrete(6)),
        ]);
        let spec = PolicySpec::default().with_hidden(8).with_embed_dim(4).with_lstm(8);
        let arch = ResolvedPolicy::resolve(&spec, &space.layout(), &[3]).unwrap();
        let r = arch.ranges();
        let leaves = r.leaves();
        let mut off = 0usize;
        for (name, range) in &leaves {
            assert_eq!(range.start, off, "{name} leaves a gap/overlap at {off}");
            assert!(range.end > range.start, "{name} is empty");
            off = range.end;
        }
        assert_eq!(off, r.total, "leaves must cover the whole vector");
        assert_eq!(r.total, arch.n_params());
        let names: Vec<&str> = leaves.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            [
                "actor.b", "actor.w", "critic.b", "critic.w", "embed_00.w",
                "enc1.b", "enc1.w", "enc2.b", "enc2.w", "lstm.b", "lstm.w"
            ]
        );
        // Feedforward arch: no lstm leaves at all.
        let ff = ResolvedPolicy::from_flat(&PolicySpec::default().with_hidden(4), 3, &[2]);
        let ff_leaves = ff.ranges().leaves();
        assert!(ff_leaves.iter().all(|(n, _)| !n.starts_with("lstm")));
        assert_eq!(ff_leaves.last().unwrap().1.end, ff.n_params());
    }

    #[test]
    fn describe_names_every_leaf_and_stage() {
        let space = Space::dict(vec![
            ("feat".into(), Space::boxf(&[2], -1.0, 1.0)),
            ("tok".into(), Space::Discrete(6)),
        ]);
        let spec = PolicySpec::default().with_hidden(8).with_embed_dim(4).with_lstm(8);
        let arch = ResolvedPolicy::resolve(&spec, &space.layout(), &[3]).unwrap();
        let d = arch.describe();
        for needle in ["feat", "tok", "embed[6x4]", "enc1", "enc2", "lstm", "actor", "critic"] {
            assert!(d.contains(needle), "describe missing '{needle}':\n{d}");
        }
    }
}
