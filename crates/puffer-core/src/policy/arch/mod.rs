//! [`PolicySpec`] — the declarative policy-architecture description that
//! is the construction currency for models, exactly as
//! [`EnvSpec`](crate::wrappers::EnvSpec) is for environments.
//!
//! The paper's §3.4 model format is an encoder → recurrence → decoder
//! *sandwich*: observations are unflattened inside the policy (per-leaf
//! encoders resolved from the emulated [`StructLayout`]), a trunk MLP
//! mixes the encoded leaves, an optional LSTM cell sits between hidden
//! state and heads (recurrence is a flag, not a second model), and a
//! unified action head covers MultiDiscrete logits plus a declared
//! quantized-continuous grid ([`ActionHead::Quantized`]). Native
//! continuous (Gaussian) heads are ROADMAP item 4 and rejected with an
//! actionable error at spec parse time.
//!
//! A spec is plain data: cloneable, comparable, and embedded in
//! checkpoint keys ([`ResolvedPolicy::key_fragment`]) so parameters never
//! silently restore across architectures. [`ResolvedPolicy`] is the spec
//! bound to a concrete observation layout + action dims — what
//! `puffer-train`'s `NativeBackend` builds its forward *and backward*
//! passes from.

mod resolved;

pub use resolved::{ArchRanges, ResolvedPolicy, TrunkSegment};

/// Default trunk width (matches `python/compile/model.py::HIDDEN`).
pub const DEFAULT_HIDDEN: usize = 128;

/// Token leaves wider than this stay raw even when `embed_dim > 0`: an
/// embedding table per 10⁶-glyph vocabulary would dominate the parameter
/// vector without a hand-written spec, so resolution refuses silently
/// huge tables.
pub const MAX_EMBED_VOCAB: usize = 4096;

/// Envs whose reference spec (aot.py ENV_SPECS) is recurrent: their
/// default [`PolicySpec`] carries an LSTM stage. Accepts a full
/// [`EnvSpec`](crate::wrappers::EnvSpec) key — wrapper fragments after
/// `+` are ignored.
pub fn requires_recurrence(env_name: &str) -> bool {
    const RECURRENT_REFERENCE_SPECS: &[&str] = &["ocean/memory"];
    let base_name = env_name.split('+').next().unwrap_or(env_name);
    RECURRENT_REFERENCE_SPECS.contains(&base_name)
}

/// The recurrence stage of the sandwich.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Recurrence {
    /// Feedforward: the trunk output feeds the heads directly.
    None,
    /// A fused-gate LSTM cell between trunk and heads; `hidden` is the
    /// recurrent state width (decode fan-in becomes `hidden`).
    Lstm { hidden: usize },
}

/// The action head covering every emulated action space.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ActionHead {
    /// Per-slot categorical logits over the env's MultiDiscrete dims —
    /// the emulated form of every discrete space (a plain Discrete is
    /// the 1-slot case).
    Categorical,
    /// The continuous path: the env's Box action space was emulated as a
    /// quantization grid, so every slot must have exactly `bins`
    /// choices. Same logits math, declared so the grid resolution is
    /// part of the architecture key.
    Quantized { bins: usize },
}

impl ActionHead {
    /// The `policy.head` config-grammar form (`"categorical"` or
    /// `"quantized:<bins>"`) — what [`crate::config::policy_config`]
    /// parses and what RunSpec serialization emits.
    pub fn config_value(&self) -> String {
        match self {
            ActionHead::Categorical => "categorical".to_string(),
            ActionHead::Quantized { bins } => format!("quantized:{bins}"),
        }
    }
}

/// Declarative policy architecture: per-leaf encoders × trunk ×
/// recurrence × action head.
///
/// The default spec reproduces the pre-PolicySpec model bit for bit
/// (two-layer tanh trunk of width 128 over the raw flat observation,
/// feedforward, categorical heads), so existing checkpoints and the
/// `native_parity` golden fixtures are unaffected.
///
/// ```
/// use pufferlib::policy::arch::{PolicySpec, Recurrence};
/// let spec = PolicySpec::default().with_hidden(64).with_lstm(64).with_embed_dim(8);
/// assert_eq!(spec.recurrence, Recurrence::Lstm { hidden: 64 });
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicySpec {
    /// Trunk MLP width (two tanh layers).
    pub hidden: usize,
    /// Embedding width for Discrete / token (i32) observation leaves.
    /// `0` (default) consumes every leaf as raw f32 — the flat path.
    pub embed_dim: usize,
    pub recurrence: Recurrence,
    pub head: ActionHead,
}

impl Default for PolicySpec {
    fn default() -> Self {
        PolicySpec {
            hidden: DEFAULT_HIDDEN,
            embed_dim: 0,
            recurrence: Recurrence::None,
            head: ActionHead::Categorical,
        }
    }
}

impl PolicySpec {
    /// The default architecture for a first-party env: feedforward —
    /// except for recurrent reference specs ([`requires_recurrence`]),
    /// which default to the LSTM sandwich so e.g. `ocean/memory` trains
    /// out of the box on the native backend.
    pub fn default_for(env_name: &str) -> Self {
        let spec = PolicySpec::default();
        if requires_recurrence(env_name) {
            let hidden = spec.hidden;
            spec.with_lstm(hidden)
        } else {
            spec
        }
    }

    /// Set the trunk width.
    pub fn with_hidden(mut self, hidden: usize) -> Self {
        assert!(hidden >= 1, "trunk width must be >= 1");
        self.hidden = hidden;
        self
    }

    /// Sandwich an LSTM of state width `hidden` between trunk and heads.
    pub fn with_lstm(mut self, hidden: usize) -> Self {
        assert!(hidden >= 1, "LSTM width must be >= 1");
        self.recurrence = Recurrence::Lstm { hidden };
        self
    }

    /// Drop the recurrence stage (feedforward).
    pub fn feedforward(mut self) -> Self {
        self.recurrence = Recurrence::None;
        self
    }

    /// Embed Discrete / token (i32) observation leaves at this width
    /// (0 = raw f32 pass-through, the default).
    pub fn with_embed_dim(mut self, dim: usize) -> Self {
        self.embed_dim = dim;
        self
    }

    /// Declare the quantized-continuous head (`bins` per action dim).
    pub fn with_quantized_head(mut self, bins: usize) -> Self {
        assert!(bins >= 2, "quantized head needs at least 2 bins");
        self.head = ActionHead::Quantized { bins };
        self
    }

    /// Recurrent state width (0 when feedforward).
    pub fn state_dim(&self) -> usize {
        match self.recurrence {
            Recurrence::None => 0,
            Recurrence::Lstm { hidden } => hidden,
        }
    }

    pub fn is_recurrent(&self) -> bool {
        self.state_dim() > 0
    }

    /// Fan-in of the actor/critic heads: the LSTM state when recurrent,
    /// else the trunk output.
    pub fn decode_in(&self) -> usize {
        if self.is_recurrent() {
            self.state_dim()
        } else {
            self.hidden
        }
    }

    /// Stable `name=value` key components, in canonical order. Empty for
    /// the default spec — default-spec checkpoint keys are unchanged
    /// from before PolicySpec existed.
    pub(crate) fn key_components(&self) -> Vec<String> {
        let mut out = Vec::new();
        if self.embed_dim > 0 {
            out.push(format!("embed={}", self.embed_dim));
        }
        if self.hidden != DEFAULT_HIDDEN {
            out.push(format!("h={}", self.hidden));
        }
        if let Recurrence::Lstm { hidden } = self.recurrence {
            out.push(format!("lstm={hidden}"));
        }
        if let ActionHead::Quantized { bins } = self.head {
            out.push(format!("quantized={bins}"));
        }
        out
    }

    /// Human-readable grammar form, e.g.
    /// `"embed=8+h=128+lstm=128"`; `"mlp"` for the default.
    pub fn key(&self) -> String {
        let parts = self.key_components();
        if parts.is_empty() {
            "mlp".to_string()
        } else {
            parts.join("+")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_the_pre_refactor_architecture() {
        let s = PolicySpec::default();
        assert_eq!(s.hidden, 128);
        assert_eq!(s.embed_dim, 0);
        assert_eq!(s.recurrence, Recurrence::None);
        assert_eq!(s.head, ActionHead::Categorical);
        assert!(s.key_components().is_empty());
        assert_eq!(s.key(), "mlp");
        assert_eq!(s.state_dim(), 0);
        assert_eq!(s.decode_in(), 128);
    }

    #[test]
    fn recurrent_reference_envs_default_to_lstm() {
        assert!(requires_recurrence("ocean/memory"));
        assert!(requires_recurrence("ocean/memory+stack=4"));
        assert!(!requires_recurrence("ocean/bandit"));
        let mem = PolicySpec::default_for("ocean/memory");
        assert_eq!(mem.recurrence, Recurrence::Lstm { hidden: 128 });
        assert_eq!(mem.decode_in(), 128);
        assert_eq!(PolicySpec::default_for("ocean/bandit"), PolicySpec::default());
    }

    #[test]
    fn key_grammar_is_canonical() {
        let s = PolicySpec::default()
            .with_embed_dim(8)
            .with_hidden(64)
            .with_lstm(32)
            .with_quantized_head(15);
        assert_eq!(s.key(), "embed=8+h=64+lstm=32+quantized=15");
        assert_eq!(PolicySpec::default().with_lstm(128).key(), "lstm=128");
    }
}
