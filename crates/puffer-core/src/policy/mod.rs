//! Declarative policy architecture: [`PolicySpec`] and its resolution
//! against an env's observation layout ([`ResolvedPolicy`]). This is
//! the *description* half of the model — plain data the spec layer and
//! checkpoint keys are built from. The runtime half (the `Policy`
//! forward/sampling loop, `ParamSnapshot` publish/acquire) lives in
//! `puffer-train`, which re-exports this module's contents under the
//! same `policy::` path.

// Architecture resolution is pure data plumbing; no unsafe belongs
// here (CONCURRENCY.md).
#![forbid(unsafe_code)]

pub mod arch;

pub use arch::{ActionHead, PolicySpec, Recurrence, ResolvedPolicy};
