//! [`RunSpec`] — the top-level declarative experiment currency.
//!
//! The paper's pitch is one-line declarative construction: envs
//! ([`EnvSpec`]), models ([`PolicySpec`]), and now the remaining half of
//! every experiment — vectorization ([`VecSpec`]) and the train
//! configuration — unified in a single serializable value:
//!
//! ```text
//! RunSpec { env: EnvSpec, policy: Option<PolicySpec>, vec: VecSpec,
//!           train: TrainConfig, seed }
//! ```
//!
//! A RunSpec round-trips through TOML (`puffer run spec.toml`, the
//! `examples/specs/` gallery) and JSON (embedded in checkpoints, so
//! `puffer resume <ckpt>` / `puffer eval <ckpt>` need zero flags), with
//! the same strict unknown-key/malformed-value errors as the
//! `train.*`/`wrap.*` config layer. The single `seed` is the root from
//! which env-reset, policy, shuffle, collector, and eval streams are
//! derived via the documented split function
//! ([`crate::util::seed::SeedPlan::from_root`]) — no more duplicated
//! `VecConfig.seed` / `TrainConfig.seed` plumbing.
//!
//! ## File grammar (TOML subset)
//!
//! ```toml
//! seed = 1                  # the run root seed
//!
//! [env]
//! name = "ocean/memory"     # any first-party env name
//! [env.wrap]                # canonical innermost-first knobs
//! stack = 4
//!
//! [policy]                  # optional; omitted = the env's default arch
//! hidden = 48
//! lstm = true
//!
//! [vec]                     # serial | mt | auto (autotune, cached)
//! mode = "mt"
//! workers = 2
//! batch = "half"
//!
//! [runs]                    # optional experiment-ops knobs
//! root = "runs"             # registry root (index.jsonl lives here)
//! heartbeat_s = 5
//!
//! [train]
//! total_steps = 50000
//! lr = 0.0025
//! pipeline.depth = 1
//!
//! [grid]                    # optional sweep: any spec key -> values
//! "train.lr" = [0.001, 0.0025]
//! ```
//!
//! [`RunSpec::build_venv`] builds the standalone vectorizer (the path
//! the Python bindings drive). Trainer construction lives one crate up:
//! `puffer-train`'s `RunSpecExt` extension trait adds `build()`
//! (returning the ready `Trainer`) and the deep `validate()` that
//! resolves the policy against a backend. A `[grid]` section expands
//! into child specs ([`RunSpec::expand_grid`]) executed by `puffer
//! sweep` across a worker pool, each with its own metrics directory.

// Declarative plumbing: no unsafe belongs here (CONCURRENCY.md).
#![forbid(unsafe_code)]

use crate::config::{self, FlatConfig};
use crate::envs;
use crate::policy::{PolicySpec, Recurrence};
use crate::train::TrainConfig;
use crate::util::json::Json;
use crate::util::seed;
use crate::vector::{VecEnv, VecSpec};
use crate::wrappers::EnvSpec;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// Train keys a RunSpec file may set directly. The remaining
/// `TrainConfig` fields are derived: `env`/`wrappers` from `[env]`,
/// `policy` from `[policy]`, `seed` from the top-level root,
/// `num_workers`/`pool`/`vec` from `[vec]`.
const RUN_TRAIN_KEYS: &[&str] = &[
    "total_steps",
    "lr",
    "ent_coef",
    "epochs",
    "minibatches",
    "norm_adv",
    "anneal_lr",
    "run_dir",
    "log_every",
    "kernels",
];

/// The declarative experiment: env × policy × vectorization × training
/// × seed, plus an optional sweep grid. Plain data — cloneable,
/// comparable, TOML/JSON-serializable.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    /// Base env + wrapper chain. Only named (non-custom) specs with
    /// canonical-order chains are serializable.
    pub env: EnvSpec,
    /// Policy architecture; `None` resolves the env's default.
    pub policy: Option<PolicySpec>,
    /// Vectorization: `serial`, `mt { … }`, or `auto`.
    pub vec: VecSpec,
    /// Train settings. Derived fields (`env`, `wrappers`, `policy`,
    /// `seed`, `num_workers`, `pool`, `vec`) are overwritten from the
    /// spec parts on every use — the spec parts are authoritative.
    pub train: TrainConfig,
    /// The run root seed; every RNG stream derives from it via
    /// [`seed::split`].
    pub seed: u64,
    /// Inference-server settings (`puffer serve`); `None` for the
    /// (common) specs that never serve. Inert during training.
    pub serve: Option<crate::serve::ServeConfig>,
    /// Experiment-ops settings: registry root + heartbeat period.
    /// `None` means defaults (registry logging is always on for runs
    /// with a run dir).
    pub runs: Option<crate::runs::RunsConfig>,
    /// Sweep grid: spec key → candidate values. Empty for a single run.
    pub grid: BTreeMap<String, Vec<String>>,
}

impl RunSpec {
    /// A spec over `env` with every other part defaulted.
    pub fn new(env: EnvSpec) -> Self {
        let mut spec = RunSpec {
            env,
            policy: None,
            vec: VecSpec::default(),
            train: TrainConfig::default(),
            seed: TrainConfig::default().seed,
            serve: None,
            runs: None,
            grid: BTreeMap::new(),
        };
        spec.normalize();
        spec
    }

    pub fn with_policy(mut self, policy: PolicySpec) -> Self {
        self.policy = Some(policy);
        self.normalize();
        self
    }

    pub fn with_vec(mut self, vec: VecSpec) -> Self {
        self.vec = vec;
        self.normalize();
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self.normalize();
        self
    }

    pub fn with_serve(mut self, serve: crate::serve::ServeConfig) -> Self {
        self.serve = Some(serve);
        self.normalize();
        self
    }

    pub fn with_runs(mut self, runs: crate::runs::RunsConfig) -> Self {
        self.runs = Some(runs);
        self.normalize();
        self
    }

    /// Edit the train settings in place (derived fields are re-derived
    /// afterwards, so only the real train knobs stick).
    pub fn with_train(mut self, f: impl FnOnce(&mut TrainConfig)) -> Self {
        f(&mut self.train);
        self.normalize();
        self
    }

    /// Re-derive the `train` fields owned by the spec parts, making
    /// `env`/`policy`/`vec`/`seed` the single source of truth.
    pub fn normalize(&mut self) {
        self.train.env = self.env.name().to_string();
        self.train.wrappers = self.env.wrappers().to_vec();
        self.train.policy = self.policy.clone();
        self.train.seed = self.seed;
        self.train.vec = Some(self.vec.clone());
        let (num_workers, pool) = match &self.vec {
            VecSpec::Serial => (0, false),
            VecSpec::Mt { workers, batch, .. } => {
                (*workers, matches!(batch, crate::vector::VecBatch::Half))
            }
            VecSpec::Auto => (TrainConfig::default().num_workers, false),
        };
        self.train.num_workers = num_workers;
        self.train.pool = pool;
    }

    /// The full [`TrainConfig`] this spec trains with (derived fields
    /// filled in).
    pub fn train_config(&self) -> TrainConfig {
        let mut s = self.clone();
        s.normalize();
        s.train
    }

    // -- construction -------------------------------------------------------

    /// Build just the vectorized env for `num_envs` env copies — the
    /// standalone form of the `VecSpec::build` construction path, with
    /// the env-reset seed derived from the run root. `auto` resolves
    /// through the autotune cache under the spec's run dir.
    pub fn build_venv(&self, num_envs: usize) -> Result<Box<dyn VecEnv>> {
        self.vec
            .resolved(&self.env, num_envs, self.train.run_dir.as_deref())?
            .build(&self.env, num_envs, seed::split(self.seed, "env"))
    }

    // -- parsing ------------------------------------------------------------

    /// Parse the TOML file grammar.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = config::parse_toml(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_parts(&doc.scalars, &doc.arrays)
    }

    /// Load and parse a TOML spec file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec {}", path.display()))?;
        Self::from_toml_str(&text).with_context(|| format!("parsing spec {}", path.display()))
    }

    /// Parse the JSON form (what checkpoints embed).
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut scalars = FlatConfig::new();
        let mut arrays = BTreeMap::new();
        flatten_json(j, "", &mut scalars, &mut arrays)?;
        Self::from_parts(&scalars, &arrays)
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("parsing RunSpec JSON: {e}"))?;
        Self::from_json(&j)
    }

    /// Assemble from flat dotted keys (+ `grid.*` arrays) with strict
    /// validation: unknown keys and malformed values are errors naming
    /// the offending key.
    pub fn from_parts(
        scalars: &FlatConfig,
        arrays: &BTreeMap<String, Vec<String>>,
    ) -> Result<Self> {
        for key in scalars.keys() {
            validate_scalar_key(key)?;
        }
        for (key, values) in arrays {
            let Some(target) = key.strip_prefix("grid.") else {
                bail!(
                    "key '{key}': array values are only valid under the [grid] \
                     sweep section"
                );
            };
            validate_scalar_key(target)
                .with_context(|| format!("grid key '{key}' sweeps an invalid spec key"))?;
            ensure!(!values.is_empty(), "grid key '{key}' has no values");
        }
        // Parse the root seed up front so a malformed value errors under
        // its own name — the translated 'train.seed' alias below is the
        // very key this grammar redirects users away from.
        if let Some(v) = scalars.get("seed") {
            v.parse::<u64>().map_err(|_| {
                anyhow::anyhow!(
                    "config key 'seed': cannot parse value '{v}' as a non-negative integer"
                )
            })?;
        }
        // Translate into the config-layer grammar and reuse its strict
        // parsers for every section.
        let mut flat = FlatConfig::new();
        for (k, v) in scalars {
            let translated = if k == "seed" {
                "train.seed".to_string()
            } else if let Some(rest) = k.strip_prefix("env.wrap.") {
                format!("wrap.{rest}")
            } else if k == "env.name" {
                "train.env".to_string()
            } else {
                k.clone() // policy.* / vec.* / train.* pass through
            };
            flat.insert(translated, v.clone());
        }
        let train = config::train_config(&flat)?;
        let name = scalars
            .get("env.name")
            .cloned()
            .unwrap_or_else(|| TrainConfig::default().env);
        ensure!(
            envs::ALL_ENVS.contains(&name.as_str()),
            "config key 'env.name': unknown first-party env '{name}' \
             (known: {:?})",
            envs::ALL_ENVS
        );
        let grid = arrays
            .iter()
            // PANIC: arrays keys are collected with the 'grid.' prefix present.
            .map(|(k, v)| (k.strip_prefix("grid.").unwrap().to_string(), v.clone()))
            .collect();
        let serve = config::serve_config(&flat)?;
        let runs = config::runs_config(&flat)?;
        let mut spec = RunSpec {
            env: EnvSpec::new(name).with_wrappers(train.wrappers.iter().cloned()),
            policy: train.policy.clone(),
            vec: train.vec.clone().unwrap_or_default(),
            seed: train.seed,
            train,
            serve,
            runs,
            grid,
        };
        spec.normalize();
        Ok(spec)
    }

    // -- serialization ------------------------------------------------------

    /// The flat dotted-key form (scalars + grid arrays) — the common
    /// core of the TOML and JSON serializers. Errors when the spec is
    /// not representable (custom base env, non-canonical wrapper
    /// chain).
    pub fn to_flat(&self) -> Result<(FlatConfig, BTreeMap<String, Vec<String>>)> {
        ensure!(
            self.env.is_named(),
            "RunSpec with a custom base env ('{}') cannot be serialized — \
             only first-party env names are expressible in a spec file",
            self.env.name()
        );
        let s = {
            let mut s = self.clone();
            s.normalize();
            s
        };
        let mut flat = FlatConfig::new();
        let mut put = |k: &str, v: String| flat.insert(k.to_string(), v);
        put("seed", s.seed.to_string());
        put("env.name", s.env.name().to_string());
        for (knob, value) in config::wrap_knob_pairs(s.env.wrappers())? {
            put(&format!("env.wrap.{knob}"), value);
        }
        if let Some(p) = &s.policy {
            put("policy.hidden", p.hidden.to_string());
            match p.recurrence {
                Recurrence::None => put("policy.lstm", "false".to_string()),
                Recurrence::Lstm { hidden } => {
                    put("policy.lstm", "true".to_string());
                    put("policy.lstm_hidden", hidden.to_string());
                }
            };
            put("policy.embed_dim", p.embed_dim.to_string());
            put("policy.head", p.head.config_value());
        }
        for (knob, value) in s.vec.to_flat_pairs() {
            put(&format!("vec.{knob}"), value);
        }
        if let Some(serve) = &s.serve {
            for (knob, value) in serve.to_flat_pairs() {
                put(&format!("serve.{knob}"), value);
            }
        }
        if let Some(runs) = &s.runs {
            for (knob, value) in runs.to_flat_pairs() {
                put(&format!("runs.{knob}"), value);
            }
        }
        let t = &s.train;
        put("train.total_steps", t.total_steps.to_string());
        put("train.lr", format!("{}", t.lr));
        put("train.ent_coef", format!("{}", t.ent_coef));
        put("train.epochs", t.epochs.to_string());
        put("train.minibatches", t.minibatches.to_string());
        put("train.norm_adv", t.norm_adv.to_string());
        put("train.anneal_lr", t.anneal_lr.to_string());
        put("train.log_every", t.log_every.to_string());
        put("train.kernels", t.kernels.to_string());
        put("train.pipeline.depth", t.pipeline_depth.to_string());
        if let Some(dir) = &t.run_dir {
            put("train.run_dir", dir.clone());
        }
        let arrays = s
            .grid
            .iter()
            .map(|(k, v)| (format!("grid.{k}"), v.clone()))
            .collect();
        Ok((flat, arrays))
    }

    /// Canonical TOML text; `parse(to_toml(spec)) == spec`.
    pub fn to_toml(&self) -> Result<String> {
        let (flat, arrays) = self.to_flat()?;
        // The TOML subset has no string escapes, so values carrying a
        // quote or newline (only free-form strings like run_dir or grid
        // entries can) are unrepresentable — error here naming the key
        // instead of emitting a file that fails to re-parse.
        for (k, v) in flat
            .iter()
            .chain(arrays.iter().flat_map(|(k, vs)| vs.iter().map(move |v| (k, v))))
        {
            ensure!(
                !v.contains('"') && !v.contains('\n'),
                "key '{k}': value {v:?} is not representable in the TOML subset \
                 (no string escapes) — avoid '\"' and newlines"
            );
        }
        let mut out = String::from("# puffer RunSpec\n");
        let section_value = |out: &mut String, key: &str, value: &str| {
            out.push_str(&format!("{key} = {}\n", config::toml_value(value)));
        };
        section_value(&mut out, "seed", &flat["seed"]);
        // Emit sections in a fixed, readable order.
        for section in ["env", "env.wrap", "policy", "vec", "serve", "runs", "train"] {
            let prefix = format!("{section}.");
            let keys: Vec<&String> = flat
                .keys()
                .filter(|k| {
                    k.starts_with(&prefix)
                        && !(section == "env" && k.starts_with("env.wrap."))
                        && !(section == "train" && k.starts_with("train.pipeline."))
                })
                .collect();
            if keys.is_empty() && section != "train" {
                continue;
            }
            out.push_str(&format!("\n[{section}]\n"));
            for k in keys {
                section_value(&mut out, &k[prefix.len()..], &flat[k]);
            }
            if section == "train" {
                // Dotted key inside [train]; the parser flattens it back
                // to train.pipeline.depth.
                section_value(
                    &mut out,
                    "pipeline.depth",
                    &flat["train.pipeline.depth"],
                );
            }
        }
        if !arrays.is_empty() {
            out.push_str("\n[grid]\n");
            for (k, values) in &arrays {
                let body: Vec<String> = values.iter().map(|v| config::toml_value(v)).collect();
                out.push_str(&format!(
                    "\"{}\" = [{}]\n",
                    // PANIC: arrays keys are collected with the 'grid.' prefix present.
                    k.strip_prefix("grid.").unwrap(),
                    body.join(", ")
                ));
            }
        }
        Ok(out)
    }

    /// Compact JSON form (checkpoint embedding);
    /// `from_json(to_json(spec)) == spec`. Panics only if the spec is
    /// unserializable — call [`to_flat`](Self::to_flat) first to check.
    pub fn to_json(&self) -> Json {
        let (flat, arrays) = self
            .to_flat()
            // PANIC: documented contract — to_json panics on unserializable specs.
            .expect("unserializable RunSpec (custom env or non-canonical chain)");
        let mut root = BTreeMap::new();
        for (k, v) in &flat {
            let path: Vec<&str> = k.split('.').collect();
            insert_json_path(&mut root, &path, scalar_to_json(v));
        }
        for (k, values) in &arrays {
            let path: Vec<&str> = k.split('.').collect();
            let arr = Json::Arr(values.iter().map(|v| scalar_to_json(v)).collect());
            insert_json_path(&mut root, &path, arr);
        }
        Json::Obj(root)
    }

    // -- sweeping -----------------------------------------------------------

    /// Expand the `[grid]` section into one child spec per point of the
    /// cartesian product. Children drop the grid, apply their overrides,
    /// and get distinct `train.run_dir`s
    /// (`<base run_dir or "runs/sweep">/<key=value+...>`) so metrics and
    /// checkpoints never collide.
    pub fn expand_grid(&self) -> Result<Vec<RunSpec>> {
        ensure!(
            !self.grid.is_empty(),
            "this spec has no [grid] section to expand"
        );
        let (base_flat, _) = self.to_flat()?;
        let base_dir = self
            .train
            .run_dir
            .clone()
            .unwrap_or_else(|| "runs/sweep".to_string());
        let axes: Vec<(&String, &Vec<String>)> = self.grid.iter().collect();
        let mut children = Vec::new();
        let total: usize = axes.iter().map(|(_, vs)| vs.len()).product();
        for point in 0..total {
            let mut flat = base_flat.clone();
            let mut pairs = Vec::new();
            let mut label_parts = Vec::new();
            let mut rem = point;
            for (key, values) in &axes {
                let v = &values[rem % values.len()];
                rem /= values.len();
                pairs.push(((*key).clone(), v.clone()));
                label_parts.push(format!("{key}={}", v.replace('/', "-")));
            }
            // Through the same merge as CLI overrides, so a grid can
            // sweep discriminant keys (vec.mode, policy.lstm) too.
            merge_overrides(&mut flat, &pairs);
            let label = label_parts.join("+");
            flat.insert("train.run_dir".into(), format!("{base_dir}/{label}"));
            let child = RunSpec::from_parts(&flat, &BTreeMap::new())
                .with_context(|| format!("grid point '{label}'"))?;
            children.push(child);
        }
        // Children run concurrently; a run-dir collision (duplicate grid
        // values, or values that sanitize to the same label) would race
        // two trainers onto one metrics.csv/checkpoint.bin.
        let mut dirs = std::collections::BTreeSet::new();
        for child in &children {
            let dir = child.train.run_dir.as_deref().unwrap_or("");
            ensure!(
                dirs.insert(dir.to_string()),
                "grid expansion produced two children with the same run dir \
                 '{dir}' (duplicate values in a [grid] axis?) — sweep children \
                 must have distinct metrics directories"
            );
        }
        Ok(children)
    }

    /// Shallow structural validation: env name + serialization round
    /// trip. The deep form (policy resolution against a backend, vec
    /// satisfiability at the env's trainable batch) is
    /// `RunSpecExt::validate` in `puffer-train`, which layers on top of
    /// this.
    pub fn validate_shallow(&self) -> Result<()> {
        ensure!(
            self.env.is_named() && envs::ALL_ENVS.contains(&self.env.name()),
            "env '{}' is not a first-party env name",
            self.env.name()
        );
        // Serialization round trip (also catches non-canonical chains).
        let toml = self.to_toml()?;
        let back = Self::from_toml_str(&toml).context("re-parsing the serialized spec")?;
        let mut normalized = self.clone();
        normalized.normalize();
        ensure!(
            back == normalized,
            "spec does not round-trip through its own serialization"
        );
        Ok(())
    }
}

/// Merge override pairs onto a serialized flat spec. Discriminant keys
/// are applied first and drop the dependent knobs serialized under the
/// old value — otherwise `--vec.mode=serial` would trip over the
/// emitted `vec.workers`, and `--policy.lstm=false` over
/// `policy.lstm_hidden` — so overrides (and grid points) compose onto
/// any spec, including mode switches.
pub fn merge_overrides(flat: &mut FlatConfig, pairs: &[(String, String)]) {
    let is_discriminant =
        |k: &str, v: &str| k == "vec.mode" || (k == "policy.lstm" && v == "false");
    for (k, v) in pairs {
        // A redundant same-value switch keeps the spec's knobs: only an
        // actual mode change invalidates them.
        if k == "vec.mode" && flat.get("vec.mode") != Some(v) {
            for dep in ["vec.workers", "vec.batch", "vec.zero_copy", "vec.spin_budget"] {
                flat.remove(dep);
            }
        } else if k == "policy.lstm" && v == "false" && flat.get("policy.lstm") != Some(v) {
            flat.remove("policy.lstm_hidden");
        } else {
            continue;
        }
        flat.insert(k.clone(), v.clone());
    }
    for (k, v) in pairs {
        if !is_discriminant(k, v) {
            flat.insert(k.clone(), v.clone());
        }
    }
}

/// Translate a CLI override key into the RunSpec key grammar:
/// `wrap.*` → `env.wrap.*`, `pipeline.*` → `train.pipeline.*`; `seed`,
/// `env.*`, `policy.*`, `vec.*`, and `train.*` pass through.
pub fn translate_cli_key(key: &str) -> String {
    if let Some(rest) = key.strip_prefix("wrap.") {
        format!("env.wrap.{rest}")
    } else if let Some(rest) = key.strip_prefix("pipeline.") {
        format!("train.pipeline.{rest}")
    } else {
        key.to_string()
    }
}

// -- JSON plumbing ----------------------------------------------------------

/// Scalar string → typed JSON where the typed form round-trips exactly
/// back to the same string (otherwise it stays a string).
fn scalar_to_json(v: &str) -> Json {
    if v == "true" {
        return Json::Bool(true);
    }
    if v == "false" {
        return Json::Bool(false);
    }
    if let Ok(n) = v.parse::<f64>() {
        if n.is_finite() && Json::Num(n).dump() == v {
            return Json::Num(n);
        }
    }
    Json::Str(v.to_string())
}

fn insert_json_path(root: &mut BTreeMap<String, Json>, path: &[&str], value: Json) {
    if path.len() == 1 {
        root.insert(path[0].to_string(), value);
        return;
    }
    let entry = root
        .entry(path[0].to_string())
        .or_insert_with(|| Json::Obj(BTreeMap::new()));
    match entry {
        Json::Obj(m) => insert_json_path(m, &path[1..], value),
        _ => unreachable!("scalar and section share the key '{}'", path[0]),
    }
}

/// Nested JSON → flat dotted keys (+ arrays), the inverse of
/// [`RunSpec::to_json`].
fn flatten_json(
    j: &Json,
    prefix: &str,
    scalars: &mut FlatConfig,
    arrays: &mut BTreeMap<String, Vec<String>>,
) -> Result<()> {
    match j {
        Json::Obj(m) => {
            for (k, v) in m {
                let key = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                flatten_json(v, &key, scalars, arrays)?;
            }
            Ok(())
        }
        Json::Arr(items) => {
            let values = items
                .iter()
                .map(|i| match i {
                    Json::Obj(_) | Json::Arr(_) | Json::Null => {
                        bail!("key '{prefix}': arrays may only hold scalars")
                    }
                    other => Ok(json_scalar_string(other)),
                })
                .collect::<Result<Vec<_>>>()?;
            arrays.insert(prefix.to_string(), values);
            Ok(())
        }
        Json::Null => bail!("key '{prefix}': null is not a valid spec value"),
        other => {
            scalars.insert(prefix.to_string(), json_scalar_string(other));
            Ok(())
        }
    }
}

fn json_scalar_string(j: &Json) -> String {
    match j {
        Json::Str(s) => s.clone(),
        other => other.dump(),
    }
}

/// Strictly validate one scalar key of the RunSpec grammar, with
/// redirecting messages for keys that exist in the flat config grammar
/// but are owned by another section here.
fn validate_scalar_key(key: &str) -> Result<()> {
    match key {
        "seed" | "env.name" => return Ok(()),
        "train.env" => bail!(
            "config key 'train.env': in a RunSpec the env is the [env] \
             section — set env.name instead"
        ),
        "train.seed" => bail!(
            "config key 'train.seed': in a RunSpec the seed is the top-level \
             'seed' key (the root every stream derives from)"
        ),
        "train.num_workers" | "train.pool" => bail!(
            "config key '{key}': in a RunSpec vectorization is the [vec] \
             section (mode = \"serial\" | \"mt\" | \"auto\", workers, batch, \
             zero_copy, spin_budget)"
        ),
        _ => {}
    }
    if key.starts_with("train.wrap.") || key.starts_with("train.policy.") {
        bail!(
            "config key '{key}': in a RunSpec use the [env.wrap] / [policy] \
             sections instead of the train.* aliases"
        );
    }
    if key.starts_with("grid.") {
        bail!("config key '{key}': grid entries must be arrays of values");
    }
    let known_namespace = key.starts_with("env.wrap.")
        || key.starts_with("policy.")
        || key.starts_with("vec.")
        || key.starts_with("serve.")
        || key.starts_with("runs.")
        || key.starts_with("train.pipeline.")
        || (key.strip_prefix("train.").is_some_and(|rest| RUN_TRAIN_KEYS.contains(&rest)));
    if !known_namespace {
        if let Some(rest) = key.strip_prefix("train.") {
            bail!(
                "unknown config key 'train.{rest}' (RunSpec train keys: \
                 {RUN_TRAIN_KEYS:?}, plus pipeline.depth)"
            );
        }
        bail!(
            "unknown RunSpec key '{key}' (sections: seed, [env], [env.wrap], \
             [policy], [vec], [serve], [runs], [train], [grid])"
        );
    }
    // Namespaced keys get their suffix validation from the config-layer
    // parsers (validate_keys / from_parts), which name the key.
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vector::VecBatch;

    fn full_spec() -> RunSpec {
        RunSpec::new(EnvSpec::new("ocean/spaces").clip_reward(1.0).stack(2))
            .with_policy(
                PolicySpec::default()
                    .with_hidden(64)
                    .with_embed_dim(8)
                    .with_lstm(32),
            )
            .with_vec(VecSpec::Mt {
                workers: 2,
                batch: VecBatch::Half,
                zero_copy: true,
                spin_budget: 128,
            })
            .with_seed(42)
            .with_train(|t| {
                t.total_steps = 12_345;
                t.lr = 0.0015;
                t.ent_coef = 0.002;
                t.epochs = 2;
                t.minibatches = 4;
                t.norm_adv = false;
                t.anneal_lr = false;
                t.pipeline_depth = 1;
                t.log_every = 0;
                t.run_dir = Some("runs/full".into());
            })
    }

    #[test]
    fn defaulted_spec_round_trips_toml_and_json() {
        let spec = RunSpec::new(EnvSpec::new("ocean/bandit"));
        let toml = spec.to_toml().unwrap();
        assert_eq!(RunSpec::from_toml_str(&toml).unwrap(), spec);
        let json = spec.to_json().dump();
        assert_eq!(RunSpec::from_json_str(&json).unwrap(), spec);
    }

    #[test]
    fn fully_overridden_spec_round_trips_toml_and_json() {
        let mut spec = full_spec();
        spec.grid
            .insert("train.lr".into(), vec!["0.001".into(), "0.0025".into()]);
        spec.grid
            .insert("policy.hidden".into(), vec!["32".into(), "64".into()]);
        let toml = spec.to_toml().unwrap();
        assert_eq!(RunSpec::from_toml_str(&toml).unwrap(), spec);
        let json = spec.to_json().dump();
        assert_eq!(RunSpec::from_json_str(&json).unwrap(), spec);
    }

    #[test]
    fn serve_section_round_trips_and_rejects_unknown_knobs() {
        let serve = crate::serve::ServeConfig {
            port: 9001,
            max_batch: 32,
            max_wait_us: 250,
            session_ttl_s: 60,
            threads: 2,
        };
        let spec = full_spec().with_serve(serve.clone());
        let toml = spec.to_toml().unwrap();
        assert!(toml.contains("\n[serve]\n"), "serve gets its own section:\n{toml}");
        assert_eq!(RunSpec::from_toml_str(&toml).unwrap(), spec);
        let json = spec.to_json().dump();
        assert_eq!(RunSpec::from_json_str(&json).unwrap(), spec);

        // Specs that never serve stay serve-less (no section emitted).
        let plain = full_spec();
        assert_eq!(plain.serve, None);
        assert!(!plain.to_toml().unwrap().contains("[serve]"));

        // A partial section pulls defaults for the rest.
        let partial = RunSpec::from_toml_str(
            "[env]\nname = \"ocean/bandit\"\n[serve]\nport = 8080\n",
        )
        .unwrap();
        assert_eq!(
            partial.serve,
            Some(crate::serve::ServeConfig { port: 8080, ..Default::default() })
        );

        // Unknown serve knobs error naming the key.
        let err = RunSpec::from_toml_str("[serve]\nprot = 7777\n")
            .err()
            .expect("typo'd serve key must be rejected")
            .to_string();
        assert!(err.contains("serve key 'prot'"), "got: {err}");
    }

    #[test]
    fn runs_section_round_trips_and_rejects_unknown_knobs() {
        let runs = crate::runs::RunsConfig {
            root: "exp/registry".to_string(),
            heartbeat_s: 2.5,
        };
        let spec = full_spec().with_runs(runs.clone());
        let toml = spec.to_toml().unwrap();
        assert!(toml.contains("\n[runs]\n"), "runs gets its own section:\n{toml}");
        assert_eq!(RunSpec::from_toml_str(&toml).unwrap(), spec);
        let json = spec.to_json().dump();
        assert_eq!(RunSpec::from_json_str(&json).unwrap(), spec);

        // Specs without ops overrides stay runs-less (no section emitted).
        let plain = full_spec();
        assert_eq!(plain.runs, None);
        assert!(!plain.to_toml().unwrap().contains("[runs]"));

        // A partial section pulls defaults for the rest.
        let partial = RunSpec::from_toml_str(
            "[env]\nname = \"ocean/bandit\"\n[runs]\nheartbeat_s = 1\n",
        )
        .unwrap();
        assert_eq!(
            partial.runs,
            Some(crate::runs::RunsConfig { heartbeat_s: 1.0, ..Default::default() })
        );

        // Unknown runs knobs error naming the key.
        let err = RunSpec::from_toml_str("[runs]\nheart_beat = 5\n")
            .err()
            .expect("typo'd runs key must be rejected")
            .to_string();
        assert!(err.contains("runs key 'heart_beat'"), "got: {err}");
    }

    #[test]
    fn spec_parts_are_authoritative_over_derived_train_fields() {
        let spec = full_spec();
        let tc = spec.train_config();
        assert_eq!(tc.env, "ocean/spaces");
        assert_eq!(tc.seed, 42);
        assert_eq!(tc.wrappers.len(), 2);
        assert_eq!(tc.vec, Some(spec.vec.clone()));
        assert!(tc.pool, "half batch mirrors the legacy pool knob");
        // Tampering with a derived field does not survive normalization.
        let tampered = spec.with_train(|t| t.env = "ocean/bandit".into());
        assert_eq!(tampered.train.env, "ocean/spaces");
    }

    #[test]
    fn unknown_and_misplaced_keys_error_naming_the_key() {
        for (toml, needle) in [
            ("[env]\nname = \"ocean/bandit\"\n[train]\ntotl_steps = 5", "train.totl_steps"),
            ("[train]\nenv = \"ocean/bandit\"", "train.env"),
            ("[train]\nseed = 4", "train.seed"),
            ("[train]\nnum_workers = 4", "train.num_workers"),
            ("[train]\npool = true", "train.pool"),
            ("[train.wrap]\nstack = 4", "train.wrap"),
            ("[env]\nname = \"ocean/bandit\"\nstacc = 4", "env.stacc"),
            ("[vec]\nmode = \"warp\"", "vec.mode"),
            ("[env.wrap]\nstack = \"lots\"", "wrap.stack"),
            ("[policy]\nhidden = \"wide\"", "policy.hidden"),
            ("[env]\nname = \"atari/pong\"", "env.name"),
            ("seed = banana", "config key 'seed'"),
            ("seed = [1, 2]", "seed"),
            ("[grid]\nlr = [0.1]", "grid key"),
        ] {
            let err = RunSpec::from_toml_str(toml)
                .err()
                .unwrap_or_else(|| panic!("'{toml}' should not parse"));
            let chain = format!("{err:#}");
            assert!(
                chain.contains(needle),
                "'{toml}': expected '{needle}' in '{chain}'"
            );
        }
    }

    #[test]
    fn grid_expansion_covers_the_cartesian_product_with_distinct_dirs() {
        let mut spec = RunSpec::new(EnvSpec::new("ocean/bandit")).with_train(|t| {
            t.run_dir = Some("runs/grid_test".into());
            t.total_steps = 1;
        });
        spec.grid
            .insert("train.lr".into(), vec!["0.001".into(), "0.0025".into()]);
        spec.grid
            .insert("seed".into(), vec!["1".into(), "2".into()]);
        let children = spec.expand_grid().unwrap();
        assert_eq!(children.len(), 4);
        let dirs: std::collections::BTreeSet<_> = children
            .iter()
            .map(|c| c.train.run_dir.clone().unwrap())
            .collect();
        assert_eq!(dirs.len(), 4, "run dirs must be distinct: {dirs:?}");
        for c in &children {
            assert!(c.grid.is_empty());
            assert!(c.train.run_dir.as_ref().unwrap().starts_with("runs/grid_test/"));
        }
        // Both lr values and both seeds appear.
        let lrs: std::collections::BTreeSet<_> =
            children.iter().map(|c| format!("{}", c.train.lr)).collect();
        assert_eq!(lrs.len(), 2);
        let seeds: std::collections::BTreeSet<_> = children.iter().map(|c| c.seed).collect();
        assert_eq!(seeds, [1u64, 2].into_iter().collect());
        // No grid → expansion is an error, not an empty vec.
        assert!(RunSpec::new(EnvSpec::new("ocean/bandit")).expand_grid().is_err());
        // Duplicate axis values would collide two children onto one run
        // dir — rejected instead of racing their metrics/checkpoints.
        let mut dup = RunSpec::new(EnvSpec::new("ocean/bandit"));
        dup.grid.insert("seed".into(), vec!["1".into(), "1".into()]);
        let err = dup.expand_grid().unwrap_err().to_string();
        assert!(err.contains("same run dir"), "{err}");
    }

    #[test]
    fn validate_shallow_accepts_good_specs_and_rejects_bad_names() {
        RunSpec::new(EnvSpec::new("ocean/bandit")).validate_shallow().unwrap();
        let err = RunSpec::new(EnvSpec::new("atari/pong"))
            .validate_shallow()
            .unwrap_err()
            .to_string();
        assert!(err.contains("first-party"), "{err}");
    }

    #[test]
    fn overrides_and_grids_can_switch_discriminant_modes() {
        // Switching vec.mode drops the old mode's dependent knobs
        // instead of tripping over them.
        let spec = full_spec();
        let (mut flat, arrays) = spec.to_flat().unwrap();
        merge_overrides(&mut flat, &[("vec.mode".into(), "serial".into())]);
        let back = RunSpec::from_parts(&flat, &arrays).unwrap();
        assert_eq!(back.vec, VecSpec::Serial);
        // A redundant same-mode override keeps the spec's knobs.
        let (mut flat, arrays) = spec.to_flat().unwrap();
        merge_overrides(&mut flat, &[("vec.mode".into(), "mt".into())]);
        let back = RunSpec::from_parts(&flat, &arrays).unwrap();
        assert_eq!(back.vec, spec.vec);
        // Turning the LSTM off drops the serialized lstm_hidden.
        let (mut flat, arrays) = spec.to_flat().unwrap();
        merge_overrides(&mut flat, &[("policy.lstm".into(), "false".into())]);
        let back = RunSpec::from_parts(&flat, &arrays).unwrap();
        assert!(!back.policy.unwrap().is_recurrent());
        // A grid can sweep the discriminant itself.
        let mut gridded = RunSpec::new(EnvSpec::new("ocean/bandit"));
        gridded
            .grid
            .insert("vec.mode".into(), vec!["serial".into(), "mt".into()]);
        let children = gridded.expand_grid().unwrap();
        assert_eq!(children.len(), 2);
        assert!(children.iter().any(|c| c.vec == VecSpec::Serial));
        assert!(children.iter().any(|c| matches!(c.vec, VecSpec::Mt { .. })));
    }

    #[test]
    fn toml_rejects_unrepresentable_values_but_json_carries_them() {
        let spec = RunSpec::new(EnvSpec::new("ocean/bandit"))
            .with_train(|t| t.run_dir = Some("runs/a\"b".into()));
        let err = spec.to_toml().unwrap_err().to_string();
        assert!(err.contains("train.run_dir"), "{err}");
        // The JSON form has real escapes, so the same spec round-trips.
        assert_eq!(RunSpec::from_json(&spec.to_json()).unwrap(), spec);
    }

    #[test]
    fn cli_key_translation() {
        assert_eq!(translate_cli_key("wrap.stack"), "env.wrap.stack");
        assert_eq!(translate_cli_key("pipeline.depth"), "train.pipeline.depth");
        assert_eq!(translate_cli_key("train.lr"), "train.lr");
        assert_eq!(translate_cli_key("seed"), "seed");
        assert_eq!(translate_cli_key("vec.mode"), "vec.mode");
    }
}
