//! Deterministic pseudo-random number generation.
//!
//! A PCG64 (DXSM) generator: small state, excellent statistical quality,
//! and reproducible across platforms — every environment, the trainer, and
//! the property-test framework seed from this. No `rand` crate dependency.

/// PCG64-DXSM pseudo-random generator.
///
/// The "cheap multiplier" DXSM variant used by NumPy's default bit
/// generator. 128-bit state / 128-bit increment, 64-bit output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0xda94_2042_e4dd_58b5;

/// Advance the per-episode seed stream used by every auto-resetting
/// layer (`PufferEnv`, `PufferMultiEnv`, wrapper-forced truncation): one
/// LCG step. A single shared discipline keeps wrapped and bare envs
/// trace-comparable across backends.
pub fn next_episode_seed(seed: u64) -> u64 {
    seed.wrapping_mul(6364136223846793005).wrapping_add(1)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Distinct seeds give
    /// independent-looking streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into state + stream.
        let mut sm = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Rng { state, inc };
        rng.state = rng.state.wrapping_add(rng.inc);
        let _ = rng.next_u64();
        rng
    }

    /// Derive a child generator (e.g. one per environment instance).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // DXSM output function on the *current* state, then advance.
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(PCG_MULT as u64);
        hi ^= hi >> 48;
        hi = hi.wrapping_mul(lo);
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        hi
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo.wrapping_add(self.below((hi - lo) as u64 + 1) as i64)
    }

    /// Uniform f64 in `[0, 1)`, 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (cached second sample omitted for
    /// simplicity; the envs that use this are not throughput-bound on it).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed sample with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample an action index from a categorical distribution given logits
    /// (used by the policy's action sampler).
    pub fn categorical_logits(&mut self, logits: &[f32]) -> usize {
        // Gumbel-max: argmax(logits + Gumbel noise). Numerically robust and
        // avoids an explicit softmax on the hot path.
        let mut best = f64::NEG_INFINITY;
        let mut arg = 0;
        for (i, &l) in logits.iter().enumerate() {
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            let g = l as f64 - (-u.ln()).ln();
            if g > best {
                best = g;
                arg = i;
            }
        }
        arg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_give_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut rng = Rng::new(4);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle moved something");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Rng::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio {ratio}");
    }

    #[test]
    fn categorical_prefers_high_logits() {
        let mut rng = Rng::new(9);
        let logits = [0.0f32, 5.0, 0.0];
        let hits = (0..1000)
            .filter(|_| rng.categorical_logits(&logits) == 1)
            .count();
        assert!(hits > 950, "hits {hits}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(10);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "mean {mean}");
    }
}
