//! Minimal JSON parser and writer.
//!
//! Used for the AOT artifact manifest (written by `python/compile/aot.py`)
//! and for metrics logs. Supports the full JSON grammar except `\u` escapes
//! beyond the BMP surrogate pairs (which the manifest never contains).
//! `serde` is not available in this offline environment.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so output is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index access; Null out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Convenience: `[1,2,3]` → `vec![1,2,3]` for shape fields.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly (no whitespace).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Advance over one UTF-8 scalar.
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // PANIC: the scanned range is ASCII number bytes — always valid UTF-8.
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Builder helpers so call sites stay readable.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").at(1).as_f64(), Some(2.5));
        assert_eq!(v.get("a").at(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("b").get("c").as_str(), Some("hi\nthere"));
        assert_eq!(v.get("b").get("d"), &Json::Null);
        assert_eq!(v.get("e").as_bool(), Some(true));
        // dump → parse → identical
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \t ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("café \t ✓"));
        let back = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[3, 4, 5]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![3, 4, 5]));
        assert_eq!(Json::parse("[1.5]").unwrap().as_usize_vec(), None);
    }

    #[test]
    fn missing_keys_are_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope").get("deeper").at(3), &Json::Null);
    }

    #[test]
    fn integers_dump_without_fraction() {
        assert_eq!(num(5.0).dump(), "5");
        assert_eq!(num(5.25).dump(), "5.25");
    }
}
