//! Timing helpers for benchmarks and throughput (steps-per-second)
//! accounting.

use std::time::{Duration, Instant};

/// Simple scope timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn micros(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e6
    }
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Steps-per-second counter with windowed reporting, used by the trainer's
/// console log and the bench harness.
#[derive(Debug)]
pub struct SpsCounter {
    start: Instant,
    window_start: Instant,
    total_steps: u64,
    window_steps: u64,
}

impl Default for SpsCounter {
    fn default() -> Self {
        Self::new()
    }
}

impl SpsCounter {
    pub fn new() -> Self {
        let now = Instant::now();
        SpsCounter {
            start: now,
            window_start: now,
            total_steps: 0,
            window_steps: 0,
        }
    }

    pub fn add(&mut self, steps: u64) {
        self.total_steps += steps;
        self.window_steps += steps;
    }

    /// Overall steps/second since construction.
    pub fn overall(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.total_steps as f64 / secs
        }
    }

    /// Steps/second since the last `window()` call, then reset the window.
    pub fn window(&mut self) -> f64 {
        let secs = self.window_start.elapsed().as_secs_f64();
        let sps = if secs <= 0.0 {
            0.0
        } else {
            self.window_steps as f64 / secs
        };
        self.window_start = Instant::now();
        self.window_steps = 0;
        sps
    }

    pub fn total(&self) -> u64 {
        self.total_steps
    }
}

/// Busy-spin for a precise duration. Environment simulators use this to
/// model per-step compute cost: `thread::sleep` has ~50µs+ granularity and
/// yields the core, which would misrepresent a CPU-bound env. The spin is
/// checked against the monotonic clock so it is accurate to ~100ns.
#[inline]
pub fn spin_for(d: Duration) {
    if d.is_zero() {
        return;
    }
    let end = Instant::now() + d;
    while Instant::now() < end {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spin_for_is_accurate() {
        let t = Timer::start();
        spin_for(Duration::from_micros(200));
        let us = t.micros();
        assert!(us >= 200.0, "spun only {us}µs");
        assert!(us < 5_000.0, "spun way too long: {us}µs");
    }

    #[test]
    fn sps_counts() {
        let mut c = SpsCounter::new();
        c.add(10);
        c.add(5);
        assert_eq!(c.total(), 15);
        spin_for(Duration::from_millis(2));
        assert!(c.overall() > 0.0);
        assert!(c.window() > 0.0);
        // window resets
        c.add(1);
        assert_eq!(c.total(), 16);
    }
}
