//! Mini property-testing framework (`proptest` is unavailable offline).
//!
//! Runs a property against N randomly generated cases from a seeded
//! [`Rng`](crate::util::rng::Rng); on failure it reports the case index and
//! seed so the exact case replays deterministically. A lightweight
//! "shrinking" pass retries the property with each registered simpler
//! variant of the failing input when the generator provides them.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct CheckConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for CheckConfig {
    fn default() -> Self {
        CheckConfig {
            cases: 128,
            seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` against `cases` inputs drawn from `gen`. Panics with a
/// reproducible diagnostic on the first failing case.
pub fn check<T, G, P>(cfg: CheckConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.split();
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}):\n  input: {:?}\n  error: {msg}",
                cfg.cases, cfg.seed, input
            );
        }
    }
}

/// Like [`check`] but with shrinking: `shrink` proposes simpler variants of
/// a failing input; the smallest still-failing variant is reported.
pub fn check_shrink<T, G, S, P>(cfg: CheckConfig, mut gen: G, shrink: S, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.split();
        let input = gen(&mut case_rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first simpler variant that
            // still fails, up to a budget.
            let mut current = input.clone();
            let mut msg = first_msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in shrink(&current) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case}/{} (seed {:#x}):\n  shrunk input: {:?}\n  error: {msg}",
                cfg.cases, cfg.seed, current
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    /// Vec of length in `[min_len, max_len]` with elements from `f`.
    pub fn vec_of<T>(
        rng: &mut Rng,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Rng) -> T,
    ) -> Vec<T> {
        let len = rng.range_i64(min_len as i64, max_len as i64) as usize;
        (0..len).map(|_| f(rng)).collect()
    }

    /// Finite f32 in a reasonable range (no NaN/inf).
    pub fn f32_reasonable(rng: &mut Rng) -> f32 {
        rng.uniform(-1e4, 1e4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            CheckConfig::default(),
            |rng| rng.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 100"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        check(
            CheckConfig {
                cases: 50,
                seed: 42,
            },
            |rng| rng.below(10),
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "shrunk input: 10")]
    fn shrinking_finds_minimal_counterexample() {
        // Property: x < 10. Failing inputs are >= 10; shrinking by
        // decrement should land exactly on 10.
        check_shrink(
            CheckConfig {
                cases: 100,
                seed: 7,
            },
            |rng| rng.below(1000),
            |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
            |&x| {
                if x < 10 {
                    Ok(())
                } else {
                    Err("x >= 10".into())
                }
            },
        );
    }

    #[test]
    fn gen_vec_respects_bounds() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let v = gen::vec_of(&mut rng, 2, 5, |r| r.below(10));
            assert!(v.len() >= 2 && v.len() <= 5);
        }
    }
}
