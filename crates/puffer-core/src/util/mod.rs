//! Shared substrates: RNG, statistics, timing, JSON, and a mini
//! property-testing framework. These exist because the build environment is
//! offline — the usual crates (`rand`, `serde`, `criterion`, `proptest`)
//! are unavailable, and the implementations here are small, specified, and
//! tested.

// Pure substrates: no unsafe belongs here (CONCURRENCY.md).
#![forbid(unsafe_code)]

pub mod check;
pub mod json;
pub mod rng;
pub mod seed;
pub mod stats;
pub mod timer;
