//! Seed derivation: one root seed per run, split into independent
//! per-stream seeds.
//!
//! A run touches several RNG streams — env resets (the vectorizer's
//! `VecConfig::seed` / `async_reset`), rollout action sampling, the
//! minibatch shuffle, the pipelined collector's sampling stream, and
//! evaluation resets. Before [`RunSpec`](crate::runspec::RunSpec)
//! existed, callers copied one `TrainConfig::seed` into `VecConfig.seed`
//! by hand and the trainer XOR'd ad-hoc constants for the rest. The
//! documented split is now explicit:
//!
//! - [`split`] — `splitmix64(root ^ fnv1a(domain))`: statistically
//!   independent streams from one `run.seed` root. This is what
//!   [`SeedPlan::from_root`] uses, and therefore what every
//!   RunSpec-constructed trainer runs with.
//! - [`SeedPlan::legacy`] — the exact pre-RunSpec derivations (identity
//!   for env + policy, the historical XOR constants for the rest), kept
//!   so `Trainer::native(TrainConfig)` stays bit-identical to the
//!   pinned pre-refactor trainer (`tests/pipeline.rs`).
//!
//! Reproducibility contract: two identical RunSpecs produce identical
//! SeedPlans and therefore bit-identical first segments (pinned by
//! `tests/run_spec.rs`).

/// `splitmix64` finalizer: a cheap, well-mixed 64-bit permutation
/// (Steele et al., "Fast splittable pseudorandom number generators").
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// FNV-1a over the domain label — turns `"env"` / `"policy"` / … into a
/// 64-bit domain constant without a hand-maintained table.
fn fnv1a(label: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for b in label.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The documented split function: derive the seed for one named stream
/// from the run's root seed. Identical `(root, domain)` always produces
/// the same stream seed; distinct domains decorrelate even when `root`
/// values are small and sequential (0, 1, 2, …).
pub fn split(root: u64, domain: &str) -> u64 {
    splitmix64(root ^ fnv1a(domain))
}

/// Every per-stream seed a trainer consumes, derived from one root.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeedPlan {
    /// Env-reset stream: `VecConfig::seed` and `async_reset` (env `i`
    /// resets with `env + i`).
    pub env: u64,
    /// Rollout policy construction (action-sampling RNG).
    pub policy: u64,
    /// Minibatch row-permutation stream.
    pub shuffle: u64,
    /// The pipelined collector's own policy sampling stream.
    pub collector: u64,
    /// Evaluation env-reset stream.
    pub eval: u64,
}

impl SeedPlan {
    /// The split-function plan for a [`RunSpec`](crate::runspec::RunSpec)
    /// root seed: every stream is `split(root, <domain>)`.
    pub fn from_root(root: u64) -> Self {
        SeedPlan {
            env: split(root, "env"),
            policy: split(root, "policy"),
            shuffle: split(root, "shuffle"),
            collector: split(root, "collector"),
            eval: split(root, "eval"),
        }
    }

    /// The pre-RunSpec derivations from `TrainConfig::seed`, preserved
    /// bit for bit so directly-configured trainers reproduce the pinned
    /// pre-refactor loop (`tests/pipeline.rs` compares parameters
    /// bitwise against a replica seeded this way).
    pub fn legacy(seed: u64) -> Self {
        SeedPlan {
            env: seed,
            policy: seed,
            shuffle: seed ^ 0x5B0F_F1E5,
            collector: seed ^ 0x50C0_11EC,
            eval: seed ^ 0xEEEE,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_deterministic_and_domain_separated() {
        assert_eq!(split(7, "env"), split(7, "env"));
        assert_ne!(split(7, "env"), split(7, "policy"));
        assert_ne!(split(7, "env"), split(8, "env"));
        // Sequential roots do not produce correlated neighbors.
        assert_ne!(split(0, "env") + 1, split(1, "env"));
    }

    #[test]
    fn from_root_streams_are_pairwise_distinct() {
        let p = SeedPlan::from_root(1);
        let all = [p.env, p.policy, p.shuffle, p.collector, p.eval];
        for i in 0..all.len() {
            for j in (i + 1)..all.len() {
                assert_ne!(all[i], all[j], "streams {i} and {j} collide");
            }
        }
        assert_eq!(p, SeedPlan::from_root(1));
        assert_ne!(p, SeedPlan::from_root(2));
    }

    #[test]
    fn legacy_matches_the_historical_constants() {
        let p = SeedPlan::legacy(7);
        assert_eq!(p.env, 7);
        assert_eq!(p.policy, 7);
        assert_eq!(p.shuffle, 7 ^ 0x5B0F_F1E5);
        assert_eq!(p.collector, 7 ^ 0x50C0_11EC);
        assert_eq!(p.eval, 7 ^ 0xEEEE);
    }
}
