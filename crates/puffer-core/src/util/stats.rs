//! Streaming and batch statistics used by the benchmark harness, the
//! autotuner, and the trainer's metric logging.

/// Numerically stable streaming mean/variance (Welford's algorithm) with
/// min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Coefficient of variation (std / mean) — the quantity the paper's
    /// "% Step STD" column reports.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < 1e-12 {
            0.0
        } else {
            self.std() / self.mean
        }
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

/// Percentile over a sample (linear interpolation, like numpy's default).
/// `q` in [0, 100]. Sorts a copy; fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    let mut v: Vec<f64> = xs.to_vec();
    // PANIC: callers pass finite samples (timings/scores); partial_cmp is total here.
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Exponential moving average, used for console training metrics.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Fixed-bin histogram over a known range; used by the autotuner to report
/// step-time distributions.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo) * self.bins.len() as f64) as usize;
            let last = self.bins.len() - 1;
            self.bins[idx.min(last)] += 1;
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }
    pub fn underflow(&self) -> u64 {
        self.underflow
    }
    pub fn overflow(&self) -> u64 {
        self.overflow
    }
    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Render a compact ASCII sparkline for console output.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&b| GLYPHS[(b as usize * (GLYPHS.len() - 1)) / max as usize])
            .collect()
    }
}

/// Compact SI-suffixed magnitude for console tables (`puffer ps`/`top`
/// SPS and step columns): `512`, `34.2k`, `1.2M`, `3.4G`. Non-finite
/// values render as `-`.
pub fn fmt_si(x: f64) -> String {
    if !x.is_finite() {
        return "-".to_string();
    }
    let (mag, sign) = (x.abs(), if x < 0.0 { "-" } else { "" });
    let (scaled, suffix) = if mag >= 1e9 {
        (mag / 1e9, "G")
    } else if mag >= 1e6 {
        (mag / 1e6, "M")
    } else if mag >= 1e3 {
        (mag / 1e3, "k")
    } else {
        (mag, "")
    };
    if suffix.is_empty() {
        if mag == mag.trunc() && mag < 1e3 {
            format!("{sign}{}", mag as u64)
        } else {
            format!("{sign}{mag:.1}")
        }
    } else if scaled >= 100.0 {
        format!("{sign}{scaled:.0}{suffix}")
    } else {
        format!("{sign}{scaled:.1}{suffix}")
    }
}

/// Compact age/duration for console tables: `3s`, `2m10s`, `4h02m`,
/// `2d07h`. Negative or non-finite durations render as `-`.
pub fn fmt_age(secs: f64) -> String {
    if !secs.is_finite() || secs < 0.0 {
        return "-".to_string();
    }
    let s = secs as u64;
    if s < 60 {
        format!("{s}s")
    } else if s < 3600 {
        format!("{}m{:02}s", s / 60, s % 60)
    } else if s < 86_400 {
        format!("{}h{:02}m", s / 3600, (s % 3600) / 60)
    } else {
        format!("{}d{:02}h", s / 86_400, (s % 86_400) / 3600)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn welford_merge_equals_combined() {
        let mut a = Welford::new();
        let mut b = Welford::new();
        let mut all = Welford::new();
        for i in 0..50 {
            let x = (i as f64).sin() * 10.0;
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..30 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn fmt_si_and_fmt_age_cover_the_ranges() {
        assert_eq!(fmt_si(0.0), "0");
        assert_eq!(fmt_si(512.0), "512");
        assert_eq!(fmt_si(34_200.0), "34.2k");
        assert_eq!(fmt_si(1_200_000.0), "1.2M");
        assert_eq!(fmt_si(3.4e9), "3.4G");
        assert_eq!(fmt_si(-1500.0), "-1.5k");
        assert_eq!(fmt_si(f64::NAN), "-");
        assert_eq!(fmt_age(3.0), "3s");
        assert_eq!(fmt_age(130.0), "2m10s");
        assert_eq!(fmt_age(4.0 * 3600.0 + 120.0), "4h02m");
        assert_eq!(fmt_age(2.0 * 86_400.0 + 7.0 * 3600.0), "2d07h");
        assert_eq!(fmt_age(-1.0), "-");
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.bins(), &[1; 10]);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.total(), 12);
    }
}
