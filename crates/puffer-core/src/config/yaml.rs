//! Minimal YAML-subset parser producing flat dotted keys.

use std::collections::BTreeMap;
use std::fmt;

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "yaml error at line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for YamlError {}

/// Parse the YAML subset (nested maps via 2-space indents, scalars,
/// comments) into flat dotted keys: `{a: {b: 1}}` → `{"a.b": "1"}`.
pub fn parse_yaml(text: &str) -> Result<BTreeMap<String, String>, YamlError> {
    let mut out = BTreeMap::new();
    // Stack of (indent, key-prefix).
    let mut stack: Vec<(usize, String)> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim_end();
        if line.trim().is_empty() {
            continue;
        }
        let indent = line.len() - line.trim_start().len();
        if indent % 2 != 0 {
            return Err(YamlError {
                line: lineno + 1,
                msg: "odd indentation (use 2-space indents)".into(),
            });
        }
        let body = line.trim_start();
        let (key, value) = body.split_once(':').ok_or(YamlError {
            line: lineno + 1,
            msg: "expected 'key: value' or 'key:'".into(),
        })?;
        let key = key.trim();
        if key.is_empty() || key.contains(' ') {
            return Err(YamlError {
                line: lineno + 1,
                msg: format!("bad key '{key}'"),
            });
        }
        // Pop scopes deeper or equal to this indent.
        while let Some(&(d, _)) = stack.last() {
            if d >= indent {
                stack.pop();
            } else {
                break;
            }
        }
        if let Some(&(d, _)) = stack.last() {
            if indent > d + 2 {
                return Err(YamlError {
                    line: lineno + 1,
                    msg: "over-indented".into(),
                });
            }
        } else if indent != 0 {
            return Err(YamlError {
                line: lineno + 1,
                msg: "top-level keys must not be indented".into(),
            });
        }
        let prefix = stack
            .last()
            .map(|(_, p)| format!("{p}.{key}"))
            .unwrap_or_else(|| key.to_string());

        let value = value.trim();
        if value.is_empty() {
            // A nested map scope.
            stack.push((indent, prefix));
        } else {
            let v = value.trim_matches('"').trim_matches('\'');
            out.insert(prefix, v.to_string());
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_maps_flatten() {
        let text = "\
# Clean PuffeRL config
train:
  env: ocean/squared   # the env
  lr: 0.0025
  pool: true
  nested:
    deep: 7
vec:
  workers: 4
";
        let m = parse_yaml(text).unwrap();
        assert_eq!(m["train.env"], "ocean/squared");
        assert_eq!(m["train.lr"], "0.0025");
        assert_eq!(m["train.pool"], "true");
        assert_eq!(m["train.nested.deep"], "7");
        assert_eq!(m["vec.workers"], "4");
    }

    #[test]
    fn dedent_returns_to_outer_scope() {
        let text = "\
a:
  b: 1
c: 2
";
        let m = parse_yaml(text).unwrap();
        assert_eq!(m["a.b"], "1");
        assert_eq!(m["c"], "2");
    }

    #[test]
    fn quoted_strings_unquoted() {
        let m = parse_yaml("k: \"hello world\"\n").unwrap();
        assert_eq!(m["k"], "hello world");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_yaml("ok: 1\n   bad: 2\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(parse_yaml("just a line\n").is_err());
        assert!(parse_yaml("  indented: 1\n").is_err());
    }
}
