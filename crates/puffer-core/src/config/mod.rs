//! Configuration: a YAML-subset file format plus `--key.path=value` CLI
//! overrides (Clean PuffeRL ships "clean YAML configs" with a runner CLI;
//! serde is unavailable offline, so the parser lives here).
//!
//! Supported YAML subset: nested maps by 2-space indentation, scalar
//! values (bool/int/float/string), `#` comments, blank lines. That covers
//! every config this project ships; anything else is a parse error.
//!
//! Parsing is **strict**: unknown keys under the `train.` / `wrap.` /
//! `pipeline.` / `policy.` namespaces and malformed values are rejected
//! with an error naming the key — a typo'd `--train.totl_steps=1000`
//! fails loudly instead of silently training with the default.

// Parsing only: no unsafe belongs here (CONCURRENCY.md).
#![forbid(unsafe_code)]

mod toml;
mod yaml;

pub use toml::{parse_toml, toml_value, TomlDoc, TomlError};
pub use yaml::{parse_yaml, YamlError};

use crate::policy::PolicySpec;
use crate::train::TrainConfig;
use crate::vector::VecSpec;
use crate::wrappers::WrapperSpec;
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;

/// A flat key→scalar view of a config tree ("train.lr" → "0.0025").
pub type FlatConfig = BTreeMap<String, String>;

/// Recognized keys under `train.` (excluding the `train.wrap.` and
/// `train.pipeline.` subtrees).
const TRAIN_KEYS: &[&str] = &[
    "env",
    "total_steps",
    "lr",
    "ent_coef",
    "epochs",
    "minibatches",
    "norm_adv",
    "anneal_lr",
    "seed",
    "num_workers",
    "pool",
    "run_dir",
    "log_every",
    "kernels",
];

/// Recognized experience-pipeline knobs, reachable as `train.pipeline.X`
/// (config files) or `pipeline.X` (CLI `--pipeline.X=...` overrides).
const PIPELINE_KEYS: &[&str] = &["depth"];

/// Recognized policy-architecture knobs, reachable as `train.policy.X`
/// (config files) or `policy.X` (CLI `--policy.X=...` overrides).
const POLICY_KEYS: &[&str] = &["hidden", "lstm", "lstm_hidden", "embed_dim", "head"];

/// Recognized vectorization knobs ([`VecSpec`]), reachable as `vec.X`
/// (RunSpec `[vec]` sections and `--vec.X=...` CLI overrides).
pub const VEC_KEYS: &[&str] = &["mode", "workers", "batch", "zero_copy", "spin_budget"];

/// Recognized inference-server knobs
/// ([`ServeConfig`](crate::serve::ServeConfig)), reachable as `serve.X`
/// (RunSpec `[serve]` sections and `--serve.X=...` CLI overrides).
pub const SERVE_KEYS: &[&str] = &["port", "max_batch", "max_wait_us", "session_ttl_s", "threads"];

/// Recognized experiment-ops knobs
/// ([`RunsConfig`](crate::runs::RunsConfig)), reachable as `runs.X`
/// (RunSpec `[runs]` sections and `--runs.X=...` CLI overrides).
pub const RUNS_KEYS: &[&str] = &["root", "heartbeat_s"];

/// Recognized wrapper knobs, reachable as `train.wrap.X` (config files)
/// or `wrap.X` (CLI `--wrap.X=...` overrides).
const WRAP_KEYS: &[&str] = &[
    "clip_reward",
    "scale_reward",
    "normalize_obs",
    "stack",
    "time_limit",
    "action_repeat",
];

/// Apply `--a.b=c`-style CLI overrides onto a flat config. Returns the
/// list of unrecognized args (for the caller to reject or pass on).
pub fn apply_overrides<'a>(
    cfg: &mut FlatConfig,
    args: impl Iterator<Item = &'a str>,
) -> Vec<String> {
    let mut rest = Vec::new();
    for arg in args {
        if let Some(body) = arg.strip_prefix("--") {
            if let Some((k, v)) = body.split_once('=') {
                cfg.insert(k.to_string(), v.to_string());
                continue;
            }
        }
        rest.push(arg.to_string());
    }
    rest
}

/// Parse `cfg[key]`, or take `default` when absent. Malformed values are
/// an error naming the key — never a silent fallback.
fn get_parse<T: std::str::FromStr>(cfg: &FlatConfig, key: &str, default: T) -> Result<T> {
    match cfg.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!(
                "config key '{key}': cannot parse value '{v}' as {}",
                std::any::type_name::<T>()
            )
        }),
    }
}

/// Reject unknown keys in the `train.` and `wrap.` namespaces. Keys in
/// other namespaces pass through untouched (config files may carry
/// sections this binary does not own).
pub fn validate_keys(cfg: &FlatConfig) -> Result<()> {
    for key in cfg.keys() {
        if let Some(rest) = key.strip_prefix("train.wrap.").or_else(|| key.strip_prefix("wrap.")) {
            ensure!(
                WRAP_KEYS.contains(&rest),
                "unknown wrapper key '{key}' (known wrapper knobs: {WRAP_KEYS:?})"
            );
        } else if let Some(rest) = key
            .strip_prefix("train.pipeline.")
            .or_else(|| key.strip_prefix("pipeline."))
        {
            ensure!(
                PIPELINE_KEYS.contains(&rest),
                "unknown pipeline key '{key}' (known pipeline knobs: {PIPELINE_KEYS:?})"
            );
        } else if let Some(rest) = key
            .strip_prefix("train.policy.")
            .or_else(|| key.strip_prefix("policy."))
        {
            ensure!(
                POLICY_KEYS.contains(&rest),
                "unknown policy key '{key}' (known policy knobs: {POLICY_KEYS:?})"
            );
        } else if let Some(rest) = key.strip_prefix("vec.") {
            ensure!(
                VEC_KEYS.contains(&rest),
                "unknown vec key '{key}' (known vec knobs: {VEC_KEYS:?})"
            );
        } else if let Some(rest) = key.strip_prefix("serve.") {
            ensure!(
                SERVE_KEYS.contains(&rest),
                "unknown serve key '{key}' (known serve knobs: {SERVE_KEYS:?})"
            );
        } else if let Some(rest) = key.strip_prefix("runs.") {
            ensure!(
                RUNS_KEYS.contains(&rest),
                "unknown runs key '{key}' (known runs knobs: {RUNS_KEYS:?})"
            );
        } else if let Some(rest) = key.strip_prefix("train.") {
            ensure!(
                TRAIN_KEYS.contains(&rest),
                "unknown config key '{key}' (known train keys: {TRAIN_KEYS:?}, \
                 plus wrapper knobs under train.wrap: {WRAP_KEYS:?}, pipeline \
                 knobs under train.pipeline: {PIPELINE_KEYS:?}, and policy \
                 knobs under train.policy: {POLICY_KEYS:?})"
            );
        }
    }
    Ok(())
}

/// Read the experience-pipeline depth from a flat config. CLI-style
/// `pipeline.depth` wins over file-style `train.pipeline.depth`; 0 (the
/// default) selects the serial trainer.
pub fn pipeline_config(cfg: &FlatConfig) -> Result<usize> {
    let got = cfg
        .get("pipeline.depth")
        .map(|v| ("pipeline.depth", v))
        .or_else(|| cfg.get("train.pipeline.depth").map(|v| ("train.pipeline.depth", v)));
    match got {
        None => Ok(0),
        Some((key, v)) => v.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("config key '{key}': cannot parse value '{v}' as a non-negative integer")
        }),
    }
}

/// Build the policy architecture from a flat config. CLI-style
/// `policy.X` keys win over file-style `train.policy.X`. Returns `None`
/// when no policy key is present — the trainer then resolves the env's
/// default spec ([`PolicySpec::default_for`]); any explicit key starts
/// from that same default, so e.g. `--policy.hidden=64` on a recurrent
/// env keeps the LSTM stage.
pub fn policy_config(cfg: &FlatConfig, env: &str) -> Result<Option<PolicySpec>> {
    let get = |knob: &str| {
        cfg.get(&format!("policy.{knob}"))
            .map(|v| (format!("policy.{knob}"), v))
            .or_else(|| {
                cfg.get(&format!("train.policy.{knob}"))
                    .map(|v| (format!("train.policy.{knob}"), v))
            })
    };
    if POLICY_KEYS.iter().all(|k| get(k).is_none()) {
        return Ok(None);
    }
    let parse_dim = |knob: &str, min: usize| -> Result<Option<usize>> {
        match get(knob) {
            None => Ok(None),
            Some((key, v)) => match v.parse::<usize>() {
                Ok(x) if x >= min => Ok(Some(x)),
                _ => bail!("config key '{key}': expected an integer >= {min}, got '{v}'"),
            },
        }
    };

    let mut spec = PolicySpec::default_for(env);
    if let Some(h) = parse_dim("hidden", 1)? {
        spec = spec.with_hidden(h);
        // The recurrent state follows the trunk width unless pinned
        // separately below.
        if spec.is_recurrent() && get("lstm_hidden").is_none() && get("lstm").is_none() {
            spec = spec.with_lstm(h);
        }
    }
    if let Some((key, v)) = get("lstm") {
        let on: bool = v
            .parse()
            .map_err(|_| anyhow::anyhow!("config key '{key}': cannot parse value '{v}' as bool"))?;
        spec = if on {
            let h = spec.hidden;
            spec.with_lstm(h)
        } else {
            spec.feedforward()
        };
    }
    if let Some(h) = parse_dim("lstm_hidden", 1)? {
        ensure!(
            spec.is_recurrent(),
            "config key 'policy.lstm_hidden': set policy.lstm=true to size \
             the recurrent state (this architecture is feedforward)"
        );
        spec = spec.with_lstm(h);
    }
    if let Some(d) = parse_dim("embed_dim", 0)? {
        spec = spec.with_embed_dim(d);
    }
    if let Some((key, v)) = get("head") {
        spec.head = match v.as_str() {
            "categorical" => crate::policy::ActionHead::Categorical,
            // A continuous head is a known gap, not a typo — name the
            // roadmap item and the workaround instead of the grammar.
            "gaussian" | "continuous" | "normal" => bail!(
                "config key '{key}': continuous (Gaussian) action heads are \
                 not implemented yet — see ROADMAP item 4. Quantize the \
                 action space instead: 'quantized:<bins>' emulates a Box \
                 space as <bins> choices per dim"
            ),
            other => match other.strip_prefix("quantized:").map(str::parse::<usize>) {
                Some(Ok(bins)) if bins >= 2 => crate::policy::ActionHead::Quantized { bins },
                _ => bail!(
                    "config key '{key}': expected 'categorical' or \
                     'quantized:<bins>=2..', got '{v}'"
                ),
            },
        };
    }
    Ok(Some(spec))
}

/// Build the [`ServeConfig`](crate::serve::ServeConfig) from a flat
/// config's `serve.*` keys. Returns `None` when no serve key is present
/// (most specs never serve); present keys get strict bounds checks and
/// defaults for the rest.
pub fn serve_config(cfg: &FlatConfig) -> Result<Option<crate::serve::ServeConfig>> {
    let get = |knob: &str| cfg.get(&format!("serve.{knob}")).map(String::as_str);
    if SERVE_KEYS.iter().all(|k| get(k).is_none()) {
        return Ok(None);
    }
    let defaults = crate::serve::ServeConfig::default();
    let spec = crate::serve::ServeConfig {
        port: get_parse(cfg, "serve.port", defaults.port)?,
        max_batch: get_parse(cfg, "serve.max_batch", defaults.max_batch)?,
        max_wait_us: get_parse(cfg, "serve.max_wait_us", defaults.max_wait_us)?,
        session_ttl_s: get_parse(cfg, "serve.session_ttl_s", defaults.session_ttl_s)?,
        threads: get_parse(cfg, "serve.threads", defaults.threads)?,
    };
    ensure!(spec.max_batch >= 1, "config key 'serve.max_batch': must be >= 1");
    ensure!(
        spec.session_ttl_s >= 1,
        "config key 'serve.session_ttl_s': must be >= 1 (sessions would evict instantly)"
    );
    ensure!(spec.threads >= 1, "config key 'serve.threads': must be >= 1");
    Ok(Some(spec))
}

/// Build the [`RunsConfig`](crate::runs::RunsConfig) from a flat
/// config's `runs.*` keys. Returns `None` when no runs key is present
/// (registry logging then uses the defaults); present keys get strict
/// bounds checks and defaults for the rest.
pub fn runs_config(cfg: &FlatConfig) -> Result<Option<crate::runs::RunsConfig>> {
    let get = |knob: &str| cfg.get(&format!("runs.{knob}")).map(String::as_str);
    if RUNS_KEYS.iter().all(|k| get(k).is_none()) {
        return Ok(None);
    }
    let defaults = crate::runs::RunsConfig::default();
    let spec = crate::runs::RunsConfig {
        root: get("root").unwrap_or(&defaults.root).to_string(),
        heartbeat_s: get_parse(cfg, "runs.heartbeat_s", defaults.heartbeat_s)?,
    };
    ensure!(
        !spec.root.trim().is_empty(),
        "config key 'runs.root': must be a non-empty directory path"
    );
    ensure!(
        spec.heartbeat_s.is_finite() && spec.heartbeat_s > 0.0,
        "config key 'runs.heartbeat_s': must be a positive number of seconds"
    );
    ensure!(
        spec.heartbeat_s <= 3600.0,
        "config key 'runs.heartbeat_s': must be <= 3600 (staleness detection \
         needs a bounded period)"
    );
    Ok(Some(spec))
}

/// Build the [`VecSpec`] from a flat config's `vec.*` keys. Returns
/// `None` when no vec key is present — the trainer then falls back to
/// the legacy `train.num_workers` / `train.pool` mapping. Present keys
/// other than `vec.mode` imply `mode = "mt"`.
pub fn vec_config(cfg: &FlatConfig) -> Result<Option<VecSpec>> {
    let get = |knob: &str| cfg.get(&format!("vec.{knob}")).map(String::as_str);
    if VEC_KEYS.iter().all(|k| get(k).is_none()) {
        return Ok(None);
    }
    let mode = get("mode").unwrap_or("mt");
    VecSpec::from_parts(
        mode,
        get("workers"),
        get("batch"),
        get("zero_copy"),
        get("spin_budget"),
    )
    .map(Some)
}

/// Emit a wrapper chain as canonical `(knob, value)` pairs — the inverse
/// of [`wrap_config`], used to serialize an
/// [`EnvSpec`](crate::wrappers::EnvSpec) into a RunSpec `[env.wrap]`
/// section. Errors when the chain is not representable in the knob
/// grammar (duplicate wrappers, or an order other than the canonical
/// innermost-first one); such chains are built in code and cannot live
/// in a spec file.
pub fn wrap_knob_pairs(chain: &[WrapperSpec]) -> Result<Vec<(&'static str, String)>> {
    let mut pairs = Vec::new();
    let mut last_rank = 0usize;
    for w in chain {
        let (rank, knob, value) = match w {
            WrapperSpec::ActionRepeat(k) => {
                // k = 1 is the identity: wrap_config would drop it on
                // re-parse, so the round trip would not be exact.
                ensure!(*k >= 2, "action_repeat={k} is the identity; drop the wrapper");
                (1, "action_repeat", k.to_string())
            }
            WrapperSpec::TimeLimit(n) => (2, "time_limit", n.to_string()),
            WrapperSpec::ScaleReward(s) => (3, "scale_reward", format!("{s}")),
            WrapperSpec::ClipReward(b) => (4, "clip_reward", format!("{b}")),
            WrapperSpec::NormalizeObs => (5, "normalize_obs", "true".to_string()),
            WrapperSpec::Stack(k) => {
                ensure!(*k >= 2, "stack={k} is the identity; drop the wrapper");
                (6, "stack", k.to_string())
            }
        };
        ensure!(
            rank > last_rank,
            "wrapper chain is not expressible in the config knob grammar \
             (canonical innermost-first order is action_repeat, time_limit, \
             scale_reward, clip_reward, normalize_obs, stack, each at most \
             once; '{}' is out of order or repeated) — chains with custom \
             order are built in code via EnvSpec and cannot be serialized \
             into a RunSpec",
            w.key_fragment()
        );
        last_rank = rank;
        pairs.push((knob, value));
    }
    Ok(pairs)
}

/// Build the wrapper chain from a flat config. CLI-style `wrap.X` keys
/// win over file-style `train.wrap.X`.
///
/// Config-driven chains use a fixed canonical order, **innermost
/// first**: `action_repeat`, `time_limit`, `scale_reward`,
/// `clip_reward`, `normalize_obs`, `stack` — repeats and limits sit at
/// the env boundary; clipping sees scaled rewards; stacking is outermost
/// so it stacks normalized observations. Chains needing a different
/// order are built in code via [`crate::wrappers::EnvSpec`].
pub fn wrap_config(cfg: &FlatConfig) -> Result<Vec<WrapperSpec>> {
    let get = |knob: &str| {
        cfg.get(&format!("wrap.{knob}"))
            .map(|v| (format!("wrap.{knob}"), v))
            .or_else(|| cfg.get(&format!("train.wrap.{knob}")).map(|v| (format!("train.wrap.{knob}"), v)))
    };
    let parse = |knob: &str| -> Result<Option<(String, f64)>> {
        match get(knob) {
            None => Ok(None),
            // The f32 guard keeps huge-but-finite f64s (1e39) from
            // casting to infinity downstream and tripping wrapper
            // constructor asserts instead of a config error.
            Some((key, v)) => match v.parse::<f64>() {
                Ok(x) if x.is_finite() && (x as f32).is_finite() => Ok(Some((key, x))),
                _ => bail!("config key '{key}': cannot parse value '{v}' as a number"),
            },
        }
    };

    let mut out = Vec::new();
    if let Some((key, x)) = parse("action_repeat")? {
        ensure!(x >= 1.0 && x.fract() == 0.0, "config key '{key}': expected an integer >= 1, got {x}");
        if x > 1.0 {
            out.push(WrapperSpec::ActionRepeat(x as usize));
        }
    }
    if let Some((key, x)) = parse("time_limit")? {
        ensure!(x >= 1.0 && x.fract() == 0.0, "config key '{key}': expected an integer >= 1, got {x}");
        out.push(WrapperSpec::TimeLimit(x as u64));
    }
    if let Some((_, x)) = parse("scale_reward")? {
        out.push(WrapperSpec::ScaleReward(x as f32));
    }
    if let Some((key, x)) = parse("clip_reward")? {
        ensure!(x > 0.0, "config key '{key}': clip bound must be positive, got {x}");
        out.push(WrapperSpec::ClipReward(x as f32));
    }
    if let Some((key, v)) = get("normalize_obs") {
        let on: bool = v
            .parse()
            .map_err(|_| anyhow::anyhow!("config key '{key}': cannot parse value '{v}' as bool"))?;
        if on {
            out.push(WrapperSpec::NormalizeObs);
        }
    }
    if let Some((key, x)) = parse("stack")? {
        ensure!(x >= 1.0 && x.fract() == 0.0, "config key '{key}': expected an integer >= 1, got {x}");
        if x > 1.0 {
            out.push(WrapperSpec::Stack(x as usize));
        }
    }
    Ok(out)
}

/// Build a [`TrainConfig`] from a flat config (file + overrides merged).
/// Unknown `train.`/`wrap.` keys and malformed values are errors.
pub fn train_config(cfg: &FlatConfig) -> Result<TrainConfig> {
    validate_keys(cfg)?;
    let d = TrainConfig::default();
    let env = cfg.get("train.env").cloned().unwrap_or(d.env);
    Ok(TrainConfig {
        policy: policy_config(cfg, &env)?,
        env,
        total_steps: get_parse(cfg, "train.total_steps", d.total_steps)?,
        lr: get_parse(cfg, "train.lr", d.lr)?,
        ent_coef: get_parse(cfg, "train.ent_coef", d.ent_coef)?,
        epochs: get_parse(cfg, "train.epochs", d.epochs)?,
        minibatches: get_parse(cfg, "train.minibatches", d.minibatches)?,
        norm_adv: get_parse(cfg, "train.norm_adv", d.norm_adv)?,
        anneal_lr: get_parse(cfg, "train.anneal_lr", d.anneal_lr)?,
        seed: get_parse(cfg, "train.seed", d.seed)?,
        num_workers: get_parse(cfg, "train.num_workers", d.num_workers)?,
        pool: get_parse(cfg, "train.pool", d.pool)?,
        pipeline_depth: pipeline_config(cfg)?,
        run_dir: cfg.get("train.run_dir").cloned(),
        log_every: get_parse(cfg, "train.log_every", d.log_every)?,
        kernels: get_parse(cfg, "train.kernels", d.kernels)?,
        wrappers: wrap_config(cfg)?,
        vec: vec_config(cfg)?,
    })
}

/// Load a config file (if given) and apply CLI overrides.
pub fn load(path: Option<&str>, args: &[String]) -> Result<(FlatConfig, Vec<String>)> {
    let mut flat = match path {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("reading config {p}: {e}"))?;
            parse_yaml(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e}"))?
        }
        None => FlatConfig::new(),
    };
    let rest = apply_overrides(&mut flat, args.iter().map(String::as_str));
    Ok((flat, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_win() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.lr".into(), "0.001".into());
        let rest = apply_overrides(
            &mut cfg,
            ["--train.lr=0.01", "--train.pool=true", "positional"].into_iter(),
        );
        assert_eq!(cfg["train.lr"], "0.01");
        assert_eq!(cfg["train.pool"], "true");
        assert_eq!(rest, vec!["positional"]);
    }

    #[test]
    fn train_config_defaults_and_parsing() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.env".into(), "ocean/memory".into());
        cfg.insert("train.total_steps".into(), "50000".into());
        cfg.insert("train.pool".into(), "true".into());
        let tc = train_config(&cfg).unwrap();
        assert_eq!(tc.env, "ocean/memory");
        assert_eq!(tc.total_steps, 50_000);
        assert!(tc.pool);
        assert_eq!(tc.epochs, TrainConfig::default().epochs);
        assert!(tc.wrappers.is_empty());
    }

    #[test]
    fn bad_values_are_rejected_naming_the_key() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.lr".into(), "banana".into());
        let err = train_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("train.lr"), "{err}");
        assert!(err.contains("banana"), "{err}");
    }

    #[test]
    fn unknown_train_keys_are_rejected() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.totl_steps".into(), "1000".into());
        let err = train_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("train.totl_steps"), "{err}");
        // Other namespaces pass through untouched.
        let mut cfg = FlatConfig::new();
        cfg.insert("eval.episodes".into(), "5".into());
        assert!(train_config(&cfg).is_ok());
    }

    #[test]
    fn pipeline_and_minibatch_keys_parse() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.pipeline.depth".into(), "1".into());
        cfg.insert("train.minibatches".into(), "4".into());
        cfg.insert("train.norm_adv".into(), "false".into());
        let tc = train_config(&cfg).unwrap();
        assert_eq!(tc.pipeline_depth, 1);
        assert_eq!(tc.minibatches, 4);
        assert!(!tc.norm_adv);
        // Defaults: serial, full batch, normalization on.
        let d = train_config(&FlatConfig::new()).unwrap();
        assert_eq!(d.pipeline_depth, 0);
        assert_eq!(d.minibatches, 1);
        assert!(d.norm_adv);
    }

    #[test]
    fn pipeline_cli_alias_wins_over_file_key() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.pipeline.depth".into(), "2".into());
        cfg.insert("pipeline.depth".into(), "1".into());
        assert_eq!(pipeline_config(&cfg).unwrap(), 1);
    }

    #[test]
    fn bad_pipeline_keys_are_rejected() {
        let mut cfg = FlatConfig::new();
        cfg.insert("pipeline.depht".into(), "1".into());
        let err = validate_keys(&cfg).unwrap_err().to_string();
        assert!(err.contains("pipeline.depht"), "{err}");
        let mut cfg = FlatConfig::new();
        cfg.insert("pipeline.depth".into(), "-1".into());
        let err = train_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("pipeline.depth"), "{err}");
    }

    #[test]
    fn policy_keys_build_the_spec() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.env".into(), "ocean/bandit".into());
        cfg.insert("policy.hidden".into(), "64".into());
        cfg.insert("policy.lstm".into(), "true".into());
        cfg.insert("policy.embed_dim".into(), "8".into());
        let p = train_config(&cfg).unwrap().policy.expect("spec built");
        assert_eq!(p.hidden, 64);
        assert_eq!(p.state_dim(), 64);
        assert_eq!(p.embed_dim, 8);
        // No policy keys -> None (the trainer resolves the env default).
        assert!(train_config(&FlatConfig::new()).unwrap().policy.is_none());
    }

    #[test]
    fn policy_overrides_start_from_the_env_default() {
        // A hidden override on a recurrent env keeps (and follows) the
        // LSTM stage.
        let mut cfg = FlatConfig::new();
        cfg.insert("train.env".into(), "ocean/memory".into());
        cfg.insert("policy.hidden".into(), "48".into());
        let p = train_config(&cfg).unwrap().policy.unwrap();
        assert_eq!((p.hidden, p.state_dim()), (48, 48));
        // Explicit lstm_hidden pins the state width separately.
        cfg.insert("policy.lstm_hidden".into(), "32".into());
        let p = train_config(&cfg).unwrap().policy.unwrap();
        assert_eq!((p.hidden, p.state_dim()), (48, 32));
        // CLI alias wins over the file key.
        let mut cfg = FlatConfig::new();
        cfg.insert("train.policy.hidden".into(), "32".into());
        cfg.insert("policy.hidden".into(), "96".into());
        assert_eq!(train_config(&cfg).unwrap().policy.unwrap().hidden, 96);
    }

    #[test]
    fn bad_policy_keys_are_rejected_naming_the_key() {
        for (k, v) in [
            ("policy.hidden", "0"),
            ("policy.hidden", "x"),
            ("policy.lstm", "maybe"),
            ("policy.head", "gaussian"),
            ("policy.head", "quantized:1"),
            ("policy.embed_dim", "-3"),
        ] {
            let mut cfg = FlatConfig::new();
            cfg.insert(k.into(), v.into());
            let err = train_config(&cfg).unwrap_err().to_string();
            assert!(err.contains(k), "{k}={v}: {err}");
        }
        let mut cfg = FlatConfig::new();
        cfg.insert("policy.hiden".into(), "64".into());
        let err = validate_keys(&cfg).unwrap_err().to_string();
        assert!(err.contains("policy.hiden"), "{err}");
        // lstm_hidden without an LSTM stage names the missing switch.
        let mut cfg = FlatConfig::new();
        cfg.insert("policy.lstm_hidden".into(), "32".into());
        let err = train_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("policy.lstm"), "{err}");
    }

    #[test]
    fn continuous_heads_fail_with_the_roadmap_pointer() {
        for head in ["gaussian", "continuous", "normal"] {
            let mut cfg = FlatConfig::new();
            cfg.insert("policy.head".into(), head.into());
            let err = train_config(&cfg).unwrap_err().to_string();
            assert!(err.contains("ROADMAP item 4"), "{head}: {err}");
            assert!(err.contains("quantized:<bins>"), "{head}: {err}");
        }
    }

    #[test]
    fn serve_config_defaults_bounds_and_unknown_keys() {
        // No serve keys → None.
        assert_eq!(serve_config(&FlatConfig::new()).unwrap(), None);
        // One key pulls in defaults for the rest.
        let mut cfg = FlatConfig::new();
        cfg.insert("serve.max_batch".into(), "16".into());
        let sc = serve_config(&cfg).unwrap().unwrap();
        assert_eq!(sc.max_batch, 16);
        assert_eq!(sc.port, crate::serve::ServeConfig::default().port);
        assert_eq!(sc.threads, 1);
        // Full section round-trips.
        let mut cfg = FlatConfig::new();
        for (k, v) in [
            ("serve.port", "0"),
            ("serve.max_batch", "8"),
            ("serve.max_wait_us", "250"),
            ("serve.session_ttl_s", "60"),
            ("serve.threads", "2"),
        ] {
            cfg.insert(k.into(), v.into());
        }
        let sc = serve_config(&cfg).unwrap().unwrap();
        assert_eq!(
            sc,
            crate::serve::ServeConfig {
                port: 0,
                max_batch: 8,
                max_wait_us: 250,
                session_ttl_s: 60,
                threads: 2
            }
        );
        // Bounds are named after their key.
        for (k, v) in [
            ("serve.max_batch", "0"),
            ("serve.threads", "0"),
            ("serve.session_ttl_s", "0"),
            ("serve.port", "70000"),
            ("serve.max_wait_us", "-1"),
        ] {
            let mut cfg = FlatConfig::new();
            cfg.insert(k.into(), v.into());
            let err = serve_config(&cfg).unwrap_err().to_string();
            assert!(err.contains(k), "{k}={v}: {err}");
        }
        // Typos are rejected by namespace validation.
        let mut cfg = FlatConfig::new();
        cfg.insert("serve.prot".into(), "7777".into());
        let err = validate_keys(&cfg).unwrap_err().to_string();
        assert!(err.contains("serve.prot"), "{err}");
    }

    #[test]
    fn runs_config_defaults_bounds_and_unknown_keys() {
        // No runs keys → None (callers fall back to defaults).
        assert_eq!(runs_config(&FlatConfig::new()).unwrap(), None);
        // One key pulls in defaults for the rest.
        let mut cfg = FlatConfig::new();
        cfg.insert("runs.heartbeat_s".into(), "2.5".into());
        let rc = runs_config(&cfg).unwrap().unwrap();
        assert_eq!(rc.heartbeat_s, 2.5);
        assert_eq!(rc.root, crate::runs::RunsConfig::default().root);
        // Full section round-trips.
        let mut cfg = FlatConfig::new();
        cfg.insert("runs.root".into(), "exp/registry".into());
        cfg.insert("runs.heartbeat_s".into(), "1".into());
        let rc = runs_config(&cfg).unwrap().unwrap();
        assert_eq!(
            rc,
            crate::runs::RunsConfig {
                root: "exp/registry".to_string(),
                heartbeat_s: 1.0
            }
        );
        // Bounds are named after their key.
        for (k, v) in [
            ("runs.root", "  "),
            ("runs.heartbeat_s", "0"),
            ("runs.heartbeat_s", "-2"),
            ("runs.heartbeat_s", "inf"),
            ("runs.heartbeat_s", "7200"),
        ] {
            let mut cfg = FlatConfig::new();
            cfg.insert(k.into(), v.into());
            let err = runs_config(&cfg).unwrap_err().to_string();
            assert!(err.contains(k), "{k}={v}: {err}");
        }
        // Typos are rejected by namespace validation.
        let mut cfg = FlatConfig::new();
        cfg.insert("runs.heart_beat".into(), "5".into());
        let err = validate_keys(&cfg).unwrap_err().to_string();
        assert!(err.contains("runs.heart_beat"), "{err}");
    }

    #[test]
    fn wrap_keys_build_the_canonical_chain() {
        let mut cfg = FlatConfig::new();
        cfg.insert("wrap.clip_reward".into(), "1.0".into());
        cfg.insert("wrap.stack".into(), "4".into());
        cfg.insert("train.wrap.action_repeat".into(), "2".into());
        let tc = train_config(&cfg).unwrap();
        assert_eq!(
            tc.wrappers,
            vec![
                WrapperSpec::ActionRepeat(2),
                WrapperSpec::ClipReward(1.0),
                WrapperSpec::Stack(4),
            ]
        );
    }

    #[test]
    fn wrap_cli_alias_wins_over_file_key() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.wrap.stack".into(), "2".into());
        cfg.insert("wrap.stack".into(), "8".into());
        let ws = wrap_config(&cfg).unwrap();
        assert_eq!(ws, vec![WrapperSpec::Stack(8)]);
    }

    #[test]
    fn wrap_validation_rejects_bad_knobs() {
        for (k, v) in [
            ("wrap.stack", "0.5"),
            ("wrap.clip_reward", "-1"),
            ("wrap.clip_reward", "1e39"), // finite f64, infinite f32
            ("wrap.time_limit", "0"),
            ("wrap.action_repeat", "x"),
            ("wrap.normalize_obs", "maybe"),
        ] {
            let mut cfg = FlatConfig::new();
            cfg.insert(k.into(), v.into());
            let err = wrap_config(&cfg).unwrap_err().to_string();
            assert!(err.contains(k), "{k}: {err}");
        }
        let mut cfg = FlatConfig::new();
        cfg.insert("wrap.stak".into(), "4".into());
        assert!(validate_keys(&cfg).unwrap_err().to_string().contains("wrap.stak"));
    }

    #[test]
    fn vec_keys_build_the_spec() {
        use crate::vector::{VecBatch, VecSpec};
        // No vec keys → None (legacy num_workers/pool mapping applies).
        assert!(train_config(&FlatConfig::new()).unwrap().vec.is_none());
        let mut cfg = FlatConfig::new();
        cfg.insert("vec.mode".into(), "serial".into());
        assert_eq!(train_config(&cfg).unwrap().vec, Some(VecSpec::Serial));
        let mut cfg = FlatConfig::new();
        cfg.insert("vec.workers".into(), "4".into()); // mode=mt implied
        cfg.insert("vec.batch".into(), "half".into());
        cfg.insert("vec.zero_copy".into(), "true".into());
        match train_config(&cfg).unwrap().vec.unwrap() {
            VecSpec::Mt {
                workers,
                batch,
                zero_copy,
                ..
            } => assert_eq!((workers, batch, zero_copy), (4, VecBatch::Half, true)),
            other => panic!("expected mt, got {other:?}"),
        }
        let mut cfg = FlatConfig::new();
        cfg.insert("vec.mode".into(), "auto".into());
        assert_eq!(train_config(&cfg).unwrap().vec, Some(VecSpec::Auto));
    }

    #[test]
    fn bad_vec_keys_are_rejected_naming_the_key() {
        let mut cfg = FlatConfig::new();
        cfg.insert("vec.wrokers".into(), "4".into());
        let err = validate_keys(&cfg).unwrap_err().to_string();
        assert!(err.contains("vec.wrokers"), "{err}");
        for (k, v) in [
            ("vec.mode", "warp"),
            ("vec.workers", "0"),
            ("vec.batch", "some"),
            ("vec.zero_copy", "maybe"),
        ] {
            let mut cfg = FlatConfig::new();
            cfg.insert(k.into(), v.into());
            let err = train_config(&cfg).unwrap_err().to_string();
            assert!(err.contains(k), "{k}={v}: {err}");
        }
    }

    #[test]
    fn wrap_knob_pairs_invert_wrap_config() {
        let chain = vec![
            WrapperSpec::ActionRepeat(2),
            WrapperSpec::TimeLimit(64),
            WrapperSpec::ScaleReward(0.5),
            WrapperSpec::ClipReward(1.0),
            WrapperSpec::NormalizeObs,
            WrapperSpec::Stack(4),
        ];
        let pairs = wrap_knob_pairs(&chain).unwrap();
        let mut cfg = FlatConfig::new();
        for (k, v) in pairs {
            cfg.insert(format!("wrap.{k}"), v);
        }
        assert_eq!(wrap_config(&cfg).unwrap(), chain);
        // Non-canonical order → actionable error, not silent reorder.
        let twisted = vec![WrapperSpec::Stack(4), WrapperSpec::ClipReward(1.0)];
        let err = wrap_knob_pairs(&twisted).unwrap_err().to_string();
        assert!(err.contains("clip_reward"), "{err}");
        let doubled = vec![WrapperSpec::Stack(2), WrapperSpec::Stack(2)];
        assert!(wrap_knob_pairs(&doubled).is_err());
    }

    #[test]
    fn identity_wrap_values_produce_no_wrappers() {
        let mut cfg = FlatConfig::new();
        cfg.insert("wrap.stack".into(), "1".into());
        cfg.insert("wrap.action_repeat".into(), "1".into());
        cfg.insert("wrap.normalize_obs".into(), "false".into());
        assert!(wrap_config(&cfg).unwrap().is_empty());
    }
}
