//! Minimal TOML-subset parser producing flat dotted keys — the
//! [`RunSpec`](crate::runspec::RunSpec) file format.
//!
//! Supported subset (everything a spec file needs; anything else is a
//! parse error naming the line):
//!
//! - `[section]` / `[section.sub]` table headers,
//! - `key = value` pairs (bare or `"quoted"` keys, which may be dotted),
//! - scalar values: `"strings"`, booleans, numbers, and bare words
//!   (treated as strings, so `mode = mt` works without quotes),
//! - single-line arrays of scalars: `lr = [0.001, 0.0025]` — the
//!   `[grid]` sweep grammar,
//! - `#` comments (full-line or trailing) and blank lines.

use super::FlatConfig;
use std::collections::BTreeMap;
use std::fmt;

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

/// A parsed spec file: scalar keys flattened to dotted paths, plus
/// array-valued keys (each element stringified) kept separate — arrays
/// are only legal where the consumer says so (the `[grid]` section).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TomlDoc {
    pub scalars: FlatConfig,
    pub arrays: BTreeMap<String, Vec<String>>,
}

/// Strip a trailing `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_str = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError {
        line,
        msg: msg.into(),
    }
}

/// Unquote one scalar token.
fn scalar(tok: &str, lineno: usize) -> Result<String, TomlError> {
    let tok = tok.trim();
    if tok.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(body) = tok.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            return Err(err(lineno, format!("unterminated string {tok}")));
        };
        if body.contains('"') {
            return Err(err(lineno, format!("stray quote inside {tok}")));
        }
        return Ok(body.to_string());
    }
    if tok.contains('"') {
        return Err(err(lineno, format!("stray quote in value {tok}")));
    }
    Ok(tok.to_string())
}

/// Validate a (possibly dotted, possibly quoted) key and return its
/// normalized dotted form.
fn key_name(tok: &str, lineno: usize) -> Result<String, TomlError> {
    let tok = tok.trim();
    let body = if let Some(b) = tok.strip_prefix('"') {
        b.strip_suffix('"')
            .ok_or_else(|| err(lineno, format!("unterminated quoted key {tok}")))?
    } else {
        tok
    };
    let ok = !body.is_empty()
        && body.split('.').all(|seg| {
            !seg.is_empty()
                && seg
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '/')
        });
    if !ok {
        return Err(err(lineno, format!("bad key '{tok}'")));
    }
    Ok(body.to_string())
}

/// Split a single-line array body (`a, b, "c"`) on commas outside
/// quotes.
fn split_array(body: &str, lineno: usize) -> Result<Vec<String>, TomlError> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in body.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                items.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        return Err(err(lineno, "unterminated string in array"));
    }
    if !cur.trim().is_empty() {
        items.push(cur);
    }
    if items.iter().any(|i| i.trim().is_empty()) {
        return Err(err(lineno, "empty element in array"));
    }
    items.into_iter().map(|i| scalar(&i, lineno)).collect()
}

/// Parse the TOML subset into a [`TomlDoc`].
pub fn parse_toml(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();

    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let Some(name) = body.strip_suffix(']') else {
                return Err(err(lineno, format!("unterminated section header {line}")));
            };
            section = key_name(name, lineno)?;
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(err(
                lineno,
                format!("expected 'key = value' or '[section]', got '{line}'"),
            ));
        };
        let key = key_name(k, lineno)?;
        let full = if section.is_empty() {
            key
        } else {
            format!("{section}.{key}")
        };
        if doc.scalars.contains_key(&full) || doc.arrays.contains_key(&full) {
            return Err(err(lineno, format!("duplicate key '{full}'")));
        }
        let v = v.trim();
        if let Some(arr_body) = v.strip_prefix('[') {
            let Some(arr_body) = arr_body.strip_suffix(']') else {
                return Err(err(lineno, format!("unterminated array for key '{full}'")));
            };
            let items = split_array(arr_body, lineno)?;
            if items.is_empty() {
                return Err(err(lineno, format!("empty array for key '{full}'")));
            }
            doc.arrays.insert(full, items);
        } else {
            doc.scalars.insert(full, scalar(v, lineno)?);
        }
    }
    Ok(doc)
}

/// Quote a value for canonical TOML output: numbers and booleans stay
/// bare, everything else is double-quoted.
pub fn toml_value(v: &str) -> String {
    let bare = v.parse::<f64>().is_ok() || v == "true" || v == "false";
    if bare {
        v.to_string()
    } else {
        format!("\"{v}\"")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_scalars_flatten() {
        let text = r#"
# a run spec
seed = 42

[env]
name = "ocean/memory"   # quoted strings
[env.wrap]
stack = 4

[vec]
mode = mt               # bare words are strings
zero_copy = true

[train.pipeline]
depth = 1
"#;
        let doc = parse_toml(text).unwrap();
        assert_eq!(doc.scalars["seed"], "42");
        assert_eq!(doc.scalars["env.name"], "ocean/memory");
        assert_eq!(doc.scalars["env.wrap.stack"], "4");
        assert_eq!(doc.scalars["vec.mode"], "mt");
        assert_eq!(doc.scalars["vec.zero_copy"], "true");
        assert_eq!(doc.scalars["train.pipeline.depth"], "1");
        assert!(doc.arrays.is_empty());
    }

    #[test]
    fn arrays_parse_for_the_grid_section() {
        let text = r#"
[grid]
"train.lr" = [0.001, 0.0025]
train.total_steps = [1000, 2000]
names = ["a", "b,c"]
"#;
        let doc = parse_toml(text).unwrap();
        assert_eq!(doc.arrays["grid.train.lr"], vec!["0.001", "0.0025"]);
        assert_eq!(doc.arrays["grid.train.total_steps"], vec!["1000", "2000"]);
        assert_eq!(doc.arrays["grid.names"], vec!["a", "b,c"]);
    }

    #[test]
    fn errors_carry_line_numbers() {
        for (text, needle) in [
            ("[sec\nk = 1", "unterminated section"),
            ("just a line", "expected 'key = value'"),
            ("k = ", "empty value"),
            ("k = \"unterminated", "unterminated string"),
            ("a = 1\na = 2", "duplicate key"),
            ("k = [1, 2", "unterminated array"),
            ("k = []", "empty array"),
            ("bad key = 1", "bad key"),
        ] {
            let e = parse_toml(text).unwrap_err();
            assert!(e.msg.contains(needle), "{text}: {e}");
        }
        assert_eq!(parse_toml("ok = 1\n???\n").unwrap_err().line, 2);
    }

    #[test]
    fn comments_respect_strings() {
        let doc = parse_toml("k = \"a # not a comment\" # real comment\n").unwrap();
        assert_eq!(doc.scalars["k"], "a # not a comment");
    }

    #[test]
    fn toml_value_quotes_only_strings() {
        assert_eq!(toml_value("42"), "42");
        assert_eq!(toml_value("0.0025"), "0.0025");
        assert_eq!(toml_value("true"), "true");
        assert_eq!(toml_value("ocean/memory"), "\"ocean/memory\"");
        assert_eq!(toml_value("half"), "\"half\"");
    }
}
