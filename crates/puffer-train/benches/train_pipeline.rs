//! **Bench P2** — pipelined vs serial trainer throughput: the end-to-end
//! payoff of overlapping rollout collection with minibatched PPO
//! learning. Two workloads:
//!
//! - `ocean/squared` — near-free env stepping: learner-bound, so the
//!   pipeline mostly exposes the learner ceiling (watch the collector
//!   stall counter).
//! - `profile/atari` — a Table 1-calibrated simulator with real step and
//!   reset cost, driven through a pooled (`M = 2N`) VecConfig: the case
//!   the pipeline is built for. Acceptance: `pipelined_sps >=
//!   1.3 × serial_sps`.
//!
//! Each env also runs the pipelined cell under `kernels = "scalar"` —
//! the pre-kernel learner math — so the JSON carries the learner-SPS
//! speedup the simd kernel path buys end-to-end, not just in isolation.
//!
//! `PUFFER_BENCH_TRAIN_STEPS` env-steps per cell (default 16384).
//! `PUFFER_BENCH_JSON` write machine-readable results to this path
//! (`make bench` sets it to `BENCH_train.json`).

use pufferlib::backend::KernelPath;
use pufferlib::train::{TrainConfig, TrainReport, Trainer};
use pufferlib::util::json::{arr, num, obj, s, Json};

struct Cell {
    env: &'static str,
    serial: TrainReport,
    pipelined: TrainReport,
    pipelined_scalar: TrainReport,
}

fn run(
    env: &str,
    total_steps: u64,
    pipeline_depth: usize,
    kernels: KernelPath,
) -> anyhow::Result<TrainReport> {
    let cfg = TrainConfig {
        env: env.to_string(),
        total_steps,
        // Pooled vectorizer: recv half the envs per batch (M = 2N), the
        // paper's EnvPool double-buffering, under both trainer paths so
        // the comparison isolates the pipeline itself.
        pool: true,
        num_workers: 2,
        // A learner heavy enough to be worth hiding, split into row
        // minibatches; identical under serial so the math matches.
        epochs: 2,
        minibatches: 2,
        pipeline_depth,
        log_every: 0,
        kernels,
        ..Default::default()
    };
    Trainer::native(cfg)?.train()
}

fn report_json(r: &TrainReport) -> Json {
    obj(vec![
        ("sps", num(r.sps)),
        ("env_sps", num(r.env_sps)),
        ("learn_sps", num(r.learn_sps)),
        ("collector_stall_s", num(r.collector_stall_s)),
        ("learner_stall_s", num(r.learner_stall_s)),
        ("max_param_staleness", num(r.max_param_staleness as f64)),
    ])
}

fn main() {
    let total_steps: u64 = std::env::var("PUFFER_BENCH_TRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16_384);
    let json_path = std::env::var("PUFFER_BENCH_JSON").ok();

    println!(
        "# Bench P2 — pipelined vs serial trainer (env-steps/sec, {total_steps} steps/cell)"
    );
    println!(
        "| {:<16} | {:>10} | {:>12} | {:>7} | {:>9} | {:>9} | {:>8} |",
        "Environment", "serial", "pipelined", "speedup", "env SPS", "learn SPS", "stall s"
    );
    println!(
        "|{}|{}|{}|{}|{}|{}|{}|",
        "-".repeat(18),
        "-".repeat(12),
        "-".repeat(14),
        "-".repeat(9),
        "-".repeat(11),
        "-".repeat(11),
        "-".repeat(10)
    );

    let mut cells = Vec::new();
    for env in ["ocean/squared", "profile/atari"] {
        let serial = match run(env, total_steps, 0, KernelPath::Simd) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{env} serial failed: {e}");
                continue;
            }
        };
        let pipelined = match run(env, total_steps, 1, KernelPath::Simd) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{env} pipelined failed: {e}");
                continue;
            }
        };
        let pipelined_scalar = match run(env, total_steps, 1, KernelPath::Scalar) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{env} pipelined (scalar kernels) failed: {e}");
                continue;
            }
        };
        println!(
            "| {:<16} | {:>10.0} | {:>12.0} | {:>6.2}x | {:>9.0} | {:>9.0} | {:>8.2} |",
            env,
            serial.sps,
            pipelined.sps,
            pipelined.sps / serial.sps,
            pipelined.env_sps,
            pipelined.learn_sps,
            pipelined.collector_stall_s + pipelined.learner_stall_s,
        );
        println!(
            "|   kernels=scalar |            | {:>12.0} | {:>6.2}x |           | {:>9.0} |          |",
            pipelined_scalar.sps,
            pipelined.sps / pipelined_scalar.sps,
            pipelined_scalar.learn_sps,
        );
        cells.push(Cell {
            env,
            serial,
            pipelined,
            pipelined_scalar,
        });
    }

    println!("\n# acceptance: profile/atari (pooled VecConfig) pipelined >= 1.3x serial,");
    println!("# and pipelined learn SPS (simd) > pipelined learn SPS (scalar kernels);");
    println!("# ocean/squared is learner-bound — expect ~1x with a large collector stall.");

    if let Some(path) = json_path {
        let cells_json = cells
            .iter()
            .map(|c| {
                obj(vec![
                    ("env", s(c.env)),
                    ("serial_sps", num(c.serial.sps)),
                    ("pipelined_sps", num(c.pipelined.sps)),
                    ("speedup", num(c.pipelined.sps / c.serial.sps)),
                    (
                        "kernel_learn_speedup",
                        num(c.pipelined.learn_sps / c.pipelined_scalar.learn_sps),
                    ),
                    ("serial", report_json(&c.serial)),
                    ("pipelined", report_json(&c.pipelined)),
                    ("pipelined_scalar_kernels", report_json(&c.pipelined_scalar)),
                ])
            })
            .collect();
        let out = obj(vec![
            ("bench", s("train_pipeline")),
            // Distinguishes a real run from the checked-in
            // "static-estimate" placeholder this file replaces.
            ("method", s("measured")),
            ("total_steps", num(total_steps as f64)),
            (
                "config",
                s("pool=true workers=2 epochs=2 minibatches=2 depth=1 kernels=simd|scalar"),
            ),
            ("cells", arr(cells_json)),
        ]);
        match std::fs::write(&path, out.dump()) {
            Ok(()) => println!("\n# wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}
