//! **Bench C3** — the paper's §4 claim: Clean PuffeRL solves each Ocean
//! env (score > 0.9) in roughly 30k interactions with one set of barely
//! tuned hyperparameters.
//!
//! Default: the three fastest-training envs (keeps `cargo bench` short).
//! `PUFFER_BENCH_FULL=1` runs the whole suite with per-env budgets — see
//! also `examples/train_ocean.rs`, the end-to-end driver.

use pufferlib::train::{TrainConfig, Trainer};

fn main() {
    let full = std::env::var("PUFFER_BENCH_FULL").is_ok();
    let quick = ["ocean/bandit", "ocean/password", "ocean/stochastic"];
    let all = [
        ("ocean/bandit", 30_000u64),
        ("ocean/password", 30_000),
        ("ocean/stochastic", 30_000),
        ("ocean/multiagent", 30_000),
        ("ocean/squared", 150_000),
        ("ocean/spaces", 150_000),
        ("ocean/memory", 120_000),
    ];

    println!("# Bench C3 — Ocean solve sweep (paper §4: score > 0.9 in ~30k)");
    println!(
        "| {:<18} | {:>8} | {:>7} | {:>9} | {:>8} |",
        "env", "budget", "score", "solved@", "SPS"
    );
    println!(
        "|{}|{}|{}|{}|{}|",
        "-".repeat(20),
        "-".repeat(10),
        "-".repeat(9),
        "-".repeat(11),
        "-".repeat(10)
    );

    let mut solved = 0;
    let mut total = 0;
    for (env, budget) in all {
        if !full && !quick.contains(&env) {
            continue;
        }
        total += 1;
        let cfg = TrainConfig {
            env: env.to_string(),
            total_steps: budget,
            log_every: 0,
            ..Default::default()
        };
        match Trainer::native(cfg).and_then(|mut t| t.train()) {
            Ok(report) => {
                let score = report.mean_score.unwrap_or(0.0);
                if score > 0.9 {
                    solved += 1;
                }
                let solved_at = report
                    .score_curve
                    .iter()
                    .find(|(_, s)| *s > 0.9)
                    .map(|(s, _)| s.to_string())
                    .unwrap_or_else(|| "-".into());
                println!(
                    "| {:<18} | {:>8} | {:>7.3} | {:>9} | {:>8.0} |",
                    env, budget, score, solved_at, report.sps
                );
            }
            Err(e) => println!("| {:<18} | {:>8} | error: {e} |", env, budget),
        }
    }
    println!("\n{solved}/{total} solved (score > 0.9)");
    if !full {
        println!("(set PUFFER_BENCH_FULL=1 for the whole suite)");
    }
}
