//! **Bench T2 + C4 + W1** — reproduces the paper's Table 2: vectorized
//! throughput of PufferLib (sync), Puffer Pool (EnvPool), and the
//! Gymnasium / SB3 baseline designs, across the profiled environments —
//! plus the wrapper-overhead cell (W1): an obs-stacking chain over
//! `profile/atari` through the ZeroCopy path versus the unwrapped
//! baseline, which must stay within a few percent because wrappers
//! operate in place on the shared slabs.
//!
//! One host column (the paper had desktop + laptop); the quantity that
//! must reproduce is the *ordering and ratios* between implementations,
//! not absolute SPS — see EXPERIMENTS.md.
//!
//! `cargo bench --bench vectorization [-- env-substring]`
//! `PUFFER_BENCH_SECS` per-cell budget (default 2.0).
//! `PUFFER_BENCH_JSON` write machine-readable results to this path
//! (`make bench` sets it to `BENCH_vector.json`).

use pufferlib::emulation::FlatEnv;
use pufferlib::envs;
use pufferlib::util::json::{arr, num, obj, s, Json};
use pufferlib::vector::autotune::measure;
use pufferlib::vector::baselines::{GymnasiumVec, Sb3Vec};
use pufferlib::vector::{Mode, Multiprocessing, VecConfig};
use pufferlib::wrappers::EnvSpec;
use std::sync::Arc;

type Factory = Arc<dyn Fn(usize) -> Box<dyn FlatEnv> + Send + Sync>;

/// (display name, factory, num_envs, workers). Slow sims are time-scaled
/// (relative comparisons unaffected; DESIGN.md §Substitutions).
fn workloads() -> Vec<(&'static str, Factory, usize, usize)> {
    fn scaled(name: &'static str, scale: f64) -> Factory {
        Arc::new(move |i| envs::profile::make_profile_scaled(name, i as u64, scale))
    }
    fn plain(name: &'static str) -> Factory {
        Arc::new(move |i| envs::make(name, i as u64))
    }
    vec![
        ("Neural MMO", scaled("nmmo", 0.1), 4, 4),
        ("Nethack", scaled("nethack", 1.0), 8, 4),
        ("Minihack", scaled("minihack", 1.0), 8, 4),
        ("Pokemon Red", scaled("pokemon", 0.1), 8, 4),
        ("Cartpole", plain("classic/cartpole"), 8, 4),
        ("Ocean Squared", plain("ocean/squared"), 8, 4),
        ("Procgen Bigfish", scaled("procgen", 1.0), 8, 4),
        ("Atari Breakout", scaled("atari", 0.25), 8, 4),
        ("Crafter", scaled("crafter", 0.05), 8, 4),
        ("Minigrid", scaled("minigrid", 1.0), 8, 4),
    ]
}

fn cell(factory: &Factory, backend: &str, num_envs: usize, workers: usize, secs: f64) -> Option<f64> {
    let f = factory.clone();
    let mk = move |i: usize| f(i);
    let sync_cfg = VecConfig {
        num_envs,
        num_workers: workers,
        batch_size: num_envs,
        ..Default::default()
    };
    let pool_cfg = VecConfig {
        num_envs,
        num_workers: workers,
        batch_size: num_envs / 2,
        ..Default::default()
    };
    let res = match backend {
        "puffer" => Multiprocessing::from_factory(mk, sync_cfg).ok().map(|v| measure(v, secs)),
        "pool" => {
            if pool_cfg.mode().is_err() {
                return None;
            }
            Multiprocessing::from_factory(mk, pool_cfg).ok().map(|v| measure(v, secs))
        }
        "gymnasium" => GymnasiumVec::new(mk, sync_cfg).ok().map(|v| measure(v, secs)),
        "sb3" => Sb3Vec::new(mk, sync_cfg).ok().map(|v| measure(v, secs)),
        _ => unreachable!(),
    };
    res.and_then(|r| r.ok())
}

/// W1: obs-stacking wrapper chain over profile/atari on the ZeroCopy
/// path vs the unwrapped baseline. Returns (unwrapped, wrapped) SPS.
fn wrapper_overhead(secs: f64) -> Option<(f64, f64)> {
    let base = || EnvSpec::custom("profile/atari", |i| envs::profile::make_profile_scaled("atari", i as u64, 0.25));
    let cfg = VecConfig {
        num_envs: 8,
        num_workers: 4,
        batch_size: 4,
        zero_copy: true,
        ..Default::default()
    };
    let plain = Multiprocessing::from_spec(&base(), cfg.clone()).ok()?;
    assert_eq!(plain.mode(), Mode::ZeroCopy);
    let plain_sps = measure(plain, secs).ok()?;
    let wrapped_spec = base().clip_reward(1.0).stack(4);
    let wrapped = Multiprocessing::from_spec(&wrapped_spec, cfg).ok()?;
    assert_eq!(wrapped.mode(), Mode::ZeroCopy);
    let wrapped_sps = measure(wrapped, secs).ok()?;
    Some((plain_sps, wrapped_sps))
}

fn main() {
    let secs: f64 = std::env::var("PUFFER_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);
    let json_path = std::env::var("PUFFER_BENCH_JSON").ok();
    let filter: Option<String> = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .map(|s| s.to_lowercase());

    println!("# Bench T2 — vectorized throughput (env-steps/sec), one host");
    println!("# paper Table 2; time-scaled sims marked (×s) in EXPERIMENTS.md");
    println!(
        "| {:<16} | {:>10} | {:>11} | {:>10} | {:>10} | {:>5} |",
        "Environment", "PufferLib", "Puffer Pool", "Gymnasium", "SB3", "best"
    );
    println!(
        "|{}|{}|{}|{}|{}|{}|",
        "-".repeat(18),
        "-".repeat(12),
        "-".repeat(13),
        "-".repeat(12),
        "-".repeat(12),
        "-".repeat(7)
    );

    let mut rows = Vec::new();
    for (name, factory, num_envs, workers) in workloads() {
        if let Some(f) = &filter {
            if !name.to_lowercase().contains(f.as_str()) {
                continue;
            }
        }
        let puffer = cell(&factory, "puffer", num_envs, workers, secs);
        let pool = cell(&factory, "pool", num_envs, workers, secs);
        let gym = cell(&factory, "gymnasium", num_envs, workers, secs);
        let sb3 = cell(&factory, "sb3", num_envs, workers, secs);
        let fmt = |x: Option<f64>| x.map(|v| format!("{v:.0}")).unwrap_or_else(|| "-".into());
        let best = [("puffer", puffer), ("pool", pool), ("gym", gym), ("sb3", sb3)]
            .into_iter()
            .filter_map(|(n, v)| v.map(|v| (n, v)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(n, _)| n)
            .unwrap_or("-");
        println!(
            "| {:<16} | {:>10} | {:>11} | {:>10} | {:>10} | {:>5} |",
            name,
            fmt(puffer),
            fmt(pool),
            fmt(gym),
            fmt(sb3),
            best
        );
        rows.push((name, puffer, pool, gym, sb3));
    }

    println!("\n# C4 note: pokemon row ≈ the paper's §7 Pokémon Red training workload;");
    println!("# compare Puffer Pool vs SB3 columns for the claimed 2-3x.");

    // W1: wrapper overhead on the zero-copy path.
    let overhead = if filter.is_none() { wrapper_overhead(secs) } else { None };
    if let Some((plain_sps, wrapped_sps)) = overhead {
        let pct = (1.0 - wrapped_sps / plain_sps) * 100.0;
        println!("\n# W1 — wrapper chain (clip_reward=1 + stack=4) over profile/atari, ZeroCopy path");
        println!("unwrapped: {plain_sps:.0} SPS   wrapped: {wrapped_sps:.0} SPS   overhead: {pct:.1}%");
        println!("# acceptance: overhead < 5% (wrappers run in place on the shared slabs)");
    }

    if let Some(path) = json_path {
        let opt = |x: Option<f64>| x.map(num).unwrap_or(Json::Null);
        let table2 = rows
            .into_iter()
            .map(|(name, puffer, pool, gym, sb3)| {
                obj(vec![
                    ("env", s(name)),
                    ("puffer", opt(puffer)),
                    ("pool", opt(pool)),
                    ("gymnasium", opt(gym)),
                    ("sb3", opt(sb3)),
                ])
            })
            .collect();
        let w1 = match overhead {
            Some((plain_sps, wrapped_sps)) => obj(vec![
                ("env", s("profile/atari")),
                ("mode", s("ZeroCopy")),
                ("chain", s("clip_reward=1+stack=4")),
                ("unwrapped_sps", num(plain_sps)),
                ("wrapped_sps", num(wrapped_sps)),
                ("overhead_pct", num((1.0 - wrapped_sps / plain_sps) * 100.0)),
            ]),
            None => Json::Null,
        };
        let out = obj(vec![
            ("bench", s("vectorization")),
            ("method", s("measured")),
            ("secs_per_cell", num(secs)),
            ("table2", arr(table2)),
            ("wrapper_overhead", w1),
        ]);
        match std::fs::write(&path, out.dump()) {
            Ok(()) => println!("\n# wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}
