//! **Bench S1** — inference-serving latency and batch occupancy: the
//! `puffer serve` dynamic batcher (dual budget: `max_batch` rows or
//! `max_wait_us`) under the built-in load generator, with pipelined
//! clients, per-session LSTM state, and a mid-run weight hot-swap.
//!
//! `cargo bench --bench serve_latency`; `PUFFER_BENCH_REQUESTS` scales
//! the run (default 10k requests over 64 sessions, the selftest
//! acceptance shape). `PUFFER_BENCH_JSON=BENCH_serve.json` writes the
//! machine-readable report.

use pufferlib::policy::PolicySpec;
use pufferlib::runspec::RunSpec;
use pufferlib::serve::selftest::{self, SelftestConfig};
use pufferlib::serve::ServeConfig;
use pufferlib::vector::VecSpec;
use pufferlib::wrappers::EnvSpec;

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::var("PUFFER_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);

    let dir = std::env::temp_dir().join("puffer_serve_bench");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("ckpt.bin").to_string_lossy().into_owned();
    // Recurrent policy: the expensive serving shape (per-session h/c
    // gather/scatter plus the unique-session batch split).
    let spec = RunSpec::new(EnvSpec::new("ocean/bandit"))
        .with_vec(VecSpec::Serial)
        .with_policy(PolicySpec::default().with_hidden(64).with_lstm(32))
        .with_seed(1);
    selftest::write_synthetic_checkpoint(&spec, &path)?;

    let cfg = ServeConfig {
        port: 0,
        ..Default::default()
    };
    let st = SelftestConfig {
        requests,
        ..Default::default()
    };
    let report = selftest::run(&path, &cfg, &st)?;

    println!("# Bench S1 — serve latency (dynamic batcher, LSTM policy)");
    println!(
        "{} requests, {} sessions, {} clients x window {}, hot-swap {}",
        st.requests, st.sessions, st.clients, st.window, st.hot_swap
    );
    println!("| {:<18} | {:>12} |", "metric", "value");
    println!("|{}|{}|", "-".repeat(20), "-".repeat(14));
    let rps = report.requests as f64 / (report.elapsed_ms.max(1) as f64 / 1000.0);
    for (metric, value) in [
        ("requests/s", format!("{rps:.0}")),
        ("p50 latency (us)", report.p50_us.to_string()),
        ("p99 latency (us)", report.p99_us.to_string()),
        ("occupancy (rows)", format!("{:.2}", report.occupancy)),
        ("max batch (rows)", report.max_batch.to_string()),
        ("batches", report.batches.to_string()),
        ("multi-row batches", report.multi_row_batches.to_string()),
        ("sessions", report.sessions.to_string()),
        ("evicted", report.evicted.to_string()),
        ("dropped", report.dropped.to_string()),
        ("weight version", report.max_version.to_string()),
    ] {
        println!("| {:<18} | {:>12} |", metric, value);
    }

    anyhow::ensure!(report.dropped == 0, "bench dropped {} requests", report.dropped);
    if let Some(p) = selftest::maybe_write_bench_json(&report)? {
        println!("wrote {p}");
    }
    Ok(())
}
