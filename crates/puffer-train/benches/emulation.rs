//! **Bench T1 + F1** — reproduces the paper's Table 1 (single-core
//! throughput and emulation overhead) and the Figure 1 claim that
//! emulation overhead is negligible below a few thousand steps/sec/core.
//!
//! For every profiled environment it measures:
//! - SPS: single-core steps/sec through the emulation wrapper;
//! - % Reset: share of total env time spent in `reset`;
//! - % Step STD: coefficient of variation of per-step time;
//! - % Overhead: (wrapped − raw) / raw step time — the emulation cost.
//!
//! `cargo bench --bench emulation` (add `-- --sweep` for the F1 curve).
//! `PUFFER_BENCH_SECS` scales the per-env budget (default 1.0).

use pufferlib::emulation::{FlatEnv, PufferEnv, StructuredEnv};
use pufferlib::envs::profile::{self, ProfileConfig, ProfileSim};
use pufferlib::envs::{classic, ocean};
use pufferlib::spaces::Value;
use pufferlib::util::rng::Rng;
use pufferlib::util::stats::Welford;
use std::time::Instant;

struct Meas {
    sps: f64,
    pct_reset: f64,
    step_cv_pct: f64,
}

/// Step a raw structured env (no emulation), sampling random actions.
fn measure_raw<E: StructuredEnv>(mut env: E, budget_secs: f64) -> Meas {
    let aspace = env.action_space();
    let mut rng = Rng::new(7);
    // Pre-sample a pool of actions so sampling isn't measured.
    let actions: Vec<Value> = (0..64).map(|_| aspace.sample(&mut rng)).collect();
    let mut step_t = Welford::new();
    let mut reset_time = 0.0;
    let t0 = Instant::now();
    let mut ep = 0u64;
    'outer: loop {
        let r0 = Instant::now();
        env.reset(ep);
        reset_time += r0.elapsed().as_secs_f64();
        ep += 1;
        loop {
            let s0 = Instant::now();
            let (_, _, term, trunc, _) = env.step(&actions[(step_t.count() % 64) as usize]);
            step_t.push(s0.elapsed().as_secs_f64() * 1e6);
            if t0.elapsed().as_secs_f64() > budget_secs && ep > 1 {
                break 'outer;
            }
            if term || trunc {
                break;
            }
        }
    }
    let total = t0.elapsed().as_secs_f64();
    Meas {
        sps: step_t.count() as f64 / total,
        pct_reset: 100.0 * reset_time / total,
        step_cv_pct: 100.0 * step_t.cv(),
    }
}

/// Step a wrapped env through the FlatEnv interface. Auto-reset folds
/// reset cost into the triggering step, so SPS here is the end-to-end
/// number a vectorizer sees (Table 1's "SPS is timed with emulation").
fn measure_flat(mut env: Box<dyn FlatEnv>, budget_secs: f64) -> Meas {
    let rows = env.num_agents();
    let w = env.obs_layout().byte_len();
    let slots = env.action_dims().len();
    let dims = env.action_dims().to_vec();
    let mut rng = Rng::new(7);
    let mut obs = vec![0u8; rows * w];
    let mut rewards = vec![0.0; rows];
    let mut terms = vec![false; rows];
    let mut truncs = vec![false; rows];
    let mut actions = vec![0i32; rows * slots];
    env.reset(0, &mut obs);
    let mut step_t = Welford::new();
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < budget_secs {
        for (i, a) in actions.iter_mut().enumerate() {
            *a = rng.below(dims[i % slots] as u64) as i32;
        }
        let s0 = Instant::now();
        env.step(&actions, &mut obs, &mut rewards, &mut terms, &mut truncs);
        step_t.push(s0.elapsed().as_secs_f64() * 1e6);
    }
    Meas {
        sps: step_t.count() as f64 * rows as f64 / t0.elapsed().as_secs_f64(),
        pct_reset: 0.0,
        step_cv_pct: 100.0 * step_t.cv(),
    }
}

fn profile_pair(name: &str, budget: f64) -> (Meas, Meas) {
    let raw = measure_raw(ProfileSim::new(profile::config(name), 1), budget);
    let flat = measure_flat(
        Box::new(PufferEnv::new(ProfileSim::new(profile::config(name), 1))),
        budget,
    );
    (raw, flat)
}

fn main() {
    let budget: f64 = std::env::var("PUFFER_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let sweep = std::env::args().any(|a| a == "--sweep");

    println!("# Bench T1 — single-core throughput and emulation overhead");
    println!("# (paper Table 1; this host: 1 core, see EXPERIMENTS.md)");
    println!(
        "| {:<16} | {:>9} | {:>7} | {:>10} | {:>10} |",
        "Environment", "SPS", "% Reset", "% Step STD", "% Overhead"
    );
    println!(
        "|{}|{}|{}|{}|{}|",
        "-".repeat(18),
        "-".repeat(11),
        "-".repeat(9),
        "-".repeat(12),
        "-".repeat(12)
    );

    let report = |name: &str, raw: &Meas, flat: &Meas| {
        let overhead = 100.0 * (raw.sps / flat.sps - 1.0);
        println!(
            "| {:<16} | {:>9.0} | {:>7.2} | {:>10.1} | {:>10.2} |",
            name,
            flat.sps,
            raw.pct_reset,
            raw.step_cv_pct,
            overhead.max(0.0)
        );
    };

    // Real envs.
    {
        let raw = measure_raw(classic::CartPole::new(200), budget);
        let flat = measure_flat(Box::new(PufferEnv::new(classic::CartPole::new(200))), budget);
        report("Cartpole", &raw, &flat);
    }
    {
        let raw = measure_raw(ocean::Squared::new(11, 1), budget);
        let flat = measure_flat(Box::new(PufferEnv::new(ocean::Squared::new(11, 1))), budget);
        report("Ocean Squared", &raw, &flat);
    }
    {
        let raw = measure_raw(classic::Breakout::new(), budget);
        let flat = measure_flat(Box::new(PufferEnv::new(classic::Breakout::new())), budget);
        report("Breakout (real)", &raw, &flat);
    }

    // Profile sims calibrated to Table 1 (see DESIGN.md §Substitutions).
    for name in ["nethack", "minihack", "pokemon", "procgen", "atari", "minigrid"] {
        let (raw, flat) = profile_pair(name, budget);
        report(&format!("{name}-sim"), &raw, &flat);
    }
    // Crafter needs several episodes to sample its 1.25s resets.
    {
        let (raw, flat) = profile_pair("crafter", (budget * 6.0).max(5.0));
        report("crafter-sim", &raw, &flat);
    }
    // Neural MMO (multiagent): wrapped only; the raw loop is structurally
    // different (per-agent dict routing), which is exactly what emulation
    // abstracts away.
    {
        let flat = measure_flat(profile::make_profile("nmmo", 1), (budget * 2.0).max(2.0));
        println!(
            "| {:<16} | {:>9.0} | {:>7} | {:>10.1} | {:>10} |",
            "nmmo-sim (agent)", flat.sps, "68*", flat.step_cv_pct, "n/a"
        );
        println!("#   * nmmo %reset by calibration; raw multiagent loop not comparable");
    }

    if sweep {
        println!("\n# Bench F1 — emulation overhead vs raw env speed");
        println!("# (Figure 1 claim: negligible below several thousand SPS/core)");
        println!(
            "| {:>12} | {:>12} | {:>10} |",
            "raw SPS", "wrapped SPS", "% overhead"
        );
        println!("|{}|{}|{}|", "-".repeat(14), "-".repeat(14), "-".repeat(12));
        for step_us in [1.0, 3.16, 10.0, 31.6, 100.0, 316.0, 1000.0] {
            let mk = || ProfileSim::new(ProfileConfig::synthetic(step_us, 0.0, 0.0, 64), 1);
            let raw = measure_raw(mk(), budget * 0.5);
            let flat = measure_flat(Box::new(PufferEnv::new(mk())), budget * 0.5);
            println!(
                "| {:>12.0} | {:>12.0} | {:>10.2} |",
                raw.sps,
                flat.sps,
                (100.0 * (raw.sps / flat.sps - 1.0)).max(0.0)
            );
        }
    }
}
