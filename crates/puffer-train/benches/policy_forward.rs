//! **Bench P3** — policy forward/backward throughput per architecture:
//! what a [`PolicySpec`] costs. Three cells over synthetic data at the
//! shared rollout geometry (T=32, R=32):
//!
//! - `flat-mlp` — the default architecture (raw 64-f32 row → 128-wide
//!   trunk → heads): the baseline every env gets for free.
//! - `embed-tokens` — 8 token slots over a 128-entry vocabulary embedded
//!   at width 8 plus 16 raw features: the NetHack-style symbolic path.
//! - `lstm` — the recurrent sandwich (state 128) with full BPTT in the
//!   backward cell: what `ocean/memory`-class envs now pay natively.
//!
//! Every architecture runs twice — once per [`KernelPath`] — so each
//! row is a scalar-vs-simd cell: rollout-forward rows/s (batch 32),
//! train-step samples/s (one full PPO update over the T×R segment), and
//! the simd-over-scalar speedup for both.
//! `PUFFER_BENCH_POLICY_ITERS` scales iteration counts;
//! `PUFFER_BENCH_JSON` writes machine-readable results (`make bench`
//! sets it to `BENCH_policy.json`); `PUFFER_KERNEL_THREADS` caps the
//! simd path's fork-join width.

use pufferlib::backend::{AdamState, KernelPath, NativeBackend, PolicyBackend, TrainBatch};
use pufferlib::policy::{PolicySpec, ResolvedPolicy};
use pufferlib::runtime::SpecManifest;
use pufferlib::spaces::Space;
use pufferlib::util::json::{arr, num, obj, s, Json};
use pufferlib::util::timer::Timer;
use std::collections::BTreeMap;

const T: usize = 32;
const R: usize = 32;
const ACT: usize = 4;

/// One (architecture, kernel path) measurement.
struct Run {
    fwd_rows_per_s: f64,
    train_samples_per_s: f64,
    n_params: usize,
}

/// One table row: the same architecture under both kernel paths.
struct Cell {
    label: &'static str,
    scalar: Run,
    simd: Run,
}

impl Cell {
    fn fwd_speedup(&self) -> f64 {
        self.simd.fwd_rows_per_s / self.scalar.fwd_rows_per_s
    }
    fn train_speedup(&self) -> f64 {
        self.simd.train_samples_per_s / self.scalar.train_samples_per_s
    }
}

fn manifest_for(arch: &ResolvedPolicy) -> SpecManifest {
    SpecManifest {
        obs_dim: arch.obs_dim,
        n_params: arch.n_params(),
        act_dims: arch.act_dims.clone(),
        agents: 1,
        lstm: arch.is_recurrent(),
        hidden: arch.hidden(),
        policy: arch.effective_spec(),
        batch_fwd: R,
        batch_roll: R,
        horizon: T,
        gamma: 0.99,
        lam: 0.95,
        params0: String::new(),
        artifacts: BTreeMap::new(),
    }
}

fn bench_arch(label: &str, arch: ResolvedPolicy, iters: usize, path: KernelPath) -> Run {
    let spec = manifest_for(&arch);
    let d = arch.obs_dim;
    let lstm = arch.is_recurrent();
    let sd = arch.state_dim();
    let n_params = arch.n_params();
    let mut b = NativeBackend::from_arch(label.to_string(), spec, arch, 1).unwrap();
    b.set_kernel_path(path);
    let params = b.init_params().unwrap();

    // Deterministic pseudo-random inputs; token slots get small values
    // that stay in-vocabulary after rounding.
    let obs: Vec<f32> = (0..T * R * d)
        .map(|i| ((i * 37 % 97) as f32 / 97.0) * 3.0)
        .collect();

    // Rollout-forward throughput at the pool batch width.
    let t0 = Timer::start();
    let (h0, c0) = (vec![0.0f32; R * sd], vec![0.0f32; R * sd]);
    for i in 0..iters {
        let rows = &obs[(i % T) * R * d..((i % T) + 1) * R * d];
        if lstm {
            b.forward_lstm(&params, rows, &h0, &c0, R).unwrap();
        } else {
            b.forward(&params, rows, R).unwrap();
        }
    }
    let fwd_rows_per_s = (iters * R) as f64 / t0.secs();

    // Train-step throughput over the full segment.
    let mut p = params.clone();
    let mut opt = AdamState::new(p.len());
    let n = T * R;
    let actions: Vec<i32> = (0..n).map(|i| (i % ACT) as i32).collect();
    let logp = vec![-1.2f32; n];
    let adv: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) * 0.3).collect();
    let ret: Vec<f32> = (0..n).map(|i| (i % 5) as f32 * 0.2).collect();
    let starts: Vec<f32> = (0..n).map(|i| if i % 6 == 0 { 1.0 } else { 0.0 }).collect();
    let batch = TrainBatch {
        t: T,
        r: R,
        norm_adv: true,
        obs: &obs,
        starts: &starts,
        actions: &actions,
        logp: &logp,
        adv: &adv,
        ret: &ret,
    };
    let train_iters = (iters / 16).max(2);
    let t1 = Timer::start();
    for _ in 0..train_iters {
        b.train_step(&mut p, &mut opt, 1e-4, 0.01, &batch).unwrap();
    }
    let train_samples_per_s = (train_iters * n) as f64 / t1.secs();

    Run {
        fwd_rows_per_s,
        train_samples_per_s,
        n_params,
    }
}

fn main() {
    let iters: usize = std::env::var("PUFFER_BENCH_POLICY_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let json_path = std::env::var("PUFFER_BENCH_JSON").ok();
    let act_dims = [ACT];

    let flat_space = Space::boxf(&[64], -1.0, 1.0);
    let flat = ResolvedPolicy::resolve(&PolicySpec::default(), &flat_space.layout(), &act_dims)
        .unwrap();

    let embed_space = Space::dict(vec![
        ("feats".into(), Space::boxf(&[16], -1.0, 1.0)),
        ("tokens".into(), Space::boxi32(&[8], 0.0, 127.0)),
    ]);
    let embed = ResolvedPolicy::resolve(
        &PolicySpec::default().with_embed_dim(8),
        &embed_space.layout(),
        &act_dims,
    )
    .unwrap();
    assert!(embed.has_embeds(), "bench arch must actually embed");

    let lstm = ResolvedPolicy::resolve(
        &PolicySpec::default().with_lstm(128),
        &flat_space.layout(),
        &act_dims,
    )
    .unwrap();

    println!(
        "# Bench P3 — policy fwd/bwd throughput, scalar vs simd kernels ({iters} fwd iters)"
    );
    println!(
        "| {:<14} | {:>10} | {:>12} | {:>12} | {:>7} | {:>12} | {:>12} | {:>7} |",
        "Architecture",
        "params",
        "fwd scalar",
        "fwd simd",
        "speedup",
        "train scalar",
        "train simd",
        "speedup"
    );
    println!(
        "|{}|{}|{}|{}|{}|{}|{}|{}|",
        "-".repeat(16),
        "-".repeat(12),
        "-".repeat(14),
        "-".repeat(14),
        "-".repeat(9),
        "-".repeat(14),
        "-".repeat(14),
        "-".repeat(9)
    );
    let mut cells = Vec::new();
    for (label, arch) in [("flat-mlp", flat), ("embed-tokens", embed), ("lstm", lstm)] {
        let scalar = bench_arch(label, arch.clone(), iters, KernelPath::Scalar);
        let simd = bench_arch(label, arch, iters, KernelPath::Simd);
        let cell = Cell { label, scalar, simd };
        println!(
            "| {:<14} | {:>10} | {:>12.0} | {:>12.0} | {:>6.2}x | {:>12.0} | {:>12.0} | {:>6.2}x |",
            cell.label,
            cell.scalar.n_params,
            cell.scalar.fwd_rows_per_s,
            cell.simd.fwd_rows_per_s,
            cell.fwd_speedup(),
            cell.scalar.train_samples_per_s,
            cell.simd.train_samples_per_s,
            cell.train_speedup(),
        );
        cells.push(cell);
    }
    println!("\n# flat-mlp is the baseline; embed-tokens trades a gather for a");
    println!("# narrower effective input; lstm pays the cell + BPTT tax natively.");
    println!("# acceptance: simd >= 4x scalar forward, >= 3x train-step (flat-mlp).");

    if let Some(path) = json_path {
        let run_json = |r: &Run| {
            obj(vec![
                ("fwd_rows_per_s", num(r.fwd_rows_per_s)),
                ("train_samples_per_s", num(r.train_samples_per_s)),
            ])
        };
        let cells_json: Vec<Json> = cells
            .iter()
            .map(|c| {
                obj(vec![
                    ("arch", s(c.label)),
                    ("n_params", num(c.scalar.n_params as f64)),
                    ("scalar", run_json(&c.scalar)),
                    ("simd", run_json(&c.simd)),
                    ("fwd_speedup", num(c.fwd_speedup())),
                    ("train_speedup", num(c.train_speedup())),
                ])
            })
            .collect();
        let out = obj(vec![
            ("bench", s("policy_forward")),
            // Distinguishes a real run from the checked-in
            // "static-estimate" placeholder this file replaces.
            ("method", s("measured")),
            ("iters", num(iters as f64)),
            ("geometry", s("T=32 R=32")),
            ("kernel_threads", num(pufferlib::backend::kernels::thread_cap_from_env() as f64)),
            ("cells", arr(cells_json)),
        ]);
        match std::fs::write(&path, out.dump()) {
            Ok(()) => println!("\n# wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}
