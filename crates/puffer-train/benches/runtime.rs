//! **Bench P1** — learner-side entry-point latency/throughput through the
//! `PolicyBackend` abstraction: forward (both batch sizes), the GAE scan,
//! and the full PPO train step. This is the hot path the trainer drives;
//! the §Perf targets in EXPERIMENTS.md come from here.
//!
//! Runs on the default pure-Rust `NativeBackend` (no artifacts needed).
//! Build with `--features pjrt` and set `PUFFER_BACKEND=pjrt` to measure
//! the AOT/PJRT path instead.
//!
//! `cargo bench --bench runtime`; `PUFFER_BENCH_SECS` per entry.

use pufferlib::backend::{AdamState, NativeBackend, PolicyBackend, TrainBatch};
use pufferlib::envs;
use pufferlib::util::stats::{percentile, Welford};
use std::time::Instant;

fn bench_entry(
    label: &str,
    reps_budget_secs: f64,
    mut run: impl FnMut() -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    // Warmup.
    for _ in 0..3 {
        run()?;
    }
    let mut lat = Welford::new();
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < reps_budget_secs {
        let s = Instant::now();
        run()?;
        let us = s.elapsed().as_secs_f64() * 1e6;
        lat.push(us);
        samples.push(us);
    }
    println!(
        "| {:<22} | {:>9.0} | {:>9.0} | {:>9.0} | {:>7} |",
        label,
        lat.mean(),
        percentile(&samples, 50.0),
        percentile(&samples, 99.0),
        lat.count()
    );
    Ok(())
}

fn make_backend() -> anyhow::Result<Box<dyn PolicyBackend>> {
    let choice = std::env::var("PUFFER_BACKEND").unwrap_or_else(|_| "native".into());
    match choice.as_str() {
        "native" => {
            let probe = envs::make("ocean/squared", 0);
            Ok(Box::new(NativeBackend::for_env("ocean/squared", probe.as_ref())?))
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(pufferlib::backend::PjrtBackend::new(
            "artifacts",
            "ocean_squared",
        )?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => anyhow::bail!(
            "this bench was built without the `pjrt` feature; rebuild with \
             `cargo bench --features pjrt`"
        ),
        other => anyhow::bail!("unknown PUFFER_BACKEND '{other}'"),
    }
}

fn main() -> anyhow::Result<()> {
    let secs: f64 = std::env::var("PUFFER_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);

    let mut backend = make_backend()?;
    let spec = backend.spec().clone();
    let (bf, br, t, d) = (spec.batch_fwd, spec.batch_roll, spec.horizon, spec.obs_dim);
    let n = t * br;
    let params = backend.init_params()?;

    println!(
        "# Bench P1 — backend entry-point latency (ocean_squared spec: obs {d}, {} params)",
        spec.n_params
    );
    println!(
        "| {:<22} | {:>9} | {:>9} | {:>9} | {:>7} |",
        "entry", "mean µs", "p50 µs", "p99 µs", "reps"
    );
    println!(
        "|{}|{}|{}|{}|{}|",
        "-".repeat(24),
        "-".repeat(11),
        "-".repeat(11),
        "-".repeat(11),
        "-".repeat(9)
    );

    // forward at both batch sizes
    for b in [bf, br] {
        let obs = vec![0.1f32; b * d];
        bench_entry(&format!("forward_b{b}"), secs, || {
            let out = backend.forward(&params, &obs, b)?;
            std::hint::black_box(&out);
            Ok(())
        })?;
    }

    // GAE reverse scan
    {
        let z = vec![0.1f32; n];
        let zeros = vec![0.0f32; n];
        let lv = vec![0.0f32; br];
        bench_entry("gae", secs, || {
            let out = backend.gae(&z, &z, &zeros, &lv)?;
            std::hint::black_box(&out);
            Ok(())
        })?;
    }

    // train_step (full PPO update: fwd + bwd + clip + Adam)
    {
        let obs = vec![0.1f32; n * d];
        let actions = vec![0i32; n];
        let zn = vec![0.0f32; n];
        let starts = vec![0.0f32; n];
        let mut p = params.clone();
        let mut opt = AdamState::new(p.len());
        bench_entry("train_step", secs.max(3.0), || {
            let batch = TrainBatch {
                t,
                r: br,
                norm_adv: true,
                obs: &obs,
                starts: &starts,
                actions: &actions,
                logp: &zn,
                adv: &zn,
                ret: &zn,
            };
            let out = backend.train_step(&mut p, &mut opt, 1e-3, 0.01, &batch)?;
            std::hint::black_box(&out);
            Ok(())
        })?;
    }

    println!("\n# derived: forward_b{bf} rows/s and train_step steps/s set the");
    println!("# learner ceiling; compare against rollout SPS in bench T2.");
    Ok(())
}
