//! **Bench C1** — the EnvPool ablation: where does pooling win, and by
//! how much? Reproduces the paper's §5 claims:
//! - ≥30–40% uplift generally from EnvPool;
//! - up to ~6× on Crafter-like workloads (long resets + high step-time
//!   variance), because sync vectorization waits for every straggler.
//!
//! Sweeps step-time CV × reset share on a synthetic env, then runs the
//! calibrated crafter-sim head-to-head.
//!
//! `cargo bench --bench pool_ablation`; `PUFFER_BENCH_SECS` per cell.

use pufferlib::emulation::{FlatEnv, PufferEnv};
use pufferlib::envs::profile::{self, ProfileConfig, ProfileSim};
use pufferlib::vector::autotune::measure;
use pufferlib::vector::{Multiprocessing, VecConfig};

fn sync_vs_pool(
    mk: impl Fn(usize) -> Box<dyn FlatEnv> + Send + Sync + Clone + 'static,
    num_envs: usize,
    workers: usize,
    secs: f64,
) -> (f64, f64) {
    let sync_cfg = VecConfig {
        num_envs,
        num_workers: workers,
        batch_size: num_envs,
        ..Default::default()
    };
    let pool_cfg = VecConfig {
        num_envs,
        num_workers: workers,
        batch_size: num_envs / 2,
        ..Default::default()
    };
    let mk2 = mk.clone();
    let sync = measure(Multiprocessing::from_factory(move |i| mk(i), sync_cfg).unwrap(), secs).unwrap();
    let pool = measure(Multiprocessing::from_factory(move |i| mk2(i), pool_cfg).unwrap(), secs).unwrap();
    (sync, pool)
}

fn main() {
    let secs: f64 = std::env::var("PUFFER_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.5);

    println!("# Bench C1 — EnvPool ablation: pool speedup vs workload shape");
    println!("# synthetic env: 100µs mean step, 8 envs / 4 workers, batch 4 (M=2N)");
    println!(
        "| {:>8} | {:>10} | {:>9} | {:>9} | {:>8} |",
        "step CV", "reset frac", "sync SPS", "pool SPS", "speedup"
    );
    println!(
        "|{}|{}|{}|{}|{}|",
        "-".repeat(10),
        "-".repeat(12),
        "-".repeat(11),
        "-".repeat(11),
        "-".repeat(10)
    );
    for cv in [0.0, 0.5, 1.0, 2.0] {
        for reset_frac in [0.0, 0.5, 0.8] {
            let mk = move |i: usize| -> Box<dyn FlatEnv> {
                let mut cfg = ProfileConfig::synthetic(100.0, cv, reset_frac, 16);
                cfg.ep_len = 64;
                Box::new(PufferEnv::new(ProfileSim::new(cfg, i as u64)))
            };
            let (sync, pool) = sync_vs_pool(mk, 8, 4, secs);
            println!(
                "| {:>8.1} | {:>10.1} | {:>9.0} | {:>9.0} | {:>7.2}x |",
                cv,
                reset_frac,
                sync,
                pool,
                pool / sync
            );
        }
    }

    println!("\n# Crafter head-to-head (calibrated sim, time-scaled 0.1)");
    let mk = |i: usize| profile::make_profile_scaled("crafter", i as u64, 0.1);
    let (sync, pool) = sync_vs_pool(mk, 8, 4, (secs * 4.0).max(6.0));
    println!(
        "crafter-sim: sync {sync:.0} SPS, pool {pool:.0} SPS — {:.2}x (paper: 6x via Puffer Pool)",
        pool / sync
    );
}
