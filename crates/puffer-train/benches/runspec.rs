//! **Bench R1** — RunSpec-construction microbench: how much the
//! declarative layer costs. Cells:
//!
//! - `parse_toml` — TOML text → RunSpec (the `puffer run` hot path),
//! - `to_toml` — canonical serialization (checkpoint/validate path),
//! - `json_round_trip` — to_json → parse (what every checkpoint embeds),
//! - `expand_grid` — 16-point sweep expansion,
//! - `build_trainer` — full `RunSpec::build()` (probe + backend +
//!   serial vectorizer + buffers), the end-to-end construction cost.
//!
//! `PUFFER_BENCH_JSON` writes machine-readable results (`make bench`
//! sets it to `BENCH_runspec.json`).

use pufferlib::runspec::{RunSpec, RunSpecExt as _};
use pufferlib::util::json::{arr, num, obj, s};
use pufferlib::util::timer::Timer;
use pufferlib::vector::VecSpec;
use pufferlib::wrappers::EnvSpec;

fn spec_toml() -> String {
    let mut spec = RunSpec::new(EnvSpec::new("ocean/spaces").clip_reward(1.0).stack(2))
        .with_policy(
            pufferlib::policy::PolicySpec::default()
                .with_hidden(64)
                .with_embed_dim(8),
        )
        .with_vec(VecSpec::pooled(2))
        .with_seed(42)
        .with_train(|t| {
            t.total_steps = 30_000;
            t.minibatches = 2;
        });
    spec.grid
        .insert("train.lr".into(), vec!["0.001".into(), "0.002".into(), "0.003".into(), "0.004".into()]);
    spec.grid
        .insert("seed".into(), vec!["1".into(), "2".into(), "3".into(), "4".into()]);
    spec.to_toml().unwrap()
}

/// Time `iters` runs of `f`, returning ns/op.
fn bench(iters: u64, mut f: impl FnMut()) -> f64 {
    // Warmup.
    for _ in 0..iters.min(3) {
        f();
    }
    let t = Timer::start();
    for _ in 0..iters {
        f();
    }
    t.secs() * 1e9 / iters as f64
}

fn main() {
    let json_path = std::env::var("PUFFER_BENCH_JSON").ok();
    let toml = spec_toml();
    let spec = RunSpec::from_toml_str(&toml).unwrap();
    let json = spec.to_json().dump();
    let single = {
        let mut s = spec.clone();
        s.grid.clear();
        s.with_vec(VecSpec::Serial).with_train(|t| {
            t.total_steps = 0;
            t.log_every = 0;
            t.run_dir = None;
        })
    };

    let mut cells: Vec<(&str, u64, f64)> = Vec::new();
    cells.push(("parse_toml", 2000, bench(2000, || {
        let _ = RunSpec::from_toml_str(&toml).unwrap();
    })));
    cells.push(("to_toml", 2000, bench(2000, || {
        let _ = spec.to_toml().unwrap();
    })));
    cells.push(("json_round_trip", 2000, bench(2000, || {
        let _ = RunSpec::from_json_str(&json).unwrap();
    })));
    cells.push(("expand_grid", 500, bench(500, || {
        assert_eq!(spec.expand_grid().unwrap().len(), 16);
    })));
    cells.push(("build_trainer", 20, bench(20, || {
        let _ = single.build().unwrap();
    })));

    println!("# Bench R1 — RunSpec construction (ns/op)");
    println!("| {:<16} | {:>8} | {:>14} |", "cell", "iters", "ns/op");
    println!("|{}|{}|{}|", "-".repeat(18), "-".repeat(10), "-".repeat(16));
    for (name, iters, ns) in &cells {
        println!("| {name:<16} | {iters:>8} | {ns:>14.0} |");
    }

    if let Some(path) = json_path {
        let out = obj(vec![
            ("bench", s("runspec")),
            ("method", s("measured")),
            (
                "cells",
                arr(cells
                    .iter()
                    .map(|(name, iters, ns)| {
                        obj(vec![
                            ("cell", s(name)),
                            ("iters", num(*iters as f64)),
                            ("ns_per_op", num(*ns)),
                        ])
                    })
                    .collect()),
            ),
        ]);
        match std::fs::write(&path, out.dump()) {
            Ok(()) => println!("\n# wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}
