//! **Bench C2** — the four optimized code paths and the
//! multiple-envs-per-worker scaling claim (paper §5: Gymnasium/SB3
//! degrade above ~1000 synchronizations/sec/core; PufferLib's
//! envs-per-worker stacking scales to 100k+ SPS envs).
//!
//! Fast env (Ocean Squared, ~µs steps), sweeping envs-per-worker and
//! comparing every code path against the baseline designs.
//!
//! `cargo bench --bench codepaths`; `PUFFER_BENCH_SECS` per cell.

use pufferlib::envs;
use pufferlib::vector::autotune::measure;
use pufferlib::vector::baselines::{GymnasiumVec, Sb3Vec};
use pufferlib::vector::{Multiprocessing, Serial, VecConfig};

fn main() {
    let secs: f64 = std::env::var("PUFFER_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let mk = |i: usize| envs::make("ocean/squared", i as u64);

    println!("# Bench C2a — four code paths on a fast env (8 envs, 4 workers)");
    println!("| {:<22} | {:>10} |", "path", "SPS");
    println!("|{}|{}|", "-".repeat(24), "-".repeat(12));

    let serial = measure(
        Serial::from_factory(mk, VecConfig {
            num_envs: 8,
            num_workers: 1,
            batch_size: 8,
            ..Default::default()
        })
        .unwrap(),
        secs,
    )
    .unwrap();
    println!("| {:<22} | {:>10.0} |", "serial (reference)", serial);

    let paths: [(&str, usize, bool); 4] = [
        ("sync (N=M)", 8, false),
        ("async (N=M/2)", 4, false),
        ("async-single (N=epw)", 2, false),
        ("zero-copy (N=M/2)", 4, true),
    ];
    for (label, batch, zero_copy) in paths {
        let cfg = VecConfig {
            num_envs: 8,
            num_workers: 4,
            batch_size: batch,
            zero_copy,
            ..Default::default()
        };
        let sps = measure(Multiprocessing::from_factory(mk, cfg).unwrap(), secs).unwrap();
        println!("| {:<22} | {:>10.0} |", label, sps);
    }
    for (label, make) in [
        ("gymnasium design", 0usize),
        ("sb3 design", 1usize),
    ] {
        let cfg = VecConfig {
            num_envs: 8,
            num_workers: 8,
            batch_size: 8,
            ..Default::default()
        };
        let sps = match make {
            0 => measure(GymnasiumVec::new(mk, cfg).unwrap(), secs).unwrap(),
            _ => measure(Sb3Vec::new(mk, cfg).unwrap(), secs).unwrap(),
        };
        println!("| {:<22} | {:>10.0} |", label, sps);
    }

    println!("\n# Bench C2b — multiple envs per worker (4 workers fixed)");
    println!("# paper: don't clog the system with small processes");
    println!(
        "| {:>6} | {:>11} | {:>12} | {:>12} |",
        "envs", "envs/worker", "puffer SPS", "gym-design SPS"
    );
    println!(
        "|{}|{}|{}|{}|",
        "-".repeat(8),
        "-".repeat(13),
        "-".repeat(14),
        "-".repeat(14)
    );
    for num_envs in [4usize, 8, 16, 32] {
        let cfg = VecConfig {
            num_envs,
            num_workers: 4,
            batch_size: num_envs,
            ..Default::default()
        };
        let puffer = measure(Multiprocessing::from_factory(mk, cfg).unwrap(), secs).unwrap();
        // Gymnasium design: one env per worker, always.
        let gcfg = VecConfig {
            num_envs,
            num_workers: num_envs,
            batch_size: num_envs,
            ..Default::default()
        };
        let gym = measure(GymnasiumVec::new(mk, gcfg).unwrap(), secs).unwrap();
        println!(
            "| {:>6} | {:>11} | {:>12.0} | {:>12.0} |",
            num_envs,
            num_envs / 4,
            puffer,
            gym
        );
    }
}
