//! RunSpec integration suite: the declarative experiment currency
//! end-to-end — file parsing + validation over the shipped
//! `examples/specs/` gallery, TOML/JSON round trips, root-seed
//! reproducibility (bit-identical first segments), checkpoint → resume
//! with zero flags, and sweep-grid execution with per-child isolation.

use pufferlib::runspec::{run_sweep, RunSpec, RunSpecExt as _};
use pufferlib::train::Checkpoint;
use pufferlib::vector::VecSpec;
use pufferlib::wrappers::EnvSpec;
use std::path::PathBuf;

const SPECS_DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/specs");

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("puffer_run_spec_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A fast, fully deterministic base spec (serial vectorizer, one
/// segment = 1024 steps).
fn bandit_spec(run_dir: &std::path::Path) -> RunSpec {
    RunSpec::new(EnvSpec::new("ocean/bandit"))
        .with_vec(VecSpec::Serial)
        .with_seed(11)
        .with_train(|t| {
            t.total_steps = 1; // rounds up to exactly one segment
            t.log_every = 0;
            t.run_dir = Some(run_dir.to_string_lossy().into_owned());
        })
}

#[test]
fn example_spec_gallery_parses_validates_and_round_trips() {
    let mut seen = 0;
    for entry in std::fs::read_dir(SPECS_DIR).expect("examples/specs exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        seen += 1;
        let spec = RunSpec::load(&path).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        spec.validate().unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        // Serialize → parse → identical spec (and identical text, since
        // to_toml is canonical).
        let toml = spec.to_toml().unwrap();
        let back = RunSpec::from_toml_str(&toml).unwrap();
        assert_eq!(back, spec, "{path:?} does not round-trip");
        assert_eq!(back.to_toml().unwrap(), toml);
        let json = spec.to_json().dump();
        assert_eq!(RunSpec::from_json_str(&json).unwrap(), spec);
    }
    // The gallery covers every env family plus a recurrent and a swept
    // spec.
    assert!(seen >= 5, "expected a spec gallery, found {seen} files");
    let names: Vec<String> = std::fs::read_dir(SPECS_DIR)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    for family in ["ocean", "classic", "profile", "sweep"] {
        assert!(
            names.iter().any(|n| n.starts_with(family)),
            "gallery is missing a {family} spec: {names:?}"
        );
    }
}

#[test]
fn identical_run_specs_produce_bit_identical_first_segments() {
    let run = |seed: u64, tag: &str| {
        let dir = temp_dir(&format!("seed_split_{tag}"));
        let spec = bandit_spec(&dir).with_seed(seed);
        let mut trainer = spec.build().unwrap();
        trainer.train().unwrap();
        trainer.checkpoint()
    };
    let a = run(11, "a");
    let b = run(11, "b");
    assert_eq!(a.params, b.params, "identical RunSpecs must train identically");
    assert_eq!(a.adam_m, b.adam_m);
    assert_eq!(a.adam_v, b.adam_v);
    assert_eq!(a.global_step, b.global_step);
    // A different root seed changes the derived streams (env resets,
    // sampling) and therefore the trajectory.
    let c = run(12, "c");
    assert_ne!(a.params, c.params, "the root seed must matter");
}

#[test]
fn checkpoint_embeds_the_spec_and_resume_continues_training() {
    let dir = temp_dir("resume");
    let spec = bandit_spec(&dir);

    // Phase 1: train one segment; train() drops a checkpoint in run_dir.
    let mut first = spec.build().unwrap();
    let report1 = first.train().unwrap();
    assert!(report1.global_step >= 1);
    drop(first);
    let ck_path = dir.join("checkpoint.bin");
    let ck = Checkpoint::load(&ck_path).unwrap();

    // The checkpoint reproduces the original spec exactly — same value,
    // same re-serialized TOML.
    let embedded = RunSpec::from_json_str(ck.run_spec_json.as_deref().expect("spec embedded")).unwrap();
    assert_eq!(embedded, spec);
    assert_eq!(embedded.to_toml().unwrap(), spec.to_toml().unwrap());

    // Zero-flag resume: rebuild from the embedded spec alone, restore,
    // and train — the budget is already met, so state is preserved
    // as-is.
    let mut resumed = embedded.build().unwrap();
    resumed.restore(&ck).unwrap();
    assert_eq!(resumed.global_step(), ck.global_step);

    // Extending the budget (puffer resume --train.total_steps=N)
    // continues training from the restored state — and appends to the
    // original run's metrics history instead of truncating it.
    let lines_before = std::fs::read_to_string(dir.join("metrics.csv"))
        .unwrap()
        .lines()
        .count();
    let extended = embedded
        .clone()
        .with_train(|t| t.total_steps = ck.global_step * 2);
    let mut more = extended.build().unwrap();
    more.restore(&ck).unwrap();
    let report2 = more.train().unwrap();
    assert_eq!(report2.global_step, ck.global_step * 2);
    assert_ne!(
        more.checkpoint().params,
        ck.params,
        "continued training must update parameters"
    );
    let csv = std::fs::read_to_string(dir.join("metrics.csv")).unwrap();
    assert!(csv.starts_with("global_step,"), "header written once");
    assert!(
        csv.lines().count() > lines_before,
        "resume must append to metrics.csv, not truncate the run's history"
    );
}

#[test]
fn cross_spec_restores_keep_their_actionable_rejections() {
    let dir = temp_dir("cross_restore");
    let spec = bandit_spec(&dir);
    let mut trainer = spec.build().unwrap();
    trainer.train().unwrap();
    let ck = trainer.checkpoint();
    drop(trainer);

    // A differently-wrapped env must refuse the checkpoint.
    let wrapped = RunSpec::new(EnvSpec::new("ocean/bandit").stack(2))
        .with_vec(VecSpec::Serial)
        .with_train(|t| {
            t.total_steps = 0;
            t.log_every = 0;
        });
    let err = wrapped.build().unwrap().restore(&ck).unwrap_err().to_string();
    assert!(err.contains("checkpoint is for"), "{err}");

    // A different architecture names both archs in the rejection.
    let rearched = RunSpec::new(EnvSpec::new("ocean/bandit"))
        .with_vec(VecSpec::Serial)
        .with_policy(pufferlib::policy::PolicySpec::default().with_hidden(64))
        .with_train(|t| {
            t.total_steps = 0;
            t.log_every = 0;
        });
    let err = rearched.build().unwrap().restore(&ck).unwrap_err().to_string();
    assert!(err.contains("architecture"), "{err}");
}

#[test]
fn the_memory_example_spec_runs_and_resumes_recurrently() {
    // The acceptance-path spec: recurrent arch from the shipped gallery
    // file, budget shrunk to one segment for test speed.
    let dir = temp_dir("memory_example");
    let path = format!("{SPECS_DIR}/ocean_memory.toml");
    let spec = RunSpec::load(&path)
        .unwrap()
        .with_train(|t| {
            t.total_steps = 1;
            t.log_every = 0;
            t.run_dir = Some(dir.to_string_lossy().into_owned());
        });
    assert!(
        spec.policy.as_ref().expect("gallery spec pins the arch").is_recurrent(),
        "ocean_memory.toml must exercise the recurrent sandwich"
    );
    let mut trainer = spec.build().unwrap();
    trainer.train().unwrap();
    drop(trainer);
    let ck = Checkpoint::load(dir.join("checkpoint.bin")).unwrap();
    let embedded = RunSpec::from_json_str(ck.run_spec_json.as_deref().unwrap()).unwrap();
    assert_eq!(embedded, spec);
    let mut resumed = embedded.build().unwrap();
    resumed.restore(&ck).unwrap();
    assert_eq!(resumed.global_step(), ck.global_step);
}

#[test]
fn sweep_grid_trains_isolated_children() {
    let dir = temp_dir("sweep");
    let mut spec = bandit_spec(&dir);
    spec.grid
        .insert("train.lr".into(), vec!["0.002".into(), "0.003".into()]);
    spec.grid.insert("seed".into(), vec!["1".into(), "2".into()]);

    let children = spec.expand_grid().unwrap();
    let mut done_order = Vec::new();
    let outcomes = run_sweep(&children, 2, |i, _| done_order.push(i)).unwrap();
    assert_eq!(outcomes.len(), 4);
    assert_eq!(done_order.len(), 4);
    for (child, outcome) in children.iter().zip(&outcomes) {
        let report = outcome.report.as_ref().unwrap_or_else(|e| panic!("{}: {e:#}", outcome.label));
        assert!(report.global_step >= 1);
        // Per-child metrics directory with its own metrics + checkpoint.
        let child_dir = PathBuf::from(child.train.run_dir.as_ref().unwrap());
        assert!(child_dir.join("metrics.csv").is_file(), "{child_dir:?}");
        let ck = Checkpoint::load(child_dir.join("checkpoint.bin")).unwrap();
        // Each child's checkpoint embeds the *child* spec (grid applied,
        // no grid section left).
        let embedded = RunSpec::from_json_str(ck.run_spec_json.as_deref().unwrap()).unwrap();
        assert_eq!(&embedded, child);
        assert!(embedded.grid.is_empty());
    }
    // The four children trained four distinct (lr, seed) points.
    let points: std::collections::BTreeSet<(String, u64)> = children
        .iter()
        .map(|c| (format!("{}", c.train.lr), c.seed))
        .collect();
    assert_eq!(points.len(), 4);
}

#[test]
fn auto_vec_consumes_the_autotune_cache_and_survives_serialization() {
    let dir = temp_dir("auto_vec");
    // Seed the cache with a known winner so construction is instant and
    // deterministic (what `puffer autotune` writes).
    let env = EnvSpec::new("ocean/bandit");
    let num_envs = 32; // batch_roll 32 / 1 agent
    pufferlib::vector::autotune::write_cache(
        &pufferlib::vector::autotune::cache_path(Some(dir.to_str().unwrap())),
        &env.key(),
        num_envs,
        &VecSpec::Serial,
    )
    .unwrap();

    let spec = bandit_spec(&dir).with_vec(VecSpec::Auto);
    // `auto` survives the round trip un-resolved: the spec stays
    // declarative, the cache holds the binding.
    let toml = spec.to_toml().unwrap();
    assert!(toml.contains("\"auto\""), "{toml}");
    assert_eq!(RunSpec::from_toml_str(&toml).unwrap(), spec);

    let mut trainer = spec.build().unwrap();
    assert_eq!(trainer.run_spec().unwrap().vec, VecSpec::Auto);
    trainer.train().unwrap();
    drop(trainer);
    // The checkpoint embeds the auto spec, so a resume re-resolves from
    // the same cache.
    let ck = Checkpoint::load(dir.join("checkpoint.bin")).unwrap();
    let embedded = RunSpec::from_json_str(ck.run_spec_json.as_deref().unwrap()).unwrap();
    assert_eq!(embedded.vec, VecSpec::Auto);
}

#[test]
fn file_errors_name_the_file_and_the_key() {
    let dir = temp_dir("bad_files");
    let path = dir.join("bad.toml");
    std::fs::write(&path, "[train]\ntotl_steps = 5\n").unwrap();
    let err = format!("{:#}", RunSpec::load(&path).unwrap_err());
    assert!(err.contains("bad.toml"), "{err}");
    assert!(err.contains("train.totl_steps"), "{err}");

    std::fs::write(&path, "[env]\nname = \"ocean/bandit\"\n[vec]\nmode = \"warp\"\n").unwrap();
    let err = format!("{:#}", RunSpec::load(&path).unwrap_err());
    assert!(err.contains("vec.mode"), "{err}");
}

/// Every gallery spec's resolved architecture must expose a parameter
/// layout whose named leaves tile `0..n_params` exactly — the
/// `ArchRanges` contract `ParamView::split` and `n_params` both build
/// on. Rebuilding through `ServedModel::backend_for` exercises the same
/// construction path training and serving share.
#[test]
fn gallery_arch_ranges_tile_n_params_exactly() {
    use pufferlib::backend::PolicyBackend;
    use pufferlib::serve::ServedModel;
    let mut seen = 0;
    for entry in std::fs::read_dir(SPECS_DIR).expect("examples/specs exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        seen += 1;
        let spec = RunSpec::load(&path).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        let backend = ServedModel::backend_for(&spec).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        let arch = backend.arch();
        let ranges = arch.ranges();
        let leaves = ranges.leaves();
        let mut off = 0usize;
        for (name, range) in &leaves {
            assert_eq!(
                range.start, off,
                "{path:?}: leaf {name} starts at {} but previous leaf ended at {off}",
                range.start
            );
            assert!(range.end > range.start, "{path:?}: leaf {name} is empty");
            off = range.end;
        }
        assert_eq!(off, ranges.total, "{path:?}: leaves must cover the whole vector");
        assert_eq!(
            ranges.total,
            arch.n_params(),
            "{path:?}: ranges total and n_params disagree"
        );
        assert_eq!(
            ranges.total,
            backend.spec().n_params,
            "{path:?}: manifest n_params and ArchRanges disagree"
        );
    }
    assert!(seen >= 5, "expected a spec gallery, found {seen} files");
}
