//! Pipeline-equivalence suite for the collector/learner trainer.
//!
//! - `pipeline.depth = 0` must reproduce the pre-refactor serial loop
//!   **bit for bit**: the first test hand-rolls that loop (collect → GAE
//!   → full-batch PPO epochs, exactly the operations and ordering of the
//!   old `Trainer::train` body) against the same seeds and compares
//!   parameters, optimizer state, and step counters after 3 segments.
//! - The pipelined path must checkpoint/restore losslessly and continue
//!   training without a score cliff.
//! - The minibatch split must not break learning.

use pufferlib::backend::{AdamState, NativeBackend, PolicyBackend, TrainBatch};
use pufferlib::policy::Policy;
use pufferlib::train::{
    collect_rollout, Checkpoint, EpisodeLog, RolloutBuffer, TrainConfig, Trainer,
};
use pufferlib::vector::{Serial, VecConfig, VecEnv};
use pufferlib::wrappers::EnvSpec;

const SEED: u64 = 7;
const ENV: &str = "ocean/bandit";

fn serial_cfg(total_steps: u64) -> TrainConfig {
    TrainConfig {
        env: ENV.into(),
        total_steps,
        seed: SEED,
        num_workers: 0, // Serial vectorizer: fully deterministic ordering
        log_every: 0,
        ..Default::default()
    }
}

/// The pre-refactor trainer body, hand-rolled: rollout → GAE → lr anneal
/// → `epochs` full-batch `train_step`s, repeated until `total_steps`.
/// Construction mirrors `Trainer::native` + `Trainer::build` exactly.
fn reference_serial_run(total_steps: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, f32, u64) {
    let cfg = serial_cfg(total_steps);
    let spec_env = EnvSpec::new(cfg.env.as_str());
    let probe = spec_env.build(0);
    let mut backend = NativeBackend::for_env(&spec_env.key(), probe.as_ref()).unwrap();
    let spec = backend.spec().clone();
    drop(probe);
    let num_envs = spec.batch_roll / spec.agents;
    let mut venv = Serial::from_spec(
        &spec_env,
        VecConfig {
            num_envs,
            num_workers: 1,
            batch_size: num_envs,
            seed: cfg.seed,
            ..Default::default()
        },
    )
    .unwrap();
    let mut policy = Policy::new(&mut backend, cfg.seed).unwrap();
    let mut opt = AdamState::new(spec.n_params);
    let mut buf = RolloutBuffer::new(
        spec.horizon,
        spec.batch_roll,
        spec.obs_dim,
        spec.act_dims.len(),
    );
    let mut log = EpisodeLog::default();
    let n = (spec.horizon * spec.batch_roll) as u64;
    let mut global_step = 0u64;

    venv.async_reset(cfg.seed);
    buf.mark_all_starts();
    policy.reset_all_state();
    while global_step < cfg.total_steps {
        collect_rollout(&mut venv, &mut buf, &mut log, |obs, rows, done_rows| {
            for &r in done_rows {
                policy.reset_state(r);
            }
            policy.step(&mut backend, obs, rows)
        })
        .unwrap();
        global_step += n;

        let (adv, ret) = backend
            .gae(&buf.rewards, &buf.values, &buf.dones, &buf.last_values)
            .unwrap();
        let lr = if cfg.anneal_lr {
            let frac = 1.0 - global_step as f32 / cfg.total_steps as f32;
            cfg.lr * frac.max(0.05)
        } else {
            cfg.lr
        };
        for _ in 0..cfg.epochs {
            let batch = TrainBatch {
                t: spec.horizon,
                r: spec.batch_roll,
                norm_adv: true, // the old loop always normalized in-batch
                obs: &buf.obs,
                starts: &buf.starts,
                actions: &buf.actions,
                logp: &buf.logp,
                adv: &adv,
                ret: &ret,
            };
            backend
                .train_step(policy.params_mut(), &mut opt, lr, cfg.ent_coef, &batch)
                .unwrap();
        }
    }
    (
        policy.params().to_vec(),
        opt.m.clone(),
        opt.v.clone(),
        opt.step,
        global_step,
    )
}

#[test]
fn depth0_is_bit_identical_to_the_pre_refactor_serial_loop() {
    let spec = EnvSpec::new(ENV);
    let probe = spec.build(0);
    let backend = NativeBackend::for_env(&spec.key(), probe.as_ref()).unwrap();
    let n = (backend.spec().horizon * backend.spec().batch_roll) as u64;
    let total_steps = 3 * n; // 3 segments

    let (ref_params, ref_m, ref_v, ref_step, ref_global) = reference_serial_run(total_steps);

    let mut trainer = Trainer::native(serial_cfg(total_steps)).unwrap();
    assert_eq!(trainer.global_step(), 0);
    trainer.train().unwrap();
    assert_eq!(trainer.global_step(), ref_global);
    assert_eq!(
        trainer.policy().params(),
        &ref_params[..],
        "depth=0 params diverged from the pre-refactor serial loop"
    );
    let ck = trainer.checkpoint();
    assert_eq!(ck.adam_m, ref_m, "Adam m diverged");
    assert_eq!(ck.adam_v, ref_v, "Adam v diverged");
    assert_eq!(ck.adam_step, ref_step, "Adam step count diverged");
}

#[test]
fn depth0_is_deterministic_across_runs() {
    let run = || {
        let mut t = Trainer::native(serial_cfg(2 * 1024)).unwrap();
        t.train().unwrap();
        t.policy().params().to_vec()
    };
    assert_eq!(run(), run());
}

#[test]
fn minibatched_serial_training_still_learns() {
    let cfg = TrainConfig {
        minibatches: 4,
        ..serial_cfg(16_000)
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    let score = report.mean_score.expect("episodes finished");
    assert!(
        score > 0.75,
        "minibatched bandit should be mostly solved by 16k steps, got {score}"
    );
}

#[test]
fn pipelined_checkpoint_round_trip_continues_without_cliff() {
    let dir = std::env::temp_dir().join("puffer_pipeline_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let pipelined = |total_steps: u64| TrainConfig {
        env: ENV.into(),
        total_steps,
        seed: SEED,
        pipeline_depth: 1,
        minibatches: 2,
        log_every: 0,
        ..Default::default()
    };

    // Phase 1: train halfway through the pipelined path and checkpoint.
    let mut first = Trainer::native(pipelined(8_192)).unwrap();
    let report1 = first.train().unwrap();
    assert!(report1.global_step >= 8_192);
    let ck = first.checkpoint();
    let path = dir.join("ck.bin");
    ck.save(&path).unwrap();
    drop(first);

    // Round trip the file: params + Adam state + wrapper-chain key +
    // global_step all survive byte-exactly.
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back, ck);

    // A differently-wrapped trainer must refuse the checkpoint (the
    // wrapper chain is part of the spec key).
    let mut wrong_chain = Trainer::native(TrainConfig {
        wrappers: vec![pufferlib::wrappers::WrapperSpec::Stack(2)],
        total_steps: 0,
        ..pipelined(0)
    })
    .unwrap();
    let err = wrong_chain.restore(&back).unwrap_err().to_string();
    assert!(err.contains("checkpoint is for"), "{err}");

    // Phase 2: restore into a fresh pipelined trainer and continue to the
    // full budget. No score cliff: the restored policy evaluates well
    // immediately, and further training keeps (or improves) the score.
    let mut second = Trainer::native(pipelined(16_384)).unwrap();
    second.restore(&back).unwrap();
    assert_eq!(second.global_step(), back.global_step);
    let eval = second.eval(50).unwrap();
    assert!(
        eval.mean_score.unwrap() > 0.6,
        "restored eval score cliff: {:?}",
        eval.mean_score
    );
    let report2 = second.train().unwrap();
    assert!(report2.global_step >= 16_384);
    assert!(
        report2.mean_score.unwrap_or(0.0) > 0.75,
        "continued pipelined training regressed: {:?}",
        report2.mean_score
    );
}

#[test]
fn pipelined_trainer_supports_repeated_train_calls() {
    // The pipeline lends the trainer's segment buffer into the rotating
    // pool; it must be restored so a rewind (restore an older
    // checkpoint) followed by another train() collects again instead of
    // panicking on a zero-sized buffer.
    let cfg = TrainConfig {
        env: ENV.into(),
        total_steps: 2_048,
        seed: SEED,
        pipeline_depth: 1,
        log_every: 0,
        ..Default::default()
    };
    let mut t = Trainer::native(cfg).unwrap();
    let ck0 = t.checkpoint(); // pristine, global_step 0
    t.train().unwrap();
    assert!(t.global_step() >= 2_048);
    t.restore(&ck0).unwrap();
    assert_eq!(t.global_step(), 0);
    let report = t.train().unwrap();
    assert!(report.global_step >= 2_048);
}

#[test]
fn pipelined_learner_abort_mid_run_joins_the_collector() {
    // A learner-side error in the middle of a pipelined run (here: the
    // metrics sink cannot create its run directory because the parent is
    // a regular file) must tear the pipeline down cleanly: the scope
    // drops the learner's queue endpoints, the collector — possibly
    // blocked mid-rotation with segments still to deliver — unblocks and
    // exits, the scope joins it, and train() returns the error instead
    // of deadlocking or panicking. (If the join protocol regressed, this
    // test hangs and the harness timeout catches it.)
    let file = std::env::temp_dir().join("puffer_abort_not_a_dir");
    std::fs::write(&file, b"occupied").unwrap();
    let run_dir = file.join("run"); // create_dir_all must fail: parent is a file
    let cfg = TrainConfig {
        env: ENV.into(),
        total_steps: 16_384, // several segments: the abort is mid-run
        seed: SEED,
        pipeline_depth: 2,
        log_every: 0,
        run_dir: Some(run_dir.to_string_lossy().into_owned()),
        ..Default::default()
    };
    let mut t = Trainer::native(cfg).unwrap();
    let err = t.train().expect_err("metrics sink must fail");
    // The failure surfaced as an error, not a wedge — and the trainer is
    // still usable: the lent segment buffer was re-created on the error
    // path, so a follow-up eval (no metrics involved) works.
    let _ = err.to_string();
    let eval = t.eval(5).unwrap();
    assert!(eval.episodes >= 5);
    let _ = std::fs::remove_file(&file);
}

#[test]
fn pipelined_report_exposes_stall_accounting() {
    // A deliberately learner-light run: stall numbers must be finite and
    // the env/learn split populated.
    let cfg = TrainConfig {
        env: "ocean/stochastic".into(),
        total_steps: 2_048,
        seed: SEED,
        pipeline_depth: 2, // 3 rotating buffers
        epochs: 1,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    assert!(report.env_sps > 0.0);
    assert!(report.learn_sps > 0.0);
    assert!(report.collector_stall_s >= 0.0 && report.collector_stall_s.is_finite());
    assert!(report.learner_stall_s >= 0.0 && report.learner_stall_s.is_finite());
    // The learner publishes before recycling each buffer, so snapshot
    // staleness is bounded by the pipeline depth.
    assert!(
        report.max_param_staleness <= 2,
        "staleness {} exceeds depth 2",
        report.max_param_staleness
    );
}
