//! Integration tests across the full stack: emulation → vectorization →
//! compute backend → Clean PuffeRL. The training tests run on the default
//! pure-Rust `NativeBackend`, so they need no artifacts and no Python;
//! PJRT-specific coverage lives behind the `pjrt` feature.

use pufferlib::emulation::{FlatEnv, PufferEnv};
use pufferlib::envs;
use pufferlib::train::{Checkpoint, TrainConfig, Trainer};
use pufferlib::vector::baselines::{GymnasiumVec, Sb3Vec};
use pufferlib::vector::{Multiprocessing, Serial, VecConfig, VecEnv};

/// Every backend must produce identical step results for a deterministic
/// env when driven with the same actions — the cross-backend equivalence
/// property that guards all four code paths plus both baselines.
#[test]
fn backends_agree_on_deterministic_env() {
    fn run<V: VecEnv>(mut v: V, steps: usize) -> (Vec<f32>, Vec<u8>) {
        let slots = v.action_dims().len();
        let num_envs = v.num_envs();
        let mut rewards_log = vec![0.0f32; num_envs];
        let mut final_obs = Vec::new();
        v.async_reset(99);
        for _ in 0..steps {
            let (ids, obs, rewards) = {
                let b = v.recv().unwrap();
                (b.env_ids.to_vec(), b.obs.to_vec(), b.rewards.to_vec())
            };
            for (slot, &e) in ids.iter().enumerate() {
                rewards_log[e] += rewards[slot];
            }
            if final_obs.is_empty() {
                final_obs = obs;
            }
            // Deterministic action per env id.
            let actions: Vec<i32> = ids.iter().map(|&e| (e % 2) as i32).collect();
            assert_eq!(actions.len() * slots, ids.len() * slots);
            v.send(&actions).unwrap();
        }
        (rewards_log, final_obs)
    }

    let mk = |i: usize| envs::make("classic/cartpole", i as u64);
    let cfg_sync = VecConfig {
        num_envs: 4,
        num_workers: 2,
        batch_size: 4,
        ..Default::default()
    };
    let serial_cfg = VecConfig {
        num_envs: 4,
        num_workers: 1,
        batch_size: 4,
        ..Default::default()
    };

    let (r_serial, o_serial) = run(Serial::from_factory(mk, serial_cfg.clone()).unwrap(), 20);
    let (r_mp, o_mp) = run(Multiprocessing::from_factory(mk, cfg_sync.clone()).unwrap(), 20);
    let (r_gym, o_gym) = run(GymnasiumVec::new(mk, cfg_sync.clone()).unwrap(), 20);
    let (r_sb3, o_sb3) = run(Sb3Vec::new(mk, cfg_sync).unwrap(), 20);

    assert_eq!(r_serial, r_mp, "serial vs multiprocessing rewards");
    assert_eq!(r_serial, r_gym, "serial vs gymnasium rewards");
    assert_eq!(r_serial, r_sb3, "serial vs sb3 rewards");
    assert_eq!(o_serial, o_mp, "first-batch obs identical");
    assert_eq!(o_serial, o_gym);
    assert_eq!(o_serial, o_sb3);
}

/// Pooled modes must see every env eventually (fairness) and keep row
/// routing intact under heavy step-time imbalance.
#[test]
fn pool_fairness_under_imbalance() {
    use pufferlib::envs::profile::{ProfileConfig, ProfileSim};
    let factory = |i: usize| -> Box<dyn FlatEnv> {
        let step_us = if i % 2 == 0 { 30.0 } else { 300.0 };
        Box::new(PufferEnv::new(ProfileSim::new(
            ProfileConfig::synthetic(step_us, 0.5, 0.0, 4),
            i as u64,
        )))
    };
    let cfg = VecConfig {
        num_envs: 8,
        num_workers: 4,
        batch_size: 2,
        ..Default::default()
    };
    let mut v = Multiprocessing::from_factory(factory, cfg).unwrap();
    let slots = v.action_dims().len();
    let rows = v.batch_rows();
    let mut seen = [0usize; 8];
    v.async_reset(0);
    for _ in 0..200 {
        let ids = {
            let b = v.recv().unwrap();
            b.env_ids.to_vec()
        };
        for e in ids {
            seen[e] += 1;
        }
        v.send(&vec![0i32; rows * slots]).unwrap();
    }
    assert!(
        seen.iter().all(|&c| c > 0),
        "some env never appeared: {seen:?}"
    );
}

#[test]
fn trainer_improves_bandit_and_checkpoints() {
    let dir = std::env::temp_dir().join("puffer_it_bandit");
    let cfg = TrainConfig {
        env: "ocean/bandit".into(),
        total_steps: 16_000,
        log_every: 0,
        run_dir: Some(dir.to_str().unwrap().to_string()),
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    let score = report.mean_score.expect("episodes finished");
    assert!(
        score > 0.75,
        "bandit should be mostly solved by 16k steps, got {score}"
    );
    assert!(report.episodes > 1000);

    // Checkpoint round trip through the trainer.
    let ck = trainer.checkpoint();
    ck.save(dir.join("ck.bin")).unwrap();
    let back = Checkpoint::load(dir.join("ck.bin")).unwrap();
    assert_eq!(back.params, trainer.policy().params());
    let mut trainer2 = Trainer::native(TrainConfig {
        env: "ocean/bandit".into(),
        total_steps: 16_000,
        log_every: 0,
        ..Default::default()
    })
    .unwrap();
    trainer2.restore(&back).unwrap();
    assert_eq!(trainer2.global_step(), report.global_step);

    // Restored policy evaluates well immediately.
    let eval = trainer2.eval(50).unwrap();
    assert!(
        eval.mean_score.unwrap() > 0.7,
        "restored eval score {:?}",
        eval.mean_score
    );

    // metrics.csv was written with a header and rows.
    let csv = std::fs::read_to_string(dir.join("metrics.csv")).unwrap();
    assert!(csv.starts_with("global_step,"));
    assert!(csv.lines().count() > 3);
}

#[test]
fn trainer_pool_mode_runs() {
    let cfg = TrainConfig {
        env: "ocean/stochastic".into(),
        total_steps: 4_096,
        pool: true,
        num_workers: 2,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    assert!(report.global_step >= 4_096);
    assert!(report.episodes > 0, "episodes must complete in pool mode");
}

#[test]
fn trainer_multiagent_runs() {
    let cfg = TrainConfig {
        env: "ocean/multiagent".into(),
        total_steps: 8_192,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    // Identity routing is learnable fast; anything above random (0.5)
    // proves rows aren't crossed. (Full solve is covered by bench C3.)
    assert!(
        report.mean_score.unwrap_or(0.0) > 0.55,
        "multiagent score {:?} suggests crossed agent rows",
        report.mean_score
    );
}

/// The pipelined path (collector thread + minibatched learner) must clear
/// the same learning threshold the serial bandit test uses, despite
/// one-segment-stale rollout parameters.
#[test]
fn trainer_improves_bandit_pipelined() {
    let cfg = TrainConfig {
        env: "ocean/bandit".into(),
        total_steps: 16_000,
        pipeline_depth: 1,
        minibatches: 2,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    let score = report.mean_score.expect("episodes finished");
    assert!(
        score > 0.75,
        "pipelined bandit should be mostly solved by 16k steps, got {score}"
    );
    assert!(report.episodes > 1000);
    // The overlap actually happened: collection and learning both
    // recorded active time, and the step count matched the budget.
    assert!(report.env_sps > 0.0 && report.learn_sps > 0.0);
    assert!(report.global_step >= 16_000);
    // Publish-before-recycle bounds rollout staleness by the depth.
    assert!(
        report.max_param_staleness <= 1,
        "depth-1 staleness {}",
        report.max_param_staleness
    );
}

/// Pipelined multiagent: agent-row routing must survive the segment
/// handoff between collector and learner.
#[test]
fn trainer_multiagent_pipelined() {
    let cfg = TrainConfig {
        env: "ocean/multiagent".into(),
        total_steps: 8_192,
        pipeline_depth: 1,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    assert!(
        report.mean_score.unwrap_or(0.0) > 0.55,
        "pipelined multiagent score {:?} suggests crossed agent rows",
        report.mean_score
    );
}

/// Pool mode (M = 2N EnvPool semantics) composed with the pipelined
/// trainer — the paper's double-buffered simulation feeding an overlapped
/// learner.
#[test]
fn trainer_pool_mode_pipelined_runs() {
    let cfg = TrainConfig {
        env: "ocean/stochastic".into(),
        total_steps: 4_096,
        pool: true,
        num_workers: 2,
        pipeline_depth: 1,
        minibatches: 2,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    assert!(report.global_step >= 4_096);
    assert!(report.episodes > 0, "episodes must complete in pooled pipelined mode");
}

/// The native backend must synthesize a valid spec (shape contract,
/// geometry divisibility) for every trainable first-party env —
/// including recurrent reference envs, which now resolve an LSTM
/// default architecture instead of hard-erroring.
#[test]
fn native_backend_covers_all_trainable_envs() {
    use pufferlib::backend::native::requires_recurrence;
    use pufferlib::backend::{NativeBackend, PolicyBackend as _};
    for &env in envs::OCEAN_ENVS.iter().chain(&["classic/cartpole", "profile/nmmo"]) {
        let probe = envs::make(env, 0);
        let mut b = NativeBackend::for_env(env, probe.as_ref())
            .unwrap_or_else(|e| panic!("{env}: {e}"));
        let spec = b.spec().clone();
        assert_eq!(spec.obs_dim, probe.obs_layout().flat_len(), "{env}");
        assert_eq!(spec.act_dims, probe.action_dims(), "{env}");
        assert_eq!(spec.agents, probe.num_agents(), "{env}");
        assert_eq!(spec.batch_roll % spec.agents, 0, "{env}");
        assert_eq!(spec.lstm, requires_recurrence(env), "{env}: default recurrence");
        let params = b.init_params().unwrap();
        assert_eq!(params.len(), spec.n_params, "{env}");
    }
}

/// A recurrent env trains past its reward threshold on the **native**
/// backend (serial path). `ocean/memory` is unsolvable without
/// recurrence — a memoryless policy scores 0.5 in expectation (the
/// recall-phase observations are identical), so clearing 0.7 proves the
/// LSTM sandwich plus its BPTT gradients work end to end. The small
/// `--policy.*`-style spec (48-wide trunk/state) keeps the scalar BPTT
/// affordable at test opt-level.
#[test]
fn trainer_improves_memory_native_serial() {
    use pufferlib::policy::PolicySpec;
    let cfg = TrainConfig {
        env: "ocean/memory".into(),
        total_steps: 49_152,
        policy: Some(PolicySpec::default().with_hidden(48).with_lstm(48)),
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    let score = report.mean_score.expect("episodes finished");
    assert!(
        score > 0.7,
        "memory should train well past chance (0.5) by 49k steps, got {score}"
    );
    assert!(report.episodes > 1000);
}

/// The same recurrent env through the pipelined trainer (depth ≥ 1):
/// collector-side LSTM state, episode-start carry across rotated
/// buffers, and whole-row BPTT minibatches must all survive the
/// collector/learner handoff.
#[test]
fn trainer_improves_memory_native_pipelined() {
    use pufferlib::policy::PolicySpec;
    let cfg = TrainConfig {
        env: "ocean/memory".into(),
        total_steps: 49_152,
        policy: Some(PolicySpec::default().with_hidden(48).with_lstm(48)),
        pipeline_depth: 1,
        minibatches: 2,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    let score = report.mean_score.expect("episodes finished");
    assert!(
        score > 0.7,
        "pipelined memory should train well past chance by 49k steps, got {score}"
    );
    assert!(report.max_param_staleness <= 1);
}

/// PJRT path: the AOT manifest must cover every trainable env with
/// matching shapes (requires `make artifacts`).
#[cfg(feature = "pjrt")]
#[test]
fn manifest_covers_all_trainable_envs() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = pufferlib::runtime::Runtime::new("artifacts").unwrap();
    for env in envs::OCEAN_ENVS {
        let key = pufferlib::runtime::Manifest::spec_key_for_env(env);
        let probe = envs::make(env, 0);
        rt.check_env_contract(
            &key,
            probe.obs_layout().flat_len(),
            probe.action_dims(),
            probe.num_agents(),
        )
        .unwrap_or_else(|e| panic!("{env}: {e}"));
    }
}
