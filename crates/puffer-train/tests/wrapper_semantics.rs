//! Vectorized wrapper semantics: the wrapper pipeline must (a) preserve
//! the no-copy property of the `Sync`/`ZeroCopy` paths, (b) agree on the
//! stacked layout between the probe env and the worker slabs, and (c)
//! apply wrapper order identically across `Serial` and `Multiprocessing`.

use pufferlib::vector::{Mode, Multiprocessing, Serial, VecConfig, VecEnv};
use pufferlib::wrappers::EnvSpec;

fn cfg(num_envs: usize, num_workers: usize, batch_size: usize, zero_copy: bool) -> VecConfig {
    VecConfig {
        num_envs,
        num_workers,
        batch_size,
        zero_copy,
        ..Default::default()
    }
}

/// (a) Sync path: with an in-place wrapper chain, every recv hands back
/// the same slab region (the batch IS the shared memory, no gather copy),
/// and the mode resolution is unchanged by wrapping.
#[test]
fn in_place_wrappers_keep_sync_path_no_copy() {
    let spec = EnvSpec::new("ocean/squared").clip_reward(1.0).normalize_obs();
    let mut v = Multiprocessing::from_spec(&spec, cfg(8, 2, 8, false)).unwrap();
    assert_eq!(v.mode(), Mode::Sync);
    let rows = v.batch_rows();
    let w = v.obs_layout().byte_len();
    v.async_reset(0);
    let slots = v.action_dims().len();
    let mut ptrs = Vec::new();
    for _ in 0..6 {
        {
            let b = v.recv().unwrap();
            assert_eq!(b.obs.len(), rows * w);
            ptrs.push(b.obs.as_ptr() as usize);
        }
        v.send(&vec![0i32; rows * slots]).unwrap();
    }
    assert!(
        ptrs.windows(2).all(|p| p[0] == p[1]),
        "Sync batch moved between recvs — a copy appeared: {ptrs:?}"
    );
}

/// (a) ZeroCopy path: batches rotate through the fixed slab bands (two
/// bands here), never through a gather buffer — even with a chain that
/// includes a row-widening stack.
#[test]
fn wrapped_zero_copy_path_rotates_slab_bands() {
    let spec = EnvSpec::new("ocean/squared").clip_reward(1.0).stack(4);
    let mut v = Multiprocessing::from_spec(&spec, cfg(8, 4, 4, true)).unwrap();
    assert_eq!(v.mode(), Mode::ZeroCopy);
    let rows = v.batch_rows();
    let w = v.obs_layout().byte_len();
    v.async_reset(0);
    let slots = v.action_dims().len();
    let mut ptrs = Vec::new();
    for _ in 0..8 {
        {
            let b = v.recv().unwrap();
            assert_eq!(b.obs.len(), rows * w);
            ptrs.push(b.obs.as_ptr() as usize);
        }
        v.send(&vec![0i32; rows * slots]).unwrap();
    }
    // Two bands: recvs alternate between exactly two fixed addresses,
    // one band-width (batch bytes) apart.
    let distinct: std::collections::BTreeSet<usize> = ptrs.iter().copied().collect();
    assert_eq!(distinct.len(), 2, "expected 2 rotating bands: {ptrs:?}");
    let band: Vec<usize> = distinct.into_iter().collect();
    assert_eq!(band[1] - band[0], rows * w, "bands are not adjacent slab regions");
    for (i, p) in ptrs.iter().enumerate() {
        assert_eq!(*p, band[i % 2], "band rotation broke at recv {i}");
    }
}

/// (b) The probe env and the worker slabs agree on the stacked layout:
/// slab sizing, env-side rows, and the advertised layout all derive from
/// the same wrapped spec.
#[test]
fn stacked_layout_agrees_between_probe_and_worker_slabs() {
    let spec = EnvSpec::new("classic/cartpole").stack(4);
    let probe = spec.build(0);
    let bare = EnvSpec::new("classic/cartpole").build(0);
    assert_eq!(probe.obs_layout().byte_len(), 4 * bare.obs_layout().byte_len());

    let mut v = Multiprocessing::from_spec(&spec, cfg(4, 2, 4, false)).unwrap();
    assert_eq!(v.obs_layout().byte_len(), probe.obs_layout().byte_len());
    assert_eq!(v.obs_layout().flat_len(), probe.obs_layout().flat_len());
    v.async_reset(3);
    let b = v.recv().unwrap();
    assert_eq!(b.obs.len(), 4 * probe.obs_layout().byte_len());
    // Reset fills all 4 frames with the first observation: each row is
    // the same frame repeated 4 times.
    let w = bare.obs_layout().byte_len();
    for row in b.obs.chunks_exact(4 * w) {
        let first = &row[..w];
        for f in 1..4 {
            assert_eq!(&row[f * w..(f + 1) * w], first, "reset frames differ");
        }
    }
}

/// Drive a venv for `steps` and collect (env_id, reward) in batch order.
fn reward_trace(v: &mut dyn VecEnv, steps: usize, action: i32) -> Vec<(usize, f32)> {
    let slots = v.action_dims().len();
    let rows = v.batch_rows();
    let agents = v.agents_per_env();
    v.async_reset(11);
    let mut trace = Vec::new();
    for _ in 0..steps {
        {
            let b = v.recv().unwrap();
            for (i, &e) in b.env_ids.iter().enumerate() {
                trace.push((e, b.rewards[i * agents]));
            }
        }
        v.send(&vec![action; rows * slots]).unwrap();
    }
    trace
}

/// (c) Wrapper order matters: scale-then-clip and clip-then-scale give
/// different rewards — and each chain gives identical results on Serial
/// and on Multiprocessing (sync), step for step.
#[test]
fn wrapper_order_matters_and_is_stable_across_backends() {
    // Cartpole pays reward 1.0 every step, deterministically.
    let scale_then_clip = EnvSpec::new("classic/cartpole").scale_reward(2.0).clip_reward(0.5);
    let clip_then_scale = EnvSpec::new("classic/cartpole").clip_reward(0.5).scale_reward(2.0);

    let mut serial_a = Serial::from_spec(&scale_then_clip, cfg(4, 1, 4, false)).unwrap();
    let mut serial_b = Serial::from_spec(&clip_then_scale, cfg(4, 1, 4, false)).unwrap();
    let mut mp_a = Multiprocessing::from_spec(&scale_then_clip, cfg(4, 2, 4, false)).unwrap();
    let mut mp_b = Multiprocessing::from_spec(&clip_then_scale, cfg(4, 2, 4, false)).unwrap();

    let t_serial_a = reward_trace(&mut serial_a, 30, 1);
    let t_serial_b = reward_trace(&mut serial_b, 30, 1);
    let t_mp_a = reward_trace(&mut mp_a, 30, 1);
    let t_mp_b = reward_trace(&mut mp_b, 30, 1);

    // Order matters: ×2 then clip → 0.5; clip then ×2 → 1.0.
    // (First recv after reset carries zero rewards on every backend.)
    assert!(t_serial_a[4..].iter().all(|&(_, r)| r == 0.5), "{t_serial_a:?}");
    assert!(t_serial_b[4..].iter().all(|&(_, r)| r == 1.0), "{t_serial_b:?}");
    assert_ne!(t_serial_a, t_serial_b);

    // Stable across backends: identical traces, step for step.
    assert_eq!(t_serial_a, t_mp_a);
    assert_eq!(t_serial_b, t_mp_b);
}

/// (c) continued: a full chain (repeat + clip + stack) behaves
/// identically on Serial and Multiprocessing, including obs bytes.
#[test]
fn full_chain_identical_on_serial_and_multiprocessing() {
    let spec = EnvSpec::new("classic/cartpole").action_repeat(2).clip_reward(0.75).stack(2);
    let mut serial = Serial::from_spec(&spec, cfg(4, 1, 4, false)).unwrap();
    let mut mp = Multiprocessing::from_spec(&spec, cfg(4, 2, 4, false)).unwrap();

    let slots = serial.action_dims().len();
    let rows = serial.batch_rows();
    serial.async_reset(5);
    mp.async_reset(5);
    for step in 0..25 {
        let (obs_s, rew_s, term_s): (Vec<u8>, Vec<f32>, Vec<bool>) = {
            let b = serial.recv().unwrap();
            (b.obs.to_vec(), b.rewards.to_vec(), b.terms.to_vec())
        };
        let (obs_m, rew_m, term_m): (Vec<u8>, Vec<f32>, Vec<bool>) = {
            let b = mp.recv().unwrap();
            (b.obs.to_vec(), b.rewards.to_vec(), b.terms.to_vec())
        };
        assert_eq!(obs_s, obs_m, "obs diverged at step {step}");
        assert_eq!(rew_s, rew_m, "rewards diverged at step {step}");
        assert_eq!(term_s, term_m, "terms diverged at step {step}");
        let actions = vec![1i32; rows * slots];
        serial.send(&actions).unwrap();
        mp.send(&actions).unwrap();
    }
}
