//! Experiment-ops integration: the run registry, resumable sweeps, and
//! the `puffer ps`/`top` read side, driven end-to-end on the serial
//! `ocean/bandit` env. Serial bandit trains in 1024-step segments, so a
//! budget of 1 rounds up to exactly one segment — phases of
//! fresh-train / kill / resume / skip stay cheap and deterministic.

use pufferlib::prelude::*;
use pufferlib::runs::sweep::{self, ChildStatus};
use pufferlib::runs::{
    fsio, ps_table, snapshot, top_frame, DerivedStatus, Registry, RunRecord, RunStatus, RunsConfig,
};
use pufferlib::util::json::Json;
use std::path::{Path, PathBuf};

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("puffer_experiment_ops").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A 2×2 bandit grid (seed × lr) with registry root and sweep base both
/// absolute under `dir`, so tests never depend on the process cwd.
fn sweep_spec(dir: &Path, total_steps: u64) -> RunSpec {
    let root = dir.join("registry").to_str().unwrap().to_string();
    let base = dir.join("sweep").to_str().unwrap().to_string();
    let mut spec = RunSpec::new(EnvSpec::new("ocean/bandit"))
        .with_vec(VecSpec::Serial)
        .with_seed(11)
        .with_runs(RunsConfig { root, heartbeat_s: 1.0 })
        .with_train(|t| {
            t.total_steps = total_steps;
            t.log_every = 0;
            t.run_dir = Some(base);
        });
    spec.grid.insert("seed".into(), vec!["1".into(), "2".into()]);
    spec.grid.insert("train.lr".into(), vec!["0.002".into(), "0.003".into()]);
    spec
}

#[test]
fn sweep_survives_a_hard_kill_and_resumes_without_duplicate_records() {
    let dir = temp_dir("kill_resume");
    let reg = Registry::new(dir.join("registry"));

    // Phase 1: a tiny budget stands in for the progress a killed sweep
    // had banked — every child trains one 1024-step segment.
    let children = sweep_spec(&dir, 1).expand_grid().unwrap();
    assert_eq!(children.len(), 4);
    let outcomes = sweep::run_resumable(&reg, &children, 2, |_| {}).unwrap();
    for o in &outcomes {
        assert!(matches!(o.status, ChildStatus::Done(Some(_))), "{}: {:?}", o.label, o.status);
        assert!(!o.resumed, "{}", o.label);
    }
    let recs = reg.list().unwrap();
    assert_eq!(recs.len(), 4);
    assert!(recs.iter().all(|r| r.status == RunStatus::Done && r.attempt == 1));

    // Simulate a SIGKILL mid-run on one child: a `running` record under
    // a pid that no longer exists, heartbeat gone — exactly what kill -9
    // leaves on disk.
    let victim = children[0].train.run_dir.clone().unwrap();
    let mut rec = Registry::load(&victim).unwrap().unwrap();
    rec.status = RunStatus::Running;
    rec.host = fsio::hostname();
    rec.pid = 4_294_900_000; // above Linux PID_MAX_LIMIT: never a live pid
    rec.started_ms = rec.started_ms.saturating_sub(3_600_000);
    rec.ended_ms = 0;
    reg.write(&rec).unwrap();
    let _ = std::fs::remove_file(Path::new(&victim).join("heartbeat.json"));
    let views = snapshot(&reg).unwrap();
    let orphan = views.iter().find(|v| v.rec.run_dir == victim).unwrap();
    assert_eq!(orphan.derived(fsio::now_ms()), DerivedStatus::Stale);

    // Phase 2: double the budget and re-invoke. The orphan is reclaimed
    // (a `killed` transition lands in the event log) and all four
    // children resume from their phase-1 checkpoints instead of
    // retraining from scratch.
    let children = sweep_spec(&dir, 2048).expand_grid().unwrap();
    let outcomes = sweep::run_resumable(&reg, &children, 2, |_| {}).unwrap();
    for o in &outcomes {
        assert!(o.resumed, "{} should resume, not retrain", o.label);
        match &o.status {
            ChildStatus::Done(Some(r)) => assert_eq!(r.global_step, 2048, "{}", o.label),
            other => panic!("{}: {other:?}", o.label),
        }
    }
    let recs = reg.list().unwrap();
    assert_eq!(recs.len(), 4, "one record per child, no duplicates");
    for r in &recs {
        assert_eq!(r.status, RunStatus::Done, "{}", r.run_dir);
        assert_eq!(r.attempt, 2, "{}", r.run_dir);
        assert_eq!(r.total_steps, 2048, "budget extension absorbed into the record");
        assert!(r.checkpoint.is_some(), "{}", r.run_dir);
        assert_eq!(r.metrics.as_ref().unwrap().global_step, 2048, "{}", r.run_dir);
    }
    let index = std::fs::read_to_string(reg.index_path()).unwrap();
    assert!(index.contains("\"killed\""), "orphan reclaim must be logged:\n{index}");

    // Phase 3: same budget again — everything skips, nothing retrains,
    // attempts stay where they were.
    let outcomes = sweep::run_resumable(&reg, &children, 2, |_| {}).unwrap();
    for o in &outcomes {
        match &o.status {
            ChildStatus::Skipped(why) => assert!(why.contains("at budget"), "{why}"),
            other => panic!("{}: {other:?}", o.label),
        }
    }
    let recs = reg.list().unwrap();
    assert!(recs.iter().all(|r| r.status == RunStatus::Done && r.attempt == 2));
}

#[test]
fn a_panicking_child_fails_alone_and_retries_clean() {
    let dir = temp_dir("panic_isolation");
    let reg = Registry::new(dir.join("registry"));
    let children = sweep_spec(&dir, 1).expand_grid().unwrap();
    let victim = children
        .iter()
        .find_map(|c| {
            let d = c.train.run_dir.clone().unwrap();
            d.ends_with("seed=2+train.lr=0.003").then_some(d)
        })
        .unwrap();

    // The injection hook matches a substring of the trainer's run dir;
    // the victim's absolute path is unique to this test's temp tree, so
    // trainers running concurrently in sibling tests never trip it.
    // (This is the only test in the binary that touches the hook — the
    // env map is process-global.)
    std::env::set_var("PUFFER_TEST_TRAIN_PANIC", &victim);
    let mut events = 0usize;
    let outcomes = sweep::run_resumable(&reg, &children, 2, |_| events += 1).unwrap();
    assert_eq!(events, 4, "every child reports even when one panics");
    let failed: Vec<_> = outcomes.iter().filter(|o| o.failed()).collect();
    assert_eq!(failed.len(), 1, "exactly the injected child fails");
    assert_eq!(failed[0].run_dir, victim);
    match &failed[0].status {
        ChildStatus::Failed(msg) => {
            assert!(msg.contains("panicked"), "{msg}");
            assert!(msg.contains("PUFFER_TEST_TRAIN_PANIC"), "{msg}");
        }
        other => panic!("{other:?}"),
    }
    let recs = reg.list().unwrap();
    assert_eq!(recs.len(), 4);
    assert_eq!(recs.iter().filter(|r| r.status == RunStatus::Done).count(), 3);
    let failed_rec = recs.iter().find(|r| r.status == RunStatus::Failed).unwrap();
    assert_eq!(failed_rec.run_dir, victim);
    assert!(failed_rec.error.as_deref().unwrap().contains("panicked"));

    // Disarm the hook and re-invoke: the at-budget siblings skip, the
    // failed child retrains, and the registry converges to four `done`
    // records.
    std::env::set_var("PUFFER_TEST_TRAIN_PANIC", "::disarmed::");
    let outcomes = sweep::run_resumable(&reg, &children, 2, |_| {}).unwrap();
    let done: Vec<_> = outcomes
        .iter()
        .filter(|o| matches!(o.status, ChildStatus::Done(Some(_))))
        .collect();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].run_dir, victim);
    assert_eq!(
        outcomes.iter().filter(|o| matches!(o.status, ChildStatus::Skipped(_))).count(),
        3
    );
    let recs = reg.list().unwrap();
    assert!(recs.iter().all(|r| r.status == RunStatus::Done), "{recs:?}");
    assert_eq!(recs.iter().find(|r| r.run_dir == victim).unwrap().attempt, 2);
}

#[test]
fn process_mode_sweep_is_reinvocable_and_ps_reports_the_fleet() {
    let dir = temp_dir("process_mode");
    let root = dir.join("registry");
    let spec = sweep_spec(&dir, 1);
    let spec_path = dir.join("sweep.toml");
    std::fs::write(&spec_path, spec.to_toml().unwrap()).unwrap();
    let exe = env!("CARGO_BIN_EXE_puffer");

    let sweep_cmd = || {
        std::process::Command::new(exe)
            .args(["sweep", spec_path.to_str().unwrap(), "--processes=2"])
            .output()
            .unwrap()
    };
    let out = sweep_cmd();
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(
        out.status.success(),
        "sweep failed:\nstdout:\n{stdout}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(stdout.matches("[done]").count(), 4, "{stdout}");

    // Re-invoking the exact same command is a no-op: every at-budget
    // child skips, and the summary says so.
    let out = sweep_cmd();
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(out.status.success(), "{stdout}");
    assert_eq!(stdout.matches("[skip]").count(), 4, "{stdout}");
    assert!(stdout.contains("4 skipped"), "{stdout}");

    // `puffer ps --json` over the same registry root: four `done`
    // records, one per run dir, each with a checkpoint and final
    // metrics, plus the per-child artifacts on disk.
    let ps = std::process::Command::new(exe)
        .args(["ps", &format!("--runs.root={}", root.to_str().unwrap()), "--json"])
        .output()
        .unwrap();
    assert!(ps.status.success(), "{}", String::from_utf8_lossy(&ps.stderr));
    let text = String::from_utf8_lossy(&ps.stdout).to_string();
    let json = Json::parse(&text).unwrap();
    let items = json.as_arr().unwrap();
    assert_eq!(items.len(), 4, "{text}");
    let mut dirs = std::collections::BTreeSet::new();
    for item in items {
        assert_eq!(item.get("status").as_str(), Some("done"), "{text}");
        assert_eq!(item.get("derived_status").as_str(), Some("done"), "{text}");
        assert_eq!(item.get("attempt").as_f64(), Some(1.0), "{text}");
        assert!(item.get("checkpoint").as_str().unwrap().ends_with("checkpoint.bin"), "{text}");
        assert!(item.get("metrics").get("global_step").as_f64().unwrap() >= 1024.0, "{text}");
        let run_dir = item.get("run_dir").as_str().unwrap();
        assert!(dirs.insert(run_dir.to_string()), "duplicate run dir: {text}");
        assert!(Path::new(run_dir).join("child.log").is_file(), "{run_dir}");
        assert!(Path::new(run_dir).join("spec.toml").is_file(), "{run_dir}");
    }

    // The human-readable table renders the same states.
    let ps = std::process::Command::new(exe)
        .args(["ps", &format!("--runs.root={}", root.to_str().unwrap())])
        .output()
        .unwrap();
    assert!(ps.status.success());
    let table = String::from_utf8_lossy(&ps.stdout).to_string();
    assert!(table.starts_with("STATUS"), "{table}");
    assert_eq!(table.matches("\ndone").count(), 4, "{table}");
}

#[test]
fn ps_flags_an_orphaned_running_record_as_stale() {
    let dir = temp_dir("stale_orphan");
    let reg = Registry::new(dir.join("registry"));
    let run_dir = dir.join("run").to_str().unwrap().to_string();
    let now = fsio::now_ms();
    let rec = RunRecord {
        run_dir: run_dir.clone(),
        label: "run".into(),
        env: "ocean/bandit".into(),
        seed: 1,
        total_steps: 8192,
        spec_fingerprint: String::new(),
        status: RunStatus::Running,
        attempt: 1,
        host: fsio::hostname(),
        pid: 4_294_900_000, // above Linux PID_MAX_LIMIT: never a live pid
        created_ms: now.saturating_sub(120_000),
        started_ms: now.saturating_sub(90_000),
        ended_ms: 0,
        exit_code: None,
        error: None,
        checkpoint: None,
        metrics: None,
    };
    std::fs::create_dir_all(&run_dir).unwrap();
    reg.write(&rec).unwrap();

    let views = snapshot(&reg).unwrap();
    assert_eq!(views.len(), 1);
    assert_eq!(views[0].derived(now), DerivedStatus::Stale);
    let table = ps_table(&views, now);
    assert!(table.starts_with("STATUS"), "{table}");
    assert!(table.contains("stale"), "{table}");
    let frame = top_frame(&views, now);
    assert!(frame.contains("1 stale"), "{frame}");
    assert!(frame.contains(&run_dir), "a stale orphan stays on the in-flight board: {frame}");
}
