//! PolicySpec architecture suite: the recurrent-state lifecycle
//! (reset-on-done across auto-reset, state carry across pipelined buffer
//! rotation), cross-architecture checkpoint rejection, and the
//! bit-identical-default guarantee (the resolved default architecture
//! replays the exact pre-PolicySpec parameter stream).

use pufferlib::backend::{NativeBackend, PolicyBackend};
use pufferlib::emulation::{Info, PufferEnv, StructuredEnv};
use pufferlib::policy::{Policy, PolicySpec};
use pufferlib::spaces::{Space, Value};
use pufferlib::train::{collect_rollout, EpisodeLog, RolloutBuffer, TrainConfig, Trainer};
use pufferlib::util::rng::Rng;
use pufferlib::vector::{Serial, VecConfig, VecEnv};
use pufferlib::wrappers::EnvSpec;

/// The exact pre-PolicySpec `NativeBackend::init_params` body: seed
/// hashed from the spec key, then dense draws actor(0.01) → critic →
/// enc1 → enc2, biases zero, weights N(0, scale²/fan_in).
fn legacy_default_init(key: &str, d: usize, a: usize, h: usize) -> Vec<f32> {
    let seed = key
        .bytes()
        .fold(0x4E41_5449u64, |acc, b| acc.wrapping_mul(131).wrapping_add(b as u64));
    let mut rng = Rng::new(seed);
    let mut p = Vec::new();
    let mut dense = |rng: &mut Rng, p: &mut Vec<f32>, fan_in: usize, fan_out: usize, scale: f32| {
        p.extend(std::iter::repeat(0.0).take(fan_out));
        let s = scale / (fan_in as f32).sqrt();
        p.extend((0..fan_in * fan_out).map(|_| rng.normal() as f32 * s));
    };
    dense(&mut rng, &mut p, h, a, 0.01); // actor
    dense(&mut rng, &mut p, h, 1, 1.0); // critic
    dense(&mut rng, &mut p, d, h, 1.0); // enc1
    dense(&mut rng, &mut p, h, h, 1.0); // enc2
    p
}

/// The default PolicySpec must reproduce the pre-refactor parameter
/// vector bit for bit — same key, same seed derivation, same draw
/// order — so existing checkpoints and learning behavior are unchanged.
#[test]
fn default_spec_init_is_bit_identical_to_pre_refactor_replica() {
    for env_name in ["ocean/bandit", "ocean/squared", "classic/cartpole"] {
        let env = pufferlib::envs::make(env_name, 0);
        let mut b = NativeBackend::for_env(env_name, env.as_ref()).unwrap();
        let spec = b.spec().clone();
        let got = b.init_params().unwrap();
        let want = legacy_default_init(
            b.key(),
            spec.obs_dim,
            spec.act_dims.iter().sum(),
            spec.hidden,
        );
        assert_eq!(got.len(), want.len(), "{env_name}: n_params drifted");
        assert_eq!(got, want, "{env_name}: default init stream drifted");
        // And the key itself carries no architecture fragment.
        assert!(!b.key().contains('#'), "{env_name}: default key changed: {}", b.key());
    }
}

/// Deterministic fixed-length env: obs encodes only the episode clock,
/// rewards are zero, actions ignored. Episode length `l`, so the
/// observation stream is exactly periodic with period `l` under
/// auto-reset — which makes recurrent-state hygiene *observable*: the
/// policy's value outputs must be periodic too, iff h/c are zeroed at
/// every episode start and carried everywhere else.
struct Clock {
    l: u32,
    t: u32,
}

impl StructuredEnv for Clock {
    fn observation_space(&self) -> Space {
        Space::boxf(&[2], 0.0, 1.0)
    }
    fn action_space(&self) -> Space {
        Space::Discrete(2)
    }
    fn reset(&mut self, _seed: u64) -> Value {
        self.t = 0;
        Value::F32(vec![0.0, 1.0])
    }
    fn step(&mut self, _action: &Value) -> (Value, f32, bool, bool, Info) {
        self.t += 1;
        let done = self.t >= self.l;
        let obs = Value::F32(vec![self.t as f32 / self.l as f32, 1.0]);
        (obs, 0.0, done, false, Info::new())
    }
}

const EP_LEN: usize = 5;

fn clock_spec() -> EnvSpec {
    EnvSpec::custom("clock", |_| Box::new(PufferEnv::new(Clock { l: EP_LEN as u32, t: 0 })))
}

fn lstm_backend_for_clock() -> NativeBackend {
    let probe = clock_spec().build(0);
    NativeBackend::for_env_with_policy(
        "clock",
        probe.as_ref(),
        &PolicySpec::default().with_hidden(8).with_lstm(8),
    )
    .unwrap()
}

/// Collect `segments` consecutive segments the way the pipelined trainer
/// does — a fresh buffer per segment, episode carry threaded by hand —
/// and return the concatenated per-step values of row 0.
fn collect_values(segments: usize, horizon: usize) -> Vec<f32> {
    let mut backend = lstm_backend_for_clock();
    let num_envs = backend.spec().batch_roll / backend.spec().agents;
    let mut venv = Serial::from_spec(
        &clock_spec(),
        VecConfig {
            num_envs,
            num_workers: 1,
            batch_size: num_envs,
            ..Default::default()
        },
    )
    .unwrap();
    let mut policy = Policy::new(&mut backend, 3).unwrap();
    let rows = backend.spec().batch_roll;
    let slots = backend.spec().act_dims.len();
    let mut log = EpisodeLog::default();
    venv.async_reset(0);
    policy.reset_all_state();

    let mut carry = vec![true; rows];
    let mut values = Vec::new();
    for _ in 0..segments {
        // Buffer rotation: a brand-new buffer each segment, stale flags
        // overwritten by the carry exactly as the collector loop does.
        let mut buf = RolloutBuffer::new(horizon, rows, backend.spec().obs_dim, slots);
        buf.set_episode_carry(&carry);
        collect_rollout(&mut venv, &mut buf, &mut log, |obs, global_rows, done_rows| {
            for &r in done_rows {
                policy.reset_state(r);
            }
            policy.step(&mut backend, obs, global_rows)
        })
        .unwrap();
        carry.copy_from_slice(buf.episode_carry());
        for t in 0..horizon {
            values.push(buf.values[t * rows]); // row 0
        }
    }
    values
}

/// Reset-on-done across auto-reset AND across pipelined buffer
/// rotation: with a deterministic `EP_LEN`-periodic observation stream,
/// the recurrent value outputs must depend only on the episode phase —
/// state zeroed at every episode start (even mid-segment, even when the
/// episode spans a buffer handoff) and carried bitwise everywhere else.
#[test]
fn recurrent_state_lifecycle_is_periodic_across_segments_and_rotation() {
    // horizon 8, EP_LEN 5: episodes straddle every segment boundary, so
    // the carry between rotated buffers is actually exercised.
    const HORIZON: usize = 8;
    let values = collect_values(3, HORIZON);
    assert_eq!(values.len(), 3 * HORIZON);
    // collect_rollout's bootstrap recv advances the env by one un-stored
    // step per segment, so stored index i corresponds to global step
    // i + i/HORIZON; the episode phase is that mod EP_LEN.
    let mut by_phase: Vec<Option<(usize, f32)>> = vec![None; EP_LEN];
    for (i, &v) in values.iter().enumerate() {
        let phase = (i + i / HORIZON) % EP_LEN;
        match by_phase[phase] {
            None => by_phase[phase] = Some((i, v)),
            Some((first_i, first_v)) => assert_eq!(
                v.to_bits(),
                first_v.to_bits(),
                "phase {phase}: value at stored step {i} ({v}) diverged from \
                 stored step {first_i} ({first_v}) — recurrent state leaked \
                 across an episode boundary or was dropped at a buffer rotation"
            ),
        }
    }
    // Sanity: every phase observed, and the stream is NOT constant (the
    // LSTM state actually evolves within an episode, so the test has
    // teeth).
    assert!(by_phase.iter().all(Option::is_some));
    let distinct: std::collections::BTreeSet<u32> =
        by_phase.iter().map(|p| p.unwrap().1.to_bits()).collect();
    assert!(distinct.len() > 1, "clock values constant — state not evolving?");
}

/// reset_state(row) zeroes exactly that row: its next output matches a
/// fresh policy's first step bitwise, while untouched rows keep their
/// carried state.
#[test]
fn reset_state_is_per_row_and_exact() {
    let mut backend = lstm_backend_for_clock();
    let spec = backend.spec().clone();
    let mut policy = Policy::new(&mut backend, 7).unwrap();
    let rows: Vec<usize> = (0..spec.batch_fwd).collect();
    let obs: Vec<f32> = (0..spec.batch_fwd * spec.obs_dim)
        .map(|i| ((i % 5) as f32) * 0.2)
        .collect();

    // Advance state twice, then zero row 0 only.
    policy.step(&mut backend, &obs, &rows).unwrap();
    policy.step(&mut backend, &obs, &rows).unwrap();
    policy.reset_state(0);
    let out = policy.step(&mut backend, &obs, &rows).unwrap();

    // A fresh policy with the same parameters, first step.
    let mut fresh = Policy::new(&mut backend, 99).unwrap();
    fresh.set_params(policy.params());
    let fresh_out = fresh.step(&mut backend, &obs, &rows).unwrap();

    assert_eq!(
        out.values[0].to_bits(),
        fresh_out.values[0].to_bits(),
        "reset row must look freshly initialized"
    );
    assert_ne!(
        out.values[1].to_bits(),
        fresh_out.values[1].to_bits(),
        "non-reset rows must keep their carried state"
    );
}

/// A checkpoint written under one architecture must not restore into
/// another: the arch fragment is part of the spec key, and the error
/// names the architectures.
#[test]
fn cross_architecture_checkpoint_restore_is_rejected() {
    let mk = |policy: Option<PolicySpec>| {
        Trainer::native(TrainConfig {
            env: "ocean/bandit".into(),
            total_steps: 0,
            log_every: 0,
            policy,
            ..Default::default()
        })
        .unwrap()
    };
    let default_trainer = mk(None);
    let ck_default = default_trainer.checkpoint();

    // Wider trunk.
    let mut wide = mk(Some(PolicySpec::default().with_hidden(64)));
    let err = wide.restore(&ck_default).unwrap_err().to_string();
    assert!(err.contains("architecture"), "unhelpful error: {err}");
    assert!(err.contains("h=64"), "should name the trainer arch: {err}");

    // Recurrent vs feedforward.
    let mut rec = mk(Some(PolicySpec::default().with_lstm(128)));
    let err = rec.restore(&ck_default).unwrap_err().to_string();
    assert!(err.contains("lstm=128"), "{err}");
    // And the reverse direction.
    let ck_rec = rec.checkpoint();
    let mut default_again = mk(None);
    let err = default_again.restore(&ck_rec).unwrap_err().to_string();
    assert!(err.contains("architecture"), "{err}");

    // Same (default) arch round-trips fine.
    let mut default_again = mk(None);
    default_again.restore(&ck_default).unwrap();
}

/// An explicitly-passed env-default spec resolves to the same key as no
/// spec at all — `Some(default_for(env))` and `None` are the same
/// architecture, so their checkpoints interchange.
#[test]
fn explicit_default_spec_keeps_the_default_key() {
    let a = Trainer::native(TrainConfig {
        env: "ocean/bandit".into(),
        total_steps: 0,
        log_every: 0,
        ..Default::default()
    })
    .unwrap();
    let mut b = Trainer::native(TrainConfig {
        env: "ocean/bandit".into(),
        total_steps: 0,
        log_every: 0,
        policy: Some(PolicySpec::default_for("ocean/bandit")),
        ..Default::default()
    })
    .unwrap();
    b.restore(&a.checkpoint()).unwrap();
}
