//! Inference-server integration suite: the serving path end-to-end —
//! batched forwards bit-identical to a serial client, per-session
//! recurrent state isolation (interleaved sessions reproduce their solo
//! trajectories), episode reset + idle eviction, deterministic weight
//! hot-swaps with monotone versions and zero dropped requests, the JSON
//! debug protocol, and a scaled-down run of the built-in load
//! generator.
//!
//! Everything runs against synthetic (untrained) checkpoints written by
//! `serve::selftest::write_synthetic_checkpoint` on an ephemeral port,
//! with one shard so reply order is globally deterministic.

use pufferlib::backend::PolicyBackend;
use pufferlib::policy::{greedy_actions, PolicySpec};
use pufferlib::runspec::RunSpec;
use pufferlib::serve::protocol::{self, StepReply, StepRequest};
use pufferlib::serve::selftest::{self, synthetic_obs, write_synthetic_checkpoint};
use pufferlib::serve::{ServeConfig, ServedModel, Server, ServerHandle};
use pufferlib::train::Checkpoint;
use pufferlib::vector::VecSpec;
use pufferlib::wrappers::EnvSpec;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("puffer_serve_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Feedforward spec on the smallest env.
fn ff_spec() -> RunSpec {
    RunSpec::new(EnvSpec::new("ocean/bandit"))
        .with_vec(VecSpec::Serial)
        .with_seed(5)
}

/// The same env with an LSTM sandwich, so replies depend on per-session
/// state.
fn lstm_spec() -> RunSpec {
    ff_spec().with_policy(PolicySpec::default().with_hidden(32).with_lstm(16))
}

/// Write a synthetic checkpoint for `spec` and open it twice: once to
/// serve, once as the serial reference.
fn servable(name: &str, spec: &RunSpec) -> (String, ServedModel, ServedModel) {
    let path = temp_dir(name).join("ckpt.bin");
    let path = path.to_string_lossy().into_owned();
    write_synthetic_checkpoint(spec, &path).unwrap();
    (path.clone(), ServedModel::open(&path).unwrap(), ServedModel::open(&path).unwrap())
}

fn one_shard(max_batch: usize, max_wait_us: u64, session_ttl_s: u64) -> ServeConfig {
    ServeConfig {
        port: 0,
        max_batch,
        max_wait_us,
        session_ttl_s,
        threads: 1,
    }
}

/// A binary-protocol client with the hello handshake done.
struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
    slots: usize,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let s = TcpStream::connect(addr).unwrap();
        let mut w = s.try_clone().unwrap();
        w.write_all(protocol::CLIENT_MAGIC).unwrap();
        let mut r = BufReader::new(s);
        let (_obs_dim, slots) = protocol::read_hello(&mut r).unwrap();
        Client { w, r, slots }
    }

    fn send(&mut self, session: u64, reset: bool, obs: Vec<f32>) {
        protocol::write_request(&mut self.w, &StepRequest { session, reset, obs }).unwrap();
    }

    fn recv(&mut self) -> StepReply {
        protocol::read_reply(&mut self.r, self.slots).unwrap().expect("server closed early")
    }
}

/// Serial reference: run `obs_seq` through the model one row at a time
/// with a private state trajectory, exactly like a dedicated
/// single-session server would.
fn serial_trajectory(
    model: &mut ServedModel,
    params: &[f32],
    obs_seq: &[(bool, Vec<f32>)],
) -> Vec<(f32, Vec<i32>)> {
    let (sd, act_dims) = (model.state_dim(), model.act_dims().to_vec());
    let recurrent = model.recurrent();
    let (mut h, mut c) = (vec![0.0f32; sd], vec![0.0f32; sd]);
    let mut out = Vec::new();
    for (reset, obs) in obs_seq {
        if *reset {
            h.iter_mut().for_each(|v| *v = 0.0);
            c.iter_mut().for_each(|v| *v = 0.0);
        }
        let (logits, value) = if recurrent {
            let fwd = model.backend.forward_lstm(params, obs, &h, &c, 1).unwrap();
            h = fwd.h;
            c = fwd.c;
            (fwd.logits, fwd.values[0])
        } else {
            let fwd = model.backend.forward(params, obs, 1).unwrap();
            (fwd.logits, fwd.values[0])
        };
        out.push((value, greedy_actions(&logits, &act_dims)));
    }
    out
}

fn shutdown(handle: ServerHandle) {
    handle.shutdown().unwrap();
}

#[test]
fn feedforward_batches_are_bit_identical_to_serial() {
    let spec = ff_spec();
    let (_path, model, mut reference) = servable("ff_equality", &spec);
    let params = model.params.clone();
    let obs_dim = model.obs_dim();
    // A generous wait budget so pipelined requests coalesce.
    let handle = Server::start(model, &one_shard(8, 20_000, 300), None).unwrap();

    let mut client = Client::connect(handle.addr());
    let mut sent: Vec<(u64, Vec<f32>)> = Vec::new();
    for step in 0..8u64 {
        for session in 0..4u64 {
            let obs = synthetic_obs(session, step, obs_dim);
            client.send(session, false, obs.clone());
            sent.push((session, obs));
        }
    }
    // One shard + one connection = replies in exact send order.
    for (session, obs) in &sent {
        let rep = client.recv();
        assert_eq!(rep.session, *session);
        assert_eq!(rep.version, 0, "no swap happened");
        let expected = serial_trajectory(&mut reference, &params, &[(false, obs.clone())]);
        assert_eq!(rep.value, expected[0].0, "values must be bit-identical");
        assert_eq!(rep.actions, expected[0].1);
    }
    drop(client);
    shutdown(handle);
}

#[test]
fn interleaved_lstm_sessions_reproduce_their_solo_trajectories() {
    let spec = lstm_spec();
    let (_path, model, mut reference) = servable("lstm_isolation", &spec);
    assert!(model.recurrent(), "spec must resolve to an LSTM policy");
    let params = model.params.clone();
    let obs_dim = model.obs_dim();
    let handle = Server::start(model, &one_shard(8, 20_000, 300), None).unwrap();

    const STEPS: u64 = 10;
    let mut client = Client::connect(handle.addr());
    let mut per_session: Vec<Vec<(f32, Vec<i32>)>> = vec![Vec::new(); 2];
    // Interleave two sessions request-by-request; the batcher may fuse
    // them into shared forwards, but each must evolve its own state.
    for step in 0..STEPS {
        for session in 0..2u64 {
            client.send(session, false, synthetic_obs(session, step, obs_dim));
        }
    }
    for _ in 0..2 * STEPS {
        let rep = client.recv();
        per_session[rep.session as usize].push((rep.value, rep.actions));
    }

    for session in 0..2u64 {
        let solo: Vec<(bool, Vec<f32>)> = (0..STEPS)
            .map(|step| (false, synthetic_obs(session, step, obs_dim)))
            .collect();
        let expected = serial_trajectory(&mut reference, &params, &solo);
        assert_eq!(
            per_session[session as usize], expected,
            "session {session} diverged from its solo trajectory"
        );
    }
    drop(client);
    shutdown(handle);
}

#[test]
fn reset_and_idle_eviction_both_restart_the_state() {
    let spec = lstm_spec();
    let (_path, model, mut reference) = servable("reset_evict", &spec);
    let params = model.params.clone();
    let obs_dim = model.obs_dim();
    // 1-second TTL so the test can observe an eviction sweep.
    let handle = Server::start(model, &one_shard(8, 200, 1), None).unwrap();

    let mut client = Client::connect(handle.addr());
    let first_obs = synthetic_obs(7, 0, obs_dim);
    let fresh = serial_trajectory(&mut reference, &params, &[(false, first_obs.clone())])
        .remove(0);

    // Three steps of state, then an in-band reset: the reply must match
    // a fresh session bit for bit.
    for step in 0..3u64 {
        client.send(7, false, synthetic_obs(7, step, obs_dim));
        let rep = client.recv();
        if step == 0 {
            assert_eq!((rep.value, rep.actions), fresh.clone());
        }
    }
    client.send(7, true, first_obs.clone());
    let rep = client.recv();
    assert_eq!((rep.value, rep.actions), fresh.clone(), "reset must zero the state");

    // Idle past the TTL; traffic on another session triggers the sweep.
    std::thread::sleep(std::time::Duration::from_millis(1300));
    client.send(8, false, synthetic_obs(8, 0, obs_dim));
    client.recv();
    assert!(
        handle.stats().evicted.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "idle session 7 should have been evicted"
    );

    // Same id, no reset flag: the evicted session restarts from zeros.
    client.send(7, false, first_obs);
    let rep = client.recv();
    assert_eq!((rep.value, rep.actions), fresh, "evicted session must restart fresh");
    drop(client);
    shutdown(handle);
}

#[test]
fn hot_swap_is_monotone_lossless_and_uses_the_new_weights() {
    let spec = ff_spec();
    let (_path, model, mut reference) = servable("hot_swap", &spec);
    let old_params = model.params.clone();
    let new_params: Vec<f32> = old_params.iter().map(|p| p * 1.5 + 0.01).collect();
    let obs_dim = model.obs_dim();
    let handle = Server::start(model, &one_shard(8, 200, 300), None).unwrap();

    let mut client = Client::connect(handle.addr());
    let mut last_version = 0u64;
    let mut swapped_seen = false;
    for step in 0..60u64 {
        if step == 30 {
            assert_eq!(handle.publish_params(&new_params).unwrap(), 1);
        }
        let obs = synthetic_obs(step % 4, step, obs_dim);
        client.send(step % 4, false, obs.clone());
        let rep = client.recv();
        assert!(rep.version >= last_version, "versions must be monotone");
        last_version = rep.version;
        // The version in the reply names the weights that computed it.
        let params = if rep.version == 0 { &old_params } else { &new_params };
        let expected = serial_trajectory(&mut reference, params, &[(false, obs)]).remove(0);
        assert_eq!((rep.value, rep.actions), expected);
        swapped_seen |= rep.version == 1;
    }
    assert!(swapped_seen, "requests after publish must see version 1");

    // Wrong-size weights are rejected before they can reach a batch.
    let err = handle.publish_params(&new_params[1..]).unwrap_err().to_string();
    assert!(err.contains("parameters"), "got: {err}");
    drop(client);
    shutdown(handle);
}

#[test]
fn json_debug_mode_matches_the_binary_protocol() {
    let spec = ff_spec();
    let (_path, model, _reference) = servable("json_mode", &spec);
    let obs_dim = model.obs_dim();
    let handle = Server::start(model, &one_shard(8, 200, 300), None).unwrap();

    // Binary reply for the reference.
    let mut bin = Client::connect(handle.addr());
    let obs = synthetic_obs(3, 0, obs_dim);
    bin.send(3, false, obs.clone());
    let expected = bin.recv();

    // Same request as a JSON line.
    let stream = TcpStream::connect(handle.addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = std::io::BufRead::lines(BufReader::new(stream));
    writeln!(
        w,
        "{}",
        protocol::request_to_json(&StepRequest { session: 3, reset: false, obs })
    )
    .unwrap();
    let hello = r.next().unwrap().unwrap();
    assert!(hello.contains("puffer-serve"), "got hello: {hello}");
    let reply = protocol::reply_from_json(&r.next().unwrap().unwrap()).unwrap();
    assert_eq!(reply.session, expected.session);
    assert_eq!(reply.actions, expected.actions);
    assert_eq!(reply.value, expected.value, "JSON numbers round-trip f32 exactly");
    drop(w);
    drop(r);
    drop(bin);
    shutdown(handle);
}

#[test]
fn spec_less_v2_checkpoints_fail_with_an_actionable_error() {
    let dir = temp_dir("spec_less");
    let path = dir.join("bare.bin");
    let n = 8;
    Checkpoint {
        spec_key: "mystery".into(),
        run_spec_json: None,
        global_step: 0,
        params: vec![0.0; n],
        adam_m: vec![0.0; n],
        adam_v: vec![0.0; n],
        adam_step: 0.0,
    }
    .save(&path)
    .unwrap();
    let err = ServedModel::open(path.to_str().unwrap()).unwrap_err().to_string();
    assert!(err.contains("no embedded RunSpec"), "got: {err}");
}

#[test]
fn scaled_down_selftest_sustains_load_without_drops() {
    let spec = lstm_spec();
    let path = temp_dir("selftest").join("ckpt.bin");
    let path = path.to_string_lossy().into_owned();
    write_synthetic_checkpoint(&spec, &path).unwrap();
    let cfg = one_shard(16, 500, 300);
    let st = selftest::SelftestConfig {
        requests: 800,
        sessions: 32,
        clients: 4,
        window: 8,
        hot_swap: true,
    };
    let report = selftest::run(&path, &cfg, &st).unwrap();
    assert_eq!(report.requests, 800);
    assert_eq!(report.dropped, 0, "every accepted request must be answered");
    assert_eq!(report.sessions, 32);
    assert!(report.max_version >= 1, "the hot-swap leg must land");
    assert!(report.batches >= 1 && report.occupancy >= 1.0);
}
