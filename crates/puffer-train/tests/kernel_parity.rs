//! Kernel-path parity: the SIMD kernels against the scalar reference,
//! the scalar path against the golden JAX fixtures, and the
//! determinism guarantees the kernel module documents.
//!
//! Four layers of pinning:
//!
//! 1. **Scalar = golden.** With `KernelPath::Scalar` forced, the
//!    fixture assertions of `native_parity.rs` must still hold — the
//!    scalar kernels are the pre-kernel-module math, moved verbatim.
//! 2. **Simd ≈ scalar.** Forward within 1e-5, gradients (via the Adam
//!    moment buffers and updated params) within 1e-4 relative, across
//!    flat, LSTM, embedded, and ragged (non-multiple-of-8) shapes.
//! 3. **Thread invariance.** Every parallel kernel produces bitwise
//!    identical results at 1 and N threads — the band partition only
//!    distributes output rows, never the reduction order.
//! 4. **End-to-end.** A depth-1 pipelined trainer run on
//!    `ocean/squared` with `kernels = "simd"` clears a learning
//!    threshold, so the tolerance path trains, not just matches.

use pufferlib::backend::kernels::elementwise::{fast_exp, fast_ln, fast_tanh};
use pufferlib::backend::kernels::{adam, gemm, lstm};
use pufferlib::backend::{native, AdamState, KernelPath, NativeBackend, PolicyBackend, TrainBatch};
use pufferlib::policy::{PolicySpec, ResolvedPolicy};
use pufferlib::runtime::SpecManifest;
use pufferlib::spaces::Space;
use pufferlib::train::{TrainConfig, Trainer};
use pufferlib::util::json::Json;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// Shared helpers (fixture loading mirrors native_parity.rs).

fn fixture() -> Json {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/native_parity.json"
    );
    let text = std::fs::read_to_string(path).expect("fixture file (run gen_fixtures.py)");
    Json::parse(&text).expect("fixture parses")
}

fn f32s(j: &Json, key: &str) -> Vec<f32> {
    j.get(key)
        .as_arr()
        .unwrap_or_else(|| panic!("missing array '{key}'"))
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

fn i32s(j: &Json, key: &str) -> Vec<i32> {
    j.get(key)
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol,
            "{label}: |{g} - {w}| > {tol} at index {i}"
        );
    }
}

/// Relative comparison with a floor of 1.0 in the denominator, so
/// near-zero elements are held to an absolute `tol` instead of an
/// unattainable relative one.
fn assert_close_rel(got: &[f32], want: &[f32], tol: f32, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let denom = w.abs().max(1.0);
        assert!(
            (g - w).abs() <= tol * denom,
            "{label}: |{g} - {w}| > {tol}·{denom} at index {i}"
        );
    }
}

/// A synthetic `SpecManifest` at an arbitrary geometry, matching the
/// native backend's spec-synthesized parameter layout.
fn spec(
    d: usize,
    h: usize,
    act_dims: &[usize],
    lstm: bool,
    horizon: usize,
    batch_roll: usize,
) -> SpecManifest {
    let mut policy = PolicySpec::default().with_hidden(h);
    if lstm {
        policy = policy.with_lstm(h);
    }
    SpecManifest {
        obs_dim: d,
        n_params: native::n_params(d, act_dims, h, lstm),
        act_dims: act_dims.to_vec(),
        agents: 1,
        lstm,
        hidden: h,
        policy,
        batch_fwd: batch_roll,
        batch_roll,
        horizon,
        gamma: 0.99,
        lam: 0.95,
        params0: String::new(),
        artifacts: BTreeMap::new(),
    }
}

/// The same backend twice: one pinned to the scalar path, one to simd.
fn backend_pair(spec: SpecManifest) -> (NativeBackend, NativeBackend) {
    let mut scalar = NativeBackend::from_spec("kp".into(), spec, 7);
    let mut simd = scalar.clone();
    scalar.set_kernel_path(KernelPath::Scalar);
    simd.set_kernel_path(KernelPath::Simd);
    (scalar, simd)
}

/// Deterministic pseudo-random values in roughly [-scale/2, scale/2].
fn pseudo(n: usize, scale: f32) -> Vec<f32> {
    (0..n)
        .map(|i| (((i * 37 + 11) % 97) as f32 / 97.0 - 0.5) * scale)
        .collect()
}

/// Row-major `[row][slot]` actions, each in range for its slot.
fn actions_for(rows: usize, act_dims: &[usize]) -> Vec<i32> {
    let mut out = Vec::with_capacity(rows * act_dims.len());
    for row in 0..rows {
        for (si, &ad) in act_dims.iter().enumerate() {
            out.push(((row + si) % ad) as i32);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// 1. Scalar path = golden fixtures (the bit-exact pin).

#[test]
fn scalar_path_forward_matches_golden_fixture() {
    let fx = fixture();
    let f = fx.get("forward");
    let rows = f.get("rows").as_usize().unwrap();
    let d = fx.get("d").as_usize().unwrap();
    let h = fx.get("h").as_usize().unwrap();
    let act_dims = fx.get("act_dims").as_usize_vec().unwrap();
    let mut b = NativeBackend::from_spec("fx".into(), spec(d, h, &act_dims, false, 4, 4), 0);
    b.set_kernel_path(KernelPath::Scalar);
    let out = b
        .forward(&f32s(f, "params"), &f32s(f, "obs"), rows)
        .unwrap();
    assert_close(&out.logits, &f32s(f, "logits"), 5e-4, "scalar forward logits");
    assert_close(&out.values, &f32s(f, "value"), 5e-4, "scalar forward value");
}

#[test]
fn scalar_path_lstm_matches_golden_fixture() {
    let fx = fixture();
    let f = fx.get("forward_lstm");
    let rows = f.get("rows").as_usize().unwrap();
    let d = fx.get("d").as_usize().unwrap();
    let h = fx.get("h").as_usize().unwrap();
    let act_dims = fx.get("act_dims").as_usize_vec().unwrap();
    let mut b = NativeBackend::from_spec("fx".into(), spec(d, h, &act_dims, true, 4, 4), 0);
    b.set_kernel_path(KernelPath::Scalar);
    let out = b
        .forward_lstm(
            &f32s(f, "params"),
            &f32s(f, "obs"),
            &f32s(f, "h"),
            &f32s(f, "c"),
            rows,
        )
        .unwrap();
    assert_close(&out.logits, &f32s(f, "logits"), 5e-4, "scalar lstm logits");
    assert_close(&out.h, &f32s(f, "h2"), 5e-4, "scalar lstm h'");
    assert_close(&out.c, &f32s(f, "c2"), 5e-4, "scalar lstm c'");
}

#[test]
fn scalar_path_train_step_matches_golden_fixture() {
    let fx = fixture();
    let ts = fx.get("train_step");
    let rows = ts.get("rows").as_usize().unwrap();
    let d = fx.get("d").as_usize().unwrap();
    let h = fx.get("h").as_usize().unwrap();
    let act_dims = fx.get("act_dims").as_usize_vec().unwrap();
    let (t, r) = (4, rows / 4);
    let mut b = NativeBackend::from_spec("fx".into(), spec(d, h, &act_dims, false, t, r), 0);
    b.set_kernel_path(KernelPath::Scalar);

    let mut params = f32s(ts, "params");
    let mut opt = AdamState {
        m: f32s(ts, "m"),
        v: f32s(ts, "v"),
        step: ts.get("step").as_f64().unwrap() as f32,
    };
    let obs = f32s(ts, "obs");
    let actions = i32s(ts, "actions");
    let logp = f32s(ts, "old_logp");
    let adv = f32s(ts, "adv");
    let ret = f32s(ts, "ret");
    let starts = vec![0.0f32; rows];
    let batch = TrainBatch {
        t,
        r,
        norm_adv: true,
        obs: &obs,
        starts: &starts,
        actions: &actions,
        logp: &logp,
        adv: &adv,
        ret: &ret,
    };
    let lr = ts.get("lr").as_f64().unwrap() as f32;
    let ent_coef = ts.get("ent_coef").as_f64().unwrap() as f32;
    let metrics = b
        .train_step(&mut params, &mut opt, lr, ent_coef, &batch)
        .unwrap();
    assert_close(&metrics, &f32s(ts, "metrics"), 2e-4, "scalar metrics");
    assert_close(&params, &f32s(ts, "params2"), 1e-4, "scalar params'");
    assert_close(&opt.m, &f32s(ts, "m2"), 1e-4, "scalar m'");
    assert_close(&opt.v, &f32s(ts, "v2"), 1e-4, "scalar v'");
}

// ---------------------------------------------------------------------------
// 2. Simd vs scalar parity, across shapes.

fn check_forward_parity(sp: SpecManifest, rows: usize, label: &str) {
    let d = sp.obs_dim;
    let (mut scalar, mut simd) = backend_pair(sp);
    let params = scalar.init_params().unwrap();
    let obs = pseudo(rows * d, 2.0);
    let a = scalar.forward(&params, &obs, rows).unwrap();
    let b = simd.forward(&params, &obs, rows).unwrap();
    assert_close(&a.logits, &b.logits, 1e-5, &format!("{label} logits"));
    assert_close(&a.values, &b.values, 1e-5, &format!("{label} values"));
}

#[test]
fn simd_forward_matches_scalar_flat() {
    check_forward_parity(spec(64, 32, &[4], false, 4, 8), 8, "flat");
}

#[test]
fn simd_forward_matches_scalar_ragged() {
    // Nothing divides by 8: obs 7-wide, hidden 10, two odd action slots,
    // and a 5-row batch — every panel loop hits its scalar tail.
    check_forward_parity(spec(7, 10, &[3, 2], false, 4, 5), 5, "ragged");
}

#[test]
fn simd_lstm_matches_scalar() {
    let sp = spec(24, 16, &[4], true, 4, 6);
    let rows = 6;
    let sd = 16;
    let (mut scalar, mut simd) = backend_pair(sp);
    let params = scalar.init_params().unwrap();
    let obs = pseudo(rows * 24, 2.0);
    let h0 = pseudo(rows * sd, 1.0);
    let c0 = pseudo(rows * sd, 1.0);
    let a = scalar.forward_lstm(&params, &obs, &h0, &c0, rows).unwrap();
    let b = simd.forward_lstm(&params, &obs, &h0, &c0, rows).unwrap();
    assert_close(&a.logits, &b.logits, 1e-5, "lstm logits");
    assert_close(&a.values, &b.values, 1e-5, "lstm values");
    assert_close(&a.h, &b.h, 1e-5, "lstm h'");
    assert_close(&a.c, &b.c, 1e-5, "lstm c'");
}

#[test]
fn simd_embed_forward_matches_scalar() {
    let space = Space::dict(vec![
        ("feat".into(), Space::boxf(&[3], -10.0, 10.0)),
        ("tok".into(), Space::MultiDiscrete(vec![7, 7])),
    ]);
    let act_dims = vec![3, 2];
    let policy = PolicySpec::default().with_hidden(10).with_embed_dim(4);
    let arch = ResolvedPolicy::resolve(&policy, &space.layout(), &act_dims).unwrap();
    assert!(arch.has_embeds());
    let sp = SpecManifest {
        obs_dim: arch.obs_dim,
        n_params: arch.n_params(),
        act_dims,
        agents: 1,
        lstm: false,
        hidden: 10,
        policy,
        batch_fwd: 6,
        batch_roll: 6,
        horizon: 1,
        gamma: 0.99,
        lam: 0.95,
        params0: String::new(),
        artifacts: BTreeMap::new(),
    };
    let mut scalar = NativeBackend::from_arch("kp_embed".into(), sp, arch, 7).unwrap();
    let mut simd = scalar.clone();
    scalar.set_kernel_path(KernelPath::Scalar);
    simd.set_kernel_path(KernelPath::Simd);
    let params = scalar.init_params().unwrap();
    let rows = 6;
    // feat leaves float, token leaves integral and in-vocabulary.
    let obs: Vec<f32> = (0..rows * 5)
        .map(|i| if i % 5 < 3 { (i % 7) as f32 * 0.3 - 1.0 } else { (i % 7) as f32 })
        .collect();
    let a = scalar.forward(&params, &obs, rows).unwrap();
    let b = simd.forward(&params, &obs, rows).unwrap();
    assert_close(&a.logits, &b.logits, 1e-5, "embed logits");
    assert_close(&a.values, &b.values, 1e-5, "embed values");
}

fn check_train_parity(sp: SpecManifest, t: usize, r: usize, label: &str) {
    let d = sp.obs_dim;
    let act_dims = sp.act_dims.clone();
    let rows = t * r;
    let (mut scalar, mut simd) = backend_pair(sp);
    let params0 = scalar.init_params().unwrap();
    let obs = pseudo(rows * d, 2.0);
    let actions = actions_for(rows, &act_dims);
    let logp = vec![-1.1f32; rows];
    let adv = pseudo(rows, 1.5);
    let ret = pseudo(rows, 1.0);
    let starts: Vec<f32> = (0..rows).map(|i| if i % 4 == 0 { 1.0 } else { 0.0 }).collect();
    let batch = TrainBatch {
        t,
        r,
        norm_adv: true,
        obs: &obs,
        starts: &starts,
        actions: &actions,
        logp: &logp,
        adv: &adv,
        ret: &ret,
    };

    let mut p_a = params0.clone();
    let mut opt_a = AdamState::new(p_a.len());
    let metrics_a = scalar
        .train_step(&mut p_a, &mut opt_a, 1e-3, 0.01, &batch)
        .unwrap();

    let mut p_b = params0;
    let mut opt_b = AdamState::new(p_b.len());
    let metrics_b = simd
        .train_step(&mut p_b, &mut opt_b, 1e-3, 0.01, &batch)
        .unwrap();

    assert_close(&metrics_a, &metrics_b, 1e-4, &format!("{label} metrics"));
    assert_close_rel(&p_a, &p_b, 1e-4, &format!("{label} params'"));
    // Adam's first moment after one step is (1-β1)·g — the gradients,
    // rescaled: the ≤1e-4-relative gradient pin.
    assert_close_rel(&opt_a.m, &opt_b.m, 1e-4, &format!("{label} grads (m)"));
    assert_eq!(opt_a.step, opt_b.step, "{label} step counter");
}

#[test]
fn simd_grads_match_scalar_flat() {
    check_train_parity(spec(16, 16, &[4], false, 4, 8), 4, 8, "flat");
}

#[test]
fn simd_grads_match_scalar_ragged() {
    check_train_parity(spec(7, 10, &[3, 2], false, 4, 5), 4, 5, "ragged");
}

#[test]
fn simd_grads_match_scalar_bptt() {
    check_train_parity(spec(12, 12, &[4], true, 4, 6), 4, 6, "bptt");
}

// ---------------------------------------------------------------------------
// 3. Thread-count invariance: bitwise, not tolerance.

#[test]
fn gemm_kernels_are_thread_invariant() {
    // 1024×128×128 ≈ 16.8M mul-adds — far past the fork threshold, so
    // the 4-thread run genuinely bands.
    let (m, k, n) = (1024, 128, 128);
    let x = pseudo(m * k, 2.0);
    let w = pseudo(k * n, 0.5);
    let b = pseudo(n, 0.1);

    let mut out1 = vec![0.0f32; m * n];
    let mut out4 = vec![0.0f32; m * n];
    gemm::linear_simd(&x, &w, &b, &mut out1, m, k, n, 1);
    gemm::linear_simd(&x, &w, &b, &mut out4, m, k, n, 4);
    assert_eq!(out1, out4, "linear_simd must be bitwise thread-invariant");

    let mut g1 = vec![0.01f32; k * n];
    let mut g4 = g1.clone();
    gemm::accum_at_b_simd(&x, &out1, &mut g1, m, k, n, 1);
    gemm::accum_at_b_simd(&x, &out4, &mut g4, m, k, n, 4);
    assert_eq!(g1, g4, "accum_at_b_simd must be bitwise thread-invariant");

    let mut d1 = vec![0.0f32; m * k];
    let mut d4 = vec![0.0f32; m * k];
    gemm::matmul_a_wt_simd(&out1, &w, &mut d1, m, n, k, 1);
    gemm::matmul_a_wt_simd(&out4, &w, &mut d4, m, n, k, 4);
    assert_eq!(d1, d4, "matmul_a_wt_simd must be bitwise thread-invariant");
}

#[test]
fn lstm_cell_is_thread_invariant() {
    let (rows, h, sd) = (512, 64, 64);
    let n = 4 * sd;
    let x = pseudo(rows * h, 1.0);
    let h_in = pseudo(rows * sd, 1.0);
    let c_in = pseudo(rows * sd, 1.0);
    let w = pseudo((h + sd) * n, 0.3);
    let b = pseudo(n, 0.1);

    let run = |threads: usize| {
        let mut gates = vec![0.0f32; rows * n];
        let mut ho = vec![0.0f32; rows * sd];
        let mut co = vec![0.0f32; rows * sd];
        lstm::cell_simd(&x, &h_in, &c_in, &w, &b, &mut gates, &mut ho, &mut co, rows, h, sd, threads);
        (gates, ho, co)
    };
    let (g1, h1, c1) = run(1);
    let (g4, h4, c4) = run(4);
    assert_eq!(g1, g4, "lstm gates must be bitwise thread-invariant");
    assert_eq!(h1, h4, "lstm h' must be bitwise thread-invariant");
    assert_eq!(c1, c4, "lstm c' must be bitwise thread-invariant");
}

#[test]
fn adam_update_is_thread_invariant() {
    let n = 300_000;
    let grads = pseudo(n, 0.2);
    let run = |threads: usize| {
        let mut p = pseudo(n, 1.0);
        let mut m = vec![0.01f32; n];
        let mut v = vec![0.001f32; n];
        adam::adam_update_simd(&mut p, &mut m, &mut v, &grads, 3.0, 1e-3, 0.9, 0.999, 1e-8, 0.5, threads);
        (p, m, v)
    };
    let (p1, m1, v1) = run(1);
    let (p4, m4, v4) = run(4);
    assert_eq!(p1, p4, "adam params must be bitwise thread-invariant");
    assert_eq!(m1, m4, "adam m must be bitwise thread-invariant");
    assert_eq!(v1, v4, "adam v must be bitwise thread-invariant");
}

#[test]
fn backend_forward_is_thread_invariant() {
    // A batch big enough that the trunk GEMM forks: 1024 rows × 128×128.
    let rows = 1024;
    let sp = spec(128, 128, &[6], false, 1, rows);
    let mut b = NativeBackend::from_spec("kp_threads".into(), sp, 7);
    let params = b.init_params().unwrap();
    let obs = pseudo(rows * 128, 2.0);
    b.set_kernel_threads(1);
    let one = b.forward(&params, &obs, rows).unwrap();
    b.set_kernel_threads(4);
    let four = b.forward(&params, &obs, rows).unwrap();
    assert_eq!(one.logits, four.logits, "forward logits must not depend on thread count");
    assert_eq!(one.values, four.values, "forward values must not depend on thread count");
}

// ---------------------------------------------------------------------------
// 4. Fast transcendental accuracy and the end-to-end learning pin.

#[test]
fn fast_math_stays_inside_tolerances() {
    for i in 0..=4000 {
        let x = -20.0 + i as f32 * 0.01; // [-20, 20]
        let (e, w) = (fast_exp(x), x.exp());
        assert!(
            (e - w).abs() <= 1e-6 * w.max(f32::MIN_POSITIVE),
            "fast_exp({x}) = {e}, want {w}"
        );
        let (t, wt) = (fast_tanh(x), x.tanh());
        assert!((t - wt).abs() <= 1e-6, "fast_tanh({x}) = {t}, want {wt}");
    }
    for i in 0..=4000 {
        let z = 1.0 + i as f32 * 0.25; // [1, 1001] — the softmax-normalizer range
        let (l, w) = (fast_ln(z), z.ln());
        assert!((l - w).abs() <= 1e-6, "fast_ln({z}) = {l}, want {w}");
    }
    assert_eq!(fast_exp(0.0), 1.0);
    assert_eq!(fast_tanh(0.0), 0.0);
    assert!(fast_tanh(100.0) == 1.0 && fast_tanh(-100.0) == -1.0, "tanh saturates exactly");
}

/// The tolerance path must *train*, pipelined: depth-1 overlap +
/// minibatched PPO on `ocean/squared` under simd kernels has to clear
/// the random-walk score ceiling (the env's own unit test pins random
/// play below 0.7; full solve >0.9 at 150k steps is bench C3).
#[test]
fn pipelined_squared_learns_under_simd_kernels() {
    let cfg = TrainConfig {
        env: "ocean/squared".into(),
        total_steps: 32_768,
        pipeline_depth: 1,
        minibatches: 2,
        log_every: 0,
        kernels: KernelPath::Simd,
        ..Default::default()
    };
    let mut trainer = Trainer::native(cfg).unwrap();
    let report = trainer.train().unwrap();
    let score = report.mean_score.expect("episodes finished");
    assert!(
        score > 0.7,
        "simd-kernel pipelined squared should beat random walk by 32k steps, got {score}"
    );
    assert!(report.episodes > 100, "too few episodes: {}", report.episodes);
}
