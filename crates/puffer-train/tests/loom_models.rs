//! Exhaustive model checking of every cross-thread protocol in the
//! crate, using [loom](https://docs.rs/loom). Compiled only under
//! `RUSTFLAGS="--cfg loom"` (`make loom` / the CI loom lane), where the
//! `pufferlib::sync` facade swaps std primitives for loom's instrumented
//! doubles and each `loom::model` closure is re-run under **every**
//! reachable interleaving (bounded by `LOOM_MAX_PREEMPTIONS`).
//!
//! Each model drives the *real* production primitive — [`Flag`],
//! [`ParamSnapshot`], [`sync::queue`], [`RolloutBuffer`] — with inline
//! leader/worker drivers that mirror the call sequences in
//! `vector/multiproc.rs` and `train/{pipeline,trainer}.rs`. The map from
//! model to protocol is documented per test and in `CONCURRENCY.md`.
//!
//! Models use a spin budget of 1 so every busy-wait iteration is a loom
//! scheduling point (`Flag::wait` yields through the facade).

#![cfg(loom)]

use pufferlib::policy::ParamSnapshot;
use pufferlib::sync::atomic::{AtomicU64, Ordering};
use pufferlib::sync::{queue, Arc};
use pufferlib::train::RolloutBuffer;
use pufferlib::vector::shared::{Flag, ACTIONS_READY, OBS_READY, RESET, SHUTDOWN};

use loom::thread;

/// The slab-ownership handoff (`vector/shared.rs` module docs): leader
/// and worker alternate over one worker's region, mediated solely by the
/// flag's Release/Acquire edges. The regions are modeled as
/// [`loom::cell::UnsafeCell`]s, whose access tracking makes loom itself
/// fail the model if any interleaving lets both sides hold a window into
/// the same region at once — i.e. if `Slab::slice_mut` could ever hand
/// out overlapping `&mut` windows under this protocol.
#[test]
fn slab_handoff_yields_exclusive_windows() {
    loom::model(|| {
        let flag = Arc::new(Flag::new());
        // One worker's action region and obs region.
        let actions = Arc::new(loom::cell::UnsafeCell::new(0u32));
        let obs = Arc::new(loom::cell::UnsafeCell::new(0u32));

        let worker = {
            let (flag, actions, obs) = (flag.clone(), actions.clone(), obs.clone());
            thread::spawn(move || {
                // worker_loop's step arm: Acquire the actions, write the
                // results, publish via the completion CAS.
                let s = flag.wait(1, |s| s == ACTIONS_READY || s == SHUTDOWN);
                if s == SHUTDOWN {
                    return;
                }
                // SAFETY: loom's UnsafeCell tracks these accesses and
                // fails the model itself on any concurrent overlap —
                // exactly the property being checked.
                let a = actions.with(|p| unsafe { *p });
                // SAFETY: as above; exclusivity is loom-checked.
                obs.with_mut(|p| unsafe { *p = a * 2 });
                assert!(flag.complete(ACTIONS_READY), "no shutdown in this model");
            })
        };

        // Leader: write actions, hand the region over, await results.
        // SAFETY: loom-checked access, as in the worker above.
        actions.with_mut(|p| unsafe { *p = 21 });
        flag.store(ACTIONS_READY);
        flag.wait(1, |s| s == OBS_READY);
        // SAFETY: loom-checked access, as in the worker above.
        assert_eq!(obs.with(|p| unsafe { *p }), 42, "obs write must be visible");

        worker.join().unwrap();
    });
}

/// Pins defect #1 (`Flag::complete`): the leader may store SHUTDOWN
/// while the worker is mid-step. The worker's completion edge is a CAS,
/// so it *loses* that race detectably and exits; a blind
/// `store(OBS_READY)` would erase the shutdown signal and strand the
/// worker in its next wait — which loom reports as a livelock (every
/// remaining thread yielding) if you re-introduce it.
#[test]
fn shutdown_is_never_lost() {
    loom::model(|| {
        let flag = Arc::new(Flag::new());
        flag.store(ACTIONS_READY);

        let worker = {
            let flag = flag.clone();
            thread::spawn(move || {
                // worker_loop, compressed: one step arm, then back to wait.
                loop {
                    let s = flag.wait(1, |s| s == ACTIONS_READY || s == SHUTDOWN);
                    if s == SHUTDOWN {
                        return;
                    }
                    // (env stepping happens here)
                    if !flag.complete(ACTIONS_READY) {
                        // Preempted by SHUTDOWN mid-step: honor it.
                        assert_eq!(flag.load(), SHUTDOWN);
                        return;
                    }
                }
            })
        };

        // Drop-path leader: shutdown racing the worker's step.
        flag.store(SHUTDOWN);
        // The worker always terminates (join returns under every
        // interleaving) and the signal itself is never erased below.
        worker.join().unwrap();
        assert_eq!(flag.load(), SHUTDOWN, "shutdown signal survives the race");
    });
}

/// The learner→collector parameter handoff (`policy/snapshot.rs`):
/// params encode their version, so any torn read (a mix of two
/// publishes) or version regression fails the assertions under some
/// interleaving.
#[test]
fn snapshot_is_never_torn_and_versions_are_monotone() {
    loom::model(|| {
        let snap = Arc::new(ParamSnapshot::new(vec![0.0; 4]));

        let learner = {
            let snap = snap.clone();
            thread::spawn(move || {
                for v in 1..=2u64 {
                    assert_eq!(snap.publish(&[v as f32; 4]), v);
                }
            })
        };

        // Collector: acquire twice (start of two segments).
        let mut last = 0u64;
        for _ in 0..2 {
            let (v, p) = snap.acquire();
            assert!(p.iter().all(|&x| x == v as f32), "torn snapshot at v{v}");
            assert!(v >= last, "version went backwards: {v} < {last}");
            last = v;
        }

        learner.join().unwrap();
        assert_eq!(snap.version(), 2);
    });
}

/// The pipelined trainer's buffer rotation (`train/pipeline.rs` +
/// `train/trainer.rs`): free and filled queues rotate `RolloutBuffer`s
/// between learner and collector, and the episode carry written by one
/// side is exactly what the other reads back — never lost or crossed
/// between the two rotating buffers.
#[test]
fn rotation_preserves_carry_across_the_handover() {
    loom::model(|| {
        let (free_tx, free_rx) = queue::channel::<RolloutBuffer>(None);
        let (filled_tx, filled_rx) = queue::channel::<RolloutBuffer>(Some(2));

        let collector = thread::spawn(move || {
            // collector_loop, compressed: recv a free buffer, thread the
            // carry in, "collect" (flip the carry), send it filled.
            let mut carry = vec![true];
            for _ in 0..2 {
                let Some(mut buf) = free_rx.recv() else { return };
                buf.set_episode_carry(&carry);
                carry[0] = !carry[0];
                if filled_tx.send(buf).is_err() {
                    return;
                }
            }
        });

        // Learner: lend depth+1 = 2 buffers, then consume both segments.
        for _ in 0..2 {
            assert!(free_tx.send(RolloutBuffer::new(1, 1, 1, 1)).is_ok());
        }
        let a = filled_rx.recv().expect("collector delivers segment 1");
        let b = filled_rx.recv().expect("collector delivers segment 2");
        assert_eq!(a.episode_carry(), &[true], "segment 1 carries the hard reset");
        assert_eq!(b.episode_carry(), &[false], "segment 2 carries segment 1's end state");

        collector.join().unwrap();
    });
}

/// Rotation hangup: when the learner exits (success or error) it drops
/// both endpoints; a collector blocked on either queue must wake and
/// return instead of deadlocking the scope join — the exit protocol of
/// `Trainer::train_pipelined`'s scoped threads.
#[test]
fn rotation_hangup_always_unblocks_the_collector() {
    loom::model(|| {
        let (free_tx, free_rx) = queue::channel::<u32>(None);
        let (filled_tx, filled_rx) = queue::channel::<u32>(Some(1));

        let collector = thread::spawn(move || {
            // Fill the filled queue, then block on the next free recv
            // (or on a full filled send) until the learner hangs up.
            loop {
                let Some(x) = free_rx.recv() else { return };
                if filled_tx.send(x).is_err() {
                    return;
                }
            }
        });

        assert!(free_tx.send(1).is_ok());
        assert!(free_tx.send(2).is_ok());
        // Learner exits mid-run: drop both endpoints in scope-exit order.
        drop(free_tx);
        drop(filled_rx);
        // The collector must terminate under every interleaving: recv
        // returns None once the queue drains, send errors once the
        // receiver is gone.
        collector.join().unwrap();
    });
}

/// Pins defect #2 (`Multiprocessing::async_reset`): the two-phase reset
/// (quiesce every flag, *then* publish the seed and store RESET) means a
/// worker processing RESET always reads the seed of the reset that woke
/// it. The pre-fix ordering (seed stored before quiescing) let a worker
/// mid-RESET read the *next* reset's seed — here that shows up as the
/// worker recording `[b, b]` instead of `[a, b]`.
#[test]
fn reset_seed_matches_epoch() {
    loom::model(|| {
        let flag = Arc::new(Flag::new());
        let seed = Arc::new(AtomicU64::new(0));

        let worker = {
            let (flag, seed) = (flag.clone(), seed.clone());
            thread::spawn(move || {
                // worker_loop's RESET arm, recording each observed seed.
                let mut seen = Vec::new();
                loop {
                    let s = flag.wait(1, |s| s == RESET || s == SHUTDOWN);
                    if s == SHUTDOWN {
                        return seen;
                    }
                    // ordering: Relaxed — publication rides the RESET
                    // flag edge, as in vector/multiproc.rs.
                    seen.push(seed.load(Ordering::Relaxed));
                    if !flag.complete(RESET) {
                        return seen;
                    }
                }
            })
        };

        // Leader: two back-to-back async_resets (the double-reset that
        // motivated the fix), mimicking the two-phase protocol.
        for s in [7u64, 9u64] {
            // Phase 1: quiesce — no worker may still be consuming an
            // in-flight reset (or step) when the seed changes.
            flag.wait(1, |st| st != RESET && st != ACTIONS_READY);
            // Phase 2: publish the seed, then wake the worker into RESET.
            seed.store(s, Ordering::Relaxed);
            flag.store(RESET);
        }
        flag.wait(1, |st| st == OBS_READY);
        flag.store(SHUTDOWN);

        let seen = worker.join().unwrap();
        assert_eq!(seen, vec![7, 9], "each RESET observes its own epoch's seed");
    });
}

/// The serve batcher's close/drain protocol (`serve/batcher.rs`,
/// `serve/server.rs` module docs): connection readers and the accept
/// loop drop their `Sender<Job>` clones at shutdown, and the shard —
/// looping [`collect_batch`](pufferlib::serve::batcher::collect_batch)
/// — must hand every request sent before the close to a forward pass,
/// then observe `None` and exit. The model replaces the `Instant`
/// deadline with a bounded poll counter (the closure is the real
/// production seam: `expired()` is injected precisely so loom can drive
/// it), and checks that no interleaving of producer sends, sender
/// drops, and batch cuts can strand or duplicate a request.
#[test]
fn serve_batcher_drains_every_request_on_close() {
    use pufferlib::serve::batcher::collect_batch;
    loom::model(|| {
        let (tx, rx) = queue::channel::<u32>(None);
        let producers: Vec<_> = [1u32, 2u32]
            .into_iter()
            .map(|v| {
                let tx = tx.clone();
                thread::spawn(move || {
                    tx.send(v).expect("receiver outlives the producers");
                })
            })
            .collect();
        drop(tx); // the accept loop's clones go away with it

        // Shard loop: collect until the queue reports closed + drained.
        let mut got = Vec::new();
        loop {
            let mut polls = 0u32;
            let expired = move || {
                polls += 1;
                polls >= 2 // bounded budget so every branch terminates
            };
            let Some(batch) = collect_batch(&rx, 2, expired) else {
                break;
            };
            got.extend(batch);
        }

        for p in producers {
            p.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, vec![1, 2], "every pre-close request reaches a batch exactly once");
    });
}
