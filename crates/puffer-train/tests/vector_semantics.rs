//! Vectorization-semantics tests: the ordering and liveness contracts of
//! the optimized code paths.
//!
//! - `Mode::ZeroCopy` hands out worker bands **in rotation, in claim
//!   order** — a circular buffer of batches.
//! - `Mode::Async` (`N < M`) returns the **first finishers** without
//!   blocking on stragglers.
//! - `Serial` is inherently synchronous: one full batch, always in env
//!   order, and pool configs are rejected up front.

use pufferlib::emulation::{FlatEnv, PufferEnv};
use pufferlib::envs;
use pufferlib::envs::profile::{ProfileConfig, ProfileSim};
use pufferlib::vector::{Mode, Multiprocessing, Serial, VecConfig, VecEnv};
use std::time::Instant;

fn cfg(num_envs: usize, num_workers: usize, batch_size: usize, zero_copy: bool) -> VecConfig {
    VecConfig {
        num_envs,
        num_workers,
        batch_size,
        zero_copy,
        ..Default::default()
    }
}

/// ZeroCopy: with 4 workers × 2 envs and batch 4 (2 workers per band),
/// recv must return band 0 (envs 0–3), then band 1 (envs 4–7), then band
/// 0 again — the claim rotation — with rows in worker order inside each
/// band.
#[test]
fn zero_copy_band_rotation_returns_batches_in_claim_order() {
    let mut v = Multiprocessing::from_factory(
        |i| envs::make("ocean/squared", i as u64),
        cfg(8, 4, 4, true),
    )
    .unwrap();
    assert_eq!(v.mode(), Mode::ZeroCopy);
    let slots = v.action_dims().len();
    let rows = v.batch_rows();
    v.async_reset(0);
    for round in 0..6 {
        let ids = {
            let b = v.recv().unwrap();
            b.env_ids.to_vec()
        };
        let expect: Vec<usize> = if round % 2 == 0 {
            (0..4).collect()
        } else {
            (4..8).collect()
        };
        assert_eq!(
            ids, expect,
            "round {round}: ZeroCopy must claim bands in rotation"
        );
        v.send(&vec![0i32; rows * slots]).unwrap();
    }
}

/// Async (N < M): recv returns the first workers to finish. With one
/// worker 1000× slower than the rest, the fast workers dominate the
/// batches and the whole loop completes far faster than it could if any
/// recv blocked on the straggler.
#[test]
fn async_recv_returns_first_finishers_without_blocking() {
    const SLOW_US: f64 = 50_000.0;
    let factory = |i: usize| -> Box<dyn FlatEnv> {
        let step_us = if i == 3 { SLOW_US } else { 50.0 };
        Box::new(PufferEnv::new(ProfileSim::new(
            ProfileConfig::synthetic(step_us, 0.0, 0.0, 4),
            i as u64,
        )))
    };
    // 4 workers × 1 env, batch = 2 workers → Mode::Async.
    let mut v = Multiprocessing::from_factory(factory, cfg(4, 4, 2, false)).unwrap();
    assert_eq!(v.mode(), Mode::Async);
    let slots = v.action_dims().len();
    let rows = v.batch_rows();
    v.async_reset(0);
    let rounds = 20usize;
    let t0 = Instant::now();
    let mut counts = [0usize; 4];
    for _ in 0..rounds {
        let ids = {
            let b = v.recv().unwrap();
            b.env_ids.to_vec()
        };
        assert_eq!(ids.len(), 2, "Async batch is exactly N envs");
        for e in ids {
            counts[e] += 1;
        }
        v.send(&vec![0i32; rows * slots]).unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    // A sync vectorizer would pay the straggler every round
    // (rounds × 50 ms ≥ 1 s); first-finisher batches stay far under that.
    assert!(
        elapsed < rounds as f64 * SLOW_US / 1e6 * 0.5,
        "recv appears to block on the straggler: {elapsed:.3}s for {rounds} rounds"
    );
    let fast: usize = counts[..3].iter().sum();
    assert!(
        fast > counts[3] * 3,
        "straggler claimed too often: {counts:?}"
    );
}

/// Serial: every batch is the full env set, in env order (claim order is
/// definitionally 0..M), and `N < M` pool configs are rejected up front.
#[test]
fn serial_is_sync_and_in_order() {
    let mut v = Serial::from_factory(
        |i| envs::make("classic/cartpole", i as u64),
        cfg(4, 1, 4, false),
    )
    .unwrap();
    let slots = v.action_dims().len();
    let rows = v.batch_rows();
    v.async_reset(1);
    for _ in 0..10 {
        let ids = {
            let b = v.recv().unwrap();
            b.env_ids.to_vec()
        };
        assert_eq!(ids, vec![0, 1, 2, 3], "Serial batches are in env order");
        v.send(&vec![0i32; rows * slots]).unwrap();
    }

    // Pool semantics need a pooled backend: Serial refuses N < M.
    assert!(
        Serial::from_factory(|i| envs::make("classic/cartpole", i as u64), cfg(4, 1, 2, false)).is_err(),
        "Serial must reject batch_size < num_envs"
    );
}

/// Multiprocessing sync mode mirrors Serial's ordering contract: the
/// batch is all envs, ascending.
#[test]
fn multiprocessing_sync_matches_serial_order() {
    let mut v = Multiprocessing::from_factory(
        |i| envs::make("classic/cartpole", i as u64),
        cfg(4, 2, 4, false),
    )
    .unwrap();
    assert_eq!(v.mode(), Mode::Sync);
    let slots = v.action_dims().len();
    let rows = v.batch_rows();
    v.async_reset(1);
    for _ in 0..10 {
        let ids = {
            let b = v.recv().unwrap();
            b.env_ids.to_vec()
        };
        assert_eq!(ids, vec![0, 1, 2, 3], "Sync batches are in env order");
        v.send(&vec![0i32; rows * slots]).unwrap();
    }
}
