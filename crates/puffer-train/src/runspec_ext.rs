//! Trainer-side extensions to [`RunSpec`]: the `build()` / deep
//! `validate()` entry points and the sweep executors. These need the
//! [`Trainer`] and [`NativeBackend`](crate::backend::NativeBackend), so
//! they live in this crate — `puffer-core` owns the plain-data spec and
//! its parsing/serialization; this module owns execution. Re-exported
//! through [`crate::runspec`] so callers keep writing
//! `pufferlib::runspec::{RunSpec, RunSpecExt, run_sweep}`.

// Execution plumbing over safe primitives; no unsafe belongs here
// (CONCURRENCY.md).
#![forbid(unsafe_code)]

use crate::runspec::RunSpec;
use crate::train::{TrainReport, Trainer};
use anyhow::{ensure, Context, Result};

/// Construction and deep validation for [`RunSpec`] — the trainer-side
/// half of the spec layer, as an extension trait.
pub trait RunSpecExt {
    /// Build the ready-to-run [`Trainer`] (native backend): the one-line
    /// construction path that replaces imperative
    /// probe/backend/vectorizer assembly.
    fn build(&self) -> Result<Trainer>;

    /// Deep-validate without training: env name + wrapper chain resolve
    /// (probe construction), the policy spec resolves against the env
    /// (architecture errors like forced-feedforward on a recurrent env
    /// surface here), the vec spec is satisfiable at this env's scale,
    /// minibatches divide the batch, the spec serializes and round-trips,
    /// and every grid point assembles.
    fn validate(&self) -> Result<()>;
}

impl RunSpecExt for RunSpec {
    fn build(&self) -> Result<Trainer> {
        ensure!(
            self.grid.is_empty(),
            "this spec has a [grid] section ({} keys) — expand it with \
             expand_grid() / `puffer sweep` instead of running it directly",
            self.grid.len()
        );
        Trainer::from_run_spec(self)
    }

    fn validate(&self) -> Result<()> {
        // Structural half: env name + serialization round trip.
        self.validate_shallow()?;
        // Architecture resolution against the wrapped env.
        let probe = self.env.build(0);
        let policy = self
            .policy
            .clone()
            .unwrap_or_else(|| crate::policy::PolicySpec::default_for(self.env.name()));
        let backend = crate::backend::NativeBackend::for_env_with_policy(
            &self.env.key(),
            probe.as_ref(),
            &policy,
        )?;
        let spec = backend.spec();
        let tc = self.train_config();
        ensure!(
            tc.minibatches >= 1 && spec.batch_roll % tc.minibatches == 0,
            "train.minibatches {} must divide batch_roll {}",
            tc.minibatches,
            spec.batch_roll
        );
        // Vec satisfiability (auto validates at run time, after tuning).
        if !self.vec.is_auto() {
            let num_envs = spec.batch_roll / spec.agents;
            let vcfg = self.vec.resolve(num_envs, 0)?;
            spec.ensure_trainable_batch(&self.vec.to_string(), vcfg.batch_size)?;
        }
        if !self.grid.is_empty() {
            for child in self.expand_grid()? {
                child.validate().with_context(|| {
                    format!(
                        "grid child '{}'",
                        child.train.run_dir.as_deref().unwrap_or("?")
                    )
                })?;
            }
        }
        Ok(())
    }
}

/// One finished sweep child.
pub struct SweepOutcome {
    /// The child's distinguishing grid assignment (its run-dir leaf).
    pub label: String,
    pub run_dir: String,
    pub report: Result<TrainReport>,
}

/// Execute a sweep: train every expanded child
/// ([`RunSpec::expand_grid`]) across a pool of `jobs` worker threads
/// (each child builds its trainer inside its worker, so envs, backends,
/// and metrics files are fully isolated). `on_done` fires as each child
/// finishes, from the calling thread; outcomes come back in child
/// order. A panicking child becomes a `Failed` outcome carrying the
/// panic message — its siblings keep draining the grid.
pub fn run_sweep(
    children: &[RunSpec],
    jobs: usize,
    on_done: impl FnMut(usize, &SweepOutcome),
) -> Result<Vec<SweepOutcome>> {
    run_sweep_with(
        children,
        jobs,
        |_, child| Trainer::from_run_spec(child).and_then(|mut t| t.train()),
        on_done,
    )
}

/// [`run_sweep`] with a pluggable per-child task — what the
/// registry-aware resumable executor ([`crate::runs::sweep`]) layers
/// its record transitions onto. The task runs on a worker thread under
/// `catch_unwind`, so one child's panic is converted into an `Err`
/// outcome (message preserved) instead of killing the worker and
/// silently orphaning every index that worker would have claimed.
pub fn run_sweep_with(
    children: &[RunSpec],
    jobs: usize,
    task: impl Fn(usize, &RunSpec) -> Result<TrainReport> + Sync,
    mut on_done: impl FnMut(usize, &SweepOutcome),
) -> Result<Vec<SweepOutcome>> {
    ensure!(!children.is_empty(), "no sweep children to run");
    let n = children.len();
    let jobs = jobs.clamp(1, n);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let task = &task;
    let mut outcomes: Vec<Option<SweepOutcome>> = std::iter::repeat_with(|| None).take(n).collect();
    std::thread::scope(|s| {
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            s.spawn(move || loop {
                // ordering: Relaxed — a pure work-stealing counter; the
                // claimed index is the only data, and fetch_add's
                // atomicity alone guarantees each index is claimed once.
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= children.len() {
                    break;
                }
                // AssertUnwindSafe: on panic the child's trainer (and
                // anything it half-mutated) is dropped here, never
                // observed again — the only state that crosses the
                // boundary is the extracted panic message.
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    task(i, &children[i])
                }));
                let report = caught.unwrap_or_else(|payload| {
                    Err(anyhow::anyhow!(
                        "sweep child panicked: {}",
                        panic_message(payload.as_ref())
                    ))
                });
                if tx.send((i, report)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, report) in rx {
            let run_dir = children[i].train.run_dir.clone().unwrap_or_default();
            let label = run_dir
                .rsplit('/')
                .next()
                .unwrap_or(run_dir.as_str())
                .to_string();
            let outcome = SweepOutcome {
                label,
                run_dir,
                report,
            };
            on_done(i, &outcome);
            outcomes[i] = Some(outcome);
        }
    });
    // PANIC: the scope joined every worker; each index was reported exactly once.
    Ok(outcomes.into_iter().map(|o| o.expect("all children ran")).collect())
}

/// Extract a human-readable message from a `catch_unwind` payload
/// (`panic!` with a literal yields `&str`, with formatting yields
/// `String`; anything else is opaque).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(text) = payload.downcast_ref::<&str>() {
        (*text).to_string()
    } else if let Some(text) = payload.downcast_ref::<String>() {
        text.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicySpec;
    use crate::vector::{VecBatch, VecSpec};
    use crate::wrappers::EnvSpec;

    #[test]
    fn validate_accepts_good_specs_and_rejects_bad_resolutions() {
        RunSpec::new(EnvSpec::new("ocean/bandit")).validate().unwrap();
        // Forced-feedforward on a recurrent reference env fails with the
        // actionable architecture error.
        let bad = RunSpec::new(EnvSpec::new("ocean/memory"))
            .with_policy(PolicySpec::default());
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("--policy.lstm"), "{err}");
        // A batch nobody can forward.
        let bad = RunSpec::new(EnvSpec::new("ocean/bandit")).with_vec(VecSpec::Mt {
            workers: 8,
            batch: VecBatch::Envs(8),
            zero_copy: false,
            spin_budget: 64,
        });
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("rows"), "{err}");
    }

    #[test]
    fn gridded_spec_refuses_direct_building() {
        let mut spec = RunSpec::new(EnvSpec::new("ocean/bandit"));
        spec.grid
            .insert("train.lr".into(), vec!["0.001".into(), "0.0025".into()]);
        let err = spec.build().unwrap_err().to_string();
        assert!(err.contains("grid"), "{err}");
    }
}
