//! # PufferLib (Rust + JAX + Pallas reproduction)
//!
//! A faithful systems reproduction of *"PufferLib: Making Reinforcement
//! Learning Libraries and Environments Play Nice"* (Suárez, 2024). The
//! repo is a three-crate workspace; this crate (`puffer-train`, lib name
//! `pufferlib`) is the execution layer:
//!
//! - **`puffer-core`** — emulation ([`emulation`]), the from-scratch
//!   vectorization engine with EnvPool semantics ([`vector`]),
//!   first-party environments including the Ocean sanity suite
//!   ([`envs`]), wrapper chains ([`wrappers`]), and the declarative
//!   [`RunSpec`](runspec::RunSpec) spec layer. Re-exported here under
//!   the same paths, so `pufferlib::vector::...` keeps working.
//! - **`puffer-train` (this crate)** — the Clean PuffeRL PPO trainer
//!   ([`train`]), the native/PJRT learner backends and vectorized
//!   kernels ([`backend`]), the policy runtime ([`policy`]), the run
//!   registry and resumable sweeps ([`runs`]), the `puffer serve`
//!   inference server ([`serve`]), and the `puffer` CLI.
//! - **`puffer-py`** — the PyO3 cdylib over `puffer-core`: zero-copy
//!   numpy views of the vectorizer slabs, the `pufferlib.emulate(...)`
//!   one-liner, and a Gymnasium `VectorEnv` adapter so CleanRL/SB3
//!   scripts train against this backend unmodified (see
//!   `python/pufferlib/`).
//!
//! The learner math sits behind the [`backend`] abstraction
//! ([`backend::PolicyBackend`]): the default
//! [`NativeBackend`](backend::NativeBackend) is a pure-Rust port of the
//! JAX/Pallas reference math under `python/compile/`, so the crate
//! builds and trains on a clean machine with **zero native
//! dependencies** — no XLA, no Python. Enable the `pjrt` cargo feature
//! to execute the AOT-compiled HLO artifacts through the PJRT C API
//! instead (the [`runtime`] module), with Python still never running on
//! the rollout or training path. Disabling the default `trainer`
//! feature compiles out the training loop, pipeline, and sweep
//! executors while keeping checkpoint loading and serving — the
//! serve-only build (`cargo check -p puffer-train
//! --no-default-features`) proves the inference path never links
//! trainer code.
//!
//! ## Quickstart: one `RunSpec` per experiment
//!
//! The construction currency is the declarative [`RunSpec`](runspec::RunSpec):
//! env × policy × vectorization × training × one root seed, fully
//! TOML/JSON-serializable. One value describes a run; one value is
//! embedded in every checkpoint (`puffer resume <ckpt>` needs zero
//! flags); one file drives the CLI (`puffer run spec.toml`, see the
//! `examples/specs/` gallery).
//!
//! ```no_run
//! use pufferlib::prelude::*;
//! use pufferlib::runspec::{RunSpec, RunSpecExt as _};
//!
//! let spec = RunSpec::new(EnvSpec::new("ocean/squared").clip_reward(1.0).stack(4))
//!     .with_vec(VecSpec::pooled(2))            // mt, M = 2N double-buffering
//!     .with_seed(7)                            // root of every RNG stream
//!     .with_train(|t| t.total_steps = 30_000);
//! let report = spec.build().unwrap().train().unwrap();
//! println!("score: {:?}", report.mean_score);
//!
//! // The same spec as a file (examples/specs/*.toml):
//! let toml = spec.to_toml().unwrap();
//! assert_eq!(RunSpec::from_toml_str(&toml).unwrap(), spec);
//! ```
//!
//! Three sub-specs compose it, each usable on its own:
//!
//! - [`EnvSpec`](wrappers::EnvSpec) — base env + in-place microwrapper
//!   chain ([`wrappers`]); custom envs slot in via
//!   [`EnvSpec::custom`](wrappers::EnvSpec::custom) (see
//!   `examples/custom_env.rs`).
//! - [`PolicySpec`](policy::PolicySpec) — the architecture sandwich
//!   (below).
//! - [`VecSpec`](vector::VecSpec) — `serial`, `mt { workers, batch,
//!   zero_copy, spin_budget }`, or `auto` (autotuned once, cached under
//!   the run dir). `VecSpec::build(&env_spec, num_envs, seed)` is the
//!   public vectorizer path; `Serial::from_spec` /
//!   `Multiprocessing::from_spec` remain underneath as the typed
//!   low-level layer:
//!
//! ```no_run
//! use pufferlib::prelude::*;
//!
//! let env = EnvSpec::new("ocean/squared").clip_reward(1.0).stack(4);
//! let mut venv = VecSpec::mt(2).build(&env, 8, 0).unwrap();
//! let (obs, _rewards, _terms, _truncs, _infos) = venv.reset(0).unwrap();
//! assert_eq!(obs.len(), 8 * venv.obs_layout().byte_len());
//! ```
//!
//! The classic imperative `TrainConfig` path still works (and stays
//! bit-identical to the pre-RunSpec trainer); a RunSpec additionally
//! derives every RNG stream — env resets, policy sampling, minibatch
//! shuffle, collector, eval — from the single `seed` root via the
//! documented split function ([`util::seed::SeedPlan::from_root`]), and
//! a `[grid]` section expands into a sweep
//! ([`RunSpec::expand_grid`](runspec::RunSpec::expand_grid), `puffer
//! sweep`).
//!
//! ## Policy architectures
//!
//! The model is as composable as the env: a declarative
//! [`PolicySpec`](policy::PolicySpec) — per-leaf observation encoders ×
//! recurrence × action head, paper §3.4's encoder → LSTM → decoder
//! "sandwich" — is resolved against the env's emulated
//! [`StructLayout`](spaces::StructLayout) and becomes the construction
//! currency for models exactly as [`EnvSpec`](wrappers::EnvSpec) is for
//! envs:
//!
//! - **Per-leaf encoders**: f32/u8 leaves feed the two-layer tanh trunk
//!   raw; Discrete / token (i32) leaves become learned embedding tables
//!   when `embed_dim > 0` (indices clamped into the leaf's vocabulary,
//!   concatenated into the trunk in field order).
//! - **Recurrence is a flag, not a second model**
//!   ([`Recurrence::None`](policy::Recurrence) |
//!   [`Lstm { hidden }`](policy::Recurrence)): the native backend runs
//!   the fused-gate cell on the rollout side and **full BPTT through the
//!   time scan** on the training side, with LSTM state zeroed at episode
//!   starts. Recurrent envs (e.g. `ocean/memory`) resolve a recurrent
//!   default spec and train natively — the old "recurrent envs require
//!   `--features pjrt`" error is gone.
//! - **Unified action head** ([`ActionHead`](policy::ActionHead)):
//!   per-slot categorical logits over the emulated MultiDiscrete, or a
//!   declared quantized-continuous grid (`head=quantized:<bins>`).
//!   Native continuous (Gaussian) heads are ROADMAP item 4 and rejected
//!   with an actionable error at spec parse time.
//!
//! ```no_run
//! use pufferlib::policy::PolicySpec;
//! use pufferlib::train::{TrainConfig, Trainer};
//!
//! // ocean/memory defaults to the LSTM sandwich — this trains natively.
//! let recurrent = TrainConfig { env: "ocean/memory".into(), ..Default::default() };
//! Trainer::native(recurrent).unwrap().train().unwrap();
//!
//! // Explicit spec: 64-wide trunk, 8-wide token embeddings, 64-wide LSTM.
//! let cfg = TrainConfig {
//!     env: "ocean/spaces".into(),
//!     policy: Some(PolicySpec::default().with_hidden(64).with_embed_dim(8).with_lstm(64)),
//!     ..Default::default()
//! };
//! Trainer::native(cfg).unwrap().train().unwrap();
//! ```
//!
//! Config/CLI: `train.policy.*` keys and `--policy.*` overrides
//! (`hidden`, `lstm`, `lstm_hidden`, `embed_dim`,
//! `head=categorical|quantized:<bins>`), parsed as strictly as
//! `--wrap.*`. A non-default spec is embedded in the checkpoint key
//! (`env#h=64+lstm=64`), so restores never cross architectures;
//! `puffer policy describe <env>` prints the resolved leaves, stages,
//! and parameter counts. The PJRT backend executes AOT-lowered default
//! architectures only and rejects non-default specs at construction.
//!
//! ## Throughput tuning
//!
//! Three multiplicative levers, innermost out:
//!
//! 1. **Vectorizer pooling** (`TrainConfig::pool`, paper §3.3): `recv`
//!    returns the first half of the envs to finish (`M = 2N`), so
//!    rollout inference double-buffers against simulation and stragglers
//!    never block a batch.
//! 2. **Pipeline depth** (`TrainConfig::pipeline_depth`,
//!    `--pipeline.depth`): `0` is the serial collect-then-learn loop;
//!    `d ≥ 1` moves collection to a dedicated thread that runs up to `d`
//!    rollout segments ahead over `d + 1` rotating buffers, inferring
//!    off epoch-versioned parameter snapshots while the learner
//!    optimizes the previous segment. Simulation and backprop overlap
//!    instead of taking turns.
//! 3. **Minibatches** (`TrainConfig::minibatches`): each PPO epoch
//!    shuffles the segment's agent rows into this many row-subset
//!    updates (advantages re-normalized per minibatch,
//!    `TrainConfig::norm_adv`). More, smaller updates per segment —
//!    standard PPO — and the learner-side cost knob to balance against
//!    collection.
//!
//! ```no_run
//! use pufferlib::train::{TrainConfig, Trainer};
//!
//! let cfg = TrainConfig {
//!     env: "profile/atari".into(),
//!     pool: true,        // M = 2N double-buffered simulation
//!     pipeline_depth: 1, // collector thread overlaps the learner
//!     minibatches: 4,    // 4 shuffled row-minibatches per PPO epoch
//!     ..Default::default()
//! };
//! let report = Trainer::native(cfg).unwrap().train().unwrap();
//! // Read the balance: env_sps ≈ collection ceiling, learn_sps ≈
//! // learner ceiling; end-to-end sps approaches min(env, learn) when
//! // pipelined. collector_stall_s > 0 → learner-bound (lower epochs /
//! // minibatch cost); learner_stall_s > 0 → env-bound (more workers,
//! // enable pool).
//! println!("sps {:.0} env {:.0} learn {:.0} stalls {:.1}s/{:.1}s",
//!     report.sps, report.env_sps, report.learn_sps,
//!     report.collector_stall_s, report.learner_stall_s);
//! ```
//!
//! With `pipeline_depth = 0` and `minibatches = 1` the trainer is the
//! exact serial loop (bit-identical params; pinned by
//! `tests/pipeline.rs`), so results stay comparable when you turn the
//! knobs off.
//!
//! ### Kernel paths
//!
//! Underneath all three levers sits the native backend's compute path,
//! selected per run with `train.kernels` (`--train.kernels=scalar|simd`,
//! [`backend::KernelPath`]):
//!
//! - `simd` (default): cache-blocked, 8-lane-tiled GEMM microkernels, a
//!   fused LSTM cell, branch-free polynomial transcendentals, and
//!   structured fork-join row parallelism across the forward, backward,
//!   and Adam passes ([`backend::kernels`]). Matches the scalar path
//!   within explicit tolerances (forward ≤ 1e-5, gradients ≤ 1e-4
//!   relative — `tests/kernel_parity.rs`), and is **deterministic**:
//!   threads partition output rows only, so results are bitwise
//!   invariant to the thread count.
//! - `scalar`: the original bit-exact reference math, pinned by the
//!   golden JAX fixtures. Use it to reproduce pre-kernel runs exactly or
//!   to bisect a numerical question down to the kernel layer.
//!
//! `PUFFER_KERNEL_THREADS` caps the fork-join width (default: available
//! parallelism, capped at 8); small batches never fork. The
//! scalar-vs-simd cells in `BENCH_policy.json` / `BENCH_train.json`
//! (refreshed by `make bench`) quantify the speedup per architecture.
//!
//! ## Serving
//!
//! `puffer serve <ckpt>` ([`serve`]) turns a v2 (RunSpec-embedded)
//! checkpoint into a localhost inference service: concurrent TCP
//! clients send flat observation rows (length-prefixed binary frames,
//! or newline-JSON for debugging — [`serve::protocol`] documents the
//! exact layout), and a dynamic batcher coalesces them into batched
//! forward passes under a dual budget (`serve.max_batch` rows or
//! `serve.max_wait_us`, whichever first). Recurrent policies keep
//! per-session LSTM state server-side — sessions are created lazily,
//! reset on episode boundaries, and evicted after `serve.session_ttl_s`
//! idle — and a watcher thread hot-swaps weights through
//! [`policy::ParamSnapshot`] whenever the checkpoint file changes, so a
//! trainer can publish into a live server. Replies are deterministic
//! (greedy argmax) and bit-identical to a serial forward regardless of
//! batch shape (pinned by `tests/serve.rs`). `puffer serve <ckpt>
//! --selftest` runs a synthetic load and reports p50/p99 latency plus
//! batch occupancy; `puffer ckpt info <ckpt>` prints the embedded spec
//! (`--json` for scripts).
//!
//! ## Experiment ops
//!
//! Every `puffer run`/`resume`/`sweep` launch is logged to a crash-safe
//! run registry ([`runs`]): an append-only `runs/index.jsonl` plus one
//! atomically-rewritten `run.json` per run dir, tracking
//! `pending → running → done | failed | killed` with host/pid, attempt
//! count, final metrics, and checkpoint path. Sweeps are resumable —
//! re-invoking `puffer sweep` skips at-budget children, resumes
//! partials from their checkpoints, and reclaims orphans — and
//! `--processes=N` isolates children in their own OS processes.
//! Trainers heartbeat live SPS/stall counters to `heartbeat.json`;
//! `puffer ps` (and `--json`) tables live/recent runs with
//! stale-heartbeat detection, `puffer top` refreshes the in-flight
//! view. The `[runs]` spec section / `--runs.*` flags set the registry
//! root and heartbeat period.
//!
//! ## Concurrency correctness
//!
//! Every cross-thread protocol (slab handoff, parameter snapshots,
//! buffer rotation, shutdown/reset delivery) is written against the
//! [`sync`] facade, which swaps to [loom](https://docs.rs/loom)'s
//! model-checked primitives under `--cfg loom` so
//! `tests/loom_models.rs` can exhaustively explore interleavings.
//! The protocol contracts, memory-ordering audit, and rules for new
//! `unsafe`/atomics live in `CONCURRENCY.md` at the repo root.

// The environment half of the stack lives in puffer-core; re-export its
// modules under the historical paths so `pufferlib::vector::...` (and
// `crate::vector::...` inside this crate) keep resolving unchanged.
pub use puffer_core::{config, emulation, envs, spaces, sync, util, vector, wrappers};

pub mod backend;
pub mod policy;
pub mod runs;
pub mod runtime;
pub mod serve;
pub mod train;

#[cfg(feature = "trainer")]
mod runspec_ext;

/// The declarative experiment currency ([`RunSpec`](runspec::RunSpec),
/// from `puffer-core`) plus this crate's executors: the
/// [`RunSpecExt`](runspec::RunSpecExt) extension trait (`build()` /
/// deep `validate()`) and the sweep runners, available with the
/// default `trainer` feature.
pub mod runspec {
    pub use puffer_core::runspec::*;

    #[cfg(feature = "trainer")]
    pub use crate::runspec_ext::{run_sweep, run_sweep_with, RunSpecExt, SweepOutcome};
}

/// Convenience re-exports covering the most common entry points.
pub mod prelude {
    pub use crate::backend::{NativeBackend, PolicyBackend};
    pub use crate::emulation::{EpisodeStats, FlatEnv, PufferEnv, StructuredEnv};
    pub use crate::policy::{ActionHead, PolicySpec, Recurrence};
    pub use crate::runspec::RunSpec;
    #[cfg(feature = "trainer")]
    pub use crate::runspec::RunSpecExt;
    pub use crate::spaces::{Space, StructLayout, Value};
    pub use crate::util::rng::Rng;
    pub use crate::vector::{Multiprocessing, Serial, StepBatch, VecBatch, VecConfig, VecEnv, VecSpec};
    pub use crate::wrappers::{EnvSpec, Wrapper, WrapperSpec};
}
