//! Epoch-versioned parameter snapshots for the pipelined trainer.
//!
//! The learner mutates its parameter vector in place across PPO epochs
//! and minibatches; the collector thread must never read those
//! mid-update weights. Instead the learner **publishes** a consistent
//! copy after each segment's optimization finishes, and the collector
//! **acquires** the latest published version right before it starts a
//! segment. Publishing replaces an `Arc`, so acquire is wait-free for
//! practical purposes (one mutex-guarded pointer swap; the parameter
//! copy happens outside the lock) and a collector mid-segment keeps its
//! already-acquired version untouched.
//!
//! Built on the [`crate::sync`] facade: the
//! `snapshot_is_never_torn_and_versions_are_monotone` model in
//! `crates/puffer-train/tests/loom_models.rs` checks every publish/acquire interleaving
//! for tearing and version regression.

use crate::sync::{lock_unpoisoned, Arc, Mutex};

/// A published (version, params) pair shared between the learner
/// (publisher) and collector (consumer).
pub struct ParamSnapshot {
    slot: Mutex<(u64, Arc<Vec<f32>>)>,
}

impl ParamSnapshot {
    /// Version 0: the initial (pre-update) parameters.
    pub fn new(params: Vec<f32>) -> Self {
        ParamSnapshot {
            slot: Mutex::new((0, Arc::new(params))),
        }
    }

    /// Publish a new version (copying `params` so the caller's buffer
    /// stays free to mutate). Returns the new version number.
    pub fn publish(&self, params: &[f32]) -> u64 {
        let fresh = Arc::new(params.to_vec());
        let mut slot = lock_unpoisoned(&self.slot);
        slot.0 += 1;
        slot.1 = fresh;
        slot.0
    }

    /// Latest published (version, params). The `Arc` keeps the vector
    /// alive even if newer versions are published while the caller uses
    /// it.
    pub fn acquire(&self) -> (u64, Arc<Vec<f32>>) {
        let slot = lock_unpoisoned(&self.slot);
        (slot.0, slot.1.clone())
    }

    pub fn version(&self) -> u64 {
        lock_unpoisoned(&self.slot).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_bumps_version_and_replaces_params() {
        let snap = ParamSnapshot::new(vec![1.0, 2.0]);
        let (v0, p0) = snap.acquire();
        assert_eq!(v0, 0);
        assert_eq!(*p0, vec![1.0, 2.0]);
        assert_eq!(snap.publish(&[3.0, 4.0]), 1);
        let (v1, p1) = snap.acquire();
        assert_eq!(v1, 1);
        assert_eq!(*p1, vec![3.0, 4.0]);
        // The old acquisition is unaffected by the publish.
        assert_eq!(*p0, vec![1.0, 2.0]);
    }

    #[test]
    fn acquire_is_consistent_under_concurrent_publish() {
        // Params encode their version (all elements == version); a torn
        // read would surface as a mixed vector.
        let snap = Arc::new(ParamSnapshot::new(vec![0.0; 64]));
        let writer = {
            let snap = snap.clone();
            std::thread::spawn(move || {
                for v in 1..=200u64 {
                    snap.publish(&vec![v as f32; 64]);
                }
            })
        };
        let mut last = 0u64;
        for _ in 0..1000 {
            let (v, p) = snap.acquire();
            assert!(p.iter().all(|&x| x == v as f32), "torn snapshot at v{v}");
            assert!(v >= last, "version went backwards");
            last = v;
        }
        writer.join().unwrap();
        assert_eq!(snap.version(), 200);
    }
}
