//! The rollout-side policy: runs the forward pass through a
//! [`PolicyBackend`] (native Rust math by default, AOT/PJRT behind the
//! `pjrt` feature), samples MultiDiscrete actions from the logits, and
//! manages recurrent state (the LSTM "sandwich" of paper §3.4 —
//! recurrence is a config flag, not a second model; this module owns the
//! state-reshaping and reset-on-done logic that the paper calls the most
//! common source of hard-to-diagnose bugs).

// Policy math and snapshots go through safe primitives only
// (CONCURRENCY.md — keep the unsafe surface in vector/).
#![forbid(unsafe_code)]

pub mod snapshot;

// The architecture description half (PolicySpec and its resolution)
// lives in puffer-core — it is plain data the spec layer and the Python
// bindings need without linking backends. Re-exported here so
// `crate::policy::arch::...` keeps resolving.
pub use puffer_core::policy::arch;
pub use puffer_core::policy::{ActionHead, PolicySpec, Recurrence, ResolvedPolicy};

pub use snapshot::ParamSnapshot;

use crate::backend::PolicyBackend;
use crate::runtime::SpecManifest;
use crate::util::rng::Rng;
use anyhow::Result;

/// Output of one policy step over a batch of rows.
#[derive(Clone, Debug, Default)]
pub struct PolicyOut {
    /// Sampled actions, `rows × slots`, row-major.
    pub actions: Vec<i32>,
    /// Joint log-probability of each row's action.
    pub logp: Vec<f32>,
    /// Value estimates per row.
    pub values: Vec<f32>,
}

/// A policy bound to one spec. Parameters are an opaque flat f32 buffer
/// whose layout is owned by the backend ([`PolicyBackend::init_params`]);
/// both backends share the `ravel_pytree` layout, so checkpoints are
/// interchangeable across backends when the spec architectures match.
pub struct Policy {
    spec: SpecManifest,
    params: Vec<f32>,
    /// Per-row recurrent state, `rows × state_dim` (recurrent
    /// architectures only); indexed by global env row.
    h: Vec<f32>,
    c: Vec<f32>,
    rng: Rng,
}

impl Policy {
    /// Initialize parameters for the backend's spec.
    pub fn new(backend: &mut dyn PolicyBackend, seed: u64) -> Result<Self> {
        let spec = backend.spec().clone();
        let params = backend.init_params()?;
        anyhow::ensure!(
            params.len() == spec.n_params,
            "backend produced {} params, spec says {}",
            params.len(),
            spec.n_params
        );
        let state_rows = spec.batch_roll.max(spec.batch_fwd);
        let state = vec![0.0; state_rows * spec.policy.state_dim()];
        Ok(Policy {
            spec,
            params,
            h: state.clone(),
            c: state,
            rng: Rng::new(seed ^ 0x504F_4C49),
        })
    }

    pub fn spec(&self) -> &SpecManifest {
        &self.spec
    }
    pub fn params(&self) -> &[f32] {
        &self.params
    }
    pub fn params_mut(&mut self) -> &mut Vec<f32> {
        &mut self.params
    }

    /// Overwrite the parameter vector (e.g. from a [`ParamSnapshot`]
    /// acquired on the pipelined trainer's collector thread). Length must
    /// match the spec.
    pub fn set_params(&mut self, params: &[f32]) {
        assert_eq!(
            params.len(),
            self.spec.n_params,
            "params length != spec n_params"
        );
        self.params.copy_from_slice(params);
    }

    /// Zero the recurrent state of a global env row (call when that row's
    /// episode ended — the auto-reset means its next obs starts fresh).
    pub fn reset_state(&mut self, row: usize) {
        if !self.spec.policy.is_recurrent() {
            return;
        }
        let h = self.spec.policy.state_dim();
        self.h[row * h..(row + 1) * h].fill(0.0);
        self.c[row * h..(row + 1) * h].fill(0.0);
    }

    /// Zero all recurrent state.
    pub fn reset_all_state(&mut self) {
        self.h.fill(0.0);
        self.c.fill(0.0);
    }

    /// Run the forward pass on `obs` (`rows × obs_dim` f32) where `rows`
    /// must equal `batch_fwd` or `batch_roll`; `global_rows[i]` maps batch
    /// row `i` to its env row (for recurrent-state gather/scatter).
    pub fn step(
        &mut self,
        backend: &mut dyn PolicyBackend,
        obs: &[f32],
        global_rows: &[usize],
    ) -> Result<PolicyOut> {
        let rows = global_rows.len();
        let d = self.spec.obs_dim;
        anyhow::ensure!(obs.len() == rows * d, "obs len {} != {rows}x{d}", obs.len());
        anyhow::ensure!(
            rows == self.spec.batch_fwd || rows == self.spec.batch_roll,
            "forward compiled for {} or {} rows, got {rows}",
            self.spec.batch_fwd,
            self.spec.batch_roll
        );
        let hdim = self.spec.policy.state_dim();

        let (logits, values) = if self.spec.policy.is_recurrent() {
            // Gather recurrent state for these rows.
            let mut hbuf = vec![0.0f32; rows * hdim];
            let mut cbuf = vec![0.0f32; rows * hdim];
            for (i, &g) in global_rows.iter().enumerate() {
                hbuf[i * hdim..(i + 1) * hdim]
                    .copy_from_slice(&self.h[g * hdim..(g + 1) * hdim]);
                cbuf[i * hdim..(i + 1) * hdim]
                    .copy_from_slice(&self.c[g * hdim..(g + 1) * hdim]);
            }
            let out = backend.forward_lstm(&self.params, obs, &hbuf, &cbuf, rows)?;
            // Scatter updated state back.
            for (i, &g) in global_rows.iter().enumerate() {
                self.h[g * hdim..(g + 1) * hdim]
                    .copy_from_slice(&out.h[i * hdim..(i + 1) * hdim]);
                self.c[g * hdim..(g + 1) * hdim]
                    .copy_from_slice(&out.c[i * hdim..(i + 1) * hdim]);
            }
            (out.logits, out.values)
        } else {
            let out = backend.forward(&self.params, obs, rows)?;
            (out.logits, out.values)
        };

        Ok(self.sample(&logits, &values, rows))
    }

    /// Sample MultiDiscrete actions from logits; compute joint log-probs.
    fn sample(&mut self, logits: &[f32], values: &[f32], rows: usize) -> PolicyOut {
        let act_dims = &self.spec.act_dims;
        let n_act: usize = act_dims.iter().sum();
        debug_assert_eq!(logits.len(), rows * n_act);
        let slots = act_dims.len();
        let mut actions = vec![0i32; rows * slots];
        let mut logp = vec![0.0f32; rows];
        for r in 0..rows {
            let row = &logits[r * n_act..(r + 1) * n_act];
            let mut off = 0;
            for (s, &n) in act_dims.iter().enumerate() {
                let seg = &row[off..off + n];
                let a = self.rng.categorical_logits(seg);
                actions[r * slots + s] = a as i32;
                logp[r] += log_softmax_at(seg, a);
                off += n;
            }
        }
        PolicyOut {
            actions,
            logp,
            values: values[..rows].to_vec(),
        }
    }

    /// Greedy (argmax) actions — deterministic evaluation.
    pub fn greedy(&self, logits_row: &[f32]) -> Vec<i32> {
        greedy_actions(logits_row, &self.spec.act_dims)
    }
}

/// Greedy (argmax) action per head slot from one row of logits — the
/// deterministic decode shared by [`Policy::greedy`] and the serve
/// batcher (which runs the backend directly, without a [`Policy`]).
pub fn greedy_actions(logits_row: &[f32], act_dims: &[usize]) -> Vec<i32> {
    let mut out = Vec::with_capacity(act_dims.len());
    let mut off = 0;
    for &n in act_dims {
        let seg = &logits_row[off..off + n];
        let arg = seg
            .iter()
            .enumerate()
            // PANIC: act_dims entries are > 0, so the segment is non-empty and logits are finite.
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        out.push(arg as i32);
        off += n;
    }
    out
}

/// Numerically stable `log softmax(seg)[idx]`.
pub fn log_softmax_at(seg: &[f32], idx: usize) -> f32 {
    let max = seg.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let logz = seg.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
    seg[idx] - logz
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_sane() {
        let seg = [0.0f32, 0.0];
        assert!((log_softmax_at(&seg, 0) - (-0.6931472)).abs() < 1e-5);
        // Invariant to shifts.
        let a = log_softmax_at(&[1.0, 3.0, 2.0], 1);
        let b = log_softmax_at(&[101.0, 103.0, 102.0], 1);
        assert!((a - b).abs() < 1e-4);
        // Sums to one in prob space.
        let seg = [0.3f32, -1.2, 2.0, 0.0];
        let total: f32 = (0..4).map(|i| log_softmax_at(&seg, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn policy_steps_through_native_backend() {
        use crate::backend::{NativeBackend, PolicyBackend as _};
        let env = crate::envs::make("ocean/bandit", 0);
        let mut backend = NativeBackend::for_env("ocean/bandit", env.as_ref()).unwrap();
        let spec = backend.spec().clone();
        let mut policy = Policy::new(&mut backend, 5).unwrap();
        let rows: Vec<usize> = (0..spec.batch_fwd).collect();
        let obs = vec![0.0f32; spec.batch_fwd * spec.obs_dim];
        let out = policy.step(&mut backend, &obs, &rows).unwrap();
        assert_eq!(out.actions.len(), spec.batch_fwd * spec.act_dims.len());
        assert_eq!(out.values.len(), spec.batch_fwd);
        assert!(out.logp.iter().all(|l| *l <= 0.0));
        // Wrong batch size is rejected (the PJRT artifact contract).
        let bad_rows: Vec<usize> = (0..3).collect();
        assert!(policy.step(&mut backend, &vec![0.0; 3 * spec.obs_dim], &bad_rows).is_err());
    }
}
