//! The durable unit of the registry: one [`RunRecord`] per run
//! directory, serialized as compact JSON in `<run_dir>/run.json` and
//! rewritten atomically on every status transition. The record is the
//! single source of truth for "what happened to this run" — `puffer ps`
//! renders it, resumable sweeps classify children from it, and the
//! root `index.jsonl` merely points at it.

use crate::runspec::RunSpec;
use crate::train::TrainReport;
use crate::util::json::{num, obj, s, Json};
use anyhow::{bail, Result};

/// Lifecycle of a registered run. Transitions are
/// `Pending → Running → Done | Failed | Killed`; `Running → Pending`
/// happens only when a sweep re-queues an orphan, and re-launches bump
/// [`RunRecord::attempt`] back through `Running`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunStatus {
    /// Queued by a sweep; no process has claimed it yet.
    Pending,
    /// Claimed: `pid`/`host`/`started_ms` identify the worker.
    Running,
    /// Trained to budget; `metrics` and `checkpoint` are final.
    Done,
    /// The trainer returned an error or panicked; see `error`.
    Failed,
    /// The process died without writing a terminal status (SIGKILL,
    /// OOM); recorded post-hoc by the sweep parent or orphan reconciler.
    Killed,
}

impl RunStatus {
    pub fn as_str(self) -> &'static str {
        match self {
            RunStatus::Pending => "pending",
            RunStatus::Running => "running",
            RunStatus::Done => "done",
            RunStatus::Failed => "failed",
            RunStatus::Killed => "killed",
        }
    }

    pub fn parse(text: &str) -> Result<Self> {
        Ok(match text {
            "pending" => RunStatus::Pending,
            "running" => RunStatus::Running,
            "done" => RunStatus::Done,
            "failed" => RunStatus::Failed,
            "killed" => RunStatus::Killed,
            other => bail!("unknown run status '{other}'"),
        })
    }

    /// Terminal states are never overwritten by the orphan reconciler.
    pub fn is_terminal(self) -> bool {
        matches!(self, RunStatus::Done | RunStatus::Failed | RunStatus::Killed)
    }
}

/// The throughput/score summary copied from the final [`TrainReport`]
/// when a run completes.
#[derive(Clone, Debug, PartialEq)]
pub struct FinalMetrics {
    pub global_step: u64,
    pub sps: f64,
    pub env_sps: f64,
    pub learn_sps: f64,
    pub mean_score: Option<f64>,
    pub mean_return: Option<f64>,
    pub episodes: u64,
}

impl FinalMetrics {
    pub fn from_report(r: &TrainReport) -> Self {
        FinalMetrics {
            global_step: r.global_step,
            sps: r.sps,
            env_sps: r.env_sps,
            learn_sps: r.learn_sps,
            mean_score: r.mean_score,
            mean_return: r.mean_return,
            episodes: r.episodes as u64,
        }
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("global_step", num(self.global_step as f64)),
            ("sps", num(self.sps)),
            ("env_sps", num(self.env_sps)),
            ("learn_sps", num(self.learn_sps)),
            ("mean_score", opt_num(self.mean_score)),
            ("mean_return", opt_num(self.mean_return)),
            ("episodes", num(self.episodes as f64)),
        ])
    }

    fn from_json(j: &Json) -> Self {
        FinalMetrics {
            global_step: j.get("global_step").as_f64().unwrap_or(0.0) as u64,
            sps: j.get("sps").as_f64().unwrap_or(0.0),
            env_sps: j.get("env_sps").as_f64().unwrap_or(0.0),
            learn_sps: j.get("learn_sps").as_f64().unwrap_or(0.0),
            mean_score: j.get("mean_score").as_f64(),
            mean_return: j.get("mean_return").as_f64(),
            episodes: j.get("episodes").as_f64().unwrap_or(0.0) as u64,
        }
    }
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => num(x),
        None => Json::Null,
    }
}

fn opt_str(v: &Option<String>) -> Json {
    match v {
        Some(x) => s(x),
        None => Json::Null,
    }
}

/// One registered run. Everything `puffer ps` shows and resumable
/// sweeps decide from lives here; the struct is plain data and the JSON
/// form is stable (unknown fields are ignored on read, missing optional
/// fields default).
#[derive(Clone, Debug, PartialEq)]
pub struct RunRecord {
    /// The run directory — the registry's primary key.
    pub run_dir: String,
    /// Display label: the run-dir leaf (the grid assignment for sweep
    /// children).
    pub label: String,
    /// The env key, wrappers included (e.g. `ocean/bandit`).
    pub env: String,
    pub seed: u64,
    /// The step budget the launching spec asked for.
    pub total_steps: u64,
    /// Serialized spec identity minus `train.total_steps` (budget
    /// extensions are resumes, not collisions) — what `puffer validate`
    /// compares to warn about two different specs claiming one run dir.
    /// Empty for unserializable specs.
    pub spec_fingerprint: String,
    pub status: RunStatus,
    /// How many times this run entered `Running`. Attempt 2+ means a
    /// resume (after completion with a bigger budget, or after a crash).
    pub attempt: u64,
    pub host: String,
    pub pid: u32,
    pub created_ms: u64,
    /// 0 = never started.
    pub started_ms: u64,
    /// 0 = not ended (pending/running).
    pub ended_ms: u64,
    /// Process-mode child exit code, when the child exited by itself.
    pub exit_code: Option<i64>,
    /// Failure detail: the error chain or panic message.
    pub error: Option<String>,
    /// Path to the run's checkpoint, once one exists.
    pub checkpoint: Option<String>,
    /// Final metrics, set when the run reaches `Done`.
    pub metrics: Option<FinalMetrics>,
}

impl RunRecord {
    /// A fresh `Pending` record for `spec` at `run_dir`.
    pub fn new(spec: &RunSpec, run_dir: &str) -> Self {
        RunRecord {
            run_dir: run_dir.to_string(),
            label: label_of(run_dir),
            env: spec.env.key(),
            seed: spec.seed,
            total_steps: spec.train.total_steps,
            spec_fingerprint: spec_fingerprint(spec),
            status: RunStatus::Pending,
            attempt: 0,
            host: String::new(),
            pid: 0,
            created_ms: super::fsio::now_ms(),
            started_ms: 0,
            ended_ms: 0,
            exit_code: None,
            error: None,
            checkpoint: None,
            metrics: None,
        }
    }

    /// Refresh the spec-derived fields (budget, seed, fingerprint) from
    /// a re-launch spec — a resume may legitimately extend the budget.
    pub fn absorb_spec(&mut self, spec: &RunSpec) {
        self.env = spec.env.key();
        self.seed = spec.seed;
        self.total_steps = spec.train.total_steps;
        self.spec_fingerprint = spec_fingerprint(spec);
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("run_dir", s(&self.run_dir)),
            ("label", s(&self.label)),
            ("env", s(&self.env)),
            ("seed", num(self.seed as f64)),
            ("total_steps", num(self.total_steps as f64)),
            ("spec_fingerprint", s(&self.spec_fingerprint)),
            ("status", s(self.status.as_str())),
            ("attempt", num(self.attempt as f64)),
            ("host", s(&self.host)),
            ("pid", num(self.pid as f64)),
            ("created_ms", num(self.created_ms as f64)),
            ("started_ms", num(self.started_ms as f64)),
            ("ended_ms", num(self.ended_ms as f64)),
            (
                "exit_code",
                match self.exit_code {
                    Some(c) => num(c as f64),
                    None => Json::Null,
                },
            ),
            ("error", opt_str(&self.error)),
            ("checkpoint", opt_str(&self.checkpoint)),
            (
                "metrics",
                match &self.metrics {
                    Some(m) => m.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let run_dir = match j.get("run_dir").as_str() {
            Some(d) if !d.is_empty() => d.to_string(),
            _ => bail!("run record missing 'run_dir'"),
        };
        let status = match j.get("status").as_str() {
            Some(text) => RunStatus::parse(text)?,
            None => bail!("run record missing 'status'"),
        };
        let get_u64 = |key: &str| j.get(key).as_f64().unwrap_or(0.0) as u64;
        Ok(RunRecord {
            label: j
                .get("label")
                .as_str()
                .map(str::to_string)
                .unwrap_or_else(|| label_of(&run_dir)),
            env: j.get("env").as_str().unwrap_or("").to_string(),
            seed: get_u64("seed"),
            total_steps: get_u64("total_steps"),
            spec_fingerprint: j.get("spec_fingerprint").as_str().unwrap_or("").to_string(),
            status,
            attempt: get_u64("attempt"),
            host: j.get("host").as_str().unwrap_or("").to_string(),
            pid: get_u64("pid") as u32,
            created_ms: get_u64("created_ms"),
            started_ms: get_u64("started_ms"),
            ended_ms: get_u64("ended_ms"),
            exit_code: j.get("exit_code").as_f64().map(|c| c as i64),
            error: j.get("error").as_str().map(str::to_string),
            checkpoint: j.get("checkpoint").as_str().map(str::to_string),
            metrics: match j.get("metrics") {
                Json::Null => None,
                m => Some(FinalMetrics::from_json(m)),
            },
            run_dir,
        })
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("parsing run record: {e}"))?;
        Self::from_json(&j)
    }
}

/// The run-dir leaf, used as the display label.
pub fn label_of(run_dir: &str) -> String {
    run_dir
        .trim_end_matches('/')
        .rsplit('/')
        .next()
        .unwrap_or(run_dir)
        .to_string()
}

/// Spec identity for cross-spec collision warnings: the flat serialized
/// form minus `train.total_steps` (extending a budget re-queues the same
/// run — it is not a different experiment). Empty when the spec is
/// unserializable (custom env), in which case no collision check fires.
pub fn spec_fingerprint(spec: &RunSpec) -> String {
    match spec.to_flat() {
        Ok((mut flat, arrays)) => {
            flat.remove("train.total_steps");
            let mut parts: Vec<String> =
                flat.iter().map(|(k, v)| format!("{k}={v}")).collect();
            for (k, vs) in &arrays {
                parts.push(format!("{k}=[{}]", vs.join(",")));
            }
            parts.join(";")
        }
        Err(_) => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wrappers::EnvSpec;

    fn spec() -> RunSpec {
        RunSpec::new(EnvSpec::new("ocean/bandit"))
            .with_seed(7)
            .with_train(|t| {
                t.total_steps = 4096;
                t.run_dir = Some("runs/sweep/lr=0.001".into());
            })
    }

    #[test]
    fn record_round_trips_through_json() {
        let mut rec = RunRecord::new(&spec(), "runs/sweep/lr=0.001");
        rec.status = RunStatus::Failed;
        rec.attempt = 2;
        rec.host = "box".into();
        rec.pid = 1234;
        rec.started_ms = 17;
        rec.ended_ms = 99;
        rec.exit_code = Some(101);
        rec.error = Some("panicked: \"boom\"\nline two".into());
        rec.checkpoint = Some("runs/sweep/lr=0.001/checkpoint.bin".into());
        rec.metrics = Some(FinalMetrics {
            global_step: 4096,
            sps: 1e5,
            env_sps: 2e5,
            learn_sps: 3e5,
            mean_score: Some(0.75),
            mean_return: None,
            episodes: 12,
        });
        let back = RunRecord::parse(&rec.to_json().dump()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.label, "lr=0.001");
    }

    #[test]
    fn fingerprint_ignores_budget_but_not_other_knobs() {
        let a = spec();
        let mut b = spec();
        b.train.total_steps = 999_999;
        assert_eq!(spec_fingerprint(&a), spec_fingerprint(&b));
        let c = spec().with_seed(8);
        assert_ne!(spec_fingerprint(&a), spec_fingerprint(&c));
    }

    #[test]
    fn status_parse_rejects_unknown() {
        for st in [
            RunStatus::Pending,
            RunStatus::Running,
            RunStatus::Done,
            RunStatus::Failed,
            RunStatus::Killed,
        ] {
            assert_eq!(RunStatus::parse(st.as_str()).unwrap(), st);
            assert_eq!(st.is_terminal(), !matches!(st, RunStatus::Pending | RunStatus::Running));
        }
        assert!(RunStatus::parse("zombie").is_err());
    }
}
