//! Live-run telemetry: each trainer with a run dir rewrites a tiny
//! `<run_dir>/heartbeat.json` (atomic replace, so readers never see a
//! torn file) once per configured period. `puffer ps` uses its age for
//! stale-heartbeat orphan detection; `puffer top` tails the SPS/stall
//! counters across live runs. The file is a throwaway — deleting it
//! only makes a live run look momentarily stale.

use super::fsio;
use crate::util::json::{num, obj, Json};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// A heartbeat stale for longer than `max(3 × period, 10 s)` marks its
/// run as orphaned (`puffer ps` shows `stale`, resumable sweeps reclaim
/// the child). Three periods tolerates scheduler hiccups; the 10 s
/// floor keeps sub-second periods from flapping.
pub fn stale_after_s(period_s: f64) -> f64 {
    (period_s * 3.0).max(10.0)
}

/// One parsed `heartbeat.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct Heartbeat {
    pub pid: u32,
    pub global_step: u64,
    pub total_steps: u64,
    /// Collection / learning steps-per-second for the last segment.
    pub env_sps: f64,
    pub learn_sps: f64,
    /// Cumulative collector + learner stall seconds.
    pub stall_s: f64,
    pub mean_score: Option<f64>,
    /// Wall-clock write time (ms since epoch) — the staleness anchor.
    pub updated_ms: u64,
    /// The writer's configured period, so readers compute staleness
    /// against the cadence the trainer actually promised.
    pub period_s: f64,
}

impl Heartbeat {
    pub fn path_for(run_dir: &str) -> PathBuf {
        Path::new(run_dir).join("heartbeat.json")
    }

    /// Load the heartbeat for `run_dir`; `Ok(None)` when none exists.
    pub fn load(run_dir: &str) -> Result<Option<Heartbeat>> {
        let path = Self::path_for(run_dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(text) => text,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let get_u64 = |key: &str| j.get(key).as_f64().unwrap_or(0.0) as u64;
        Ok(Some(Heartbeat {
            pid: get_u64("pid") as u32,
            global_step: get_u64("global_step"),
            total_steps: get_u64("total_steps"),
            env_sps: j.get("env_sps").as_f64().unwrap_or(0.0),
            learn_sps: j.get("learn_sps").as_f64().unwrap_or(0.0),
            stall_s: j.get("stall_s").as_f64().unwrap_or(0.0),
            mean_score: j.get("mean_score").as_f64(),
            updated_ms: get_u64("updated_ms"),
            period_s: j.get("period_s").as_f64().unwrap_or(5.0),
        }))
    }

    /// Seconds since this heartbeat was written, as seen at `now_ms`.
    pub fn age_s(&self, now_ms: u64) -> f64 {
        (now_ms.saturating_sub(self.updated_ms)) as f64 / 1e3
    }

    /// Stale as seen at `now_ms`? See [`stale_after_s`].
    pub fn is_stale(&self, now_ms: u64) -> bool {
        self.age_s(now_ms) > stale_after_s(self.period_s)
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("pid", num(self.pid as f64)),
            ("global_step", num(self.global_step as f64)),
            ("total_steps", num(self.total_steps as f64)),
            ("env_sps", num(self.env_sps)),
            ("learn_sps", num(self.learn_sps)),
            ("stall_s", num(self.stall_s)),
            (
                "mean_score",
                match self.mean_score {
                    Some(x) => num(x),
                    None => Json::Null,
                },
            ),
            ("updated_ms", num(self.updated_ms as f64)),
            ("period_s", num(self.period_s)),
        ])
    }
}

/// The trainer-side throttled writer: call [`beat`](Self::beat) once
/// per logged segment (cheap — it returns immediately inside the
/// period) and [`force`](Self::force) at run start/end so `ps` sees a
/// fresh file even for runs shorter than one period.
#[derive(Debug)]
pub struct HeartbeatWriter {
    run_dir: String,
    period: Duration,
    period_s: f64,
    total_steps: u64,
    last_write: Option<Instant>,
}

impl HeartbeatWriter {
    pub fn new(run_dir: &str, period_s: f64, total_steps: u64) -> Self {
        let period_s = if period_s.is_finite() && period_s > 0.0 {
            period_s
        } else {
            5.0
        };
        HeartbeatWriter {
            run_dir: run_dir.to_string(),
            period: Duration::from_secs_f64(period_s),
            period_s,
            total_steps,
            last_write: None,
        }
    }

    /// Throttled write: a no-op until a full period has elapsed since
    /// the last write.
    pub fn beat(
        &mut self,
        global_step: u64,
        env_sps: f64,
        learn_sps: f64,
        stall_s: f64,
        mean_score: Option<f64>,
    ) -> Result<()> {
        if let Some(last) = self.last_write {
            if last.elapsed() < self.period {
                return Ok(());
            }
        }
        self.force(global_step, env_sps, learn_sps, stall_s, mean_score)
    }

    /// Unthrottled write.
    pub fn force(
        &mut self,
        global_step: u64,
        env_sps: f64,
        learn_sps: f64,
        stall_s: f64,
        mean_score: Option<f64>,
    ) -> Result<()> {
        let hb = Heartbeat {
            pid: std::process::id(),
            global_step,
            total_steps: self.total_steps,
            env_sps,
            learn_sps,
            stall_s,
            mean_score,
            updated_ms: fsio::now_ms(),
            period_s: self.period_s,
        };
        fsio::write_atomic(Heartbeat::path_for(&self.run_dir), hb.to_json().dump().as_bytes())?;
        self.last_write = Some(Instant::now());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("puffer_heartbeat_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn round_trips_and_throttles() {
        let dir = tdir("rt");
        let dir_s = dir.to_string_lossy().to_string();
        let mut w = HeartbeatWriter::new(&dir_s, 3600.0, 8192);
        w.force(1024, 1e5, 2e5, 0.5, Some(0.9)).unwrap();
        let hb = Heartbeat::load(&dir_s).unwrap().unwrap();
        assert_eq!(hb.global_step, 1024);
        assert_eq!(hb.total_steps, 8192);
        assert_eq!(hb.pid, std::process::id());
        assert_eq!(hb.mean_score, Some(0.9));
        // Within the (1 h) period, beat() is a no-op.
        w.beat(2048, 0.0, 0.0, 0.0, None).unwrap();
        let again = Heartbeat::load(&dir_s).unwrap().unwrap();
        assert_eq!(again.global_step, 1024, "throttled beat must not rewrite");
        // force() always writes.
        w.force(2048, 0.0, 0.0, 0.0, None).unwrap();
        assert_eq!(Heartbeat::load(&dir_s).unwrap().unwrap().global_step, 2048);
    }

    #[test]
    fn staleness_uses_period_with_a_floor() {
        let hb = Heartbeat {
            pid: 1,
            global_step: 0,
            total_steps: 0,
            env_sps: 0.0,
            learn_sps: 0.0,
            stall_s: 0.0,
            mean_score: None,
            updated_ms: 1_000_000,
            period_s: 5.0,
        };
        assert!(!hb.is_stale(1_000_000 + 14_000), "within 3x period");
        assert!(hb.is_stale(1_000_000 + 16_000), "past 3x period");
        // Sub-second periods still get the 10 s floor.
        let fast = Heartbeat { period_s: 0.1, ..hb };
        assert!(!fast.is_stale(1_000_000 + 9_000));
        assert!(fast.is_stale(1_000_000 + 11_000));
        assert_eq!(stale_after_s(5.0), 15.0);
        assert_eq!(stale_after_s(1.0), 10.0);
    }

    #[test]
    fn missing_heartbeat_is_none() {
        let dir = tdir("none");
        assert!(Heartbeat::load(&dir.to_string_lossy()).unwrap().is_none());
    }
}
