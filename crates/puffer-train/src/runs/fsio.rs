//! Crash-safe file primitives for the run registry: every durable
//! artifact in `runs/` goes through exactly two write shapes, both of
//! which leave a parseable file no matter where a SIGKILL lands.
//!
//! * [`write_atomic`] — whole-file replace via tmp sibling + `fsync` +
//!   `rename`. Readers see either the old complete file or the new
//!   complete file, never a torn one. Used for `run.json`,
//!   `heartbeat.json`, per-child `spec.toml`, and `checkpoint.bin`.
//! * [`append_line_fsync`] — one `O_APPEND` write of a single
//!   newline-terminated line, then `fsync`. A kill mid-write can at
//!   worst leave one truncated *final* line, which the index reader
//!   tolerates and skips. Used for `index.jsonl`.

use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Milliseconds since the Unix epoch (0 if the clock is before it).
pub fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Best-effort hostname: `/etc/hostname`, then `$HOSTNAME`, then
/// `"unknown"`. Recorded so `puffer ps` on one machine does not
/// liveness-probe pids that belong to another.
pub fn hostname() -> String {
    if let Ok(h) = std::fs::read_to_string("/etc/hostname") {
        let h = h.trim();
        if !h.is_empty() {
            return h.to_string();
        }
    }
    match std::env::var("HOSTNAME") {
        Ok(h) if !h.trim().is_empty() => h.trim().to_string(),
        _ => "unknown".to_string(),
    }
}

/// Is `pid` a live process? `Some(alive)` where the answer is knowable
/// (Linux procfs), `None` elsewhere — callers must treat `None` as
/// "unknown", not "dead", so a registry copied to another OS never
/// misreports live runs as orphans.
pub fn pid_alive(pid: u32) -> Option<bool> {
    if cfg!(target_os = "linux") {
        Some(Path::new(&format!("/proc/{pid}")).exists())
    } else {
        None
    }
}

fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Replace `path` atomically: write a `.tmp` sibling, `fsync` it, then
/// `rename` over the target (same directory, so the rename is atomic on
/// POSIX filesystems), then best-effort `fsync` the directory so the
/// rename itself survives power loss. Creates parent directories.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let parent = path.parent().filter(|p| !p.as_os_str().is_empty());
    if let Some(dir) = parent {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    }
    let tmp = tmp_sibling(path);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all()
            .with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    if let Some(dir) = parent {
        // Directory fsync is advisory: some filesystems refuse O_RDONLY
        // dir syncs, and the rename already happened.
        let _ = std::fs::File::open(dir).and_then(|d| d.sync_all());
    }
    Ok(())
}

/// Append one newline-terminated line to `path` with `O_APPEND` + fsync.
/// The line must not itself contain a newline (compact JSON never does);
/// embedded newlines are replaced with spaces as a last-ditch guard so a
/// bad payload degrades to one odd line, not a corrupt log.
pub fn append_line_fsync(path: impl AsRef<Path>, line: &str) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    }
    let mut buf = Vec::with_capacity(line.len() + 1);
    if line.contains('\n') {
        buf.extend_from_slice(line.replace('\n', " ").as_bytes());
    } else {
        buf.extend_from_slice(line.as_bytes());
    }
    buf.push(b'\n');
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    // One write_all over one buffer: O_APPEND makes the whole line land
    // as a single atomic append on local filesystems.
    f.write_all(&buf)
        .with_context(|| format!("appending to {}", path.display()))?;
    f.sync_all()
        .with_context(|| format!("syncing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("puffer_fsio_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let d = tdir("atomic");
        let p = d.join("nested/dir/file.json");
        write_atomic(&p, b"{\"a\":1}").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"{\"a\":1}");
        write_atomic(&p, b"{\"a\":2}").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"{\"a\":2}");
        assert!(!tmp_sibling(&p).exists(), "tmp sibling must be renamed away");
    }

    #[test]
    fn append_line_fsync_appends_and_sanitizes() {
        let d = tdir("append");
        let p = d.join("index.jsonl");
        append_line_fsync(&p, "{\"x\":1}").unwrap();
        append_line_fsync(&p, "bad\nline").unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "{\"x\":1}\nbad line\n");
    }

    #[test]
    fn pid_alive_sees_this_process() {
        if let Some(alive) = pid_alive(std::process::id()) {
            assert!(alive);
        }
        // A pid beyond pid_max is never alive.
        if let Some(alive) = pid_alive(u32::MAX - 1) {
            assert!(!alive);
        }
    }
}
