//! The run registry: a root directory holding one append-only
//! `index.jsonl` (one line per status transition, fsync'd) plus the
//! per-run `run.json` records the index points at. The split gives both
//! durability shapes their natural home — the index is a log (appends
//! survive anything, torn final lines are skipped), the record is a
//! snapshot (atomic replace, never torn) — so a SIGKILL at any point
//! leaves a parseable registry with exactly one record per run dir.

use super::fsio;
use super::record::{FinalMetrics, RunRecord, RunStatus};
use crate::runspec::RunSpec;
use crate::train::TrainReport;
use crate::util::json::{num, obj, s, Json};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Handle on a registry root. Cheap plain data: every operation opens
/// the files it needs, so handles can live on sweep worker threads
/// without shared state.
#[derive(Clone, Debug)]
pub struct Registry {
    root: PathBuf,
}

impl Registry {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        Registry { root: root.into() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// `<root>/index.jsonl`.
    pub fn index_path(&self) -> PathBuf {
        self.root.join("index.jsonl")
    }

    /// `<run_dir>/run.json` — the record lives with the run, not under
    /// the registry root, so deleting a run dir deletes its record.
    pub fn record_path(run_dir: &str) -> PathBuf {
        Path::new(run_dir).join("run.json")
    }

    /// Load the record for `run_dir`; `Ok(None)` when none exists.
    pub fn load(run_dir: &str) -> Result<Option<RunRecord>> {
        let path = Self::record_path(run_dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(text) => text,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        RunRecord::parse(&text)
            .map(Some)
            .with_context(|| format!("parsing {}", path.display()))
    }

    /// Persist a record: atomic `run.json` replace, then append the
    /// transition to the index. Crash between the two loses only the
    /// index line; the reader unions index entries with the authoritative
    /// `run.json` files it already knows, so the record still wins.
    pub fn write(&self, rec: &RunRecord) -> Result<()> {
        fsio::write_atomic(Self::record_path(&rec.run_dir), rec.to_json().dump().as_bytes())?;
        let event = obj(vec![
            ("ts_ms", num(fsio::now_ms() as f64)),
            ("run_dir", s(&rec.run_dir)),
            ("status", s(rec.status.as_str())),
            ("attempt", num(rec.attempt as f64)),
            ("pid", num(rec.pid as f64)),
        ]);
        fsio::append_line_fsync(self.index_path(), &event.dump())
    }

    /// All registered runs, most recent transition first. Run dirs are
    /// discovered from the index (deduplicated); each loads its
    /// `run.json`. Torn or unparseable index lines are skipped, and a
    /// run dir whose record vanished (deleted by hand) is synthesized
    /// from its last index event so `ps` still explains it.
    pub fn list(&self) -> Result<Vec<RunRecord>> {
        let path = self.index_path();
        let text = match std::fs::read_to_string(&path) {
            Ok(text) => text,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        // Last event per run dir, in last-seen order (iterate in file
        // order; later lines overwrite and push recency).
        let mut order: Vec<String> = Vec::new();
        let mut last: std::collections::BTreeMap<String, Json> = std::collections::BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Ok(event) = Json::parse(line) else {
                continue; // torn final line, or hand-edited garbage
            };
            let Some(run_dir) = event.get("run_dir").as_str() else {
                continue;
            };
            let run_dir = run_dir.to_string();
            if let Some(pos) = order.iter().position(|d| d == &run_dir) {
                order.remove(pos);
            }
            order.push(run_dir.clone());
            last.insert(run_dir, event);
        }
        let mut out = Vec::with_capacity(order.len());
        for run_dir in order.iter().rev() {
            match Self::load(run_dir) {
                Ok(Some(rec)) => out.push(rec),
                Ok(None) | Err(_) => {
                    // PANIC: every dir in `order` has an entry in `last` by construction.
                    let event = last.get(run_dir).expect("indexed run dir");
                    let status = event
                        .get("status")
                        .as_str()
                        .and_then(|t| RunStatus::parse(t).ok())
                        .unwrap_or(RunStatus::Killed);
                    let mut rec = RunRecord {
                        run_dir: run_dir.clone(),
                        label: super::record::label_of(run_dir),
                        env: String::new(),
                        seed: 0,
                        total_steps: 0,
                        spec_fingerprint: String::new(),
                        status,
                        attempt: event.get("attempt").as_f64().unwrap_or(0.0) as u64,
                        host: String::new(),
                        pid: event.get("pid").as_f64().unwrap_or(0.0) as u32,
                        created_ms: event.get("ts_ms").as_f64().unwrap_or(0.0) as u64,
                        started_ms: 0,
                        ended_ms: 0,
                        exit_code: None,
                        error: None,
                        checkpoint: None,
                        metrics: None,
                    };
                    rec.error = Some("run.json missing or unreadable".into());
                    out.push(rec);
                }
            }
        }
        Ok(out)
    }

    // -- lifecycle helpers ---------------------------------------------------

    /// Register `run_dir` as `Pending` without bumping the attempt
    /// counter — what the sweep parent does for every child it is about
    /// to (re-)queue. Refreshes spec-derived fields on existing records.
    pub fn mark_pending(&self, spec: &RunSpec, run_dir: &str) -> Result<RunRecord> {
        let mut rec = match Self::load(run_dir)? {
            Some(rec) => rec,
            None => RunRecord::new(spec, run_dir),
        };
        rec.absorb_spec(spec);
        rec.status = RunStatus::Pending;
        rec.ended_ms = 0;
        rec.exit_code = None;
        rec.error = None;
        self.write(&rec)?;
        Ok(rec)
    }

    /// Claim `run_dir` for this process: transition to `Running`, bump
    /// the attempt counter, stamp host/pid/start time.
    pub fn begin(&self, spec: &RunSpec, run_dir: &str) -> Result<RunRecord> {
        let mut rec = match Self::load(run_dir)? {
            Some(rec) => rec,
            None => RunRecord::new(spec, run_dir),
        };
        rec.absorb_spec(spec);
        rec.status = RunStatus::Running;
        rec.attempt += 1;
        rec.host = fsio::hostname();
        rec.pid = std::process::id();
        rec.started_ms = fsio::now_ms();
        rec.ended_ms = 0;
        rec.exit_code = None;
        rec.error = None;
        self.write(&rec)?;
        Ok(rec)
    }

    /// Terminal success: `Done` with the final metrics and checkpoint.
    pub fn finish_ok(
        &self,
        mut rec: RunRecord,
        report: &TrainReport,
        checkpoint: Option<String>,
    ) -> Result<RunRecord> {
        rec.status = RunStatus::Done;
        rec.ended_ms = fsio::now_ms();
        rec.metrics = Some(FinalMetrics::from_report(report));
        if checkpoint.is_some() {
            rec.checkpoint = checkpoint;
        }
        self.write(&rec)?;
        Ok(rec)
    }

    /// Terminal failure: `Failed` (trainer error / child panic / nonzero
    /// exit) or `Killed` (died without a terminal status of its own).
    pub fn finish_err(
        &self,
        mut rec: RunRecord,
        status: RunStatus,
        error: &str,
        exit_code: Option<i64>,
    ) -> Result<RunRecord> {
        debug_assert!(status.is_terminal());
        rec.status = status;
        rec.ended_ms = fsio::now_ms();
        rec.error = Some(error.to_string());
        rec.exit_code = exit_code;
        self.write(&rec)?;
        Ok(rec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wrappers::EnvSpec;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("puffer_registry_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn spec(dir: &str) -> RunSpec {
        RunSpec::new(EnvSpec::new("ocean/bandit")).with_train(|t| {
            t.total_steps = 2048;
            t.run_dir = Some(dir.to_string());
        })
    }

    #[test]
    fn transitions_keep_one_record_per_dir_and_log_every_event() {
        let root = tdir("transitions");
        let reg = Registry::new(&root);
        let dir_a = root.join("a").to_string_lossy().to_string();
        let dir_b = root.join("b").to_string_lossy().to_string();

        reg.mark_pending(&spec(&dir_a), &dir_a).unwrap();
        reg.mark_pending(&spec(&dir_b), &dir_b).unwrap();
        let rec = reg.begin(&spec(&dir_a), &dir_a).unwrap();
        assert_eq!(rec.attempt, 1);
        assert_eq!(rec.pid, std::process::id());
        let report = TrainReport {
            global_step: 2048,
            mean_score: Some(1.0),
            episodes: 3,
            ..Default::default()
        };
        reg.finish_ok(rec, &report, Some(format!("{dir_a}/checkpoint.bin"))).unwrap();

        let runs = reg.list().unwrap();
        assert_eq!(runs.len(), 2, "one record per dir, not per transition");
        // Most recent transition first: a finished after b was queued.
        assert_eq!(runs[0].run_dir, dir_a);
        assert_eq!(runs[0].status, RunStatus::Done);
        assert_eq!(runs[0].metrics.as_ref().unwrap().global_step, 2048);
        assert_eq!(runs[1].status, RunStatus::Pending);
        // The index logged all four transitions.
        let index = std::fs::read_to_string(reg.index_path()).unwrap();
        assert_eq!(index.lines().count(), 4);
    }

    #[test]
    fn torn_index_lines_and_missing_records_are_tolerated() {
        let root = tdir("torn");
        let reg = Registry::new(&root);
        let dir = root.join("child").to_string_lossy().to_string();
        let rec = reg.begin(&spec(&dir), &dir).unwrap();
        reg.finish_err(rec, RunStatus::Failed, "boom", Some(101)).unwrap();
        // Simulate a SIGKILL mid-append: a truncated final line.
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(reg.index_path())
            .unwrap();
        f.write_all(b"{\"ts_ms\":123,\"run_dir\":\"runs/tor").unwrap();
        drop(f);
        let runs = reg.list().unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].status, RunStatus::Failed);
        assert_eq!(runs[0].exit_code, Some(101));
        // Now delete the record: the run still lists, synthesized from
        // its last index event.
        std::fs::remove_file(Registry::record_path(&dir)).unwrap();
        let runs = reg.list().unwrap();
        assert_eq!(runs.len(), 1);
        assert!(runs[0].error.as_deref().unwrap().contains("run.json missing"));
    }

    #[test]
    fn resume_bumps_attempt_and_absorbs_budget_extension() {
        let root = tdir("resume");
        let reg = Registry::new(&root);
        let dir = root.join("child").to_string_lossy().to_string();
        let rec = reg.begin(&spec(&dir), &dir).unwrap();
        reg.finish_ok(rec, &TrainReport::default(), None).unwrap();
        let mut bigger = spec(&dir);
        bigger.train.total_steps = 9999;
        let rec = reg.begin(&bigger, &dir).unwrap();
        assert_eq!(rec.attempt, 2);
        assert_eq!(rec.total_steps, 9999);
        assert_eq!(rec.status, RunStatus::Running);
        assert!(rec.error.is_none(), "re-launch clears stale failure detail");
    }
}
