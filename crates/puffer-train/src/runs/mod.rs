//! Fleet-scale experiment ops: the run registry, resumable sweeps, and
//! the `puffer ps` / `puffer top` live watch (ROADMAP north-star item
//! 5 — one durable, machine-readable record per experiment instead of
//! loose `metrics.csv` directories).
//!
//! ## The registry
//!
//! Every `RunSpec` launch is logged under a registry root (default
//! `runs/`, the `[runs]` spec section / `--runs.root` flag):
//!
//! ```text
//! runs/
//!   index.jsonl                  # append-only event log, fsync'd: one
//!                                #   line per status transition
//!   <run_dir>/run.json           # the authoritative per-run record,
//!                                #   rewritten atomically per transition
//!   <run_dir>/heartbeat.json     # live SPS/stall telemetry, rewritten
//!                                #   atomically once per period
//! ```
//!
//! Records transition `pending → running → done | failed | killed`
//! with host/pid, start/end times, attempt count, final metrics, and
//! checkpoint path. Both write shapes ([`fsio`]) are crash-safe, so a
//! SIGKILL at any point leaves a parseable registry — the property the
//! resume path builds on.
//!
//! ## Resumable sweeps
//!
//! `puffer sweep` consults the registry before launching each grid
//! child ([`sweep::classify`]): at-budget children are skipped,
//! partials resume from their checkpoints via the zero-flag resume
//! path, and orphans (stale heartbeat, dead pid) are reclaimed. With
//! `--processes=N` the children run as separate OS processes
//! ([`sweep::run_processes`]) so a child panic/OOM/SIGKILL costs that
//! child alone, with its exit status captured into the registry.
//!
//! ## Live watch
//!
//! Trainers heartbeat env-SPS / learner-SPS / stall counters to
//! `heartbeat.json` ([`heartbeat::HeartbeatWriter`]); `puffer ps`
//! ([`watch::ps_table`], `--json` for scripts) tables live/recent runs
//! with stale-heartbeat orphan detection, and `puffer top`
//! ([`watch::top_frame`]) refreshes the in-flight view.

// Registry plumbing is pure std-file I/O over safe primitives; the
// crate's unsafe surface stays in vector/ (CONCURRENCY.md).
#![forbid(unsafe_code)]

pub mod fsio;
pub mod heartbeat;
pub mod record;
pub mod registry;
#[cfg(feature = "trainer")]
pub mod sweep;
pub mod watch;

pub use heartbeat::{Heartbeat, HeartbeatWriter};
pub use record::{FinalMetrics, RunRecord, RunStatus};
pub use registry::Registry;
pub use watch::{ps_json, ps_table, snapshot, top_frame, DerivedStatus, RunView};

// The plain-data `[runs]` config lives in puffer-core (the spec layer
// needs it without linking this crate); re-exported here so
// `crate::runs::RunsConfig` keeps resolving.
pub use puffer_core::runs::RunsConfig;
