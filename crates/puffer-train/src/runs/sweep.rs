//! Registry-aware sweep execution: classify every grid child against
//! the registry before launching anything (at-budget children are
//! skipped, partials resume from their checkpoints, orphans are
//! reclaimed), then drain the remainder either on an in-process worker
//! pool ([`run_resumable`], panic-isolated per child) or as separate
//! OS processes ([`run_processes`], surviving child SIGKILL/OOM with
//! per-child exit status captured into the registry). Re-invoking
//! `puffer sweep` on the same spec is therefore always safe: finished
//! work is never redone, and every child ends with exactly one
//! terminal record.

use super::heartbeat::Heartbeat;
use super::record::{RunRecord, RunStatus};
use super::registry::Registry;
use super::watch::{DerivedStatus, RunView};
use crate::runspec::{run_sweep_with, RunSpec};
use crate::train::{Checkpoint, TrainReport, Trainer};
use anyhow::{Context, Result};
use std::path::Path;

/// Why a child needs no launch.
#[derive(Clone, Debug, PartialEq)]
pub enum SkipReason {
    /// The checkpoint already holds `step >= budget`.
    AtBudget { step: u64, budget: u64 },
    /// A live process (fresh heartbeat, live pid) owns the run dir.
    Live,
}

impl SkipReason {
    pub fn describe(&self) -> String {
        match self {
            SkipReason::AtBudget { step, budget } => {
                format!("checkpoint at budget ({step}/{budget} steps)")
            }
            SkipReason::Live => "a live process already owns this run dir".to_string(),
        }
    }
}

/// What the registry says should happen to one grid child.
#[derive(Clone, Debug, PartialEq)]
pub enum Plan {
    /// Nothing to do (at budget, or a live run already owns the dir).
    Skip(SkipReason),
    /// A checkpoint below budget exists — resume from this step.
    Resume(u64),
    /// No usable checkpoint — train from scratch.
    Fresh,
}

/// Terminal outcome of one child under a resumable sweep.
#[derive(Debug)]
pub enum ChildStatus {
    Skipped(String),
    /// Trained to budget. The report is `None` when the child ran in
    /// its own process (its record's final metrics carry the numbers).
    Done(Option<TrainReport>),
    Failed(String),
}

/// One child's result, in grid order.
#[derive(Debug)]
pub struct ChildOutcome {
    pub run_dir: String,
    pub label: String,
    pub resumed: bool,
    pub status: ChildStatus,
}

impl ChildOutcome {
    pub fn failed(&self) -> bool {
        matches!(self.status, ChildStatus::Failed(_))
    }
}

/// The checkpoint path a trainer with this run dir writes.
pub fn checkpoint_path(run_dir: &str) -> String {
    format!("{}/checkpoint.bin", run_dir.trim_end_matches('/'))
}

/// Decide a child's fate from its checkpoint and registry record. The
/// checkpoint is the ground truth for progress (`probe_progress` reads
/// only the header); the record guards against double-launching a dir
/// some live process already owns. An unreadable checkpoint classifies
/// as `Fresh` — pre-atomic-write torn files should retrain, not wedge
/// the sweep.
pub fn classify(child: &RunSpec) -> Result<(String, Plan)> {
    let run_dir = child
        .train
        .run_dir
        .clone()
        .context("sweep children always carry a run dir")?;
    let ckpt = checkpoint_path(&run_dir);
    let step = if Path::new(&ckpt).exists() {
        Checkpoint::probe_progress(&ckpt).ok().map(|(_, step)| step)
    } else {
        None
    };
    if let Some(step) = step {
        if step >= child.train.total_steps {
            return Ok((
                run_dir,
                Plan::Skip(SkipReason::AtBudget {
                    step,
                    budget: child.train.total_steps,
                }),
            ));
        }
    }
    if let Some(rec) = Registry::load(&run_dir)? {
        if rec.status == RunStatus::Running {
            let view = RunView {
                rec,
                heartbeat: Heartbeat::load(&run_dir).unwrap_or(None),
            };
            if view.derived(super::fsio::now_ms()) == DerivedStatus::Live {
                return Ok((run_dir, Plan::Skip(SkipReason::Live)));
            }
        }
    }
    Ok((
        run_dir,
        match step {
            Some(step) => Plan::Resume(step),
            None => Plan::Fresh,
        },
    ))
}

/// The shared prologue of both executors: classify every child, emit
/// skip outcomes immediately, reclaim orphaned `Running` records
/// (terminal `Killed` transition, so the event log explains the gap),
/// and register the survivors as `Pending`.
struct LaunchSet {
    /// Slots for all children; skips pre-filled.
    outcomes: Vec<Option<ChildOutcome>>,
    /// Indices into `children` that actually launch.
    to_run: Vec<usize>,
    /// Parallel to `to_run`: resume (vs fresh) launch.
    resumed: Vec<bool>,
}

fn prepare(
    reg: &Registry,
    children: &[RunSpec],
    mut on_event: impl FnMut(&ChildOutcome),
) -> Result<LaunchSet> {
    let mut set = LaunchSet {
        outcomes: children.iter().map(|_| None).collect(),
        to_run: Vec::new(),
        resumed: Vec::new(),
    };
    for (i, child) in children.iter().enumerate() {
        let (run_dir, plan) = classify(child)?;
        let label = super::record::label_of(&run_dir);
        match plan {
            Plan::Skip(reason) => {
                // An at-budget child can still hold a non-terminal
                // record (a process SIGKILLed right after its final
                // checkpoint landed). Settle it so the registry shows
                // exactly one terminal record per child.
                if matches!(reason, SkipReason::AtBudget { .. }) {
                    if let Some(rec) = Registry::load(&run_dir)? {
                        if !rec.status.is_terminal() {
                            reg.finish_err(
                                rec,
                                RunStatus::Killed,
                                "process died after reaching budget; checkpoint is complete",
                                None,
                            )?;
                        }
                    }
                }
                let outcome = ChildOutcome {
                    run_dir,
                    label,
                    resumed: false,
                    status: ChildStatus::Skipped(reason.describe()),
                };
                on_event(&outcome);
                set.outcomes[i] = Some(outcome);
            }
            Plan::Resume(_) | Plan::Fresh => {
                if let Some(rec) = Registry::load(&run_dir)? {
                    if rec.status == RunStatus::Running {
                        // classify() said not-live, so this is an orphan.
                        reg.finish_err(
                            rec,
                            RunStatus::Killed,
                            "orphaned run (stale heartbeat / dead pid) reclaimed by sweep",
                            None,
                        )?;
                    }
                }
                reg.mark_pending(child, &run_dir)?;
                set.to_run.push(i);
                set.resumed.push(matches!(plan, Plan::Resume(_)));
            }
        }
    }
    Ok(set)
}

/// Settle the registry record for a finished in-process child and
/// translate its report into a [`ChildStatus`].
fn settle(
    reg: &Registry,
    child: &RunSpec,
    run_dir: &str,
    report: &Result<TrainReport>,
) -> ChildStatus {
    let rec = match Registry::load(run_dir) {
        Ok(Some(rec)) => rec,
        // The child may have failed before begin() wrote anything.
        _ => RunRecord::new(child, run_dir),
    };
    match report {
        Ok(report) => {
            let ckpt = checkpoint_path(run_dir);
            let ckpt = Path::new(&ckpt).exists().then_some(ckpt);
            if let Err(e) = reg.finish_ok(rec, report, ckpt) {
                return ChildStatus::Failed(format!("trained, but registry write failed: {e:#}"));
            }
            ChildStatus::Done(Some(report.clone()))
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = reg.finish_err(rec, RunStatus::Failed, &msg, None);
            ChildStatus::Failed(msg)
        }
    }
}

/// In-process resumable sweep: the registry-aware layer over
/// [`run_sweep_with`]. Each launched child transitions
/// `pending → running` on its worker (host/pid/attempt stamped),
/// resumes from its checkpoint when one exists, and settles to
/// `done`/`failed` as it finishes — a panic in one child becomes its
/// `failed` record while siblings keep draining. Outcomes come back in
/// child order; `on_event` fires per settled child.
pub fn run_resumable(
    reg: &Registry,
    children: &[RunSpec],
    jobs: usize,
    mut on_event: impl FnMut(&ChildOutcome),
) -> Result<Vec<ChildOutcome>> {
    let mut set = prepare(reg, children, &mut on_event)?;
    if set.to_run.is_empty() {
        // PANIC: every child was classified Skip, so every slot is filled.
        return Ok(set.outcomes.into_iter().map(|o| o.expect("skipped")).collect());
    }
    let subset: Vec<RunSpec> = set.to_run.iter().map(|&i| children[i].clone()).collect();
    let resumed = &set.resumed;
    let reg_ref = &*reg;
    let outcomes = run_sweep_with(
        &subset,
        jobs,
        |si, child| {
            // PANIC: prepare() only queues children that carry run dirs.
            let run_dir = child.train.run_dir.as_deref().expect("queued child has a run dir");
            reg_ref.begin(child, run_dir)?;
            let mut trainer = Trainer::from_run_spec(child)?;
            if resumed[si] {
                let ck = Checkpoint::load(checkpoint_path(run_dir))?;
                trainer.restore(&ck)?;
            }
            trainer.train()
        },
        |si, outcome| {
            let i = set.to_run[si];
            let status = settle(reg_ref, &children[i], &outcome.run_dir, &outcome.report);
            let child_outcome = ChildOutcome {
                run_dir: outcome.run_dir.clone(),
                label: outcome.label.clone(),
                resumed: resumed[si],
                status,
            };
            on_event(&child_outcome);
            set.outcomes[i] = Some(child_outcome);
        },
    )?;
    debug_assert_eq!(outcomes.len(), subset.len());
    // PANIC: prepare() filled the skips and run_sweep_with reported every launched child.
    Ok(set.outcomes.into_iter().map(|o| o.expect("all children settled")).collect())
}

/// Process-mode resumable sweep: each launched child is a separate
/// `puffer run <run_dir>/spec.toml [--resume]` OS process (stdout and
/// stderr tee'd to `<run_dir>/child.log`), so a child panic, OOM kill,
/// or SIGKILL costs that child alone. The child process writes its own
/// `running → done|failed` transitions (its pid in the record); the
/// parent only reconciles children that died without settling —
/// `killed` for signals, `failed` with the exit code otherwise.
pub fn run_processes(
    reg: &Registry,
    children: &[RunSpec],
    processes: usize,
    mut on_event: impl FnMut(&ChildOutcome),
) -> Result<Vec<ChildOutcome>> {
    let exe = std::env::current_exe().context("locating the puffer binary for child spawns")?;
    let mut set = prepare(reg, children, &mut on_event)?;
    if set.to_run.is_empty() {
        // PANIC: every child was classified Skip, so every slot is filled.
        return Ok(set.outcomes.into_iter().map(|o| o.expect("skipped")).collect());
    }
    // Materialize each child's spec where its process (and a curious
    // human) can find it.
    for &i in &set.to_run {
        let child = &children[i];
        // PANIC: prepare() only queues children that carry run dirs.
        let run_dir = child.train.run_dir.as_deref().expect("queued child has a run dir");
        let toml = child
            .to_toml()
            .with_context(|| format!("serializing child spec for {run_dir}"))?;
        super::fsio::write_atomic(Path::new(run_dir).join("spec.toml"), toml.as_bytes())?;
    }
    let n = set.to_run.len();
    let processes = processes.clamp(1, n);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let to_run = &set.to_run;
    let resumed = &set.resumed;
    let reg_ref = &*reg;
    let exe = &exe;
    let mut results: Vec<Option<ChildOutcome>> = children.iter().map(|_| None).collect();
    std::thread::scope(|s| {
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..processes {
            let tx = tx.clone();
            let next = &next;
            s.spawn(move || loop {
                // ordering: Relaxed — a pure work-stealing counter; the
                // claimed slot is the only data, and fetch_add's
                // atomicity alone guarantees each slot is claimed once.
                let slot = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if slot >= to_run.len() {
                    break;
                }
                let i = to_run[slot];
                let child = &children[i];
                // PANIC: prepare() only queues children that carry run dirs.
                let run_dir =
                    child.train.run_dir.as_deref().expect("queued child has a run dir");
                let status = spawn_child(exe, run_dir, resumed[slot], reg_ref, child);
                if tx.send((i, slot, status)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, slot, status) in rx {
            // PANIC: prepare() only queues children that carry run dirs.
            let run_dir = children[i].train.run_dir.as_deref().expect("queued child");
            let outcome = ChildOutcome {
                run_dir: run_dir.to_string(),
                label: super::record::label_of(run_dir),
                resumed: resumed[slot],
                status,
            };
            on_event(&outcome);
            results[i] = Some(outcome);
        }
    });
    for (i, slot) in set.outcomes.iter_mut().enumerate() {
        if slot.is_none() {
            *slot = results[i].take();
        }
    }
    // PANIC: every child is either a prepare() skip or a reported process result.
    Ok(set.outcomes.into_iter().map(|o| o.expect("all children settled")).collect())
}

/// Run one child to completion in its own process and reconcile its
/// registry record against the exit status.
fn spawn_child(
    exe: &Path,
    run_dir: &str,
    resume: bool,
    reg: &Registry,
    child: &RunSpec,
) -> ChildStatus {
    let spec_path = Path::new(run_dir).join("spec.toml");
    let log_path = Path::new(run_dir).join("child.log");
    let open_log = || -> Result<std::fs::File> {
        std::fs::File::create(&log_path)
            .with_context(|| format!("creating {}", log_path.display()))
    };
    let spawned = open_log().and_then(|log| {
        let mut cmd = std::process::Command::new(exe);
        cmd.arg("run").arg(&spec_path);
        if resume {
            cmd.arg("--resume");
        }
        cmd.stdin(std::process::Stdio::null())
            .stdout(std::process::Stdio::from(log.try_clone().context("duping child log")?))
            .stderr(std::process::Stdio::from(log));
        cmd.spawn().with_context(|| format!("spawning child for {run_dir}"))
    });
    let mut proc = match spawned {
        Ok(proc) => proc,
        Err(e) => {
            let msg = format!("{e:#}");
            let rec = Registry::load(run_dir).ok().flatten();
            let rec = rec.unwrap_or_else(|| RunRecord::new(child, run_dir));
            let _ = reg.finish_err(rec, RunStatus::Failed, &msg, None);
            return ChildStatus::Failed(msg);
        }
    };
    let status = match proc.wait() {
        Ok(status) => status,
        Err(e) => {
            let msg = format!("waiting on child for {run_dir}: {e}");
            return ChildStatus::Failed(msg);
        }
    };
    // The child settles its own record on clean paths; the parent only
    // steps in when it died mid-flight.
    let rec = Registry::load(run_dir).ok().flatten();
    let terminal = rec.as_ref().map(|r| r.status.is_terminal()).unwrap_or(false);
    if status.success() {
        if terminal {
            return ChildStatus::Done(None);
        }
        let msg = "child exited 0 without settling its record".to_string();
        let rec = rec.unwrap_or_else(|| RunRecord::new(child, run_dir));
        let _ = reg.finish_err(rec, RunStatus::Failed, &msg, None);
        return ChildStatus::Failed(msg);
    }
    if terminal {
        // The child recorded its own failure before exiting nonzero.
        let detail = rec
            .and_then(|r| r.error)
            .unwrap_or_else(|| format!("child exited with {status}"));
        return ChildStatus::Failed(detail);
    }
    // Died without a terminal record: a signal (SIGKILL/OOM) or a hard
    // abort. `{status}` spells out "signal: 9" / "exit status: 101".
    let (term_status, msg) = if status.code().is_none() {
        (RunStatus::Killed, format!("child killed ({status})"))
    } else {
        (RunStatus::Failed, format!("child died ({status})"))
    };
    let rec = rec.unwrap_or_else(|| RunRecord::new(child, run_dir));
    let _ = reg.finish_err(rec, term_status, &msg, status.code().map(|c| c as i64));
    ChildStatus::Failed(msg)
}
