//! Read-side of the registry: the snapshots behind `puffer ps` (table
//! or `--json` of live/recent runs, with stale-heartbeat orphan
//! detection) and `puffer top` (refreshing SPS/stall view across live
//! runs). Pure functions over [`RunRecord`]s + [`Heartbeat`]s — the
//! CLI loop in `main.rs` owns the terminal.

use super::fsio;
use super::heartbeat::{stale_after_s, Heartbeat};
use super::record::{RunRecord, RunStatus};
use super::registry::Registry;
use crate::util::json::{arr, obj, s, Json};
use crate::util::stats::{fmt_age, fmt_si};
use anyhow::Result;

/// What `ps` actually reports per run: the recorded status refined by
/// liveness evidence (heartbeat age, pid existence). A `Running` record
/// whose writer can no longer be observed is `Stale` — the registry's
/// word for "probably orphaned; a resumable sweep would reclaim it".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DerivedStatus {
    Live,
    Stale,
    Pending,
    Done,
    Failed,
    Killed,
}

impl DerivedStatus {
    pub fn as_str(self) -> &'static str {
        match self {
            DerivedStatus::Live => "live",
            DerivedStatus::Stale => "stale",
            DerivedStatus::Pending => "pending",
            DerivedStatus::Done => "done",
            DerivedStatus::Failed => "failed",
            DerivedStatus::Killed => "killed",
        }
    }
}

/// One run as `ps`/`top` see it: the durable record plus the volatile
/// heartbeat sampled at snapshot time.
#[derive(Clone, Debug)]
pub struct RunView {
    pub rec: RunRecord,
    pub heartbeat: Option<Heartbeat>,
}

impl RunView {
    /// Refine the recorded status with liveness evidence as of `now_ms`.
    pub fn derived(&self, now_ms: u64) -> DerivedStatus {
        match self.rec.status {
            RunStatus::Pending => DerivedStatus::Pending,
            RunStatus::Done => DerivedStatus::Done,
            RunStatus::Failed => DerivedStatus::Failed,
            RunStatus::Killed => DerivedStatus::Killed,
            RunStatus::Running => {
                // Same-host pid probe is the strongest signal: a dead
                // pid is an orphan no matter how fresh the heartbeat.
                if self.rec.host == fsio::hostname()
                    && fsio::pid_alive(self.rec.pid) == Some(false)
                {
                    return DerivedStatus::Stale;
                }
                match &self.heartbeat {
                    Some(hb) => {
                        if hb.is_stale(now_ms) {
                            DerivedStatus::Stale
                        } else {
                            DerivedStatus::Live
                        }
                    }
                    // No heartbeat yet: trust a fresh launch for one
                    // default staleness window, then call it orphaned.
                    None => {
                        let started_age_s =
                            now_ms.saturating_sub(self.rec.started_ms) as f64 / 1e3;
                        if self.rec.started_ms > 0 && started_age_s <= stale_after_s(5.0) {
                            DerivedStatus::Live
                        } else {
                            DerivedStatus::Stale
                        }
                    }
                }
            }
        }
    }

    /// Seconds since the run last showed signs of life (heartbeat,
    /// terminal transition, start, or registration — whichever is
    /// latest).
    pub fn age_s(&self, now_ms: u64) -> f64 {
        let mut latest = self.rec.created_ms.max(self.rec.started_ms).max(self.rec.ended_ms);
        if let Some(hb) = &self.heartbeat {
            latest = latest.max(hb.updated_ms);
        }
        now_ms.saturating_sub(latest) as f64 / 1e3
    }

    fn progress(&self) -> (u64, u64) {
        let (mut step, mut total) = (0u64, self.rec.total_steps);
        if let Some(m) = &self.rec.metrics {
            step = m.global_step;
        }
        if let Some(hb) = &self.heartbeat {
            step = step.max(hb.global_step);
            total = total.max(hb.total_steps);
        }
        (step, total)
    }

    fn sps_pair(&self) -> (f64, f64) {
        if self.rec.status == RunStatus::Running {
            if let Some(hb) = &self.heartbeat {
                return (hb.env_sps, hb.learn_sps);
            }
        }
        match &self.rec.metrics {
            Some(m) => (m.env_sps, m.learn_sps),
            None => (f64::NAN, f64::NAN),
        }
    }

    fn score(&self) -> Option<f64> {
        if self.rec.status == RunStatus::Running {
            if let Some(hb) = &self.heartbeat {
                if hb.mean_score.is_some() {
                    return hb.mean_score;
                }
            }
        }
        self.rec.metrics.as_ref().and_then(|m| m.mean_score)
    }
}

/// Every registered run with its heartbeat sampled now, most recent
/// transition first (the registry's list order).
pub fn snapshot(reg: &Registry) -> Result<Vec<RunView>> {
    let mut views = Vec::new();
    for rec in reg.list()? {
        let heartbeat = Heartbeat::load(&rec.run_dir).unwrap_or(None);
        views.push(RunView { rec, heartbeat });
    }
    Ok(views)
}

fn render_rows(header: &[&str], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.chars().count()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(cols) {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let mut out = String::new();
    let emit = |out: &mut String, cells: &[String]| {
        let line: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:<width$}", width = widths[i]))
            .collect();
        out.push_str(line.join("  ").trim_end());
        out.push('\n');
    };
    let header_cells: Vec<String> = header.iter().map(|h| h.to_string()).collect();
    emit(&mut out, &header_cells);
    for row in rows {
        emit(&mut out, row);
    }
    out
}

fn table_row(v: &RunView, now_ms: u64) -> Vec<String> {
    let (step, total) = v.progress();
    let (env_sps, learn_sps) = v.sps_pair();
    vec![
        v.derived(now_ms).as_str().to_string(),
        v.rec.run_dir.clone(),
        format!("{}/{}", fmt_si(step as f64), fmt_si(total as f64)),
        format!("{}/{}", fmt_si(env_sps), fmt_si(learn_sps)),
        match v.score() {
            Some(x) => format!("{x:.3}"),
            None => "-".to_string(),
        },
        format!("a{}", v.rec.attempt),
        fmt_age(v.age_s(now_ms)),
        if v.rec.pid == 0 {
            "-".to_string()
        } else {
            format!("{}:{}", v.rec.host, v.rec.pid)
        },
    ]
}

const TABLE_HEADER: &[&str] = &[
    "STATUS", "RUN", "STEPS", "SPS(env/learn)", "SCORE", "ATT", "AGE", "HOST:PID",
];

/// The `puffer ps` table over all registered runs.
pub fn ps_table(views: &[RunView], now_ms: u64) -> String {
    if views.is_empty() {
        return "no registered runs\n".to_string();
    }
    let rows: Vec<Vec<String>> = views.iter().map(|v| table_row(v, now_ms)).collect();
    render_rows(TABLE_HEADER, &rows)
}

/// The `puffer ps --json` form: an array of run objects, each the
/// `run.json` payload plus `derived_status`, `age_s`, and the sampled
/// `heartbeat` (null when absent) — what the CI invariants script
/// asserts over.
pub fn ps_json(views: &[RunView], now_ms: u64) -> String {
    let items: Vec<Json> = views
        .iter()
        .map(|v| {
            let mut o = match v.rec.to_json() {
                Json::Obj(map) => map,
                // PANIC: RunRecord::to_json always builds an object.
                _ => unreachable!("run record serializes to an object"),
            };
            o.insert("derived_status".into(), s(v.derived(now_ms).as_str()));
            o.insert(
                "age_s".into(),
                crate::util::json::num((v.age_s(now_ms) * 1e3).round() / 1e3),
            );
            o.insert(
                "heartbeat".into(),
                match &v.heartbeat {
                    Some(hb) => obj(vec![
                        ("pid", crate::util::json::num(hb.pid as f64)),
                        ("global_step", crate::util::json::num(hb.global_step as f64)),
                        ("env_sps", crate::util::json::num(hb.env_sps)),
                        ("learn_sps", crate::util::json::num(hb.learn_sps)),
                        ("stall_s", crate::util::json::num(hb.stall_s)),
                        ("age_s", crate::util::json::num(hb.age_s(now_ms))),
                        ("stale", Json::Bool(hb.is_stale(now_ms))),
                    ]),
                    None => Json::Null,
                },
            );
            Json::Obj(o)
        })
        .collect();
    arr(items).dump()
}

/// One `puffer top` frame: the in-flight runs (live + stale first),
/// stall column included, with a one-line fleet summary.
pub fn top_frame(views: &[RunView], now_ms: u64) -> String {
    let mut counts = [0usize; 6];
    for v in views {
        counts[match v.derived(now_ms) {
            DerivedStatus::Live => 0,
            DerivedStatus::Stale => 1,
            DerivedStatus::Pending => 2,
            DerivedStatus::Done => 3,
            DerivedStatus::Failed => 4,
            DerivedStatus::Killed => 5,
        }] += 1;
    }
    let mut active: Vec<&RunView> = views
        .iter()
        .filter(|v| {
            matches!(
                v.derived(now_ms),
                DerivedStatus::Live | DerivedStatus::Stale | DerivedStatus::Pending
            )
        })
        .collect();
    active.sort_by(|a, b| a.rec.run_dir.cmp(&b.rec.run_dir));
    let mut out = format!(
        "puffer top — {} live, {} stale, {} pending, {} done, {} failed, {} killed\n\n",
        counts[0], counts[1], counts[2], counts[3], counts[4], counts[5]
    );
    if active.is_empty() {
        out.push_str("no in-flight runs\n");
        return out;
    }
    let rows: Vec<Vec<String>> = active
        .iter()
        .map(|v| {
            let mut row = table_row(v, now_ms);
            let stall = v.heartbeat.as_ref().map(|hb| hb.stall_s).unwrap_or(f64::NAN);
            row.insert(4, if stall.is_finite() { format!("{stall:.1}s") } else { "-".into() });
            row
        })
        .collect();
    let header: &[&str] = &[
        "STATUS", "RUN", "STEPS", "SPS(env/learn)", "STALL", "SCORE", "ATT", "AGE", "HOST:PID",
    ];
    out.push_str(&render_rows(header, &rows));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runs::record::FinalMetrics;

    fn rec(dir: &str, status: RunStatus) -> RunRecord {
        RunRecord {
            run_dir: dir.to_string(),
            label: super::super::record::label_of(dir),
            env: "ocean/bandit".into(),
            seed: 1,
            total_steps: 8192,
            spec_fingerprint: String::new(),
            status,
            attempt: 1,
            host: "elsewhere".into(),
            pid: 42,
            created_ms: 1_000,
            started_ms: 2_000,
            ended_ms: 0,
            exit_code: None,
            error: None,
            checkpoint: None,
            metrics: None,
        }
    }

    fn hb(updated_ms: u64) -> Heartbeat {
        Heartbeat {
            pid: 42,
            global_step: 4096,
            total_steps: 8192,
            env_sps: 120_000.0,
            learn_sps: 450_000.0,
            stall_s: 0.25,
            mean_score: Some(0.9),
            updated_ms,
            period_s: 5.0,
        }
    }

    #[test]
    fn derived_status_distinguishes_live_stale_and_terminals() {
        let now = 100_000u64;
        let live = RunView {
            rec: rec("runs/a", RunStatus::Running),
            heartbeat: Some(hb(now - 2_000)),
        };
        assert_eq!(live.derived(now), DerivedStatus::Live);
        let stale = RunView {
            rec: rec("runs/b", RunStatus::Running),
            heartbeat: Some(hb(now - 60_000)),
        };
        assert_eq!(stale.derived(now), DerivedStatus::Stale);
        let no_hb_old = RunView {
            rec: rec("runs/c", RunStatus::Running),
            heartbeat: None,
        };
        assert_eq!(no_hb_old.derived(now), DerivedStatus::Stale);
        for (status, want) in [
            (RunStatus::Pending, DerivedStatus::Pending),
            (RunStatus::Done, DerivedStatus::Done),
            (RunStatus::Failed, DerivedStatus::Failed),
            (RunStatus::Killed, DerivedStatus::Killed),
        ] {
            let v = RunView { rec: rec("runs/t", status), heartbeat: None };
            assert_eq!(v.derived(now), want);
        }
    }

    #[test]
    fn tables_and_json_render_every_run() {
        let now = 100_000u64;
        let mut done = rec("runs/done", RunStatus::Done);
        done.metrics = Some(FinalMetrics {
            global_step: 8192,
            sps: 1e5,
            env_sps: 2e5,
            learn_sps: 3e5,
            mean_score: Some(1.0),
            mean_return: Some(0.5),
            episodes: 9,
        });
        done.ended_ms = now - 5_000;
        let views = vec![
            RunView { rec: done, heartbeat: None },
            RunView {
                rec: rec("runs/live", RunStatus::Running),
                heartbeat: Some(hb(now - 1_000)),
            },
        ];
        let table = ps_table(&views, now);
        assert!(table.contains("runs/done"), "{table}");
        assert!(table.contains("done"), "{table}");
        assert!(table.contains("live"), "{table}");
        assert!(table.contains("4.1k/8.2k"), "live row shows heartbeat steps: {table}");
        let json = Json::parse(&ps_json(&views, now)).unwrap();
        let items = json.as_arr().unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].get("derived_status").as_str(), Some("done"));
        assert_eq!(items[1].get("derived_status").as_str(), Some("live"));
        assert_eq!(items[1].get("heartbeat").get("global_step").as_f64(), Some(4096.0));
        let frame = top_frame(&views, now);
        assert!(frame.contains("1 live"), "{frame}");
        assert!(frame.contains("1 done"), "{frame}");
        assert!(frame.contains("runs/live"), "{frame}");
        assert!(!frame.contains("runs/done"), "top shows in-flight runs only: {frame}");
    }
}
