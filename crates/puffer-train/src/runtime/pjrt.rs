//! The PJRT runtime (`pjrt` cargo feature): loads the HLO-text artifacts
//! produced by `python/compile/aot.py`, compiles them on the CPU PJRT
//! client, and executes them from the training/serving hot path.

use super::manifest::{Manifest, SpecManifest};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled entry point plus its name (for error messages).
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple elements.
    ///
    /// aot.py lowers with `return_tuple=True`, so every artifact returns
    /// one tuple literal; this unpacks it into its elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let mut result = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let tuple = result.decompose_tuple()?;
        Ok(tuple)
    }
}

/// A loaded model spec: the PJRT client, all compiled entry points, and
/// the shape contract from the manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, Executable>,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and read `artifacts/manifest.json`.
    /// Entry points compile lazily on first use (see [`Runtime::load`]).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = artifacts_dir.join("manifest.json");
        let manifest = Manifest::load(&manifest_path).with_context(|| {
            format!(
                "loading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            executables: HashMap::new(),
            artifacts_dir,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (and cache) the `entry` point of `spec`, e.g.
    /// `load("ocean_squared", "train_step")`.
    pub fn load(&mut self, spec: &str, entry: &str) -> Result<&Executable> {
        let key = format!("{spec}/{entry}");
        if !self.executables.contains_key(&key) {
            let sm = self
                .manifest
                .spec(spec)
                .with_context(|| format!("spec '{spec}' not in manifest"))?;
            let file = sm
                .artifacts
                .get(entry)
                .with_context(|| format!("entry '{entry}' not in spec '{spec}'"))?;
            let path = self.artifacts_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?;
            self.executables.insert(
                key.clone(),
                Executable {
                    name: key.clone(),
                    exe,
                },
            );
        }
        Ok(&self.executables[&key])
    }

    /// Assert the Rust-side env shape matches the manifest contract
    /// (obs_dim drift between aot.py's spec table and the Rust env fails
    /// loudly here).
    pub fn check_env_contract(
        &self,
        spec: &str,
        obs_dim: usize,
        act_dims: &[usize],
        agents: usize,
    ) -> Result<&SpecManifest> {
        let sm = self.manifest.spec(spec)?;
        anyhow::ensure!(
            sm.obs_dim == obs_dim,
            "spec '{spec}': manifest obs_dim {} != env flat obs len {obs_dim} \
             (python/compile/aot.py ENV_SPECS is out of sync with the Rust env)",
            sm.obs_dim
        );
        anyhow::ensure!(
            sm.act_dims == act_dims,
            "spec '{spec}': manifest act_dims {:?} != env action dims {act_dims:?}",
            sm.act_dims
        );
        anyhow::ensure!(
            sm.agents == agents,
            "spec '{spec}': manifest agents {} != env num_agents {agents}",
            sm.agents
        );
        Ok(sm)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers

/// f32 vector literal.
pub fn lit_f32(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// f32 scalar literal.
pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// f32 matrix literal of shape `(rows, cols)` from row-major data.
pub fn lit_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// f32 rank-3 literal from row-major data.
pub fn lit_f32_3d(data: &[f32], d0: usize, d1: usize, d2: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), d0 * d1 * d2);
    Ok(xla::Literal::vec1(data).reshape(&[d0 as i64, d1 as i64, d2 as i64])?)
}

/// i32 matrix literal.
pub fn lit_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// i32 rank-3 literal.
pub fn lit_i32_3d(data: &[i32], d0: usize, d1: usize, d2: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), d0 * d1 * d2);
    Ok(xla::Literal::vec1(data).reshape(&[d0 as i64, d1 as i64, d2 as i64])?)
}

/// Extract an f32 vector from a literal (any shape, row-major).
pub fn to_f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn manifest_contract_checks() {
        if !artifacts_ready() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        let rt = Runtime::new("artifacts").unwrap();
        let env = crate::envs::make("ocean/bandit", 0);
        let sm = rt
            .check_env_contract(
                "ocean_bandit",
                env.obs_layout().flat_len(),
                env.action_dims(),
                env.num_agents(),
            )
            .unwrap();
        assert!(sm.n_params > 0);
        assert!(rt.check_env_contract("ocean_bandit", 999, &[4], 1).is_err());
    }

    #[test]
    fn load_and_run_forward() {
        if !artifacts_ready() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut rt = Runtime::new("artifacts").unwrap();
        let sm = rt.manifest().spec("ocean_bandit").unwrap().clone();
        let b = sm.batch_fwd;
        let exe = rt.load("ocean_bandit", &format!("forward_b{b}")).unwrap();
        let params = lit_f32(&vec![0.01; sm.n_params]);
        let obs = lit_f32_2d(&vec![0.0; b * sm.obs_dim], b, sm.obs_dim).unwrap();
        let out = exe.run(&[params, obs]).unwrap();
        assert_eq!(out.len(), 2, "forward returns (logits, value)");
        let logits = to_f32s(&out[0]).unwrap();
        assert_eq!(logits.len(), b * sm.act_dims.iter().sum::<usize>());
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}
