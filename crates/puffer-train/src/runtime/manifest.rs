//! The AOT manifest: the Python→Rust shape contract written by
//! `python/compile/aot.py`.

use crate::policy::arch::PolicySpec;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Per-spec entry of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct SpecManifest {
    pub obs_dim: usize,
    pub act_dims: Vec<usize>,
    pub agents: usize,
    pub lstm: bool,
    pub n_params: usize,
    pub hidden: usize,
    /// The declarative architecture descriptor this spec executes. For
    /// manifest-parsed (AOT/PJRT) specs it is synthesized from
    /// `hidden`/`lstm` — the AOT pipeline lowers default architectures
    /// only; native backends carry the full resolved spec here.
    pub policy: PolicySpec,
    /// Agent rows per pooled forward call (`N`).
    pub batch_fwd: usize,
    /// Total agent rows across all envs (`M`, the GAE/train width).
    pub batch_roll: usize,
    /// Rollout segment length `T`.
    pub horizon: usize,
    pub gamma: f64,
    pub lam: f64,
    /// File holding the initial flat parameter vector (little-endian f32).
    pub params0: String,
    /// entry name → artifact file name.
    pub artifacts: BTreeMap<String, String>,
}

impl SpecManifest {
    /// Validate that a vectorizer batch of `batch_envs` envs can feed
    /// the policy forward, which is compiled for exactly `batch_fwd`
    /// (pooled) or `batch_roll` (sync) agent rows. The single source of
    /// this invariant — `Trainer::build` and `RunSpec::validate` both
    /// call it.
    pub fn ensure_trainable_batch(&self, vec_desc: &str, batch_envs: usize) -> Result<()> {
        let batch_rows = batch_envs * self.agents;
        anyhow::ensure!(
            batch_rows == self.batch_fwd || batch_rows == self.batch_roll,
            "vec spec '{vec_desc}' yields batches of {batch_rows} agent rows, \
             but this spec forwards {} (pooled) or {} (sync) rows — use \
             vec.batch = \"full\" or \"half\"",
            self.batch_fwd,
            self.batch_roll
        );
        Ok(())
    }
}

/// The full manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch_fwd: usize,
    pub batch_roll: usize,
    pub horizon: usize,
    specs: BTreeMap<String, SpecManifest>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut specs = BTreeMap::new();
        let spec_obj = j
            .get("specs")
            .as_obj()
            .context("manifest missing 'specs'")?;
        for (name, s) in spec_obj {
            let need_usize = |key: &str| -> Result<usize> {
                s.get(key)
                    .as_usize()
                    .with_context(|| format!("spec {name}: bad '{key}'"))
            };
            let artifacts = s
                .get("artifacts")
                .as_obj()
                .with_context(|| format!("spec {name}: missing artifacts"))?
                .iter()
                .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                .collect();
            let lstm = s.get("lstm").as_bool().unwrap_or(false);
            let hidden = need_usize("hidden")?;
            let mut policy = PolicySpec::default().with_hidden(hidden);
            if lstm {
                policy = policy.with_lstm(hidden);
            }
            specs.insert(
                name.clone(),
                SpecManifest {
                    obs_dim: need_usize("obs_dim")?,
                    act_dims: s
                        .get("act_dims")
                        .as_usize_vec()
                        .with_context(|| format!("spec {name}: bad act_dims"))?,
                    agents: need_usize("agents")?,
                    lstm,
                    n_params: need_usize("n_params")?,
                    hidden,
                    policy,
                    batch_fwd: need_usize("batch_fwd")?,
                    batch_roll: need_usize("batch_roll")?,
                    horizon: need_usize("horizon")?,
                    gamma: s.get("gamma").as_f64().unwrap_or(0.99),
                    lam: s.get("lam").as_f64().unwrap_or(0.95),
                    params0: s
                        .get("params0")
                        .as_str()
                        .with_context(|| format!("spec {name}: missing params0"))?
                        .to_string(),
                    artifacts,
                },
            );
        }
        Ok(Manifest {
            batch_fwd: j
                .get("batch_fwd")
                .as_usize()
                .context("manifest: bad batch_fwd")?,
            batch_roll: j
                .get("batch_roll")
                .as_usize()
                .context("manifest: bad batch_roll")?,
            horizon: j
                .get("horizon")
                .as_usize()
                .context("manifest: bad horizon")?,
            specs,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&SpecManifest> {
        self.specs.get(name).with_context(|| {
            format!(
                "spec '{name}' not in manifest (have: {:?})",
                self.specs.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn spec_names(&self) -> impl Iterator<Item = &str> {
        self.specs.keys().map(String::as_str)
    }

    /// Map a first-party env name ("ocean/squared") to its manifest spec
    /// key ("ocean_squared").
    pub fn spec_key_for_env(env_name: &str) -> String {
        env_name.replace('/', "_")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch_fwd": 16, "batch_roll": 32, "horizon": 32,
      "specs": {
        "ocean_bandit": {
          "obs_dim": 1, "act_dims": [4], "agents": 1, "lstm": false,
          "n_params": 17000, "hidden": 128, "batch_fwd": 16, "batch_roll": 32,
          "horizon": 32, "gamma": 0.99, "lam": 0.95, "params0": "p.bin",
          "artifacts": {"forward_b16": "f.hlo.txt", "gae": "g.hlo.txt", "train_step": "t.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch_fwd, 16);
        assert_eq!(m.batch_roll, 32);
        let s = m.spec("ocean_bandit").unwrap();
        assert_eq!(s.act_dims, vec![4]);
        assert_eq!(s.artifacts["gae"], "g.hlo.txt");
        assert!(!s.lstm);
        // The synthesized architecture descriptor mirrors hidden/lstm.
        assert_eq!(s.policy, PolicySpec::default().with_hidden(128));
        assert_eq!(s.policy.state_dim(), 0);
        assert!(m.spec("nope").is_err());
    }

    #[test]
    fn env_name_mapping() {
        assert_eq!(Manifest::spec_key_for_env("ocean/squared"), "ocean_squared");
        assert_eq!(
            Manifest::spec_key_for_env("classic/cartpole"),
            "classic_cartpole"
        );
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"batch_fwd": 16}"#).is_err());
    }
}
