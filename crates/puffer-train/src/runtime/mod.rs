//! The AOT shape contract and (optionally) the PJRT runtime.
//!
//! [`Manifest`] / [`SpecManifest`] describe a compiled model spec — shapes,
//! parameter counts, rollout geometry — and are always available: the
//! pure-Rust [`NativeBackend`](crate::backend::NativeBackend) synthesizes
//! one per env, and checkpoints key off the spec name.
//!
//! The PJRT execution path ([`Runtime`], behind the `pjrt` cargo feature)
//! loads the HLO-text artifacts produced by `python/compile/aot.py`,
//! compiles them on the CPU PJRT client, and executes them from the
//! training/serving hot path. Python is never involved at runtime — the
//! manifest + artifacts are the entire contract.

mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::{Manifest, SpecManifest};
#[cfg(feature = "pjrt")]
pub use pjrt::{
    lit_f32, lit_f32_2d, lit_f32_3d, lit_i32_2d, lit_i32_3d, lit_scalar, to_f32s, Executable,
    Runtime,
};
