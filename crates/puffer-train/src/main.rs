//! `puffer` — the Clean PuffeRL runner CLI (paper §6: "a runner file with
//! a CLI for all included PufferLib environments, clean YAML configs").
//!
//! ```text
//! puffer run <spec.toml> [--train.lr=3e-3 --vec.workers=4 ...] [--resume]
//! puffer validate <spec.toml> [more.toml ...]
//! puffer resume <checkpoint.bin>            # zero flags: spec is embedded
//! puffer sweep <spec.toml> [--jobs=N | --processes=N]  # resumable [grid] sweep
//! puffer ps [--runs.root=DIR] [--json]      # registry table: live/done/failed/stale
//! puffer top [--runs.root=DIR] [--refresh=S] [--iters=N]  # refreshing live view
//! puffer train <env> [--config cfg.yaml] [--train.lr=3e-3] [--wrap.stack=4] ...
//! puffer eval <checkpoint.bin> [--episodes=N]      # spec from the checkpoint
//! puffer eval <env> --checkpoint=FILE [--episodes=N]
//! puffer sweep                              # legacy: train the whole Ocean suite
//! puffer autotune <env> [--envs=N] [--workers=W] [--secs=S] [--run_dir=DIR]
//! puffer policy describe <env> [--wrap.* ...] [--policy.* ...]
//! puffer serve <checkpoint.bin> [--serve.port=7777 ...] [--selftest]
//! puffer ckpt info <checkpoint.bin> [--json]  # version, arch key, embedded spec
//! puffer envs                               # list first-party environments
//! ```
//!
//! The declarative path: a `RunSpec` TOML file (see `examples/specs/`)
//! describes the whole experiment — `[env]` + `[env.wrap]`, `[policy]`,
//! `[vec]` (`serial` | `mt` | `auto`), `[train]`, one root `seed`, and
//! an optional `[grid]` sweep. `puffer run` executes it, embeds the spec
//! in the checkpoint, and `puffer resume` / `puffer eval` reconstruct
//! the run from the checkpoint alone. CLI `--section.key=value`
//! overrides compose onto any spec (`--wrap.*` / `--pipeline.*` are
//! aliases for `env.wrap.*` / `train.pipeline.*`).
//!
//! The imperative path (`puffer train <env>`) still accepts the classic
//! flat keys, now including `--vec.*`. The default backend is the
//! pure-Rust `NativeBackend`; `--backend=pjrt` (train/eval only) selects
//! the AOT/PJRT path, which requires a build with `--features pjrt` plus
//! `make artifacts`.

use anyhow::{Context, Result};
use pufferlib::config;
use pufferlib::envs;
use pufferlib::runs::{self, Registry, RunStatus};
use pufferlib::runspec::{self, RunSpec, RunSpecExt as _};
use pufferlib::train::{Checkpoint, TrainConfig, TrainReport, Trainer};
use pufferlib::vector::autotune;
use pufferlib::wrappers::EnvSpec;

#[cfg(feature = "pjrt")]
const ARTIFACTS: &str = "artifacts";

/// Override namespaces every spec-consuming command accepts.
const SPEC_NAMESPACES: &[&str] =
    &["train.", "wrap.", "pipeline.", "policy.", "vec.", "env.", "serve.", "runs.", "seed"];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = args.iter().skip(1).cloned().collect();

    match cmd {
        "run" => cmd_run(&rest),
        "validate" => cmd_validate(&rest),
        "resume" => cmd_resume(&rest),
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "sweep" => cmd_sweep(&rest),
        "ps" => cmd_ps(&rest),
        "top" => cmd_top(&rest),
        "autotune" => cmd_autotune(&rest),
        "policy" => cmd_policy(&rest),
        "serve" => cmd_serve(&rest),
        "ckpt" => cmd_ckpt(&rest),
        "envs" => {
            for name in envs::ALL_ENVS {
                println!("{name}");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown command '{other}'");
        }
    }
}

fn print_help() {
    println!(
        "puffer — PufferLib (Rust + JAX + Pallas) runner\n\n\
         USAGE:\n  puffer run <spec.toml> [--KEY=VAL ...] [--resume]  run a declarative RunSpec\n  \
         puffer validate <spec.toml> [...]               parse + deep-check spec files\n  \
         puffer resume <checkpoint.bin> [--KEY=VAL ...]  continue a run (spec embedded)\n  \
         puffer sweep <spec.toml> [--jobs=N | --processes=N]  resumable [grid] sweep\n  \
         puffer ps [--runs.root=DIR] [--json]            registry: live/done/failed/stale runs\n  \
         puffer top [--runs.root=DIR] [--refresh=SECS] [--iters=N]  refreshing live view\n  \
         puffer train <env> [--config FILE] [--train.KEY=VAL ...] [--wrap.KEY=VAL ...] [--policy.KEY=VAL ...] [--pipeline.KEY=VAL ...] [--vec.KEY=VAL ...] [--backend=native|pjrt]\n  \
         puffer eval <checkpoint.bin> [--episodes=N]     evaluate from a RunSpec checkpoint\n  \
         puffer eval <env> --checkpoint=FILE [--episodes=N]\n  \
         puffer sweep [--train.KEY=VAL ...]              legacy: train the whole Ocean suite\n  \
         puffer autotune <env> [--envs=N] [--workers=W] [--secs=S] [--run_dir=DIR] [--wrap.KEY=VAL ...]\n  \
         puffer policy describe <env> [--wrap.KEY=VAL ...] [--policy.KEY=VAL ...]\n  \
         puffer serve <checkpoint.bin> [--serve.KEY=VAL ...] [--selftest]\n  \
         puffer ckpt info <checkpoint.bin> [--json]      print version + embedded spec\n  \
         puffer envs                                     list first-party envs\n\n\
         RunSpec files (examples/specs/*.toml): seed = N, [env] name + [env.wrap]\n\
         \x20 knobs, [policy] hidden/lstm/lstm_hidden/embed_dim/head, [vec]\n\
         \x20 mode=serial|mt|auto + workers/batch/zero_copy/spin_budget, [train]\n\
         \x20 keys below, and an optional [grid] of key = [values] to sweep.\n\
         \x20 `vec = auto` benchmarks once and caches under the run dir\n\
         \x20 (puffer autotune writes the same cache).\n\n\
         Train keys: env total_steps lr ent_coef epochs minibatches norm_adv\n\
         \x20           anneal_lr seed num_workers pool run_dir log_every\n\
         \x20           kernels scalar|simd (native compute path; worker cap\n\
         \x20           via PUFFER_KERNEL_THREADS)\n\
         Pipeline keys: depth — 0 (default) trains serially; d >= 1 runs an\n\
         \x20 overlapped collector/learner pipeline\n\
         Wrap keys (innermost-first order): action_repeat time_limit\n\
         \x20 scale_reward clip_reward normalize_obs stack\n\
         Policy keys: hidden | lstm true/false | lstm_hidden | embed_dim |\n\
         \x20 head categorical|quantized:<bins>\n\
         Vec keys: mode serial|mt|auto | workers | batch full|half|<envs> |\n\
         \x20 zero_copy | spin_budget\n\
         Serve keys: port | max_batch | max_wait_us | session_ttl_s | threads\n\
         Runs keys: root (registry root, default `runs`) | heartbeat_s — every\n\
         \x20 run/sweep launch writes the registry; `puffer sweep` re-invoked on\n\
         \x20 the same spec skips at-budget children and resumes partials, and\n\
         \x20 `puffer ps`/`puffer top` read the same root\n\n\
         Backends: native (default, pure Rust; any spec) | pjrt (train/eval\n\
         \x20         only; AOT artifacts, default archs; needs --features pjrt\n\
         \x20         and `make artifacts`)"
    );
}

/// Extract `--config FILE` and positional args, leaving `--k=v` overrides.
fn split_args(args: &[String]) -> (Option<String>, Vec<String>, Vec<String>) {
    let mut cfg_file = None;
    let mut positional = Vec::new();
    let mut overrides = Vec::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if a == "--config" {
            cfg_file = it.next().cloned();
        } else if a.starts_with("--") {
            overrides.push(a.clone());
        } else {
            positional.push(a.clone());
        }
    }
    (cfg_file, positional, overrides)
}

/// Reject `--key=value` overrides outside the namespaces this command
/// owns. Without this, a typo'd `--clip_reward=1` (missing the `wrap.`
/// prefix) or `--trian.lr=3e-3` would be silently ignored — the same
/// footgun the strict config parser closes for key *suffixes*.
fn reject_stray_overrides(overrides: &[String], allowed: &[&str]) -> Result<()> {
    for a in overrides {
        if let Some(body) = a.strip_prefix("--") {
            let key = body.split('=').next().unwrap_or(body);
            if !allowed.iter().any(|ns| key.starts_with(ns)) {
                let expected: Vec<String> = allowed.iter().map(|ns| format!("--{ns}KEY=VAL")).collect();
                anyhow::bail!(
                    "unrecognized flag '--{key}...': this command accepts {}",
                    expected.join(" and ")
                );
            }
            // Space-separated values (`--wrap.stack 4`) would otherwise
            // be dropped without effect by the override parser.
            anyhow::ensure!(
                body.contains('='),
                "flag '--{key}' is missing a value: use --{key}=VALUE"
            );
        }
    }
    Ok(())
}

/// Pull `--backend=...` out of the override list (default: native).
fn take_backend(overrides: &mut Vec<String>) -> String {
    let mut backend = "native".to_string();
    overrides.retain(|a| {
        if let Some(v) = a.strip_prefix("--backend=") {
            backend = v.to_string();
            false
        } else {
            true
        }
    });
    backend
}

fn make_trainer(tc: TrainConfig, backend: &str) -> Result<Trainer> {
    match backend {
        "native" => Trainer::native(tc),
        "pjrt" => pjrt_trainer(tc),
        other => anyhow::bail!("unknown backend '{other}' (expected native or pjrt)"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_trainer(tc: TrainConfig) -> Result<Trainer> {
    Trainer::pjrt(tc, ARTIFACTS)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_trainer(_tc: TrainConfig) -> Result<Trainer> {
    anyhow::bail!(
        "this binary was built without the `pjrt` feature; rebuild with \
         `cargo build --release --features pjrt` and run `make artifacts`"
    )
}

fn print_train_report(report: &TrainReport) {
    println!(
        "pipeline: env {:.0} SPS, learner {:.0} SPS, stalls {:.2}s collector / {:.2}s learner",
        report.env_sps, report.learn_sps, report.collector_stall_s, report.learner_stall_s,
    );
    println!(
        "done: {} steps @ {:.0} SPS, {} episodes, score {}, return {}",
        report.global_step,
        report.sps,
        report.episodes,
        report
            .mean_score
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
        report
            .mean_return
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
    );
}

// -- declarative commands ---------------------------------------------------

/// Merge `--section.key=value` overrides onto a spec (through its flat
/// serialized form, so override values get exactly the file grammar's
/// strict validation, and discriminant switches like `--vec.mode=serial`
/// drop the old mode's dependent knobs).
fn apply_spec_overrides(spec: RunSpec, overrides: &[String]) -> Result<RunSpec> {
    if overrides.is_empty() {
        return Ok(spec);
    }
    let (mut flat, arrays) = spec.to_flat()?;
    let pairs: Vec<(String, String)> = overrides
        .iter()
        .filter_map(|a| {
            let body = a.strip_prefix("--")?;
            let (k, v) = body.split_once('=')?;
            Some((runspec::translate_cli_key(k), v.to_string()))
        })
        .collect();
    runspec::merge_overrides(&mut flat, &pairs);
    RunSpec::from_parts(&flat, &arrays)
}

/// The deterministic default run dir for an env key — shared by
/// `puffer run` (when the spec has none) and `puffer autotune` (so its
/// cache lands exactly where a default `puffer run` of the same env
/// will look for it).
fn run_dir_for(env_key: &str) -> String {
    let leaf: String = env_key
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    format!("runs/{leaf}")
}

/// Give a spec a deterministic run dir when it has none, so every
/// `puffer run` leaves a resumable checkpoint + metrics behind. Applied
/// *before* the trainer embeds the spec, so resumed runs agree.
fn default_run_dir(spec: RunSpec) -> RunSpec {
    if spec.train.run_dir.is_some() {
        return spec;
    }
    let dir = run_dir_for(&spec.env.key());
    spec.with_train(|t| t.run_dir = Some(dir))
}

fn cmd_run(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    anyhow::ensure!(
        backend == "native",
        "puffer run drives the native backend; use `puffer train <env> --backend=pjrt` for the AOT path"
    );
    // --resume: continue from the run dir's checkpoint when one exists
    // (a fresh dir trains from scratch) — what resumable sweeps pass to
    // their child processes.
    let mut resume = false;
    overrides.retain(|a| {
        if a == "--resume" {
            resume = true;
            false
        } else {
            true
        }
    });
    let path = positional
        .first()
        .cloned()
        .or(cfg_file)
        .context("usage: puffer run <spec.toml> [--KEY=VAL ...] [--resume]")?;
    reject_stray_overrides(&overrides, SPEC_NAMESPACES)?;
    let spec = RunSpec::load(&path)?;
    anyhow::ensure!(
        spec.grid.is_empty(),
        "{path} has a [grid] section — execute it with `puffer sweep {path}`"
    );
    let spec = default_run_dir(apply_spec_overrides(spec, &overrides)?);
    let run_dir = spec.train.run_dir.clone().unwrap_or_default();
    println!(
        "running {} (policy {}, vec {}, seed {}) for {} steps → {run_dir}",
        spec.env.key(),
        spec.policy.as_ref().map(|p| p.key()).unwrap_or_else(|| "default".into()),
        spec.vec,
        spec.seed,
        spec.train.total_steps,
    );
    // Every launch is registered: running → done|failed, so `puffer ps`
    // and resumable sweeps see this run. A crash between begin() and the
    // terminal write leaves a Running record that stale-heartbeat
    // detection reports (and sweeps reclaim).
    let reg = Registry::new(&runs::RunsConfig::for_spec(&spec).root);
    let rec = reg.begin(&spec, &run_dir)?;
    let trained = (|| -> Result<TrainReport> {
        let mut trainer = spec.build()?;
        if resume {
            let ckpt = runs::sweep::checkpoint_path(&run_dir);
            if std::path::Path::new(&ckpt).is_file() {
                let ck = Checkpoint::load(&ckpt).context("loading checkpoint for --resume")?;
                trainer.restore(&ck)?;
                println!("resumed from {ckpt} at step {}", trainer.global_step());
            }
        }
        trainer.train()
    })();
    match trained {
        Ok(report) => {
            let ckpt = runs::sweep::checkpoint_path(&run_dir);
            let ckpt = std::path::Path::new(&ckpt).is_file().then_some(ckpt);
            reg.finish_ok(rec, &report, ckpt)?;
            print_train_report(&report);
            println!("checkpoint: {run_dir}/checkpoint.bin (resume with `puffer resume {run_dir}/checkpoint.bin`)");
            Ok(())
        }
        Err(e) => {
            let _ = reg.finish_err(rec, RunStatus::Failed, &format!("{e:#}"), None);
            Err(e)
        }
    }
}

fn cmd_validate(args: &[String]) -> Result<()> {
    let (_, positional, overrides) = split_args(args);
    anyhow::ensure!(
        !positional.is_empty() && overrides.is_empty(),
        "usage: puffer validate <spec.toml> [more.toml ...]"
    );
    // Every concrete run the invocation describes (grid sections expand
    // to their children): (spec file, run dir, spec fingerprint).
    let mut planned: Vec<(String, String, String)> = Vec::new();
    for path in &positional {
        let spec = RunSpec::load(path)?;
        spec.validate().with_context(|| format!("validating {path}"))?;
        let grid_note = if spec.grid.is_empty() {
            String::new()
        } else {
            format!(
                ", grid {} points",
                spec.expand_grid().map(|c| c.len()).unwrap_or(0)
            )
        };
        println!(
            "OK {path}: env {}, policy {}, vec {}, seed {}, {} steps{grid_note}",
            spec.env.key(),
            spec.policy.as_ref().map(|p| p.key()).unwrap_or_else(|| "default".into()),
            spec.vec,
            spec.seed,
            spec.train.total_steps,
        );
        let concrete = if spec.grid.is_empty() {
            vec![spec]
        } else {
            spec.expand_grid().unwrap_or_default()
        };
        for child in &concrete {
            if let Some(dir) = &child.train.run_dir {
                planned.push((
                    path.clone(),
                    dir.clone(),
                    runs::record::spec_fingerprint(child),
                ));
            }
        }
    }
    // Run-dir collision warnings. Two *different* specs writing one dir
    // would silently share a checkpoint and registry record — resumes
    // would cross-contaminate. Identical fingerprints are the normal
    // re-invoke/resume case and stay quiet.
    for (i, (path_a, dir_a, fp_a)) in planned.iter().enumerate() {
        for (path_b, dir_b, fp_b) in planned.iter().skip(i + 1) {
            if dir_a == dir_b && !fp_a.is_empty() && fp_a != fp_b {
                println!(
                    "WARN {dir_a}: {path_a} and {path_b} both write this run dir \
                     with different specs — their checkpoints and registry \
                     records would collide"
                );
            }
        }
    }
    for (path, dir, fp) in &planned {
        if let Ok(Some(rec)) = Registry::load(dir) {
            if !rec.spec_fingerprint.is_empty() && !fp.is_empty() && rec.spec_fingerprint != *fp {
                println!(
                    "WARN {dir}: already registered by a different spec than \
                     {path} (registry fingerprint mismatch) — running this file \
                     would resume a foreign checkpoint"
                );
            }
        }
    }
    Ok(())
}

fn cmd_resume(args: &[String]) -> Result<()> {
    let (_, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    anyhow::ensure!(backend == "native", "puffer resume drives the native backend");
    let path = positional
        .first()
        .context("usage: puffer resume <checkpoint.bin> [--KEY=VAL ...]")?;
    reject_stray_overrides(&overrides, SPEC_NAMESPACES)?;
    let ck = Checkpoint::load(path).context("loading checkpoint")?;
    let json = ck.run_spec_json.as_deref().with_context(|| {
        format!(
            "{path} has no embedded RunSpec (written by `puffer train` or an \
             older version) — rerun through `puffer run`, or use \
             `puffer train`/`puffer eval` with explicit flags"
        )
    })?;
    let spec = RunSpec::from_json_str(json).context("parsing the embedded RunSpec")?;
    let spec = apply_spec_overrides(spec, &overrides)?;
    println!(
        "resuming {} at step {} of {} (spec from checkpoint)",
        spec.env.key(),
        ck.global_step,
        spec.train.total_steps
    );
    // Resumed attempts are registered like fresh ones: begin() bumps the
    // record's attempt counter so `puffer ps` shows the retry history.
    let reg_ctx = match spec.train.run_dir.clone() {
        Some(dir) => {
            let reg = Registry::new(&runs::RunsConfig::for_spec(&spec).root);
            let rec = reg.begin(&spec, &dir)?;
            Some((reg, rec, dir))
        }
        None => None,
    };
    let trained = (|| -> Result<TrainReport> {
        let mut trainer = spec.build()?;
        trainer.restore(&ck)?;
        if trainer.global_step() >= spec.train.total_steps {
            println!(
                "already at the step budget — extend with --train.total_steps=N to keep training"
            );
        }
        trainer.train()
    })();
    match trained {
        Ok(report) => {
            if let Some((reg, rec, dir)) = reg_ctx {
                let ckpt = runs::sweep::checkpoint_path(&dir);
                let ckpt = std::path::Path::new(&ckpt).is_file().then_some(ckpt);
                reg.finish_ok(rec, &report, ckpt)?;
            }
            print_train_report(&report);
            Ok(())
        }
        Err(e) => {
            if let Some((reg, rec, _)) = reg_ctx {
                let _ = reg.finish_err(rec, RunStatus::Failed, &format!("{e:#}"), None);
            }
            Err(e)
        }
    }
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    // Spec-based grid sweep:
    // `puffer sweep <spec.toml> [--jobs=N | --processes=N]`. Registry-
    // aware and crash-resumable: at-budget children are skipped, partial
    // checkpoints resume, orphaned `running` records are reclaimed, and
    // every child ends with exactly one terminal registry record.
    if let Some(path) = positional.first().cloned() {
        anyhow::ensure!(backend == "native", "puffer sweep drives the native backend");
        let mut jobs: Option<usize> = None;
        let mut processes: Option<usize> = None;
        let mut bad: Option<String> = None;
        overrides.retain(|a| {
            if let Some(v) = a.strip_prefix("--jobs=") {
                match v.parse::<usize>() {
                    Ok(j) if j >= 1 => jobs = Some(j),
                    _ => bad = Some(format!("--jobs: expected an integer >= 1, got '{v}'")),
                }
                false
            } else if let Some(v) = a.strip_prefix("--processes=") {
                match v.parse::<usize>() {
                    Ok(p) if p >= 1 => processes = Some(p),
                    _ => bad = Some(format!("--processes: expected an integer >= 1, got '{v}'")),
                }
                false
            } else {
                true
            }
        });
        if let Some(msg) = bad {
            anyhow::bail!("{msg}");
        }
        anyhow::ensure!(
            jobs.is_none() || processes.is_none(),
            "--jobs (in-process threads) and --processes (separate OS processes) \
             are mutually exclusive — pick one executor"
        );
        reject_stray_overrides(&overrides, SPEC_NAMESPACES)?;
        let spec = apply_spec_overrides(RunSpec::load(&path)?, &overrides)?;
        anyhow::ensure!(
            !spec.grid.is_empty(),
            "{path} has no [grid] section to sweep — run it with `puffer run {path}`"
        );
        let children = spec.expand_grid()?;
        let reg = Registry::new(&runs::RunsConfig::for_spec(&spec).root);
        let width = processes.or(jobs).unwrap_or(2).min(children.len());
        println!(
            "sweeping {}: {} grid points across {} {} (registry: {})",
            spec.env.key(),
            children.len(),
            width,
            if processes.is_some() { "process(es)" } else { "worker(s)" },
            reg.index_path().display(),
        );
        use pufferlib::runs::sweep::{ChildOutcome, ChildStatus};
        let fmt_score = |s: Option<f64>| s.map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into());
        let on_event = |o: &ChildOutcome| {
            let resumed = if o.resumed { " (resumed)" } else { "" };
            match &o.status {
                ChildStatus::Skipped(why) => println!("[skip]   {:<40} {why}", o.label),
                ChildStatus::Done(Some(r)) => println!(
                    "[done]   {:<40} score {}  ({} steps @ {:.0} SPS){resumed} → {}",
                    o.label,
                    fmt_score(r.mean_score),
                    r.global_step,
                    r.sps,
                    o.run_dir
                ),
                ChildStatus::Done(None) => {
                    println!("[done]   {:<40}{resumed} → {}", o.label, o.run_dir)
                }
                ChildStatus::Failed(e) => println!("[failed] {:<40} {e}", o.label),
            }
        };
        let outcomes = match processes {
            Some(p) => runs::sweep::run_processes(&reg, &children, p, on_event)?,
            None => runs::sweep::run_resumable(&reg, &children, jobs.unwrap_or(2), on_event)?,
        };
        let skipped = outcomes
            .iter()
            .filter(|o| matches!(o.status, ChildStatus::Skipped(_)))
            .count();
        let resumed = outcomes.iter().filter(|o| o.resumed && !o.failed()).count();
        let failed = outcomes.iter().filter(|o| o.failed()).count();
        println!(
            "sweep done: {}/{} children at budget ({skipped} skipped, {resumed} \
             resumed, {failed} failed) — inspect with `puffer ps --runs.root={}`",
            outcomes.len() - failed,
            outcomes.len(),
            reg.root().display(),
        );
        anyhow::ensure!(failed == 0, "{failed} sweep children failed");
        return Ok(());
    }

    // Legacy: train the whole Ocean suite with one flat config.
    reject_stray_overrides(&overrides, &["train.", "wrap.", "pipeline.", "policy.", "vec."])?;
    let mut solved = 0;
    for env in envs::OCEAN_ENVS {
        let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
        flat.insert("train.env".into(), env.to_string());
        let tc = config::train_config(&flat)?;
        let mut trainer = make_trainer(tc, &backend)?;
        let report = trainer.train()?;
        let score = report.mean_score.unwrap_or(0.0);
        let ok = score > 0.9;
        if ok {
            solved += 1;
        }
        println!(
            "{:<20} score {:.3}  {}",
            env,
            score,
            if ok { "SOLVED" } else { "unsolved" }
        );
    }
    println!("{solved}/{} Ocean envs solved", envs::OCEAN_ENVS.len());
    Ok(())
}

/// `puffer ps`: one row per registered run — derived status (live /
/// stale / pending / done / failed / killed, with dead-pid and
/// stale-heartbeat orphan detection), progress, SPS, attempt count,
/// age, and owner. `--json` emits the full records for scripts.
fn cmd_ps(args: &[String]) -> Result<()> {
    let (_, positional, overrides) = split_args(args);
    anyhow::ensure!(
        positional.is_empty(),
        "usage: puffer ps [--runs.root=DIR] [--json]"
    );
    let mut root = runs::RunsConfig::default().root;
    let mut json = false;
    for a in &overrides {
        if let Some(v) = a.strip_prefix("--runs.root=") {
            root = v.to_string();
        } else if a == "--json" {
            json = true;
        } else {
            anyhow::bail!("unrecognized flag '{a}': puffer ps accepts --runs.root=DIR and --json");
        }
    }
    let reg = Registry::new(&root);
    let views = runs::snapshot(&reg)?;
    let now = runs::fsio::now_ms();
    if json {
        println!("{}", runs::ps_json(&views, now));
    } else {
        print!("{}", runs::ps_table(&views, now));
    }
    Ok(())
}

/// `puffer top`: a refreshing in-flight view (live/stale/pending runs
/// with heartbeat SPS and stall), redrawn every `--refresh` seconds.
/// `--iters=N` exits after N frames (0 = run until killed).
fn cmd_top(args: &[String]) -> Result<()> {
    let (_, positional, overrides) = split_args(args);
    anyhow::ensure!(
        positional.is_empty(),
        "usage: puffer top [--runs.root=DIR] [--refresh=SECS] [--iters=N]"
    );
    let mut root = runs::RunsConfig::default().root;
    let mut refresh = 2.0f64;
    let mut iters = 0u64;
    for a in &overrides {
        if let Some(v) = a.strip_prefix("--runs.root=") {
            root = v.to_string();
        } else if let Some(v) = a.strip_prefix("--refresh=") {
            refresh = v
                .parse()
                .ok()
                .filter(|r: &f64| r.is_finite() && *r > 0.0)
                .ok_or_else(|| {
                    anyhow::anyhow!("--refresh: expected a positive number of seconds, got '{v}'")
                })?;
        } else if let Some(v) = a.strip_prefix("--iters=") {
            iters = v
                .parse()
                .map_err(|_| anyhow::anyhow!("--iters: expected an integer >= 0, got '{v}'"))?;
        } else {
            anyhow::bail!(
                "unrecognized flag '{a}': puffer top accepts --runs.root=DIR, \
                 --refresh=SECS, and --iters=N (0 = until killed)"
            );
        }
    }
    let reg = Registry::new(&root);
    let mut frames = 0u64;
    loop {
        let views = runs::snapshot(&reg)?;
        let frame = runs::top_frame(&views, runs::fsio::now_ms());
        // ANSI clear + cursor home, then one whole frame — flushed so
        // partial redraws never linger between refreshes.
        print!("\x1b[2J\x1b[H{frame}");
        use std::io::Write;
        std::io::stdout().flush()?;
        frames += 1;
        if iters != 0 && frames >= iters {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(refresh));
    }
}

// -- imperative commands ----------------------------------------------------

fn cmd_train(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    reject_stray_overrides(&overrides, &["train.", "wrap.", "pipeline.", "policy.", "vec."])?;
    let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
    if let Some(env) = positional.first() {
        flat.insert("train.env".into(), env.clone());
    }
    let tc = config::train_config(&flat)?;
    let spec = EnvSpec::new(tc.env.as_str()).with_wrappers(tc.wrappers.iter().cloned());
    println!(
        "training {} for {} steps ({backend} backend) ...",
        spec.key(),
        tc.total_steps
    );
    let mut trainer = make_trainer(tc, &backend)?;
    let report = trainer.train()?;
    print_train_report(&report);
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    // Pull out eval-specific flags.
    let mut checkpoint = None;
    let mut episodes = 20usize;
    let mut bad_episodes = None;
    overrides.retain(|a| {
        if let Some(v) = a.strip_prefix("--checkpoint=") {
            checkpoint = Some(v.to_string());
            false
        } else if let Some(v) = a.strip_prefix("--episodes=") {
            match v.parse::<usize>() {
                Ok(n) if n >= 1 => episodes = n,
                _ => bad_episodes = Some(v.to_string()),
            }
            false
        } else {
            true
        }
    });
    if let Some(v) = bad_episodes {
        anyhow::bail!("--episodes: expected an integer >= 1, got '{v}'");
    }

    // RunSpec form: the positional is a checkpoint file, spec embedded.
    // Route by argument shape, not just existence: a mistyped checkpoint
    // path must fail with the file error, not a confusing "unknown env".
    let positional_is_ckpt = positional.first().is_some_and(|p| {
        !envs::ALL_ENVS.contains(&p.as_str())
            && (p.ends_with(".bin") || std::path::Path::new(p).is_file())
    });
    if positional_is_ckpt {
        anyhow::ensure!(
            backend == "native",
            "RunSpec checkpoints evaluate on the native backend; use \
             `puffer eval <env> --checkpoint=FILE --backend=pjrt` for the AOT path"
        );
        anyhow::ensure!(
            checkpoint.is_none(),
            "conflicting checkpoints: a positional checkpoint and --checkpoint= \
             were both given — pass one or the other"
        );
        reject_stray_overrides(&overrides, SPEC_NAMESPACES)?;
        // PANIC: positional_is_ckpt implies a first positional exists.
        let path = positional.first().unwrap();
        let ck = Checkpoint::load(path).context("loading checkpoint")?;
        let json = ck.run_spec_json.as_deref().with_context(|| {
            format!("{path} has no embedded RunSpec — use `puffer eval <env> --checkpoint={path}`")
        })?;
        // Evaluation never writes run data: the metrics sink opens
        // lazily on the first written row (eval writes none) and the
        // checkpoint is only saved by train(). One exception by design:
        // a vec = "auto" spec whose autotune cache is missing re-tunes
        // and restores `<run_dir>/autotune.json` — infrastructure, not
        // run history.
        let spec = apply_spec_overrides(RunSpec::from_json_str(json)?, &overrides)?;
        let mut trainer = spec.build()?;
        trainer.restore(&ck)?;
        println!(
            "evaluating {} restored at step {}",
            spec.env.key(),
            ck.global_step
        );
        let report = trainer.eval(episodes)?;
        print_eval(&report);
        return Ok(());
    }

    reject_stray_overrides(&overrides, &["train.", "wrap.", "pipeline.", "policy.", "vec."])?;
    let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
    if let Some(env) = positional.first() {
        flat.insert("train.env".into(), env.clone());
    }
    let tc = config::train_config(&flat)?;
    let mut trainer = make_trainer(tc, &backend)?;
    if let Some(ck_path) = checkpoint {
        let ck = Checkpoint::load(&ck_path).context("loading checkpoint")?;
        trainer.restore(&ck)?;
        println!("restored checkpoint at step {}", ck.global_step);
    }
    let report = trainer.eval(episodes)?;
    print_eval(&report);
    Ok(())
}

fn print_eval(report: &pufferlib::train::EvalReport) {
    println!(
        "eval: {} episodes, score {}, return {}",
        report.episodes,
        report
            .mean_score
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
        report
            .mean_return
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
    );
}

/// `puffer policy describe <env>`: print the resolved architecture —
/// per-leaf encoders, trunk/recurrence/head stages, parameter counts per
/// stage, and the checkpoint key — for debugging spec/env mismatches.
fn cmd_policy(args: &[String]) -> Result<()> {
    let sub = args.first().map(String::as_str);
    anyhow::ensure!(
        sub == Some("describe"),
        "usage: puffer policy describe <env> [--wrap.KEY=VAL ...] [--policy.KEY=VAL ...]"
    );
    let (cfg_file, positional, overrides) = split_args(&args[1..]);
    reject_stray_overrides(&overrides, &["train.", "wrap.", "policy."])?;
    let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
    if let Some(env) = positional.first() {
        flat.insert("train.env".into(), env.clone());
    }
    let tc = config::train_config(&flat)?;
    let spec = EnvSpec::new(tc.env.as_str()).with_wrappers(tc.wrappers.iter().cloned());
    let pspec = tc
        .policy
        .clone()
        .unwrap_or_else(|| pufferlib::policy::PolicySpec::default_for(&tc.env));
    let probe = spec.build(0);
    let backend = pufferlib::backend::NativeBackend::for_env_with_policy(
        &spec.key(),
        probe.as_ref(),
        &pspec,
    )?;
    println!(
        "{} — resolved architecture (checkpoint key: {})",
        spec.key(),
        backend.key()
    );
    print!("{}", backend.arch().describe());
    Ok(())
}

/// `puffer serve <checkpoint.bin>`: dynamic-batching inference server
/// over the checkpoint's embedded policy. `--serve.KEY=VAL` overrides
/// the spec's `[serve]` section; `--selftest` runs the built-in load
/// generator against an ephemeral instance (port 0) and checks the
/// batching/zero-drop acceptance gates instead of serving forever.
fn cmd_serve(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    anyhow::ensure!(
        cfg_file.is_none(),
        "puffer serve takes no --config file: serve knobs come from the \
         checkpoint's [serve] section or --serve.KEY=VAL overrides"
    );
    let mut selftest = false;
    let mut st = pufferlib::serve::selftest::SelftestConfig::default();
    let mut bad: Option<String> = None;
    overrides.retain(|a| {
        if a == "--selftest" {
            selftest = true;
            false
        } else if let Some(v) = a.strip_prefix("--selftest.requests=") {
            match v.parse::<usize>() {
                Ok(n) if n >= 1 => st.requests = n,
                _ => bad = Some(format!("--selftest.requests: expected an integer >= 1, got '{v}'")),
            }
            false
        } else if let Some(v) = a.strip_prefix("--selftest.sessions=") {
            match v.parse::<usize>() {
                Ok(n) if n >= 1 => st.sessions = n,
                _ => bad = Some(format!("--selftest.sessions: expected an integer >= 1, got '{v}'")),
            }
            false
        } else {
            true
        }
    });
    if let Some(msg) = bad {
        anyhow::bail!("{msg}");
    }
    reject_stray_overrides(&overrides, &["serve."])?;
    anyhow::ensure!(
        positional.len() == 1,
        "usage: puffer serve <checkpoint.bin> [--serve.KEY=VAL ...] [--selftest]"
    );
    // PANIC: length checked above.
    let path = positional.first().unwrap();
    let model = pufferlib::serve::ServedModel::open(path)?;
    let spec = apply_spec_overrides(model.spec.clone(), &overrides)?;
    let cfg = spec.serve.clone().unwrap_or_default();

    if selftest {
        let report = pufferlib::serve::selftest::run(path, &cfg, &st)?;
        pufferlib::serve::selftest::print_report(&report);
        if let Some(p) = pufferlib::serve::selftest::maybe_write_bench_json(&report)? {
            println!("wrote {p}");
        }
        anyhow::ensure!(
            report.dropped == 0,
            "selftest dropped {} requests — the server must answer every \
             accepted request",
            report.dropped
        );
        anyhow::ensure!(
            report.occupancy > 1.0,
            "selftest never coalesced: occupancy {:.2} rows/batch should \
             exceed 1 (is max_wait_us too small for this machine?)",
            report.occupancy
        );
        return Ok(());
    }

    let recurrent = model.recurrent();
    let step = model.global_step;
    let key = model.spec_key.clone();
    let handle = pufferlib::serve::Server::start(model, &cfg, Some(path.as_str()))?;
    println!(
        "serving {key} (step {step}{}) on {} — {} shard(s), batch <= {} rows \
         or {} us, session ttl {} s; Ctrl-C to stop",
        if recurrent { ", recurrent" } else { "" },
        handle.addr(),
        cfg.threads,
        cfg.max_batch,
        cfg.max_wait_us,
        cfg.session_ttl_s,
    );
    // Foreground server: park until killed. The handle keeps the
    // accept/shard/watcher threads alive for the life of the process.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `puffer ckpt info <checkpoint.bin> [--json]`: print the file's
/// format version, arch key, training step, parameter count, and the
/// embedded RunSpec — canonical TOML for humans, or one JSON object
/// (`--json`, for scripts) with the spec inlined as a JSON value (null
/// for v1 files, which never recorded one). The human path errors on
/// v1 files, naming the limitation after the header fields.
fn cmd_ckpt(args: &[String]) -> Result<()> {
    let json_out = args.iter().any(|a| a == "--json");
    let pos: Vec<&String> = args.iter().filter(|a| a.as_str() != "--json").collect();
    anyhow::ensure!(
        pos.first().map(|a| a.as_str()) == Some("info") && pos.len() == 2,
        "usage: puffer ckpt info <checkpoint.bin> [--json]"
    );
    // PANIC: length checked above.
    let path = pos.get(1).unwrap().as_str();
    let version = Checkpoint::probe_version(path)?;
    let ck = Checkpoint::load(path).context("loading checkpoint")?;
    if json_out {
        use pufferlib::util::json::{num, obj, s, Json};
        let spec = match ck.run_spec_json.as_deref() {
            Some(text) => RunSpec::from_json_str(text)
                .with_context(|| format!("parsing the RunSpec embedded in {path}"))?
                .to_json(),
            None => Json::Null,
        };
        let info = obj(vec![
            ("file", s(path)),
            ("format_version", num(version as f64)),
            ("arch", s(&ck.spec_key)),
            ("global_step", num(ck.global_step as f64)),
            ("params", num(ck.params.len() as f64)),
            ("spec", spec),
        ]);
        println!("{}", info.dump());
        return Ok(());
    }
    println!("file:     {path}");
    println!("format:   v{version}");
    println!("arch key: {}", ck.spec_key);
    println!("step:     {}", ck.global_step);
    println!("params:   {}", ck.params.len());
    let json = ck.run_spec_json.as_deref().with_context(|| {
        format!(
            "{path} is a v{version} checkpoint with no embedded RunSpec — \
             `ckpt info` can only print the spec for v2 files, which record \
             it at save time. Re-train (or fine-tune via `puffer resume`) \
             with this build to produce one"
        )
    })?;
    let spec = RunSpec::from_json_str(json)
        .with_context(|| format!("parsing the RunSpec embedded in {path}"))?;
    println!();
    print!("{}", spec.to_toml()?);
    Ok(())
}

fn cmd_autotune(args: &[String]) -> Result<()> {
    let (_, positional, overrides) = split_args(args);
    let env = positional
        .first()
        .cloned()
        .unwrap_or_else(|| "ocean/squared".into());
    let mut num_envs = None;
    let mut workers = 4;
    let mut secs = 1.0f64;
    let mut run_dir = None;
    let mut wrap_overrides = Vec::new();
    for a in overrides {
        if let Some(v) = a.strip_prefix("--envs=") {
            num_envs = Some(v.parse().map_err(|_| anyhow::anyhow!("--envs: cannot parse '{v}'"))?);
        } else if let Some(v) = a.strip_prefix("--workers=") {
            workers = v.parse().map_err(|_| anyhow::anyhow!("--workers: cannot parse '{v}'"))?;
        } else if let Some(v) = a.strip_prefix("--secs=") {
            secs = v.parse().map_err(|_| anyhow::anyhow!("--secs: cannot parse '{v}'"))?;
        } else if let Some(v) = a.strip_prefix("--run_dir=") {
            run_dir = Some(v.to_string());
        } else {
            wrap_overrides.push(a);
        }
    }
    // Remaining overrides are --wrap.* knobs: tune with the exact
    // pipeline you will train with.
    reject_stray_overrides(&wrap_overrides, &["wrap."])?;
    let (flat, _) = config::load(None, &wrap_overrides)?;
    config::validate_keys(&flat)?;
    let spec = EnvSpec::new(env.as_str()).with_wrappers(config::wrap_config(&flat)?);
    // Default the env budget to the trainer's own count (batch_roll /
    // agents) so the cached winner is exactly what `vec = "auto"`
    // consumes on the next run of this env.
    let num_envs = match num_envs {
        Some(n) => n,
        None => {
            let probe = spec.build(0);
            let backend =
                pufferlib::backend::NativeBackend::for_env(&spec.key(), probe.as_ref())?;
            backend.spec().batch_roll / backend.spec().agents
        }
    };
    println!(
        "autotuning {} with {num_envs} envs (≤{workers} workers, {secs}s per config) ...",
        spec.key()
    );
    let results = autotune::autotune(&spec, num_envs, workers, secs)?;
    print!("{}", autotune::format_results(&results));
    println!(
        "\nrecommended: {} (num_workers={}, batch_size={}, zero_copy={})",
        results[0].label,
        results[0].cfg.num_workers,
        results[0].cfg.batch_size,
        results[0].cfg.zero_copy
    );
    // The machine-readable winner: a VecSpec, printed and cached where
    // `vec = "auto"` looks for it. Only full/half batches are trainable
    // (the policy forward is compiled for those shapes), so the cache
    // takes the fastest such candidate.
    let trainable = autotune::trainable_winner(&results, num_envs);
    if trainable.label != results[0].label {
        println!(
            "(fastest *trainable* config: {} — the overall winner's batch shape \
             cannot feed the policy forward)",
            trainable.label
        );
    }
    let winner = trainable.vec_spec();
    println!("vec spec: {}", winner.to_json().dump());
    // Default the cache location to the same run dir a default
    // `puffer run` of this env resolves, so `vec = "auto"` actually
    // consumes what was just tuned.
    let run_dir = run_dir.unwrap_or_else(|| run_dir_for(&spec.key()));
    let cache = autotune::cache_path(Some(&run_dir));
    autotune::write_cache(&cache, &spec.key(), num_envs, &winner)?;
    println!(
        "cached → {} (consumed by vec = \"auto\" for {} at {num_envs} envs)",
        cache.display(),
        spec.key()
    );
    Ok(())
}
