//! Clean PuffeRL (paper §6): the first-party PPO trainer. Heavily
//! customized in the same ways the paper describes — separate train/eval,
//! model checkpointing, fast LSTM support, asynchronous environment
//! simulation (EnvPool), episode-stat logging, and multiagent support —
//! driving the learner math through the [`crate::backend::PolicyBackend`]
//! abstraction (pure-Rust `NativeBackend` by default, AOT/PJRT behind the
//! `pjrt` feature). Python never runs here.
//!
//! Training itself is an **experience pipeline** ([`pipeline`]): with
//! `train.pipeline.depth ≥ 1` a collector thread fills rotating rollout
//! segments (inference off epoch-versioned parameter snapshots) while the
//! learner runs shuffled-minibatch PPO epochs on the previous segment —
//! simulation and optimization overlap instead of taking turns. Depth 0
//! is the serial loop, bit-identical to the pre-pipeline trainer.

// The trainer threads, but through safe primitives only (crate::sync,
// scoped threads); no unsafe belongs here (CONCURRENCY.md).
#![forbid(unsafe_code)]

mod checkpoint;
#[cfg(feature = "trainer")]
pub mod pipeline;
#[cfg(feature = "trainer")]
mod rollout;
#[cfg(feature = "trainer")]
mod trainer;

// The plain-data config/report types live in puffer-core (the spec
// layer needs them without linking this crate); re-exported here so
// `crate::train::TrainConfig` keeps resolving. Checkpoint loading is
// ungated — `puffer serve` opens checkpoints without the trainer.
pub use puffer_core::train::{EvalReport, TrainConfig, TrainReport};

pub use checkpoint::Checkpoint;
#[cfg(feature = "trainer")]
pub use pipeline::Segment;
#[cfg(feature = "trainer")]
pub use rollout::{collect_rollout, EpisodeLog, RolloutBuffer};
#[cfg(feature = "trainer")]
pub use trainer::Trainer;
